"""Elastic data pipeline: MementoHash shard→host placement + deterministic
synthetic corpus.

This is the paper's technique as a *first-class data substrate*: file shards
are consistent-hashed onto data-loading hosts, so

  * every host derives its shard list locally (no coordinator round-trip),
  * a host failure moves ONLY the failed host's shards (minimal disruption,
    Prop. VI.3) — verified by ``tests/test_substrates.py``,
  * hosts re-join in reverse order with monotone movement (Prop. VI.5),
  * cluster capacity is unbounded (vs Anchor/Dx: no a-priori `a`).

The corpus is hash-generated (shard id, position) → token, so any host can
materialize any shard deterministically — restart/elastic tests compare
token streams exactly.
"""
from __future__ import annotations

import numpy as np

from repro.core import ConsistentHash, make_hash
from repro.core.hashing import np_hash2_32


class ShardPlacement:
    """shard-id → host-bucket map driven by any ConsistentHash (Memento default).

    Movement plans (``fail_host``/``add_host``) run on the device plane when
    the state is TPU-native (``variant="32"``): the epoch-N and epoch-N+1
    images are diffed by ONE fused launch of the unified lookup engine
    (:func:`repro.kernels.engine.engine_diff`, DESIGN.md §6) instead of
    per-shard host loops, and membership events reach the device as
    O(changed-words) deltas through a
    :class:`~repro.core.DeviceImageStore` (DESIGN.md §3.5).
    """

    def __init__(self, num_shards: int, num_hosts: int, variant: str = "32",
                 algo: str | ConsistentHash = "memento", capacity: int | None = None,
                 plane: str = "jnp"):
        self.num_shards = num_shards
        self.plane = plane
        if isinstance(algo, str):
            self.ch = make_hash(algo, num_hosts, capacity=capacity, variant=variant)
        else:
            self.ch = algo
        self._store = None

    @property
    def memento(self) -> ConsistentHash:
        """Back-compat alias from the Memento-only placement."""
        return self.ch

    def host_of(self, shard: int) -> int:
        return self.ch.lookup(shard)

    def assignment(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {b: [] for b in self.ch.working_set()}
        for s in range(self.num_shards):
            out[self.host_of(s)].append(s)
        return out

    def shards_for_host(self, host: int) -> list[int]:
        return [s for s in range(self.num_shards) if self.host_of(s) == host]

    # -- device-plane migration plans ----------------------------------------
    def _device_ready(self) -> bool:
        return (getattr(self.ch, "variant", None) == "32"
                and hasattr(self.ch, "device_delta"))

    def image_store(self):
        from repro.core import DeviceImageStore
        if self._store is None:
            self._store = DeviceImageStore(self.ch, plane=self.plane)
        return self._store

    def _diff_epochs(self):
        """Sync the device image over the last event and diff the epochs."""
        store = self.image_store()
        store.sync()
        keys = np.arange(self.num_shards, dtype=np.uint32)
        return store.migration_diff(keys, plane=self.plane)

    def fail_host(self, host: int) -> dict:
        """Remove a host; returns the movement plan (only its shards move).

        With a ``variant="32"`` state the before/after placements come from
        the fused migration-diff kernel over the double-buffered epochs —
        no per-shard host loop, no image rebuild.
        """
        if not self._device_ready():
            return self._fail_host_hostplane(host)
        self.image_store().sync()  # make sure the device is at this epoch
        self.ch.remove(host)
        d = self._diff_epochs()
        moved = {int(s): int(d.new[s]) for s in np.nonzero(d.moved)[0]}
        stayed = int(((d.old != host) & ~d.moved).sum())
        return {"moved": moved, "stayed": stayed,
                "minimal": stayed == self.num_shards - len(moved)
                and all(int(d.old[s]) == host for s in moved)}

    def add_host(self) -> dict:
        if not self._device_ready():
            return self._add_host_hostplane()
        self.image_store().sync()
        host = self.ch.add()
        d = self._diff_epochs()
        moved = {int(s): host for s in np.nonzero(d.moved)[0]
                 if int(d.new[s]) == host}
        monotone = bool(np.all(~d.moved | (d.new == host)))
        return {"host": host, "moved": moved, "monotone": monotone}

    # -- host-plane fallback (variant="64" / non-emitting states) -------------
    def _fail_host_hostplane(self, host: int) -> dict:
        before = {s: self.host_of(s) for s in range(self.num_shards)}
        self.ch.remove(host)
        moved = {s: self.host_of(s) for s in range(self.num_shards)
                 if before[s] == host}
        stayed = sum(1 for s in range(self.num_shards)
                     if before[s] != host and self.host_of(s) == before[s])
        return {"moved": moved, "stayed": stayed,
                "minimal": stayed == self.num_shards - len(moved)}

    def _add_host_hostplane(self) -> dict:
        before = {s: self.host_of(s) for s in range(self.num_shards)}
        host = self.ch.add()
        moved = {s: host for s in range(self.num_shards)
                 if self.host_of(s) == host and before[s] != host}
        monotone = all(self.host_of(s) in (before[s], host)
                       for s in range(self.num_shards))
        return {"host": host, "moved": moved, "monotone": monotone}


def synthetic_shard_tokens(shard: int, length: int, vocab_size: int,
                           offset: int = 0) -> np.ndarray:
    """Deterministic pseudo-corpus: token[i] = h(shard, offset+i) mod vocab."""
    idx = (np.arange(length, dtype=np.uint64) + np.uint64(offset)).astype(np.uint32)
    h = np_hash2_32(idx, np.uint32(shard & 0xFFFFFFFF))
    return (h % np.uint32(vocab_size)).astype(np.int32)


class DataPipeline:
    """Per-host, resumable iterator over the host's shards.

    Yields ``{"tokens": (B, S), "labels": (B, S)}`` int32 batches (labels =
    next token).  State is ``{"cursor": int}``; `load_state` resumes exactly.
    """

    def __init__(self, placement: ShardPlacement, host: int, *,
                 batch: int, seq_len: int, vocab_size: int,
                 shard_tokens: int = 1 << 16):
        self.placement = placement
        self.host = host
        self.batch = batch
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.shard_tokens = shard_tokens
        self.cursor = 0

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def load_state(self, st: dict) -> None:
        self.cursor = int(st["cursor"])

    def _sequence(self, i: int) -> np.ndarray:
        shards = self.placement.shards_for_host(self.host)
        if not shards:
            raise RuntimeError(f"host {self.host} owns no shards")
        per_shard = self.shard_tokens // (self.seq_len + 1)
        shard = shards[(i // per_shard) % len(shards)]
        off = (i % per_shard) * (self.seq_len + 1)
        return synthetic_shard_tokens(shard, self.seq_len + 1,
                                      self.vocab_size, offset=off)

    def next_batch(self) -> dict[str, np.ndarray]:
        seqs = [self._sequence(self.cursor + j) for j in range(self.batch)]
        self.cursor += self.batch
        arr = np.stack(seqs)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self):
        while True:
            yield self.next_batch()
