from .pipeline import DataPipeline, ShardPlacement, synthetic_shard_tokens

__all__ = ["DataPipeline", "ShardPlacement", "synthetic_shard_tokens"]
