"""Bounded-load overlay for MementoHash — the paper's §X future work.

Implements "consistent hashing with bounded loads" (Mirrokni et al., 2016)
on top of any engine with a ``lookup`` method: each bucket accepts at most
``ceil(c · keys / working)`` assignments; overflowing keys walk a
deterministic rehash chain to the next non-full bucket.  Guarantees a
peak-to-mean load ≤ c while keeping (amortized) minimal movement.
"""
from __future__ import annotations

import math

from .hashing import MASK64, hash2_64
from .memento import MementoHash


class BoundedLoadMemento:
    name = "memento-bounded"

    def __init__(self, initial_node_count: int, c: float = 1.25):
        if c <= 1.0:
            raise ValueError("load factor c must exceed 1")
        self.m = MementoHash(initial_node_count)
        self.c = c
        self.load: dict[int, int] = {}
        self.assignment: dict[int, int] = {}

    # -- capacity ---------------------------------------------------------
    def capacity(self) -> int:
        total = len(self.assignment) + 1
        return max(1, math.ceil(self.c * total / self.m.working))

    # -- key management -----------------------------------------------------
    def assign(self, key: int) -> int:
        key &= MASK64
        cap = self.capacity()
        b = self.m.lookup(key)
        probe, k = 0, key
        while self.load.get(b, 0) >= cap:
            probe += 1
            k = hash2_64(k, probe)
            b = self.m.lookup(k)
            if probe > 64 * self.m.working:  # cannot happen if c > 1
                raise RuntimeError("no bucket below capacity")
        self.assignment[key] = b
        self.load[b] = self.load.get(b, 0) + 1
        return b

    def release(self, key: int) -> None:
        b = self.assignment.pop(key & MASK64)
        self.load[b] -= 1

    # -- membership -----------------------------------------------------------
    def remove(self, bucket: int) -> dict[int, int]:
        """Remove a bucket; re-assign only the keys it held. Returns moves."""
        self.m.remove(bucket)
        victims = [k for k, b in self.assignment.items() if b == bucket]
        for k in victims:
            self.release(k)
        moves = {}
        for k in victims:
            moves[k] = self.assign(k)
        return moves

    def add(self) -> int:
        return self.m.add()

    def peak_to_mean(self) -> float:
        if not self.assignment:
            return 0.0
        mean = len(self.assignment) / self.m.working
        return max(self.load.values(), default=0) / mean
