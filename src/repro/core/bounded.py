"""Bounded-load overlay — protocol-generic and device-resident (DESIGN.md §4.2).

Implements "consistent hashing with bounded loads" (Mirrokni, Thorup &
Zadimoghaddam, 2016 — see PAPERS.md, *Consistent Hashing with Bounded
Loads*) on top of ANY :class:`~repro.core.protocol.ConsistentHash`: each
bucket accepts at most ``cap = ceil(c · keys / working)`` assignments;
overflowing keys walk a deterministic rehash chain (``chain ← hash2(chain,
probe)``) to the next non-full bucket.  Guarantees peak-to-mean load ≤ c
while keeping (amortized) minimal movement.

What changed from the original dict-based ``BoundedLoadMemento`` (its API
is preserved): the per-bucket load lives in a flat int32 **load-word
array** that rides in the :class:`~repro.core.protocol.DeviceImage` next to
the algorithm's lookup tables and is synced to the device as epoch deltas
(O(changed-words), like every other table — DESIGN.md §3.5/§4.2).  The
chain walk itself runs on the device planes too
(:func:`repro.kernels.engine.engine_chain_walk` /
:func:`~repro.kernels.engine.bounded_assign`), bit-identical
to the host walk here on ``variant="32"`` states; intra-batch races are
resolved in key-index order by :func:`accept_in_index_order`, shared
verbatim between the numpy reference and the device driver.
"""
from __future__ import annotations

import math

import numpy as np

from .hashing import MASK32, MASK64, hash2_32, hash2_64
from .memento import MementoHash
from .protocol import ConsistentHash, DeltaEmitter, DeviceImage, round_up


def accept_in_index_order(b, pending, load, cap) -> np.ndarray:
    """Indices of the pending keys accepted this round: per bucket, the
    lowest-batch-index proposers up to the bucket's remaining room
    ``cap − load[b]``.  The one acceptance rule both the numpy reference
    (:func:`bounded_assign_ref`) and the device driver
    (:func:`repro.kernels.engine.bounded_assign`) apply, so the planes
    cannot diverge on intra-batch races."""
    idx = np.nonzero(pending)[0]
    pb = np.asarray(b)[idx]
    order = np.argsort(pb, kind="stable")
    sorted_b = pb[order]
    starts = (np.r_[True, sorted_b[1:] != sorted_b[:-1]] if len(sorted_b)
              else np.zeros(0, bool))
    seg_start = np.maximum.accumulate(
        np.where(starts, np.arange(len(sorted_b)), 0))
    rank = np.empty(len(idx), np.int64)
    rank[order] = np.arange(len(sorted_b)) - seg_start
    return idx[rank < (cap - np.asarray(load)[pb])]


def walk_probe_bound(load_len: int) -> int:
    """Chain-walk termination guard, shared by the host reference and the
    device kernels (derived from the load-array length so every plane uses
    the same bound): a lane still above the cap after this many probes means
    the cap is infeasible (cap·buckets < keys) — raise instead of spinning.
    Unreachable when c > 1 and the cap covers the batch."""
    return 64 * load_len + 64


def bounded_assign_ref(ch, keys, load, cap: int):
    """Numpy reference for batch bounded assignment (host control plane).

    Round-based, deterministic: every pending key chain-walks (host scalar
    lookups) to the first bucket with ``load[b] < cap``; races are resolved
    by :func:`accept_in_index_order`; rejected keys' buckets are full next
    round, so their walk advances.  A batch of one degenerates to the
    classic sequential assign.  Returns ``(assignments int32 [m],
    new_load)``.  The device planes must match this bit-for-bit on
    ``variant="32"`` states (tested in tests/test_replicas.py).
    """
    h2 = hash2_32 if getattr(ch, "variant", "64") == "32" else hash2_64
    mask = MASK32 if getattr(ch, "variant", "64") == "32" else MASK64
    keys = np.asarray(keys, dtype=np.uint64)
    m = len(keys)
    chain = [int(k) & mask for k in keys]
    probe = [0] * m
    out = np.full(m, -1, np.int32)
    pending = np.ones(m, bool)
    load = np.asarray(load, dtype=np.int32).copy()
    b = np.zeros(m, np.int32)
    max_probe = walk_probe_bound(len(load))
    while pending.any():
        for i in np.nonzero(pending)[0]:
            bi = ch.lookup(chain[i])
            while load[bi] >= cap:
                if probe[i] >= max_probe:
                    raise RuntimeError(
                        "no bucket below capacity (infeasible cap: "
                        f"cap={cap} cannot hold the pending keys)")
                probe[i] += 1
                chain[i] = h2(chain[i], probe[i])
                bi = ch.lookup(chain[i])
            b[i] = bi
        acc = accept_in_index_order(b, pending, load, cap)
        out[acc] = b[acc]
        np.add.at(load, b[acc], 1)
        pending[acc] = False
    return out, load


class BoundedLoad(DeltaEmitter):
    """Bounded-load overlay over any ConsistentHash implementation.

    Speaks the ConsistentHash protocol itself (lookup/lookup_k delegate to
    the inner state; ``device_image()`` is the inner image plus the
    ``load`` word array), so a :class:`~repro.core.DeviceImageStore` can
    keep the load words device-resident and every load change — an
    assignment, a release, a failure re-spill — reaches the device as an
    O(changed-words) epoch delta.
    """

    def __init__(self, ch: ConsistentHash | str, c: float = 1.25, *,
                 initial_node_count: int | None = None,
                 capacity: int | None = None, variant: str = "64"):
        if c <= 1.0:
            raise ValueError("load factor c must exceed 1")
        if isinstance(ch, str):
            from .protocol import make_hash
            ch = make_hash(ch, initial_node_count, capacity=capacity,
                           variant=variant)
        self.ch = ch
        self.c = c
        self.assignment: dict[int, int] = {}
        self._load = np.zeros(round_up(max(ch.size, 1)), np.int32)
        self._init_delta_log()

    # -- protocol plumbing -------------------------------------------------
    @property
    def name(self) -> str:
        return f"{self.ch.name}-bounded"

    @property
    def image_algo(self) -> str:
        return self.ch.name  # device planes dispatch on the inner layout

    @property
    def variant(self) -> str:
        return getattr(self.ch, "variant", "64")

    @property
    def size(self) -> int:
        return self.ch.size

    @property
    def working(self) -> int:
        return self.ch.working

    def working_set(self) -> set[int]:
        return self.ch.working_set()

    def memory_bytes(self) -> int:
        """Inner state + one load word per working bucket (host view)."""
        return self.ch.memory_bytes() + 4 * self.ch.working

    def lookup(self, key: int) -> int:
        return self.ch.lookup(key)

    def lookup_k(self, key: int, k: int) -> list[int]:
        return self.ch.lookup_k(key, k)

    @property
    def load(self) -> np.ndarray:
        """Per-bucket load words, int32, bucket-indexed (flat — the exact
        array the device image carries)."""
        return self._load

    def _image_n(self) -> int:
        return self.ch._image_n()

    def _image_scalars(self) -> dict[str, int]:
        return self.ch._image_scalars()

    def device_image(self, capacity: int | None = None) -> DeviceImage:
        """Inner image + the ``load`` array (padded to the bucket-id space),
        stamped with the overlay's own epoch (which also counts load-word
        events, not just membership)."""
        img = self.ch.device_image(capacity=capacity)
        pad = max(round_up(max(img.n, capacity or 0, 1)), self._load.shape[0])
        load = np.zeros(pad, np.int32)
        load[: self._load.shape[0]] = self._load
        return DeviceImage(algo=img.algo, n=img.n,
                           arrays={**img.arrays, "load": load},
                           scalars=img.scalars, epoch=self._epoch)

    # -- capacity ----------------------------------------------------------
    def capacity(self, incoming: int = 1) -> int:
        """The cap for assigning ``incoming`` more keys:
        ``max(1, ceil(c · (assigned + incoming) / working))``."""
        total = len(self.assignment) + incoming
        return max(1, math.ceil(self.c * total / self.ch.working))

    def _grow_load(self, need: int) -> None:
        if need <= self._load.shape[0]:
            return
        grown = np.zeros(round_up(max(need, 2 * self._load.shape[0])), np.int32)
        grown[: self._load.shape[0]] = self._load
        self._load = grown

    def _inner_event_updates(self) -> dict[str, dict[int, int]]:
        """The inner algorithm's last membership event, for merging into the
        overlay's delta log (same package: reading the emitter log is the
        supported way to re-emit an event under the overlay's epochs)."""
        if not getattr(self.ch, "_delta_log", None):
            return {}
        _epoch, updates, _n, _scalars = self.ch._delta_log[-1]
        return {name: dict(edits) for name, edits in updates.items()}

    # -- key management ----------------------------------------------------
    def _walk(self, key: int, cap: int) -> int:
        """Host chain walk: first bucket of the deterministic rehash chain
        below ``cap`` — the scalar original of the device chain-walk kernel."""
        h2 = hash2_32 if self.variant == "32" else hash2_64
        b = self.ch.lookup(key)
        probe, chain = 0, key
        while self._load[b] >= cap:
            probe += 1
            chain = h2(chain, probe)
            b = self.ch.lookup(chain)
            if probe > 64 * self.ch.working:  # cannot happen if c > 1
                raise RuntimeError("no bucket below capacity")
        return b

    def assign(self, key: int) -> int:
        mask = MASK32 if self.variant == "32" else MASK64
        key &= mask
        b = self._walk(key, self.capacity())
        self.assignment[key] = b
        self._load[b] += 1
        self._record({"load": {b: int(self._load[b])}}, self._image_n(),
                     self._image_scalars())
        return b

    def assign_batch(self, keys) -> np.ndarray:
        """Batch assignment at ``cap = ceil(c·(assigned+len(keys))/working)``
        via the numpy reference semantics; one composed epoch delta carries
        every changed load word.  (Device-plane callers run
        ``kernels.engine.bounded_assign`` against the synced image and
        get bit-identical assignments.)"""
        keys = np.asarray(keys, dtype=np.uint64)
        cap = self.capacity(incoming=len(keys))
        out, new_load = bounded_assign_ref(self.ch, keys, self._load, cap)
        mask = MASK32 if self.variant == "32" else MASK64
        changed = np.nonzero(new_load != self._load)[0]
        self._load = new_load
        for key, b in zip(keys, out):
            self.assignment[int(key) & mask] = int(b)
        self._record({"load": {int(i): int(new_load[i]) for i in changed}},
                     self._image_n(), self._image_scalars())
        return out

    def release(self, key: int) -> None:
        mask = MASK32 if self.variant == "32" else MASK64
        b = self.assignment.pop(key & mask)
        self._load[b] -= 1
        self._record({"load": {b: int(self._load[b])}}, self._image_n(),
                     self._image_scalars())

    # -- membership --------------------------------------------------------
    def remove(self, bucket: int) -> dict[int, int]:
        """Remove a bucket; re-assign only the keys it held (plus their
        bounded-capacity spill).  Returns the moves.  The membership edit
        and every touched load word land in ONE epoch delta."""
        self.ch.remove(bucket)
        updates = self._inner_event_updates()
        victims = [k for k, b in self.assignment.items() if b == bucket]
        touched: set[int] = set()
        for k in victims:
            del self.assignment[k]
        self._load[bucket] = 0
        touched.add(bucket)
        moves = {}
        for k in victims:
            b = self._walk(k, self.capacity())
            self.assignment[k] = b
            self._load[b] += 1
            touched.add(b)
            moves[k] = b
        updates.setdefault("load", {}).update(
            {int(b): int(self._load[b]) for b in touched})
        self._record(updates, self._image_n(), self._image_scalars())
        return moves

    def add(self) -> int:
        b = self.ch.add()
        self._grow_load(self.ch.size)
        updates = self._inner_event_updates()
        self._record(updates, self._image_n(), self._image_scalars())
        return b

    # -- metrics -----------------------------------------------------------
    def peak_to_mean(self) -> float:
        if not self.assignment:
            return 0.0
        mean = len(self.assignment) / self.ch.working
        return float(self._load.max()) / mean


class BoundedLoadMemento(BoundedLoad):
    """The original Memento-only overlay, now a thin alias over the generic
    :class:`BoundedLoad` (API preserved: ``m``, ``assign``, ``release``,
    ``remove`` → moves, ``capacity``, ``peak_to_mean``)."""

    def __init__(self, initial_node_count: int, c: float = 1.25,
                 variant: str = "64"):
        super().__init__(MementoHash(initial_node_count, variant=variant), c)

    @property
    def m(self) -> MementoHash:
        return self.ch
