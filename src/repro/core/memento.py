"""MementoHash — faithful implementation of the paper (Algs. 1-4).

State ``S = ⟨n, R, l⟩``:
  * ``n``  — size of the b-array,
  * ``R``  — replacement set ``{b: (c, p)}`` (hash table, Θ(r) memory),
  * ``l``  — last removed bucket (``l = n`` when ``R`` is empty).

Engine: JumpHash (``jump64`` faithful / ``jump32`` TPU-native — the latter is
bit-identical to the device data plane so lookups agree across planes).
"""
from __future__ import annotations

import numpy as np

from .hashing import MASK32, MASK64, hash2_32, hash2_64
from .jump import jump32, jump64
from .protocol import DeltaEmitter, DeviceImage, ReplicatedLookup, round_up


class MementoHash(ReplicatedLookup, DeltaEmitter):
    name = "memento"

    def __init__(self, initial_node_count: int, variant: str = "64"):
        if initial_node_count <= 0:
            raise ValueError("initial_node_count must be positive")
        # Alg. 1 (Init).
        self.n = initial_node_count
        self.l = self.n
        self.R: dict[int, tuple[int, int]] = {}
        self.variant = variant
        self._init_delta_log()
        if variant == "64":
            self._jump, self._hash2, self._mask = jump64, hash2_64, MASK64
        elif variant == "32":
            self._jump, self._hash2, self._mask = jump32, hash2_32, MASK32
        else:
            raise ValueError(f"unknown variant {variant!r}")

    # -- state inspection ---------------------------------------------------
    @property
    def size(self) -> int:
        """Size of the b-array (paper's n)."""
        return self.n

    @property
    def working(self) -> int:
        """Number of working buckets w = n − r (Prop. V.6)."""
        return self.n - len(self.R)

    def is_working(self, b: int) -> bool:
        return 0 <= b < self.n and b not in self.R

    def working_set(self) -> set[int]:
        return {b for b in range(self.n) if b not in self.R}

    def memory_bytes(self) -> int:
        """Θ(r): one ⟨b → c, p⟩ tuple per removed bucket (3 × int32) + ⟨n, l⟩."""
        return 8 + 12 * len(self.R)

    # -- Alg. 2 (Remove) ------------------------------------------------------
    def remove(self, b: int) -> None:
        if not self.is_working(b):
            raise ValueError(f"bucket {b} is not a working bucket")
        if self.working == 1:
            raise ValueError("cannot remove the last working bucket")
        if b == self.n - 1 and not self.R:
            # LIFO removal: shrink the b-array, stay in the Jump regime.
            # repl[n-1] was -1 (working) and stays -1: the delta is just n.
            self.n -= 1
            self.l = self.n
            self._record({}, self.n)
        else:
            w = self.working  # before this removal
            self.R[b] = (w - 1, self.l)  # ⟨b → w−1, l⟩  (Prop. V.3: c = new w)
            self.l = b
            self._record({"repl": {b: w - 1}}, self.n)

    # -- Alg. 3 (Add) ---------------------------------------------------------
    def add(self) -> int:
        if not self.R:
            b = self.n  # append to the tail
            self.n += 1
            self.l = self.n
            # repl beyond the old n is already -1: the delta is just n (the
            # image store rebuilds only when n outgrows its padded buffer).
            self._record({}, self.n)
            return b
        b = self.l  # restore the last removed bucket (untangles chains)
        _, p = self.R.pop(b)
        self.l = p
        self._record({"repl": {b: -1}}, self.n)
        return b

    def _image_n(self) -> int:
        return self.n

    def device_image(self, capacity: int | None = None) -> DeviceImage:
        """Dense repl image: repl[b] = |W_b| if removed else -1 (DESIGN.md §3.2).

        ``capacity`` requests extra headroom (still 128-padded) so delta
        appliers can grow ``n`` in place without reallocating.
        """
        repl = np.full((round_up(max(self.n, capacity or 0)),), -1, dtype=np.int32)
        for b, (c, _p) in self.R.items():
            repl[b] = c
        return DeviceImage(algo=self.name, n=self.n, arrays={"repl": repl},
                           epoch=self._epoch)

    # -- Alg. 4 (Lookup) -------------------------------------------------------
    def lookup(self, key) -> int:
        key &= self._mask
        b = self._jump(key, self.n)
        R = self.R
        while b in R:
            c, _ = R[b]
            wb = c  # working buckets after b was removed (Prop. V.3)
            d = self._hash2(key, b) % wb
            # follow the replacement chain only while u ≥ w_b (balance!)
            while d in R and R[d][0] >= wb:
                d = R[d][0]
            b = d
        return b

    # convenience for tests/benchmarks
    def lookup_trace(self, key) -> tuple[int, int, int]:
        """Lookup returning (bucket, external_iters, internal_iters)."""
        key &= self._mask
        b = self._jump(key, self.n)
        ext = inn = 0
        while b in self.R:
            ext += 1
            wb = self.R[b][0]
            d = self._hash2(key, b) % wb
            while d in self.R and self.R[d][0] >= wb:
                inn += 1
                d = self.R[d][0]
            b = d
        return b, ext, inn


def random_state(
    rng: np.random.Generator, n0: int, removals: int, variant: str = "64"
) -> MementoHash:
    """Build a MementoHash with ``removals`` random (non-LIFO-biased) removals."""
    m = MementoHash(n0, variant=variant)
    for _ in range(removals):
        working = sorted(m.working_set())
        m.remove(working[int(rng.integers(len(working)))])
    return m
