"""ConsistentHash — the one protocol every algorithm in this repo speaks.

Host control plane (paper-faithful, Θ(state) python):
    ``lookup / remove / add / working / size / working_set / memory_bytes``

Device data plane (DESIGN.md §3.3): ``device_image()`` flattens the host
state into a :class:`DeviceImage` — a bundle of flat, 128-padded
int32/uint32 arrays plus the dynamic scalars the lane-synchronous lookups
need.  One image format serves three consumers:

  * ``core/jax_lookup.lookup_image``   — pure-jnp oracle (any backend),
  * ``kernels/ops.device_lookup``      — Pallas kernels (Mosaic on TPU,
    interpret mode elsewhere),
  * tests/benchmarks                   — cross-plane equivalence sweeps.

Images are *snapshots*: rebuild (or incrementally mirror, see
``core/tables.py``) after membership changes.  Device lookups are
bit-identical to the host ``lookup`` of the TPU-native ``variant="32"``
state; the default ``variant="64"`` remains paper-faithful host-only.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np


def round_up(x: int, m: int = 128) -> int:
    """Round ``x`` up to a multiple of ``m`` (TPU lane alignment)."""
    return ((x + m - 1) // m) * m


@dataclass
class DeviceImage:
    """Flat device image of a consistent-hash state.

    * ``algo``    — "memento" | "anchor" | "dx" | "jump" (dispatch key),
    * ``n``       — the dynamic size scalar (b-array size for Memento/Jump,
      overall capacity ``a`` for Anchor/Dx),
    * ``arrays``  — named flat int32/uint32 arrays, lengths 128-padded,
    * ``scalars`` — extra dynamic int scalars (e.g. Dx probe bound).
    """

    algo: str
    n: int
    arrays: dict[str, np.ndarray] = field(default_factory=dict)
    scalars: dict[str, int] = field(default_factory=dict)


@runtime_checkable
class ConsistentHash(Protocol):
    """Uniform algorithm API: host ops + a flat device image."""

    name: str

    def lookup(self, key: int) -> int: ...

    def remove(self, b: int) -> None: ...

    def add(self) -> int: ...

    @property
    def size(self) -> int: ...

    @property
    def working(self) -> int: ...

    def working_set(self) -> set[int]: ...

    def memory_bytes(self) -> int: ...

    def device_image(self) -> DeviceImage: ...


def make_hash(algo: str, initial_node_count: int, *, capacity: int | None = None,
              variant: str = "64"):
    """Factory: algorithm name → ConsistentHash implementation.

    ``capacity`` only applies to the fixed-capacity baselines (Anchor/Dx);
    it defaults to the paper's a/w = 10 compromise.  ``variant="32"`` selects
    the TPU-native arithmetic that the device planes match bit-for-bit.
    """
    from .anchor import AnchorHash
    from .dx import DxHash
    from .jump import JumpHash
    from .memento import MementoHash

    if algo == "memento":
        return MementoHash(initial_node_count, variant=variant)
    if algo == "jump":
        return JumpHash(initial_node_count, variant=variant)
    if algo == "anchor":
        return AnchorHash(capacity or 10 * initial_node_count,
                          initial_node_count, variant=variant)
    if algo == "dx":
        return DxHash(capacity or 10 * initial_node_count,
                      initial_node_count, variant=variant)
    raise ValueError(f"unknown algorithm {algo!r}")
