"""ConsistentHash — the one protocol every algorithm in this repo speaks.

Host control plane (paper-faithful, Θ(state) python):
    ``lookup / remove / add / working / size / working_set / memory_bytes``

Device data plane (DESIGN.md §3.3): ``device_image()`` flattens the host
state into a :class:`DeviceImage` — a bundle of flat, 128-padded
int32/uint32 arrays plus the dynamic scalars the lane-synchronous lookups
need.  One image format serves three consumers:

  * ``core/jax_lookup.lookup_image``   — pure-jnp oracle (any backend),
  * ``kernels/ops.device_lookup``      — Pallas kernels (Mosaic on TPU,
    interpret mode elsewhere),
  * tests/benchmarks                   — cross-plane equivalence sweeps.

Device control plane (DESIGN.md §3.5): membership churn is epoch-versioned.
Every ``remove()``/``add()`` bumps the algorithm's ``epoch`` and appends an
O(changed-words) record to a bounded delta log; ``device_delta(since)``
composes the records after ``since`` into one :class:`ImageDelta` —
scatter indices/values per named array plus the new dynamic scalars.  A
:class:`~repro.core.image_store.DeviceImageStore` applies deltas to
double-buffered on-device arrays and flips epochs atomically, so bulk
lookups keep serving epoch N while N+1 is applied; images built at a given
epoch stay immutable snapshots of that epoch.  Device lookups are
bit-identical to the host ``lookup`` of the TPU-native ``variant="32"``
state; the default ``variant="64"`` remains paper-faithful host-only.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np


def round_up(x: int, m: int = 128) -> int:
    """Round ``x`` up to a multiple of ``m`` (TPU lane alignment)."""
    return ((x + m - 1) // m) * m


#: Salted re-lookup bound for ``lookup_k`` (DESIGN.md §4.1).  With k ≤ w the
#: probability a salted lookup collides with an already-chosen bucket is
#: ≤ (k−1)/w per try, so exhausting the bound has probability ≤ ((k−1)/w)^CAP
#: — unreachable in practice; the bound exists so the device loops terminate
#: even on adversarial states.  Host and device share the constant so the
#: planes stay bit-identical.
REPLICA_SALT_CAP = 4096


class ReplicatedLookup:
    """Mixin: protocol-generic k-replication by salted re-lookup (DESIGN.md §4.1).

    ``lookup_k(key, k)`` returns k *distinct* working buckets.  Replica 0 is
    the plain ``lookup(key)`` (so k = 1 degenerates to the base algorithm);
    replica j is found by looking up the salted key ``hash2(key, salt)`` for
    salt = 1, 2, … and keeping the first candidate not already chosen.  The
    salt counter is shared across slots, so the construction is a single
    deterministic walk — the same walk the jnp and Pallas planes run
    lane-synchronously (``kernels/engine.py``), bit-identical on
    ``variant="32"`` states.

    Disruption bound: removing bucket b changes a key's replica set only if
    some salted lookup in its walk mapped to b; each salted lookup inherits
    the base algorithm's minimal disruption, so expected slot churn per
    removal is ≤ (k + expected dedup retries)/w — the per-slot analogue of
    the paper's minimal-disruption property (DESIGN.md §4.1).
    """

    def _salt_hash2(self, key: int, salt: int) -> int:
        """The salted re-key — variant-matched so device planes agree."""
        from .hashing import hash2_32, hash2_64

        if getattr(self, "variant", "64") == "32":
            return hash2_32(key, salt)
        return hash2_64(key, salt)

    def lookup_k_filtered(self, key: int, k: int, reject,
                          trace: list | None = None,
                          check_first: bool = False) -> list[int]:
        """The one salted walk every k-replica variant shares.

        ``reject(cand, chosen)`` skips a candidate the way the dedup rule
        skips duplicates (plain ``lookup_k`` passes exactly that rule;
        failure-domain placement adds a domain check — see
        ``runtime/elastic.domain_distinct_replicas``).  Slot 0 is the plain
        lookup, accepted unconditionally unless ``check_first`` — the
        bounded-replica op (``kernels/engine.bounded_replica_sets``) applies
        its load-cap rule to slot 0 too, so even the primary replica walks
        past full buckets.  ``trace``, if given, collects every
        salted-lookup result in walk order (rejected ones included).
        Keeping the walk in ONE place is what keeps the host bit-identical
        to the device planes (``kernels/engine.replica_body``).
        """
        if k < 1:
            raise ValueError("k must be ≥ 1")
        first = self.lookup(key)
        if trace is not None:
            trace.append(first)
        out = [] if check_first and reject(first, []) else [first]
        salt = 1
        while len(out) < k:
            if salt > REPLICA_SALT_CAP:
                raise RuntimeError("replica salt budget exhausted")
            cand = self.lookup(self._salt_hash2(key, salt))
            if trace is not None:
                trace.append(cand)
            if not reject(cand, out):
                out.append(cand)
            salt += 1
        return out

    @staticmethod
    def _reject_duplicate(cand: int, chosen: list[int]) -> bool:
        return cand in chosen

    def lookup_k(self, key: int, k: int) -> list[int]:
        """k distinct working buckets for ``key``; ``lookup_k(key, 1)[0] ==
        lookup(key)``.  Requires ``k ≤ working``."""
        if k > self.working:
            raise ValueError(f"k={k} exceeds working buckets ({self.working})")
        return self.lookup_k_filtered(key, k, self._reject_duplicate)

    def lookup_k_trace(self, key: int, k: int) -> tuple[list[int], list[int]]:
        """``lookup_k`` returning ``(replicas, candidates)`` where
        ``candidates`` lists every salted-lookup result in walk order
        (including dedup-rejected ones) — the instrument the replica-stability
        property tests use: a removal can change the set only if the removed
        bucket appears among the candidates."""
        if k > self.working:
            raise ValueError(f"k={k} exceeds working buckets ({self.working})")
        cands: list[int] = []
        out = self.lookup_k_filtered(key, k, self._reject_duplicate,
                                     trace=cands)
        return out, cands


def replica_sets(h, keys, k: int) -> np.ndarray:
    """Numpy oracle: ``lookup_k`` over a key batch → int32 [len(keys), k].

    The ground truth the device planes (`kernels/engine.py`) are
    tested against; per-key scalar walk on the host control plane.
    """
    keys = np.asarray(keys)
    out = np.empty((len(keys), k), dtype=np.int32)
    for i, key in enumerate(keys):
        out[i] = h.lookup_k(int(key), k)
    return out


@dataclass
class DeviceImage:
    """Flat device image of a consistent-hash state.

    * ``algo``    — a name in :data:`ALGORITHMS` (dispatch key),
    * ``n``       — the dynamic size scalar (b-array size for Memento/Jump,
      overall capacity ``a`` for Anchor/Dx),
    * ``arrays``  — named flat int32/uint32 arrays, lengths 128-padded,
    * ``scalars`` — extra dynamic int scalars (e.g. Dx probe bound),
    * ``epoch``   — membership epoch this image snapshots (one per
      remove/add event since construction of the host state),
    * ``packed``  — True when ``arrays`` hold the compact layout of
      :mod:`repro.core.packing` (bit-packed bucket state + narrowed
      words, DESIGN.md §8.2) instead of the full-width dense layout.
      The engine dispatches on this flag, so packed and dense images
      share every public lookup entry point.
    """

    algo: str
    n: int
    arrays: dict[str, np.ndarray] = field(default_factory=dict)
    scalars: dict[str, int] = field(default_factory=dict)
    epoch: int = 0
    packed: bool = False


@dataclass
class ImageDelta:
    """O(changed-words) edit advancing a :class:`DeviceImage` one or more
    epochs.

    * ``algo``       — dispatch key (must match the image's),
    * ``base_epoch`` — epoch of the image the delta applies to,
    * ``epoch``      — epoch of the image after applying,
    * ``n``          — the new dynamic size scalar,
    * ``updates``    — per array name, ``(indices int32[k], values[k])``
      scatter pairs (last-write-wins composition of every event in
      ``(base_epoch, epoch]``),
    * ``scalars``    — new values of the image's dynamic scalars.

    Jump's delta is just the new ``n``; Memento scatters ≤ 1 word per
    event, Anchor 2, Dx 1 (one bitmap word) — versus the O(n) arrays a
    full snapshot re-transfers.
    """

    algo: str
    base_epoch: int
    epoch: int
    n: int
    updates: dict[str, tuple[np.ndarray, np.ndarray]] = field(default_factory=dict)
    scalars: dict[str, int] = field(default_factory=dict)

    @property
    def events(self) -> int:
        return self.epoch - self.base_epoch

    def num_words(self) -> int:
        """Host→device scatter payload in 32-bit words (indices + values)."""
        return sum(2 * len(idx) for idx, _ in self.updates.values())


@dataclass(frozen=True)
class AlgoInfo:
    """One algorithm's registry entry — THE single description every list
    in the repo derives from (engine dispatch, wire ids, image layouts,
    sim churn policy, benchmark grids, the conformance harness).  Adding
    algorithm #N+1 means adding exactly one entry here plus its host class
    and engine body; nothing else enumerates algorithms by hand
    (``tests/test_conformance.py`` scans the sources to enforce that).

    * ``factory``        — ``(initial_nodes, capacity, variant) → instance``
      (lazy-imports the host class, preserving :func:`make_hash` semantics),
    * ``scalars``        — dynamic image scalars, ``n`` always first,
    * ``tables``         — dense-layout table array names,
    * ``required``       — ``n → {table: min length}`` a lookup may gather,
    * ``lifo_only``      — removals restricted to the highest bucket (the
      jump-family contract the sim's victim policies degrade to),
    * ``fixed_capacity`` — overall capacity ``a`` fixed at construction
      (Anchor/Dx); growable algorithms get snapshot headroom instead,
    * ``packed_tables``  — compact-layout table names when the packed
      encoding differs from the dense one (``None`` → same names).
    """

    name: str
    factory: object
    scalars: tuple[str, ...]
    tables: tuple[str, ...]
    required: object
    lifo_only: bool = False
    fixed_capacity: bool = False
    packed_tables: tuple[str, ...] | None = None


def _memento_factory(n0: int, capacity, variant: str):
    from .memento import MementoHash

    return MementoHash(n0, variant=variant)


def _anchor_factory(n0: int, capacity, variant: str):
    from .anchor import AnchorHash

    return AnchorHash(capacity or 10 * n0, n0, variant=variant)


def _dx_factory(n0: int, capacity, variant: str):
    from .dx import DxHash

    return DxHash(capacity or 10 * n0, n0, variant=variant)


def _jump_factory(n0: int, capacity, variant: str):
    from .jump import JumpHash

    return JumpHash(n0, variant=variant)


def _power_factory(n0: int, capacity, variant: str):
    from .power import PowerHash

    return PowerHash(n0, variant=variant)


#: Registry order is the replication wire format (``launch/replicate.py``
#: frame ``algo_id`` = position) — append new algorithms, never reorder.
ALGORITHM_REGISTRY: dict[str, AlgoInfo] = {
    info.name: info for info in (
        AlgoInfo("memento", _memento_factory, ("n",), ("repl",),
                 lambda n: {"repl": n},
                 packed_tables=("state", "slot_b", "slot_c")),
        AlgoInfo("anchor", _anchor_factory, ("n",), ("A", "K"),
                 lambda n: {"A": n, "K": n}, fixed_capacity=True),
        AlgoInfo("dx", _dx_factory, ("n", "max_probes", "fallback"),
                 ("words",), lambda n: {"words": -(-n // 32)},
                 fixed_capacity=True),
        AlgoInfo("jump", _jump_factory, ("n",), (), lambda n: {},
                 lifo_only=True),
        AlgoInfo("power", _power_factory, ("n",), (), lambda n: {},
                 lifo_only=True),
    )
}

#: algorithm names in wire-id order — the ONE list everything derives from
ALGORITHMS: tuple[str, ...] = tuple(ALGORITHM_REGISTRY)

#: per-algorithm device image layout: (scalar names, table array names).
#: ``n`` is always the first scalar; the rest index ``image.scalars``.
IMAGE_LAYOUT: dict[str, tuple[tuple[str, ...], tuple[str, ...]]] = {
    name: (info.scalars, info.tables)
    for name, info in ALGORITHM_REGISTRY.items()
}


def image_scalar_vec(image: DeviceImage) -> list[int]:
    """The image's dynamic scalars in layout order (``n`` first)."""
    names = IMAGE_LAYOUT[image.algo][0]
    return [int(image.n)] + [int(image.scalars[s]) for s in names[1:]]


def required_lengths(algo: str, n: int) -> dict[str, int]:
    """Minimum array lengths a lookup at size ``n`` may gather from."""
    info = ALGORITHM_REGISTRY.get(algo)
    if info is None:
        raise ValueError(f"unknown algo {algo!r}")
    return info.required(n)


def image_fingerprint(image: DeviceImage) -> str:
    """CRC32 hex digest of every word a lookup can observe.

    Hashes ``n``, ``epoch``, the layout scalars, and each array trimmed to
    its :func:`required_lengths` prefix (plus the bucket-indexed ``load``
    overlay words, if present) — capacity padding is excluded, so two
    stores that reached the same epoch through different snapshot/delta
    histories (hence different padded capacities) fingerprint equal iff
    their lookups are bit-identical.  This is the convergence instrument
    for cross-process replication (``launch/replicate.py``) and the sim's
    follower-convergence checker.  Packed images hash their full arrays
    (their layout has no unread padding words beyond the slot area).
    """
    import zlib

    crc = zlib.crc32(np.asarray([image.n, image.epoch], np.int64).tobytes())
    trim = {} if image.packed else required_lengths(image.algo, image.n)
    if "load" in image.arrays:
        trim = dict(trim, load=image.n)
    for name in sorted(image.arrays):
        arr = np.ascontiguousarray(np.asarray(image.arrays[name]))
        if name in trim:
            arr = arr[: trim[name]]
        crc = zlib.crc32(name.encode(), crc)
        crc = zlib.crc32(arr.tobytes(), crc)
    for name in sorted(image.scalars):
        crc = zlib.crc32(f"{name}={int(image.scalars[name])}".encode(), crc)
    return f"{crc & 0xFFFFFFFF:08x}"


def apply_delta(image: DeviceImage, delta: ImageDelta) -> DeviceImage:
    """Host-side (numpy) reference apply: returns a NEW image at
    ``delta.epoch``; ``image`` is left untouched (double-buffer semantics).

    Raises if the delta does not chain onto the image's epoch, or if the
    new ``n`` outgrows an array the delta scatters into (the caller must
    fall back to a fresh snapshot at larger capacity — see
    ``DeviceImageStore``).
    """
    if delta.algo != image.algo:
        raise ValueError(f"delta algo {delta.algo!r} != image {image.algo!r}")
    if delta.base_epoch != image.epoch:
        raise ValueError(
            f"delta base epoch {delta.base_epoch} != image epoch {image.epoch}")
    needed = required_lengths(delta.algo, delta.n)
    arrays = {}
    for name, arr in image.arrays.items():
        if needed.get(name, 0) > arr.shape[0]:
            raise ValueError(f"delta outgrows array {name!r} "
                             f"({arr.shape[0]} < {needed[name]})")
        if name in delta.updates:
            idx, vals = delta.updates[name]
            if len(idx) and int(idx.max()) >= arr.shape[0]:
                raise ValueError(f"delta outgrows array {name!r}")
            arr = arr.copy()
            arr[idx] = vals.astype(arr.dtype)
        arrays[name] = arr
    return DeviceImage(algo=image.algo, n=delta.n, arrays=arrays,
                       scalars=dict(delta.scalars) or dict(image.scalars),
                       epoch=delta.epoch)


class DeltaEmitter:
    """Mixin: epoch counter + bounded per-event delta log (DESIGN.md §3.5).

    Implementations call ``_init_delta_log()`` once and then
    ``_record(updates, n, scalars)`` after every committed membership
    event, where ``updates`` maps array name → {flat index: new value}.
    ``device_delta(since)`` composes the log suffix into one
    :class:`ImageDelta`; when ``since`` predates the log window it returns
    ``None`` — the caller must rebuild from a fresh ``device_image()``.
    """

    _DELTA_LOG_CAP = 8192

    @property
    def image_algo(self) -> str:
        """Dispatch key stamped on emitted images/deltas.  Defaults to
        ``name``; overlay states (e.g. :class:`~repro.core.bounded.
        BoundedLoad`) override it to their *inner* algorithm so the device
        planes dispatch on the real table layout."""
        return self.name

    def _init_delta_log(self) -> None:
        self._epoch = 0
        self._delta_log: list = []

    @property
    def epoch(self) -> int:
        return self._epoch

    def _record(self, updates: dict[str, dict[int, int]], n: int,
                scalars: dict[str, int] | None = None) -> None:
        self._epoch += 1
        self._delta_log.append((self._epoch, updates, n, scalars or {}))
        if len(self._delta_log) > self._DELTA_LOG_CAP:
            # drop the oldest half: amortized O(1) per event, and readers
            # that far behind need a snapshot rebuild anyway
            del self._delta_log[: len(self._delta_log) // 2]

    def device_delta(self, since_epoch: int):
        """Compose every event in ``(since_epoch, epoch]`` into one delta.

        O(events-behind), NOT O(log): log entries hold contiguous epochs
        ending at ``epoch``, so the suffix is an index computation.
        Returns ``None`` when ``since_epoch`` has fallen out of the bounded
        log (snapshot rebuild required).  An up-to-date caller gets an
        empty delta (``events == 0``).
        """
        if since_epoch > self._epoch:
            raise ValueError(f"since_epoch {since_epoch} is in the future "
                             f"(current epoch {self._epoch})")
        if since_epoch < self._epoch - len(self._delta_log):
            return None  # out of the log window
        merged: dict[str, dict[int, int]] = {}
        n = getattr(self, "_image_n")()
        scalars: dict[str, int] = dict(getattr(self, "_image_scalars")())
        start = len(self._delta_log) - (self._epoch - since_epoch)
        for _epoch, updates, _ev_n, _ev_scalars in self._delta_log[start:]:
            for name, edits in updates.items():
                merged.setdefault(name, {}).update(edits)
        updates = {
            name: (np.fromiter(edits.keys(), dtype=np.int32, count=len(edits)),
                   np.fromiter(edits.values(), dtype=np.int64,
                               count=len(edits)).astype(np.int32))
            for name, edits in merged.items()
        }
        return ImageDelta(algo=self.image_algo, base_epoch=since_epoch,
                          epoch=self._epoch, n=n, updates=updates,
                          scalars=scalars)

    def device_delta_range(self, since_epoch: int, until_epoch: int):
        """Compose the events in ``(since_epoch, until_epoch]`` into one
        delta — :meth:`device_delta` generalized to an intermediate target
        epoch, the primitive behind cross-epoch frame batching
        (``launch/replicate.py``): a publisher can chunk a long pending
        range into several ``DELTA_BATCH`` frames without ever composing
        past a chunk boundary.  ``n`` and the dynamic scalars come from the
        log entry AT ``until_epoch`` (every ``_record`` call site commits
        the full post-event scalar set), so the delta lands the follower on
        exactly the epoch-``until`` image.  Returns ``None`` when
        ``since_epoch`` predates the bounded log window.
        """
        if until_epoch > self._epoch:
            raise ValueError(f"until_epoch {until_epoch} is in the future "
                             f"(current epoch {self._epoch})")
        if since_epoch > until_epoch:
            raise ValueError(f"empty range ({since_epoch}, {until_epoch}]")
        if since_epoch < self._epoch - len(self._delta_log):
            return None  # out of the log window
        start = len(self._delta_log) - (self._epoch - since_epoch)
        stop = len(self._delta_log) - (self._epoch - until_epoch)
        if stop == start:  # empty range: report the until-epoch state
            if until_epoch == self._epoch:
                n = getattr(self, "_image_n")()
                scalars = dict(getattr(self, "_image_scalars")())
            elif stop <= 0:  # until sits at the window edge: no entry for it
                return None
            else:
                _e, _u, n, scalars = self._delta_log[stop - 1]
                scalars = dict(scalars)
            return ImageDelta(algo=self.image_algo, base_epoch=since_epoch,
                              epoch=until_epoch, n=n, scalars=scalars)
        merged: dict[str, dict[int, int]] = {}
        for _epoch, updates, _ev_n, _ev_scalars in self._delta_log[start:stop]:
            for name, edits in updates.items():
                merged.setdefault(name, {}).update(edits)
        _e, _u, n, scalars = self._delta_log[stop - 1]
        updates = {
            name: (np.fromiter(edits.keys(), dtype=np.int32, count=len(edits)),
                   np.fromiter(edits.values(), dtype=np.int64,
                               count=len(edits)).astype(np.int32))
            for name, edits in merged.items()
        }
        return ImageDelta(algo=self.image_algo, base_epoch=since_epoch,
                          epoch=until_epoch, n=n, updates=updates,
                          scalars=dict(scalars))

    # -- per-algorithm hooks -------------------------------------------------
    def _image_n(self) -> int:
        raise NotImplementedError

    def _image_scalars(self) -> dict[str, int]:
        return {}


@runtime_checkable
class ConsistentHash(Protocol):
    """Uniform algorithm API: host ops + a flat device image + epoch deltas."""

    name: str

    def lookup(self, key: int) -> int: ...

    def lookup_k(self, key: int, k: int) -> list[int]: ...

    def remove(self, b: int) -> None: ...

    def add(self) -> int: ...

    @property
    def size(self) -> int: ...

    @property
    def working(self) -> int: ...

    def working_set(self) -> set[int]: ...

    def memory_bytes(self) -> int: ...

    def device_image(self, capacity: int | None = None) -> DeviceImage: ...

    @property
    def epoch(self) -> int: ...

    def device_delta(self, since_epoch: int) -> ImageDelta | None: ...


def make_hash(algo: str, initial_node_count: int, *, capacity: int | None = None,
              variant: str = "64"):
    """Factory: algorithm name → ConsistentHash implementation (registry
    dispatch — see :data:`ALGORITHM_REGISTRY`).

    ``capacity`` only applies to the fixed-capacity baselines (Anchor/Dx);
    it defaults to the paper's a/w = 10 compromise.  ``variant="32"`` selects
    the TPU-native arithmetic that the device planes match bit-for-bit.
    """
    info = ALGORITHM_REGISTRY.get(algo)
    if info is None:
        raise ValueError(f"unknown algorithm {algo!r}")
    return info.factory(initial_node_count, capacity, variant)
