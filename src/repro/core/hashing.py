"""Shared hash primitives for the consistent-hashing control plane.

Two families are provided (see DESIGN.md §3 "Hardware adaptation"):

* 64-bit: paper-faithful (JumpHash's LCG, murmur-style fmix64).  Used by the
  host control plane and the paper-reproduction benchmarks.
* 32-bit: TPU-native (murmur3 fmix32 mixing).  The device data plane
  (``core/jax_lookup.py`` and ``kernels/``) uses *exactly* this arithmetic;
  the numpy implementations here are bit-identical so host and device agree.

All scalar functions take/return python ints; ``np_*`` variants are
vectorized over ``np.uint32`` arrays with wrap-around semantics.
"""
from __future__ import annotations

import numpy as np

MASK32 = 0xFFFFFFFF
MASK64 = 0xFFFFFFFFFFFFFFFF

# Knuth / murmur constants.
LCG_MULT = 2862933555777941757          # JumpHash's 64-bit LCG multiplier
GOLDEN32 = 0x9E3779B1
GOLDEN64 = 0x9E3779B97F4A7C15
_C1_32 = 0x85EBCA6B
_C2_32 = 0xC2B2AE35
_C1_64 = 0xFF51AFD7ED558CCD
_C2_64 = 0xC4CEB9FE1A85EC53


# ---------------------------------------------------------------------------
# Scalar (python int) versions — host control plane.
# ---------------------------------------------------------------------------

def fmix64(h: int) -> int:
    """Murmur3 64-bit finalizer: a high-quality uniform mixer."""
    h &= MASK64
    h ^= h >> 33
    h = (h * _C1_64) & MASK64
    h ^= h >> 33
    h = (h * _C2_64) & MASK64
    h ^= h >> 33
    return h


def fmix32(h: int) -> int:
    """Murmur3 32-bit finalizer."""
    h &= MASK32
    h ^= h >> 16
    h = (h * _C1_32) & MASK32
    h ^= h >> 13
    h = (h * _C2_32) & MASK32
    h ^= h >> 16
    return h


def hash2_64(key: int, seed: int) -> int:
    """Uniform hash of (key, seed) — the ``hash(key, b)`` of paper Alg. 4."""
    return fmix64((key & MASK64) ^ fmix64(seed * GOLDEN64 + 1))


def hash2_32(key: int, seed: int) -> int:
    """32-bit (key, seed) hash; bit-identical to the device plane."""
    return fmix32((key & MASK32) ^ fmix32((seed * GOLDEN32 + 1) & MASK32))


def key_to_u64(key) -> int:
    """Map an arbitrary key (int/str/bytes) to uint64."""
    if isinstance(key, (int, np.integer)):
        return int(key) & MASK64
    if isinstance(key, str):
        key = key.encode("utf-8")
    if isinstance(key, bytes):
        h = 0xCBF29CE484222325  # FNV-1a 64
        for byte in key:
            h = ((h ^ byte) * 0x100000001B3) & MASK64
        return h
    raise TypeError(f"unsupported key type: {type(key)!r}")


def key_to_u32(key) -> int:
    return fmix32(key_to_u64(key) & MASK32 ^ (key_to_u64(key) >> 32))


# ---------------------------------------------------------------------------
# Vectorized numpy versions — bit-identical to the jnp/Pallas data plane.
# ---------------------------------------------------------------------------

def np_fmix32(h: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):
        h = h.astype(np.uint32)
        h ^= h >> np.uint32(16)
        h = (h * np.uint32(_C1_32)).astype(np.uint32)
        h ^= h >> np.uint32(13)
        h = (h * np.uint32(_C2_32)).astype(np.uint32)
        h ^= h >> np.uint32(16)
    return h


def np_key_to_u32(keys: np.ndarray) -> np.ndarray:
    """Vectorized `key_to_u32` for integer keys (matches the scalar path)."""
    k = keys.astype(np.uint64)
    return np_fmix32(((k & np.uint64(MASK32)) ^ (k >> np.uint64(32))).astype(np.uint32))


def np_hash2_32(keys: np.ndarray, seed: np.ndarray | int) -> np.ndarray:
    seed = np.asarray(seed, dtype=np.uint32)
    with np.errstate(over="ignore"):
        s = np_fmix32(seed * np.uint32(GOLDEN32) + np.uint32(1))
        return np_fmix32(keys.astype(np.uint32) ^ s)
