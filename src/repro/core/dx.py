"""DxHash (Dong & Wang, 2021) — bit-array + pseudo-random probing.

Fixed overall capacity ``a``; a bit-array marks working buckets (Θ(a) bits).
Lookup draws the pseudo-random sequence ``hash(key, 0), hash(key, 1), ...``
mod ``a`` and returns the first *working* bucket — expected O(a/w) probes.

A removal stack provides the restore order for additions (the original keeps
an analogous free-slot structure; its size is counted in ``memory_bytes``).
"""
from __future__ import annotations

from .hashing import MASK64, hash2_64


class DxHash:
    name = "dx"

    _MAX_PROBE_FACTOR = 64  # cap = factor * ceil(a/w) probes, then fallback scan

    def __init__(self, capacity: int, initial_node_count: int):
        if not (0 < initial_node_count <= capacity):
            raise ValueError("need 0 < initial_node_count <= capacity")
        self.a = capacity
        self.N = initial_node_count
        self.active = bytearray([1] * initial_node_count + [0] * (capacity - initial_node_count))
        self.R: list[int] = list(range(capacity - 1, initial_node_count - 1, -1))

    def remove(self, b: int) -> None:
        if not (0 <= b < self.a) or not self.active[b]:
            raise ValueError(f"bucket {b} is not working")
        if self.N == 1:
            raise ValueError("cannot remove the last working bucket")
        self.active[b] = 0
        self.R.append(b)
        self.N -= 1

    def add(self) -> int:
        if not self.R:
            raise ValueError("DxHash capacity exhausted (fixed a)")
        b = self.R.pop()
        self.active[b] = 1
        self.N += 1
        return b

    def lookup(self, key: int) -> int:
        key &= MASK64
        a, active = self.a, self.active
        max_probes = self._MAX_PROBE_FACTOR * max(1, (a + self.N - 1) // self.N)
        for i in range(max_probes):
            b = hash2_64(key, i) % a
            if active[b]:
                return b
        for b in range(a):  # vanishing-probability fallback
            if active[b]:
                return b
        raise RuntimeError("no working bucket")

    @property
    def size(self) -> int:
        return self.a

    @property
    def working(self) -> int:
        return self.N

    def is_working(self, b: int) -> bool:
        return 0 <= b < self.a and bool(self.active[b])

    def working_set(self) -> set[int]:
        return {b for b in range(self.a) if self.active[b]}

    def memory_bytes(self) -> int:
        """Θ(a): the availability bit-array + the free-slot stack."""
        return (self.a + 7) // 8 + 4 * len(self.R) + 8
