"""DxHash (Dong & Wang, 2021) — bit-array + pseudo-random probing.

Fixed overall capacity ``a``; a bit-array marks working buckets (Θ(a) bits).
Lookup draws the pseudo-random sequence ``hash(key, 0), hash(key, 1), ...``
mod ``a`` and returns the first *working* bucket — expected O(a/w) probes.

A removal stack provides the restore order for additions (the original keeps
an analogous free-slot structure; its size is counted in ``memory_bytes``).
"""
from __future__ import annotations

import numpy as np

from .hashing import MASK32, MASK64, hash2_32, hash2_64
from .protocol import DeltaEmitter, DeviceImage, ReplicatedLookup, round_up


class DxHash(ReplicatedLookup, DeltaEmitter):
    name = "dx"

    _MAX_PROBE_FACTOR = 64  # cap = factor * ceil(a/w) probes, then fallback scan

    def __init__(self, capacity: int, initial_node_count: int, variant: str = "64"):
        if not (0 < initial_node_count <= capacity):
            raise ValueError("need 0 < initial_node_count <= capacity")
        if variant == "64":
            self._hash2, self._mask = hash2_64, MASK64
        elif variant == "32":
            # TPU-native arithmetic — bit-identical to the device data plane.
            self._hash2, self._mask = hash2_32, MASK32
        else:
            raise ValueError(f"unknown variant {variant!r}")
        self.variant = variant
        self.a = capacity
        self.N = initial_node_count
        self.active = bytearray([1] * initial_node_count + [0] * (capacity - initial_node_count))
        self.R: list[int] = list(range(capacity - 1, initial_node_count - 1, -1))
        self._fallback = 0  # first working bucket (bucket 0 starts active)
        self._init_delta_log()

    def _word(self, wi: int) -> int:
        """Re-pack bitmap word ``wi`` (bits b&31 of buckets 32wi…32wi+31)."""
        base = wi << 5
        return sum(self.active[j] << (j - base)
                   for j in range(base, min(base + 32, self.a)))

    def remove(self, b: int) -> None:
        if not (0 <= b < self.a) or not self.active[b]:
            raise ValueError(f"bucket {b} is not working")
        if self.N == 1:
            raise ValueError("cannot remove the last working bucket")
        self.active[b] = 0
        self.R.append(b)
        self.N -= 1
        if b == self._fallback:
            # b was the first working bucket ⇒ everything below is inactive:
            # resume the scan at b+1 (amortized O(a) over a whole drain)
            self._fallback = self.active.index(1, b + 1)
        self._record({"words": {b >> 5: self._word(b >> 5)}}, self.a,
                     self._image_scalars())

    def add(self) -> int:
        if not self.R:
            raise ValueError("DxHash capacity exhausted (fixed a)")
        b = self.R.pop()
        self.active[b] = 1
        self.N += 1
        self._fallback = min(self._fallback, b)
        self._record({"words": {b >> 5: self._word(b >> 5)}}, self.a,
                     self._image_scalars())
        return b

    def _image_n(self) -> int:
        return self.a

    def _image_scalars(self) -> dict[str, int]:
        return {"max_probes": self.max_probes(), "fallback": self._fallback}

    def max_probes(self) -> int:
        """Probe bound before the first-working fallback: 64·⌈a/w⌉."""
        return self._MAX_PROBE_FACTOR * max(1, (self.a + self.N - 1) // self.N)

    def lookup(self, key: int) -> int:
        key &= self._mask
        a, active = self.a, self.active
        for i in range(self.max_probes()):
            b = self._hash2(key, i) % a
            if active[b]:
                return b
        for b in range(a):  # vanishing-probability fallback
            if active[b]:
                return b
        raise RuntimeError("no working bucket")

    # convenience for tests/benchmarks (mirrors MementoHash.lookup_trace)
    def lookup_trace(self, key: int) -> tuple[int, int, int]:
        """Lookup returning (bucket, probes_past_first, 0) — Dx's cost is
        its geometric probe count, reported in the external slot."""
        key &= self._mask
        a, active = self.a, self.active
        for i in range(self.max_probes()):
            b = self._hash2(key, i) % a
            if active[b]:
                return b, i, 0
        return self.lookup(key), self.max_probes(), 0

    def device_image(self, capacity: int | None = None) -> DeviceImage:
        """Packed active bitmap (bucket b ↔ bit b&31 of word b>>5) plus the
        dynamic probe bound and the maintained first-working ``fallback``
        bucket — the same first-working scan result the host lookup uses
        (DESIGN.md §3.3).  ``capacity`` is accepted for protocol uniformity
        but the overall capacity ``a`` is fixed."""
        bits = np.frombuffer(bytes(self.active), dtype=np.uint8).astype(np.uint32)
        words = np.zeros((round_up(-(-self.a // 32)),), dtype=np.uint32)
        idx = np.arange(self.a, dtype=np.uint64)
        shifted = (bits.astype(np.uint64) << (idx & np.uint64(31))).astype(np.uint32)
        np.bitwise_or.at(words, (idx >> np.uint64(5)).astype(np.int64), shifted)
        return DeviceImage(
            algo=self.name, n=self.a, arrays={"words": words},
            scalars=self._image_scalars(), epoch=self._epoch,
        )

    @property
    def size(self) -> int:
        return self.a

    @property
    def working(self) -> int:
        return self.N

    def is_working(self, b: int) -> bool:
        return 0 <= b < self.a and bool(self.active[b])

    def working_set(self) -> set[int]:
        return {b for b in range(self.a) if self.active[b]}

    def memory_bytes(self) -> int:
        """Θ(a): the availability bit-array + the free-slot stack."""
        return (self.a + 7) // 8 + 4 * len(self.R) + 8
