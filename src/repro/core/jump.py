"""JumpHash (Lamping & Veach, 2014) — the paper's core engine.

Two variants (DESIGN.md §3):

* ``jump64``: the paper-faithful 64-bit LCG implementation (the exact
  pseudo-code from arXiv:1406.2294).
* ``jump32``: the TPU-native variant.  Each step's uniform variate comes from
  a murmur3-mixed (key, step) hash and the divide runs in float32, matching
  the device data plane bit-for-bit (numpy f32 and XLA f32 divisions are both
  IEEE correctly-rounded, so host and device agree exactly).
"""
from __future__ import annotations

import numpy as np

from .hashing import GOLDEN32, LCG_MULT, MASK32, MASK64, np_fmix32, fmix32
from .protocol import DeltaEmitter, DeviceImage, ReplicatedLookup


def jump64(key: int, num_buckets: int) -> int:
    """Faithful JumpHash: O(ln n), stateless, no memory access."""
    if num_buckets <= 0:
        raise ValueError("num_buckets must be positive")
    key &= MASK64
    b, j = -1, 0
    while j < num_buckets:
        b = j
        key = (key * LCG_MULT + 1) & MASK64
        j = int(float(b + 1) * (float(1 << 31) / float((key >> 33) + 1)))
    return b


def jump32(key: int, num_buckets: int) -> int:
    """TPU-native JumpHash variant (scalar reference; see np_jump32)."""
    out = np_jump32(np.asarray([key & MASK32], dtype=np.uint32), num_buckets)
    return int(out[0])


def _step_u24(keys: np.ndarray, step: int | np.ndarray) -> np.ndarray:
    """Per-(key, step) uniform 24-bit variate (exactly representable in f32)."""
    step = np.asarray(step, dtype=np.uint32)
    with np.errstate(over="ignore"):
        h = np_fmix32(keys ^ (step * np.uint32(GOLDEN32) + np.uint32(0x2545F491)))
    return (h >> np.uint32(8)).astype(np.uint32)


def np_jump32(keys: np.ndarray, num_buckets: int) -> np.ndarray:
    """Vectorized TPU-native jump over a uint32 key array.

    State machine identical to jump64's: ``b ← j; j ← floor((b+1)/r)`` with
    ``r`` uniform in (0, 1], iterated while ``j < n``.  ``r`` is quantized to
    24 bits so every intermediate is exact in f32.
    """
    if num_buckets <= 0:
        raise ValueError("num_buckets must be positive")
    keys = keys.astype(np.uint32)
    n = np.float32(num_buckets)
    b = np.zeros(keys.shape, dtype=np.int32)
    j = np.zeros(keys.shape, dtype=np.float32)
    i = 0
    active = j < n
    while active.any():
        b = np.where(active, j.astype(np.int32), b)
        u = _step_u24(keys, i)
        r = (u.astype(np.float32) + np.float32(1.0)) * np.float32(2.0 ** -24)
        jn = np.float32(1.0) * (b.astype(np.float32) + np.float32(1.0)) / r
        jn = np.minimum(np.floor(jn), n)  # clamp: anything ≥ n terminates
        j = np.where(active, jn, j)
        active = j < n
        i += 1
        if i > 256:  # 24-bit r ⇒ ≤ ~2^24 expansion/step; unreachable in practice
            raise RuntimeError("jump32 failed to terminate")
    return b


class JumpHash(ReplicatedLookup, DeltaEmitter):
    """Stateful wrapper exposing the uniform engine API (LIFO-only resizes)."""

    name = "jump"

    def __init__(self, initial_node_count: int, variant: str = "64"):
        if initial_node_count <= 0:
            raise ValueError("initial_node_count must be positive")
        if variant == "64":
            self._fn = jump64
        elif variant == "32":
            self._fn = jump32
        else:
            raise ValueError(f"unknown variant {variant!r}")
        self.variant = variant
        self.n = initial_node_count
        self._init_delta_log()

    def lookup(self, key: int) -> int:
        return self._fn(key, self.n)

    # convenience for tests/benchmarks (mirrors MementoHash.lookup_trace)
    def lookup_trace(self, key: int) -> tuple[int, int, int]:
        """Jump has no replacement walk: the jump chain is internal to
        ``jump32``/``jump64``, so the step counts are reported as 0."""
        return self.lookup(key), 0, 0

    def add(self) -> int:
        self.n += 1
        self._record({}, self.n)  # the whole delta is the new n
        return self.n - 1

    def remove(self, b: int) -> None:
        if b != self.n - 1:
            raise ValueError("JumpHash only supports LIFO removals")
        if self.n == 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        self._record({}, self.n)

    def _image_n(self) -> int:
        return self.n

    @property
    def size(self) -> int:
        return self.n

    @property
    def working(self) -> int:
        return self.n

    def working_set(self) -> set[int]:
        return set(range(self.n))

    def memory_bytes(self) -> int:
        return 8  # a single counter

    def device_image(self, capacity: int | None = None) -> DeviceImage:
        """Stateless: the image is just the dynamic n (lookup = jump32)."""
        return DeviceImage(algo=self.name, n=self.n, epoch=self._epoch)
