"""Core of the reproduction: the MementoHash consistent-hashing family.

Host control plane (paper-faithful):
  * :class:`MementoHash`  — the paper's contribution (Algs. 1-4, Θ(r) state)
  * :class:`JumpHash`     — the stateless core engine (LIFO-only)
  * :class:`AnchorHash`   — fixed-capacity baseline (in-place, Θ(a))
  * :class:`DxHash`       — fixed-capacity baseline (bit-array, Θ(a))

Device data plane:
  * :class:`MementoTables` — dense int32 image of a Memento state
  * :mod:`repro.core.jax_lookup` — batched jnp lookup (oracle for kernels/)
"""
from .anchor import AnchorHash
from .dx import DxHash
from .jump import JumpHash, jump32, jump64, np_jump32
from .memento import MementoHash, random_state
from .tables import MementoTables, tables_from_state

__all__ = [
    "AnchorHash",
    "DxHash",
    "JumpHash",
    "MementoHash",
    "MementoTables",
    "jump32",
    "jump64",
    "np_jump32",
    "random_state",
    "tables_from_state",
]
