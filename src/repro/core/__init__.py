"""Core of the reproduction: the MementoHash consistent-hashing family.

Host control plane (paper-faithful):
  * :class:`MementoHash`  — the paper's contribution (Algs. 1-4, Θ(r) state)
  * :class:`JumpHash`     — the stateless core engine (LIFO-only)
  * :class:`AnchorHash`   — fixed-capacity baseline (in-place, Θ(a))
  * :class:`DxHash`       — fixed-capacity baseline (bit-array, Θ(a))
  * :class:`PowerHash`    — O(1)-expected successor baseline (LIFO-only,
    no fixed capacity; Leu 2023, arXiv 2307.12448)

All five implement the :class:`ConsistentHash` protocol (host ops +
``device_image()``) and are registered in :data:`ALGORITHM_REGISTRY` —
the ONE list every dispatch site (engine ops, wire ids, sim drivers,
benchmarks, conformance tests) derives from; :func:`make_hash` is the
name → implementation factory and :data:`ALGORITHMS` the ordered names.

Device data plane:
  * :class:`DeviceImage`   — flat per-algorithm int32/uint32 device arrays
  * :class:`MementoTables` — incrementally-mirrored dense Memento image
  * :mod:`repro.core.jax_lookup` — batched jnp lookups (oracle for kernels/)

Device control plane (epochs & deltas, DESIGN.md §3.5):
  * :class:`ImageDelta`       — O(changed-words) epoch-advancing edit
  * :func:`apply_delta`       — host (numpy) reference apply
  * :class:`DeviceImageStore` — double-buffered on-device images + sync()
"""
from .anchor import AnchorHash
from .bounded import BoundedLoad, BoundedLoadMemento
from .dx import DxHash
from .image_store import DeviceImageStore, SyncHandle, SyncStats
from .jump import JumpHash, jump32, jump64, np_jump32
from .memento import MementoHash, random_state
from .power import PowerHash, power32, power64
from .protocol import (ALGORITHM_REGISTRY, ALGORITHMS, REPLICA_SALT_CAP,
                       AlgoInfo, ConsistentHash, DeviceImage, ImageDelta,
                       ReplicatedLookup, apply_delta, image_fingerprint,
                       make_hash, replica_sets)
from .tables import MementoTables, tables_from_state

__all__ = [
    "ALGORITHMS",
    "ALGORITHM_REGISTRY",
    "AlgoInfo",
    "AnchorHash",
    "BoundedLoad",
    "BoundedLoadMemento",
    "ConsistentHash",
    "DeviceImage",
    "DeviceImageStore",
    "DxHash",
    "ImageDelta",
    "JumpHash",
    "MementoHash",
    "MementoTables",
    "PowerHash",
    "REPLICA_SALT_CAP",
    "ReplicatedLookup",
    "SyncHandle",
    "SyncStats",
    "apply_delta",
    "image_fingerprint",
    "jump32",
    "jump64",
    "make_hash",
    "np_jump32",
    "power32",
    "power64",
    "random_state",
    "replica_sets",
    "tables_from_state",
]
