"""Core of the reproduction: the MementoHash consistent-hashing family.

Host control plane (paper-faithful):
  * :class:`MementoHash`  — the paper's contribution (Algs. 1-4, Θ(r) state)
  * :class:`JumpHash`     — the stateless core engine (LIFO-only)
  * :class:`AnchorHash`   — fixed-capacity baseline (in-place, Θ(a))
  * :class:`DxHash`       — fixed-capacity baseline (bit-array, Θ(a))

All four implement the :class:`ConsistentHash` protocol (host ops +
``device_image()``); :func:`make_hash` is the name → implementation factory.

Device data plane:
  * :class:`DeviceImage`   — flat per-algorithm int32/uint32 device arrays
  * :class:`MementoTables` — incrementally-mirrored dense Memento image
  * :mod:`repro.core.jax_lookup` — batched jnp lookups (oracle for kernels/)

Device control plane (epochs & deltas, DESIGN.md §3.5):
  * :class:`ImageDelta`       — O(changed-words) epoch-advancing edit
  * :func:`apply_delta`       — host (numpy) reference apply
  * :class:`DeviceImageStore` — double-buffered on-device images + sync()
"""
from .anchor import AnchorHash
from .bounded import BoundedLoad, BoundedLoadMemento
from .dx import DxHash
from .image_store import DeviceImageStore, SyncHandle, SyncStats
from .jump import JumpHash, jump32, jump64, np_jump32
from .memento import MementoHash, random_state
from .protocol import (REPLICA_SALT_CAP, ConsistentHash, DeviceImage,
                       ImageDelta, ReplicatedLookup, apply_delta,
                       image_fingerprint, make_hash, replica_sets)
from .tables import MementoTables, tables_from_state

__all__ = [
    "AnchorHash",
    "BoundedLoad",
    "BoundedLoadMemento",
    "ConsistentHash",
    "DeviceImage",
    "DeviceImageStore",
    "DxHash",
    "ImageDelta",
    "JumpHash",
    "MementoHash",
    "MementoTables",
    "REPLICA_SALT_CAP",
    "ReplicatedLookup",
    "SyncHandle",
    "SyncStats",
    "apply_delta",
    "image_fingerprint",
    "jump32",
    "jump64",
    "make_hash",
    "np_jump32",
    "random_state",
    "replica_sets",
    "tables_from_state",
]
