"""Survey extras: the other consistent-hashing algorithms from the authors'
comparison papers [11][12] — Ring (Karger), Rendezvous (HRW), Maglev, and
Multi-probe.  Useful as additional baselines in benchmarks and to sanity-check
Memento's placement quality against the full literature.

Sources (see PAPERS.md, "Cited by the code"): Karger et al., STOC 1997
(RingHash); Thaler & Ravishankar, ToN 1998 (RendezvousHash); Eisenbud et
al., NSDI 2016 (MaglevHash); Appleton & O'Reilly, arXiv:1505.00062
(MultiProbeHash).
"""
from __future__ import annotations

import bisect

from .hashing import MASK64, fmix64, hash2_64


class RingHash:
    """Karger consistent-hashing ring with virtual nodes."""

    name = "ring"

    def __init__(self, initial_node_count: int, vnodes: int = 100):
        self.vnodes = vnodes
        self.points: list[tuple[int, int]] = []  # (hash, bucket) sorted
        self.n = 0
        self._removed: list[int] = []
        for _ in range(initial_node_count):
            self.add()

    def _bucket_points(self, b: int) -> list[tuple[int, int]]:
        return [(hash2_64(b, v), b) for v in range(self.vnodes)]

    def add(self) -> int:
        b = self._removed.pop() if self._removed else self.n
        if b == self.n:
            self.n += 1
        for pt in self._bucket_points(b):
            bisect.insort(self.points, pt)
        return b

    def remove(self, b: int) -> None:
        pts = set(self._bucket_points(b))
        before = len(self.points)
        self.points = [p for p in self.points if p not in pts]
        if len(self.points) == before:
            raise ValueError(f"bucket {b} not present")
        if len(self.points) == 0:
            raise ValueError("cannot remove the last bucket")
        self._removed.append(b)

    def lookup(self, key: int) -> int:
        h = fmix64(key & MASK64)
        i = bisect.bisect_right(self.points, (h, 1 << 62))
        return self.points[i % len(self.points)][1]

    def working_set(self) -> set[int]:
        return {b for _, b in self.points}

    @property
    def working(self) -> int:
        return len(self.working_set())

    def memory_bytes(self) -> int:
        return 12 * len(self.points)


class RendezvousHash:
    """Highest-random-weight (Thaler & Ravishankar): O(w) lookup, Θ(w) state."""

    name = "rendezvous"

    def __init__(self, initial_node_count: int):
        self.buckets = set(range(initial_node_count))
        self._next = initial_node_count

    def add(self) -> int:
        b = self._next
        self._next += 1
        self.buckets.add(b)
        return b

    def remove(self, b: int) -> None:
        if b not in self.buckets:
            raise ValueError(f"bucket {b} not present")
        if len(self.buckets) == 1:
            raise ValueError("cannot remove the last bucket")
        self.buckets.discard(b)

    def lookup(self, key: int) -> int:
        return max(self.buckets, key=lambda b: hash2_64(key, b))

    def working_set(self) -> set[int]:
        return set(self.buckets)

    @property
    def working(self) -> int:
        return len(self.buckets)

    def memory_bytes(self) -> int:
        return 4 * len(self.buckets)


class MaglevHash:
    """Maglev (Eisenbud et al.): O(1) lookup via a permutation-filled table;
    table rebuild on membership change (Θ(M) with M ≳ 100·n)."""

    name = "maglev"

    def __init__(self, initial_node_count: int, table_size: int = 65537):
        self.M = table_size  # prime
        self.buckets = list(range(initial_node_count))
        self._next = initial_node_count
        self._build()

    def _build(self) -> None:
        if not self.buckets:
            raise ValueError("empty cluster")
        M = self.M
        offsets = {b: hash2_64(b, 0xA) % M for b in self.buckets}
        skips = {b: hash2_64(b, 0xB) % (M - 1) + 1 for b in self.buckets}
        table = [-1] * M
        nexts = {b: 0 for b in self.buckets}
        filled = 0
        while filled < M:
            for b in self.buckets:
                while True:
                    c = (offsets[b] + nexts[b] * skips[b]) % M
                    nexts[b] += 1
                    if table[c] < 0:
                        table[c] = b
                        filled += 1
                        break
                if filled == M:
                    break
        self.table = table

    def add(self) -> int:
        b = self._next
        self._next += 1
        self.buckets.append(b)
        self._build()
        return b

    def remove(self, b: int) -> None:
        if b not in self.buckets or len(self.buckets) == 1:
            raise ValueError(f"cannot remove {b}")
        self.buckets.remove(b)
        self._build()

    def lookup(self, key: int) -> int:
        return self.table[fmix64(key & MASK64) % self.M]

    def working_set(self) -> set[int]:
        return set(self.buckets)

    @property
    def working(self) -> int:
        return len(self.buckets)

    def memory_bytes(self) -> int:
        return 4 * self.M + 4 * len(self.buckets)


class MultiProbeHash:
    """Multi-probe consistent hashing (Appleton & O'Reilly): one point per
    node, k probes per key, closest-successor wins — Θ(w) state, O(k·log w)
    lookup, balance improves with k."""

    name = "multiprobe"

    def __init__(self, initial_node_count: int, probes: int = 21):
        self.k = probes
        self.points: list[tuple[int, int]] = []
        self.n = 0
        self._removed: list[int] = []
        for _ in range(initial_node_count):
            self.add()

    def add(self) -> int:
        b = self._removed.pop() if self._removed else self.n
        if b == self.n:
            self.n += 1
        bisect.insort(self.points, (hash2_64(b, 0xC), b))
        return b

    def remove(self, b: int) -> None:
        pt = (hash2_64(b, 0xC), b)
        if pt not in self.points or len(self.points) == 1:
            raise ValueError(f"cannot remove {b}")
        self.points.remove(pt)
        self._removed.append(b)

    def lookup(self, key: int) -> int:
        best = None
        for i in range(self.k):
            h = hash2_64(key, i)
            j = bisect.bisect_right(self.points, (h, 1 << 62))
            ph, pb = self.points[j % len(self.points)]
            dist = (ph - h) % (1 << 64)
            if best is None or dist < best[0]:
                best = (dist, pb)
        return best[1]

    def working_set(self) -> set[int]:
        return {b for _, b in self.points}

    @property
    def working(self) -> int:
        return len(self.points)

    def memory_bytes(self) -> int:
        return 12 * len(self.points)
