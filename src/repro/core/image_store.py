"""DeviceImageStore — epoch-versioned, double-buffered on-device images.

The device side of the incremental control plane (DESIGN.md §3.5).  A store
wraps one :class:`~repro.core.protocol.ConsistentHash` host state and keeps
its :class:`~repro.core.protocol.DeviceImage` resident on device:

  * **stable shapes** — arrays are allocated 128-padded with headroom
    (``headroom×`` the initial size for the growable algorithms), so churn
    edits never reshape device buffers; ``n`` travels as a dynamic scalar;
  * **delta application** — ``sync()`` drains the host's
    ``device_delta(epoch)`` and applies it as an O(changed-words) scatter
    (functional jnp ``.at[].set`` or the Pallas apply-delta kernel,
    ``kernels/delta_apply.py``) instead of re-transferring an O(n)
    snapshot;
  * **double-buffered epochs** — applying never mutates the serving
    buffers: the epoch-N image stays valid (and keeps answering bulk
    lookups) while epoch N+1 is materialized, then the store flips
    atomically (a python reference swap).  ``image()`` is the current
    front; ``previous_image()`` is the retained epoch the migration-diff
    kernel compares against.

Snapshot rebuilds still happen — but only when they must: when the host's
bounded delta log no longer covers the store's epoch, or when Memento/Jump
growth outruns the padded capacity (rebuilt with doubled headroom, so the
amortized cost stays O(1) per event).  ``last_sync``/``totals`` expose
which path ran and how many 32-bit words crossed host→device — the numbers
the churn benchmark reports.

Epoch advancement comes in two flavours (DESIGN.md §9.1):

  * ``sync()``        — prepare + flip in one call (the classic path);
  * ``sync_async()``  — dispatch the delta-apply scatter and return a
    :class:`SyncHandle` WITHOUT flipping.  The front image keeps serving
    epoch N the whole time the device materializes N+1; ``handle.commit()``
    (or the store's ``poll()``/``flush()``) performs the deferred atomic
    flip, so delta-apply latency hides behind lookup work instead of
    adding to it.  One handle may be in flight at a time; starting another
    sync first commits the pending one, so epochs stay linear.

The store is overlay-agnostic: a bounded-load state (DESIGN.md §4.2)
simply adds a bucket-indexed ``load`` word array to its image, and load
changes ride the same delta path (``_fits`` sizes it to the bucket-id
space).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.obs.metrics import default_registry as _default_obs
from .protocol import (ALGORITHM_REGISTRY, ConsistentHash, DeviceImage,
                       ImageDelta, required_lengths, round_up)


def delta_fits(caps: dict[str, int], delta: ImageDelta, *,
               compact: bool = False) -> bool:
    """Do buffers of the given per-array lengths absorb ``delta``?

    The ONE capacity rule shared by the store's delta-vs-snapshot decision
    and the replication publisher's cursor (``launch/replicate.py``):
    ``caps`` maps array name → allocated (or wire-announced) length, and
    the delta fits iff every array a lookup at ``delta.n`` may gather from
    is long enough.  ``compact`` switches Memento to its packed bitmap rule
    (32 buckets per ``state`` word); the bounded-load ``load`` overlay is
    bucket-indexed regardless of layout.  Keeping leader store and
    publisher on the same predicate is what lets the publisher decide
    snapshot-vs-delta for every follower at once (the leader-decides
    invariant, DESIGN.md §9.3).
    """
    if compact and delta.algo == "memento":
        # the bitmap is the bucket-indexed array: 32 buckets per word.
        needed = {"state": -(-delta.n // 32)}
    else:
        needed = dict(required_lengths(delta.algo, delta.n))
    if "load" in caps:  # bounded-load overlay: load words are bucket-indexed
        needed["load"] = delta.n
    return all(caps.get(name, 0) >= need for name, need in needed.items())


@dataclass
class SyncStats:
    """What one ``sync()`` did."""

    mode: str            # "noop" | "delta" | "snapshot"
    events: int          # membership events covered
    words: int           # 32-bit words transferred host→device
    epoch: int           # store epoch after the sync


@dataclass
class SyncTotals:
    syncs: int = 0
    delta_applies: int = 0
    snapshot_rebuilds: int = 0
    events: int = 0
    words: int = 0


class SyncHandle:
    """One in-flight ``sync_async()``: epoch N+1 materializing off the hot path.

    The handle owns the not-yet-front image whose scatter (or snapshot
    transfer) has been *dispatched* but whose epoch flip is deferred.  The
    store keeps serving the old front the whole time; nothing observable
    changes until ``commit()`` (blocking) or ``poll()`` (non-blocking,
    flips only if the device result is ready) lands the flip.  Handles are
    idempotent — ``commit()`` after the flip just returns the stats — and
    the flip itself happens under the store's lock, so concurrent lookup
    threads always observe either the complete old epoch or the complete
    new one, never a torn mix.
    """

    def __init__(self, store: "DeviceImageStore", stats: SyncStats,
                 new_front: DeviceImage | None,
                 new_mirror: dict | None = None):
        self._store = store
        self._stats = stats
        self._new = new_front           # None → noop: nothing to flip
        self._new_mirror = new_mirror
        self._done = new_front is None
        if self._done:
            store._account(stats)

    @property
    def done(self) -> bool:  # obs-exempt: pure accessor
        return self._done

    @property
    def stats(self) -> SyncStats:  # obs-exempt: pure accessor
        """Target-epoch stats (valid before and after the flip)."""
        return self._stats

    def ready(self) -> bool:
        """True iff every dispatched device buffer has materialized.


        Non-blocking: uses ``jax.Array.is_ready()``.  Arrays without the
        probe (plain numpy in interpret paths) count as ready.
        """
        # obs-exempt: readiness probe only, no device dispatch
        if self._done:
            return True
        return all(v.is_ready() for v in self._new.arrays.values()
                   if hasattr(v, "is_ready"))

    def poll(self) -> bool:
        """Flip iff the device result is ready; never blocks.  Returns
        whether the handle is done (flipped or was a noop)."""
        # obs-exempt: delegates to commit(), which records the flip
        if not self._done and self.ready():
            self.commit()
        return self._done

    def commit(self) -> SyncStats:
        """Block until epoch N+1 is materialized, then flip atomically."""
        with self._store._lock:
            if self._done:
                return self._stats
            reg = self._store._obs()
            with reg.span("store.sync.commit", epoch=self._stats.epoch):
                with reg.span("store.sync.materialize"):
                    for v in self._new.arrays.values():
                        if hasattr(v, "block_until_ready"):
                            v.block_until_ready()
                with reg.span("store.sync.flip", epoch=self._stats.epoch):
                    self._store._flip(self._new, self._new_mirror,
                                      self._stats)
            self._done = True
            if self._store._pending is self:
                self._store._pending = None
            reg.gauge("store.pending").set(0)
        return self._stats


class DeviceImageStore:
    """Double-buffered device image of a ConsistentHash, updated by deltas."""

    def __init__(self, ch: ConsistentHash, *, plane: str = "jnp",
                 headroom: int = 2, interpret: bool | None = None,
                 compact: bool = False, registry=None):
        if plane not in ("jnp", "pallas"):
            raise ValueError(f"unknown plane {plane!r}")
        self._ch = ch
        self._registry = registry  # None → follow the process default
        self.plane = plane
        self.headroom = max(1, headroom)
        self.compact = compact
        self._mirror: dict | None = None  # host copy of the packed arrays
        if interpret is None:
            import jax
            interpret = jax.default_backend() != "tpu"
        self._interpret = interpret
        self.totals = SyncTotals()
        self.last_sync: SyncStats | None = None
        self._prev: DeviceImage | None = None
        self._lock = threading.RLock()
        self._pending: SyncHandle | None = None
        self._rebuild()

    def _obs(self):
        """The live telemetry registry (DESIGN.md §11): the injected one,
        else whatever the process default currently is — so ``enable()``
        after construction still reaches existing stores."""
        return self._registry or _default_obs()

    # -- buffers ---------------------------------------------------------------
    def _snapshot(self) -> tuple[DeviceImage, dict | None]:
        """Build (dispatch, don't install) a full snapshot image + mirror."""
        import jax.numpy as jnp

        algo = getattr(self._ch, "image_algo", self._ch.name)
        if not ALGORITHM_REGISTRY[algo].fixed_capacity:  # growth: headroom
            cap = round_up(max(self.headroom * self._image_size_hint(), 128))
        else:  # fixed overall capacity a: padding beyond a is never read
            cap = None
        img = self._ch.device_image(capacity=cap)
        mirror = None
        if self.compact:
            from .packing import pack_image

            # slot headroom 2 → ≤ 0.25 load factor at rebuild, so epoch
            # deltas insert in place; the numpy mirror is the host copy
            # packed_delta_updates edits to derive device scatters.
            img = pack_image(img, slot_headroom=2)
            mirror = {k: np.array(v) for k, v in img.arrays.items()}
        front = DeviceImage(
            algo=img.algo, n=img.n,
            arrays={k: jnp.asarray(v) for k, v in img.arrays.items()},
            scalars=dict(img.scalars), epoch=img.epoch,
            packed=img.packed)
        return front, mirror

    def _rebuild(self) -> None:
        """Full snapshot upload (init, log overflow, or capacity growth)."""
        self._front, self._mirror = self._snapshot()

    def _image_size_hint(self) -> int:
        return self._ch.size

    @property
    def epoch(self) -> int:  # obs-exempt: pure accessor
        return self._front.epoch

    @property
    def capacity(self) -> dict[str, int]:  # obs-exempt: pure accessor
        return {k: int(v.shape[0]) for k, v in self._front.arrays.items()}

    def image(self) -> DeviceImage:  # obs-exempt: pure accessor
        """The serving (front) image.  Immutable: syncs replace, never edit."""
        return self._front

    def previous_image(self) -> DeviceImage | None:  # obs-exempt: pure accessor
        """The retained pre-sync epoch (migration-diff comparand), if any."""
        return self._prev

    # -- epoch advancement -----------------------------------------------------
    def sync(self) -> SyncStats:
        """Advance the device image to the host's current epoch.

        Applies an O(changed-words) delta when the host log covers our
        epoch and capacity suffices; falls back to a full snapshot rebuild
        otherwise.  Either way the old front buffer is retained as
        ``previous_image()`` and the flip is atomic.  Any pending async
        epoch is committed first, so epochs stay linear.
        """
        reg = self._obs()
        t0 = time.perf_counter_ns() if reg.active else 0
        with reg.span("store.sync", mode="block"):
            self.flush()
            with reg.span("store.sync.dispatch"):
                new, mirror, stats = self._prepare()
            with self._lock:
                if new is not None:
                    with reg.span("store.sync.flip", epoch=stats.epoch):
                        self._flip(new, mirror, stats)
                else:
                    self._account(stats)
        if reg.active:
            reg.histogram("store.sync.us", mode=stats.mode).observe(
                (time.perf_counter_ns() - t0) / 1e3)
        return stats

    def sync_async(self) -> SyncHandle:
        """Dispatch epoch N+1 (delta scatter or snapshot transfer) without
        flipping and without blocking on the device result.

        The front image keeps serving epoch N until the returned
        :class:`SyncHandle` is committed — by ``handle.commit()``, the
        store's ``poll()``/``flush()``, or implicitly by the next
        ``sync``/``sync_async`` call (one handle in flight at a time, so
        epochs remain linear).  Lookups issued meanwhile are epoch-N
        consistent; lookups after the commit are epoch-N+1 consistent.
        """
        reg = self._obs()
        with reg.span("store.sync.dispatch", mode="overlap"):
            self.flush()
            new, mirror, stats = self._prepare()
        handle = SyncHandle(self, stats, new, mirror)
        if not handle.done:
            self._pending = handle
            reg.gauge("store.pending").set(1)
        return handle

    def poll(self) -> bool:
        """Commit the pending async epoch iff its device result is ready
        (never blocks).  True when no flip remains outstanding."""
        # obs-exempt: delegates to SyncHandle.commit (instrumented)
        h = self._pending
        return h.poll() if h is not None else True

    def flush(self) -> SyncStats | None:
        """Commit the pending async epoch, blocking if needed."""
        # obs-exempt: delegates to SyncHandle.commit (instrumented)
        h = self._pending
        return h.commit() if h is not None else None

    @property
    def pending(self) -> SyncHandle | None:  # obs-exempt: pure accessor
        """The in-flight ``sync_async`` handle, if any."""
        return self._pending

    def _prepare(self) -> tuple[DeviceImage | None, dict | None, SyncStats]:
        """Drain the host delta and dispatch (but do not install) the
        next-epoch image.  Returns ``(new_front, new_mirror, stats)``;
        ``new_front is None`` means nothing to flip (noop)."""
        delta = self._drain_delta()
        applied = None
        if delta is not None and delta.events == 0:
            return None, None, SyncStats("noop", 0, 0, self.epoch)
        if delta is not None and self._fits(delta) and (
                applied := (self._apply_packed(delta) if self.compact
                            else (self._apply(delta), delta.num_words()))
        ) is not None:
            new, words = applied
            return new, self._mirror, SyncStats("delta", delta.events, words,
                                                new.epoch)
        events = getattr(self._ch, "epoch", self._front.epoch) - self._front.epoch
        new, mirror = self._snapshot()
        words = sum(int(v.size) for v in new.arrays.values()) + 1
        return new, mirror, SyncStats("snapshot", events, words, new.epoch)

    def _flip(self, new: DeviceImage, mirror: dict | None,
              stats: SyncStats) -> None:
        """Atomically install epoch N+1 (caller holds ``_lock``)."""
        old = self._front
        self._front = new
        self._mirror = mirror
        self._prev = old
        self._account(stats)

    def _account(self, stats: SyncStats) -> None:
        if stats.mode == "delta":
            self.totals.delta_applies += 1
        elif stats.mode == "snapshot":
            self.totals.snapshot_rebuilds += 1
        self.totals.syncs += 1
        self.totals.events += stats.events
        self.totals.words += stats.words
        self.last_sync = stats
        reg = self._obs()
        if reg.active:  # mirror SyncTotals onto the registry (one source
            reg.counter("store.syncs").inc()  # of counters for exporters)
            reg.counter("store.sync_events").inc(stats.events)
            if stats.mode == "delta":
                reg.counter("store.delta_applies").inc()
                reg.counter("store.delta_words").inc(stats.words)
            elif stats.mode == "snapshot":
                reg.counter("store.snapshot_rebuilds").inc()
                reg.counter("store.snapshot_words").inc(stats.words)
            reg.sink.emit("sync", mode=stats.mode, events=stats.events,
                          words=stats.words, epoch=stats.epoch)

    def _drain_delta(self) -> ImageDelta | None:
        ch = self._ch
        if not hasattr(ch, "device_delta"):
            return None  # non-emitting implementation: snapshots only
        return ch.device_delta(self._front.epoch)

    def _fits(self, delta: ImageDelta) -> bool:
        return delta_fits(self.capacity, delta, compact=self.compact)

    def _apply(self, delta: ImageDelta) -> DeviceImage:
        from repro.kernels.delta_apply import apply_updates

        arrays = apply_updates(self._front.arrays, delta.updates,
                               plane=self.plane, interpret=self._interpret)
        return DeviceImage(algo=delta.algo, n=delta.n, arrays=arrays,
                           scalars=dict(delta.scalars), epoch=delta.epoch)

    def _apply_packed(self, delta: ImageDelta) -> tuple[DeviceImage, int] | None:
        """Translate a dense-layout delta into packed-layout scatters and
        apply them, or return ``None`` (→ snapshot rebuild) when the packed
        buffers cannot absorb it (bitmap outgrown, slots saturated, or a
        value overflows a narrowed dtype)."""
        from .packing import packed_delta_updates
        from repro.kernels.delta_apply import scatter_update

        updates = packed_delta_updates(self._mirror, delta)
        if updates is None:
            return None
        arrays = dict(self._front.arrays)
        words = 0
        for name, (idx, vals) in updates.items():
            if not len(idx):
                continue
            arrays[name] = scatter_update(arrays[name], idx, vals,
                                          plane=self.plane,
                                          interpret=self._interpret)
            words += 2 * len(idx)
        img = DeviceImage(algo=delta.algo, n=delta.n, arrays=arrays,
                          scalars=dict(delta.scalars), epoch=delta.epoch,
                          packed=True)
        return img, words

    # -- data plane ------------------------------------------------------------
    def lookup(self, keys, *, plane: str | None = None, k: int = 1,
               **kw) -> np.ndarray:
        """Bulk lookup against the front image via the unified engine
        (DESIGN.md §6; jitted jnp or one Pallas launch).

        Compiles once per engine configuration and shape set; the store's
        stable padded capacities make every subsequent epoch a cache hit.
        ``k > 1`` returns [K, k] replica sets in the same single program.
        Defaults to the store's configured apply plane.
        """
        from repro.kernels.engine import engine_lookup

        plane = plane or self.plane
        reg = self._obs()
        t0 = time.perf_counter_ns() if reg.active else 0
        out = np.asarray(engine_lookup(keys, self._front, k=k, plane=plane,
                                       **kw))
        if reg.active:
            reg.counter("store.lookups").inc()
            reg.counter("store.lookup_keys").inc(int(out.shape[0]))
            reg.histogram("store.lookup.us").observe(
                (time.perf_counter_ns() - t0) / 1e3)
        return out

    def migration_diff(self, keys, *, plane: str = "jnp", k: int = 1, **kw):
        """Moved-key mask between the retained epoch and the front epoch
        (one fused engine launch; ``k > 1`` diffs whole replica sets)."""
        from repro.kernels.engine import engine_diff

        if self._prev is None:
            raise ValueError("no previous epoch retained (sync() first)")
        with self._obs().span("store.diff", epoch=self._front.epoch):
            return engine_diff(keys, self._prev, self._front, plane=plane,
                               k=k, **kw)
