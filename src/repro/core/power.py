"""PowerHash ("Fast Consistent Hashing in Constant Time", Leu 2023,
arXiv 2307.12448) — O(1) expected lookup with NO fixed cluster capacity.

The algorithm is a power-of-two *level descent*.  Buckets are the prefix
``[0, n)`` (jump-family semantics: ``add()`` appends bucket ``n``,
``remove`` is LIFO-only), partitioned into levels: level ``j`` holds
buckets ``[2^j, 2^(j+1))`` and the top level ``L = ⌊log2(n−1)⌋`` is
truncated at ``n``.  A lookup draws one uniform variate per level from
independent salted hashes, starting at the top:

* **top level** — rejection-resample ``v ← hash(key, salt(L, t)) &
  (2^(L+1)−1)`` for ``t = 0, 1, …`` until ``v < n`` (geometric, success
  probability ``n/2^(L+1) > 1/2`` ⇒ < 2 expected draws).  Accept ``v``
  when ``v ≥ 2^L`` (it names a top-level bucket), else descend;
* **full levels** ``j = L−1 … 0`` — one draw ``v ← hash(key, salt(j, 0))
  & (2^(j+1)−1)``; accept when ``v ≥ 2^j`` (probability exactly ½), else
  descend.  Past level 0 the bucket is 0.

Why this is correct (the three consistent-hashing laws):

* **balance** — conditional on reaching level ``j``, the draw is uniform
  over ``[0, 2^(j+1))``, so P(bucket = b) telescopes to exactly ``1/n``
  for every ``b < n``;
* **monotonicity** (minimal disruption) — growing ``n → n+1`` inside a
  level, the accepted draw becomes the first ``v < n+1``: a key moves iff
  an earlier rejected draw equals ``n`` — it moves TO the new bucket,
  probability ``1/(n+1)``.  Crossing a power of two (``n = 2^(L+1)``) the
  old top level is full and always accepts its ``t = 0`` draw — exactly
  the draw the full-level rule uses once the level sinks below a new top
  — so placements are preserved there too.  (The tempting shortcut of
  collapsing all full levels into one masked hash is uniform but NOT
  monotone across power-of-two crossings; the per-level independent
  draws are load-bearing.)
* **O(1) expected** — < 2 draws at the top, then each level exits with
  probability ½: ≈ ≤ 4 hashes expected, independent of ``n`` (versus
  Jump's Θ(ln n) chain); worst case is the ≤ 31-level descent.

The rejection loop carries a deterministic try cap (``POWER_TRY_CAP``,
miss probability ≤ 2^−64) whose fallback — descend — is identical on the
host and device planes, the same vanishing-probability device-safety
pattern as Dx's ``fallback`` bucket.

``variant="32"`` draws from ``hash2_32`` — bit-identical to the jnp /
Pallas ``power32`` in :mod:`repro.kernels.primitives`; ``variant="64"``
is the host-only 64-bit flavour.  The device image is just the dynamic
``n`` (like Jump), so deltas are O(1) words and a million-bucket
follower replicates in one header frame.
"""
from __future__ import annotations

from .hashing import hash2_32, hash2_64
from .protocol import DeltaEmitter, DeviceImage, ReplicatedLookup

#: salt offset of the level-descent draw stream: ``salt = POWER_SALT +
#: (level << 6) + try``.  Level < 32 and try < 64 never collide, and the
#: offset keeps the stream disjoint from the replica-walk salts
#: (1 … REPLICA_SALT_CAP) and Jump's STEP_SALT stream.
POWER_SALT = 0x506F5748  # "PoWH"

#: top-level rejection draw budget; exhausting it (probability ≤ 2^-64 —
#: each draw succeeds w.p. > 1/2) deterministically descends instead.
POWER_TRY_CAP = 64


def power_lookup_with(h2, key: int, n: int) -> tuple[int, int, int]:
    """One level-descent lookup under hash ``h2(key, salt)``.

    Returns ``(bucket, extra top-level tries, levels descended)`` — the
    last two are the cost counters ``lookup_trace`` reports (both 0 on
    the ≈75 % of lookups that settle on the first top-level draw).
    """
    if n <= 0:
        raise ValueError("n must be positive")
    if n == 1:
        return 0, 0, 0
    L = (n - 1).bit_length() - 1          # top level: buckets [2^L, n)
    hi_mask = (1 << (L + 1)) - 1
    base = POWER_SALT + (L << 6)
    tries = 0
    v = h2(key, base) & hi_mask
    while v >= n and tries + 1 < POWER_TRY_CAP:
        tries += 1
        v = h2(key, base + tries) & hi_mask
    if n > v >= (1 << L):
        return v, tries, 0
    # v landed below 2^L (or the try cap exhausted): descend full levels
    levels = 0
    for j in range(L - 1, -1, -1):
        levels += 1
        v = h2(key, POWER_SALT + (j << 6)) & ((1 << (j + 1)) - 1)
        if v >= (1 << j):
            return v, tries, levels
    return 0, tries, levels


def power64(key: int, num_buckets: int) -> int:
    """64-bit PowerHash lookup (host-only flavour)."""
    return power_lookup_with(hash2_64, key, num_buckets)[0]


def power32(key: int, num_buckets: int) -> int:
    """TPU-native PowerHash lookup — bit-identical to the device planes'
    :func:`repro.kernels.primitives.power32`."""
    return power_lookup_with(hash2_32, key, num_buckets)[0]


class PowerHash(ReplicatedLookup, DeltaEmitter):
    """Stateful wrapper exposing the uniform engine API (LIFO-only
    resizes, like Jump — but O(1) expected lookups instead of Θ(ln n))."""

    name = "power"

    def __init__(self, initial_node_count: int, variant: str = "64"):
        if initial_node_count <= 0:
            raise ValueError("initial_node_count must be positive")
        if variant == "64":
            self._h2 = hash2_64
        elif variant == "32":
            self._h2 = hash2_32
        else:
            raise ValueError(f"unknown variant {variant!r}")
        self.variant = variant
        self.n = initial_node_count
        self._init_delta_log()

    def lookup(self, key: int) -> int:
        return power_lookup_with(self._h2, key, self.n)[0]

    def lookup_trace(self, key: int) -> tuple[int, int, int]:
        """(bucket, extra top-level rejection draws, levels descended) —
        the degradation-profile instrument.  Both counters are O(1) in
        expectation at ANY size, so Power's profile stays flat where
        fixed-capacity baselines turn their knee."""
        return power_lookup_with(self._h2, key, self.n)

    def add(self) -> int:
        self.n += 1
        self._record({}, self.n)  # the whole delta is the new n
        return self.n - 1

    def remove(self, b: int) -> None:
        if b != self.n - 1:
            raise ValueError("PowerHash only supports LIFO removals")
        if self.n == 1:
            raise ValueError("cannot remove the last bucket")
        self.n -= 1
        self._record({}, self.n)

    def _image_n(self) -> int:
        return self.n

    @property
    def size(self) -> int:
        return self.n

    @property
    def working(self) -> int:
        return self.n

    def working_set(self) -> set[int]:
        return set(range(self.n))

    def memory_bytes(self) -> int:
        return 8  # a single counter

    def device_image(self, capacity: int | None = None) -> DeviceImage:
        """Stateless: the image is just the dynamic n (lookup = power32)."""
        return DeviceImage(algo=self.name, n=self.n, epoch=self._epoch)
