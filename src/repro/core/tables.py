"""MementoTables — dense device image of a MementoHash state.

The host control plane keeps the paper's Θ(r) hash table.  The device data
plane (bulk lookups in ``core/jax_lookup.py`` / ``kernels/``) wants vector
gathers instead of pointer chases (DESIGN.md §3.2), so we flatten the
replacement set ``R = {b: (c, p)}`` into one int32 array::

    repl[b] = c   if b removed          (c = |W_b|, Prop. V.3)
    repl[b] = -1  if b working

``repl`` has a fixed ``capacity`` ≥ n (rounded up to a multiple of 128 for
TPU lane alignment) so that device buffers keep a stable shape across
cluster resizes — ``n`` travels as a dynamic scalar.  Updates are O(1)
in-place mirrors of Alg. 2/3; ``version`` bumps let cached device copies
invalidate.

Superseded for device use by the per-algorithm epoch deltas
(``protocol.DeltaEmitter`` + ``core/image_store.DeviceImageStore``,
DESIGN.md §3.5), which generalize this Memento-only host mirror to all
four algorithms and ship O(changed-words) scatters to the device.  Kept
as the host-side mirror utility.
"""
from __future__ import annotations

import numpy as np

from .memento import MementoHash
from .protocol import DeviceImage, round_up as _round_up


class MementoTables:
    def __init__(self, memento: MementoHash, capacity: int | None = None):
        n = memento.n
        cap = _round_up(max(capacity or 0, 2 * n, 128))
        self.capacity = cap
        self.repl = np.full((cap,), -1, dtype=np.int32)
        for b, (c, _p) in memento.R.items():
            self.repl[b] = c
        self.n = n
        self.version = 0
        self._m = memento

    # -- O(1) mirrors of Alg. 2 / Alg. 3 ------------------------------------
    def on_remove(self, b: int) -> None:
        """Call right *after* memento.remove(b)."""
        m = self._m
        if b in m.R:
            self.repl[b] = m.R[b][0]
        self.n = m.n
        self.version += 1

    def on_add(self, b: int) -> None:
        """Call right *after* memento.add() returned b."""
        m = self._m
        if self.n == m.n:  # restored bucket
            self.repl[b] = -1
        else:  # appended to tail
            if m.n > self.capacity:
                self._grow()
        self.n = m.n
        self.version += 1

    def _grow(self) -> None:
        new_cap = _round_up(2 * self.capacity)
        repl = np.full((new_cap,), -1, dtype=np.int32)
        repl[: self.capacity] = self.repl
        self.repl = repl
        self.capacity = new_cap
        self.version += 1

    def image(self) -> DeviceImage:
        """Protocol-shaped view of the incrementally-mirrored dense table."""
        return DeviceImage(algo="memento", n=self.n, arrays={"repl": self.repl})

    def check(self) -> None:
        """Consistency with the host state (tests)."""
        m = self._m
        assert self.n == m.n
        for b in range(self.n):
            if b in m.R:
                assert self.repl[b] == m.R[b][0]
            else:
                assert self.repl[b] == -1


def tables_from_state(n: int, R: dict[int, tuple[int, int]], capacity: int | None = None) -> tuple[np.ndarray, int]:
    """Standalone (repl, n) arrays from raw state — for tests/benchmarks."""
    cap = _round_up(max(capacity or 0, n, 128))
    repl = np.full((cap,), -1, dtype=np.int32)
    for b, (c, _p) in R.items():
        repl[b] = c
    return repl, n
