"""Compact (packed) device images — minimal-memory table layouts.

The paper's claim is minimal memory *and* optimal lookups; the dense
device images trade that away for simplicity (every word f32-width, the
Memento table Θ(n) even when almost nothing is removed).  This module is
the packed layout (DESIGN.md §8.2) that keeps million-bucket tables
VMEM-resident:

* **memento** — the Dx bitmap precedent applied to Memento: a uint32
  ``state`` bitmap (bit b = 1 ⇔ bucket b working, padding bits working so
  in-capacity growth needs no bitmap writes) plus the Θ(r)
  open-addressing replacement table (``slot_b``, ``slot_c``) in the
  narrowest dtype that holds every bucket id.  The probe sequence is the
  engine's ``compact_reader`` sequence (linear probing from
  ``fmix32(b·GOLDEN32 + 5) & mask``); deletions (bucket restores) leave
  TOMBSTONE slots the reader probes straight past, so epoch deltas edit
  the packed table in place.
* **anchor**  — pure dtype narrowing of A (removal stamps) and K (wrap
  successors): both are bounded by the fixed overall capacity ``a``, so
  int16 suffices for every a ≤ 32 767 (the paper's whole experimental
  range) at exactly half the bytes.
* **dx**      — already a packed bitmap; the words array is shared as-is.
* **jump**, **power** — stateless: nothing to pack.

All planes stay bit-identical to the host oracles: packing changes the
table *encoding*, never the lookup sequence (tests/test_packed.py).
"""
from __future__ import annotations

import numpy as np

from .hashing import GOLDEN32, np_fmix32
from .protocol import (ALGORITHM_REGISTRY, IMAGE_LAYOUT, DeviceImage,
                       ImageDelta, round_up)

#: slot_b sentinels: EMPTY terminates a probe chain, TOMBSTONE (a deleted
#: entry) keeps it alive — readers probe past tombstones, writers reuse them.
EMPTY = -1
TOMBSTONE = -2

#: per-algorithm packed layout: (scalar names, table array names), derived
#: from the registry.  Scalars are identical to the dense layout (the
#: engine's scalar vector must not change); only the table arrays differ —
#: algorithms without a dedicated packed encoding share their dense tables.
PACKED_LAYOUT: dict[str, tuple[tuple[str, ...], tuple[str, ...]]] = {
    name: (info.scalars, info.packed_tables
           if info.packed_tables is not None else info.tables)
    for name, info in ALGORITHM_REGISTRY.items()
}


def image_table_names(image) -> tuple[str, ...]:
    """Table array names of ``image`` in engine operand order."""
    layout = PACKED_LAYOUT if getattr(image, "packed", False) else IMAGE_LAYOUT
    return layout[image.algo][1]


def narrow_dtype(max_value: int) -> np.dtype:
    """Smallest signed dtype holding values in [TOMBSTONE, max_value]."""
    if max_value <= np.iinfo(np.int8).max:
        return np.dtype(np.int8)
    if max_value <= np.iinfo(np.int16).max:
        return np.dtype(np.int16)
    return np.dtype(np.int32)


def image_table_bytes(image) -> int:
    """Device-resident table bytes of an image (the memory the paper's
    minimal-memory claim is about; scalars excluded — O(1) either way)."""
    return sum(int(np.asarray(a).nbytes) for a in image.arrays.values())


# ---------------------------------------------------------------------------
# Memento: bitmap + open-addressing slots
# ---------------------------------------------------------------------------

def _slot_count(r: int, *, headroom: int = 1) -> int:
    """Power-of-two slot count for r removed buckets: load factor ≤ 0.5 at
    ``headroom=1`` (the probe-chain bound of ``compact_reader``), ≤ 0.25 at
    the store's default ``headroom=2`` so delta-driven inserts have room."""
    nslots = 128
    while nslots < 2 * max(headroom, 1) * max(r, 1):
        nslots *= 2
    return nslots


def build_slots(repl, *, nslots: int | None = None,
                dtype=np.int32) -> tuple[np.ndarray, np.ndarray]:
    """Dense repl image → open-addressing (slot_b, slot_c) numpy arrays.

    Insertion is vectorized: each round, every still-unplaced key whose
    current slot is free claims it (first pending key per slot wins); the
    rest advance one slot.  Slots only ever fill, so every slot a key
    skipped is occupied in the final table — the engine's probe loop (scan
    from h0 until hit or empty) finds every key.
    """
    repl = np.asarray(repl)
    removed = np.nonzero(repl >= 0)[0].astype(np.int64)
    r = int(removed.size)
    if nslots is None:
        nslots = _slot_count(r)
    if nslots & (nslots - 1):
        raise ValueError(f"nslots must be a power of two, got {nslots}")
    if nslots < 2 * r:
        raise ValueError(f"load factor > 0.5: {r} entries in {nslots} slots")
    slot_b = np.full((nslots,), EMPTY, dtype)
    slot_c = np.full((nslots,), EMPTY, dtype)
    mask = nslots - 1
    with np.errstate(over="ignore"):
        pos = np_fmix32(removed.astype(np.uint32) * np.uint32(GOLDEN32)
                        + np.uint32(5)).astype(np.int64) & mask
    pending = np.arange(r)
    while pending.size:
        p = pos[pending]
        free = slot_b[p] < 0
        cand = pending[free]
        _, first = np.unique(p[free], return_index=True)
        win = cand[first]
        slot_b[pos[win]] = removed[win].astype(dtype)
        slot_c[pos[win]] = repl[removed[win]].astype(dtype)
        pending = np.setdiff1d(pending, win, assume_unique=True)
        pos[pending] = (pos[pending] + 1) & mask
    return slot_b, slot_c


def _probe_start(b: int, mask: int) -> int:
    with np.errstate(over="ignore"):
        return int(np_fmix32(np.uint32(b) * np.uint32(GOLDEN32)
                             + np.uint32(5))) & mask


def _probe_find(slot_b: np.ndarray, b: int) -> int:
    """Slot index of live entry ``b``, or −1 (probing past tombstones)."""
    nslots = len(slot_b)
    pos = _probe_start(b, nslots - 1)
    for _ in range(nslots):
        sb = int(slot_b[pos])
        if sb == b:
            return pos
        if sb == EMPTY:
            return -1
        pos = (pos + 1) & (nslots - 1)
    return -1


def _probe_upsert(slot_b: np.ndarray, b: int) -> tuple[int, bool]:
    """(slot index, inserted?) for writing entry ``b``: an existing live
    entry is updated in place; otherwise the first tombstone on the probe
    path (else the terminating empty slot) is claimed.  (−1, True) when
    the table has no reusable slot at all."""
    nslots = len(slot_b)
    pos = _probe_start(b, nslots - 1)
    first_tomb = -1
    for _ in range(nslots):
        sb = int(slot_b[pos])
        if sb == b:
            return pos, False
        if sb == TOMBSTONE and first_tomb < 0:
            first_tomb = pos
        if sb == EMPTY:
            return (first_tomb if first_tomb >= 0 else pos), True
        pos = (pos + 1) & (nslots - 1)
    return first_tomb, True  # full scan: every slot live or tombstoned


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def pack_image(image: DeviceImage, *, slot_headroom: int = 1,
               nslots: int | None = None) -> DeviceImage:
    """Dense :class:`DeviceImage` → the packed layout (same epoch, same
    scalars, ``packed=True``).  Arrays NOT in the dense table layout (e.g.
    a bounded-load overlay's ``load`` words) are carried through unchanged.
    ``slot_headroom`` over-provisions the Memento slot table (the store
    packs with headroom 2 so epoch deltas insert without repacking);
    ``nslots`` pins the slot count exactly — the replication publisher's
    targeted catch-up snapshots (``launch/replicate.py``) must rebuild at
    the slot capacity the stream already announced, not a fresh one."""
    if image.packed:
        return image
    arrays: dict[str, np.ndarray] = {}
    if image.algo == "memento":
        repl = np.asarray(image.arrays["repl"])
        pad = repl.shape[0]
        nwords = round_up(-(-pad // 32))
        state = np.full((nwords,), 0xFFFFFFFF, np.uint32)  # all working
        removed = np.nonzero(repl >= 0)[0]
        if removed.size:
            bits = np.zeros((nwords,), np.uint32)
            np.bitwise_or.at(bits, removed >> 5,
                             np.uint32(1) << (removed & 31).astype(np.uint32))
            state &= ~bits
        dtype = narrow_dtype(pad)
        slot_b, slot_c = build_slots(
            repl, nslots=(nslots if nslots is not None
                          else _slot_count(int(removed.size),
                                           headroom=slot_headroom)),
            dtype=dtype)
        arrays = {"state": state, "slot_b": slot_b, "slot_c": slot_c}
    elif image.algo == "anchor":
        A = np.asarray(image.arrays["A"])
        K = np.asarray(image.arrays["K"])
        dtype = narrow_dtype(int(A.shape[0]))  # stamps ≤ a ≤ pad, ids < pad
        arrays = {"A": A.astype(dtype), "K": K.astype(dtype)}
    elif image.algo == "dx":
        arrays = {"words": np.asarray(image.arrays["words"])}
    elif image.algo not in IMAGE_LAYOUT:
        raise ValueError(f"unknown algo {image.algo!r}")
    # remaining algos (jump, power) are stateless: nothing to pack
    handled = set(IMAGE_LAYOUT[image.algo][1])
    for name, arr in image.arrays.items():  # overlays (e.g. "load")
        if name not in handled:
            arrays[name] = np.asarray(arr)
    return DeviceImage(algo=image.algo, n=image.n, arrays=arrays,
                       scalars=dict(image.scalars), epoch=image.epoch,
                       packed=True)


def unpack_image(image: DeviceImage) -> DeviceImage:
    """Packed image → an equivalent dense image (verification path).

    For Memento the dense capacity is the bitmap's (32 × words ≥ the
    original pad — extra padding is working, which dense lookups never
    read below ``n``); Anchor/Dx round-trip bit-exactly.
    """
    if not image.packed:
        return image
    if image.algo == "memento":
        state = np.asarray(image.arrays["state"], np.uint32)
        slot_b = np.asarray(image.arrays["slot_b"])
        slot_c = np.asarray(image.arrays["slot_c"])
        repl = np.full((32 * state.shape[0],), -1, np.int32)
        live = slot_b >= 0
        repl[slot_b[live].astype(np.int64)] = slot_c[live].astype(np.int32)
        bits = (state[np.arange(repl.shape[0]) >> 5]
                >> (np.arange(repl.shape[0]) & 31).astype(np.uint32)) & 1
        if not np.array_equal(bits == 0, repl >= 0):
            raise ValueError("packed image inconsistent: bitmap vs slots")
        arrays = {"repl": repl}
    elif image.algo == "anchor":
        arrays = {"A": np.asarray(image.arrays["A"]).astype(np.int32),
                  "K": np.asarray(image.arrays["K"]).astype(np.int32)}
    elif image.algo == "dx":
        arrays = {"words": np.asarray(image.arrays["words"])}
    elif image.algo in PACKED_LAYOUT:
        arrays = {}  # stateless (jump, power)
    else:
        raise ValueError(f"unknown algo {image.algo!r}")
    handled = set(PACKED_LAYOUT[image.algo][1])
    for name, arr in image.arrays.items():
        if name not in handled:
            arrays[name] = np.asarray(arr)
    return DeviceImage(algo=image.algo, n=image.n, arrays=arrays,
                       scalars=dict(image.scalars), epoch=image.epoch)


# ---------------------------------------------------------------------------
# Epoch deltas on the packed layout
# ---------------------------------------------------------------------------

def packed_delta_updates(mirror: dict[str, np.ndarray], delta: ImageDelta,
                         ) -> dict[str, tuple[np.ndarray, np.ndarray]] | None:
    """Translate a dense :class:`ImageDelta` into scatter updates on the
    packed layout, applying them to the host-side numpy ``mirror`` in
    place.  Returns ``{name: (indices, values)}`` for the device scatter,
    or ``None`` when the packed image must be rebuilt (the Memento slot
    table ran out of room, or live+tombstone fill crossed the 0.5
    load-factor bound that keeps probe chains short).

    Memento's dense ``repl`` scatter becomes bitmap word edits plus slot
    upserts (removals) / tombstones (restores); every other array —
    Anchor A/K, the Dx bitmap, overlays like ``load`` — scatters
    position-for-position with a dtype cast.
    """
    out: dict[str, tuple[np.ndarray, np.ndarray]] = {}
    if delta.algo == "memento" and "repl" in delta.updates:
        idx, vals = delta.updates["repl"]
        state = mirror["state"]
        slot_b, slot_c = mirror["slot_b"], mirror["slot_c"]
        nslots = len(slot_b)
        fill = int(np.count_nonzero(slot_b != EMPTY))  # live + tombstones
        touched_words: dict[int, None] = {}
        touched_slots: dict[int, None] = {}
        for b, v in zip(np.asarray(idx, np.int64), np.asarray(vals, np.int64)):
            b, v = int(b), int(v)
            if b >= 32 * state.shape[0]:
                return None  # outgrew the bitmap: snapshot rebuild
            wi, bit = b >> 5, np.uint32(1) << np.uint32(b & 31)
            if v < 0:  # bucket restored → working: set bit, tombstone slot
                state[wi] |= bit
                pos = _probe_find(slot_b, b)
                if pos >= 0:
                    slot_b[pos] = TOMBSTONE
                    slot_c[pos] = EMPTY
                    touched_slots[pos] = None
            else:      # removed (or replacement redirect): clear bit, upsert
                state[wi] &= ~bit
                pos, inserted = _probe_upsert(slot_b, b)
                if pos < 0:
                    return None  # no reusable slot: repack
                if inserted and int(slot_b[pos]) == EMPTY:
                    fill += 1
                    if 2 * fill > nslots:
                        return None  # probe-chain bound breached: repack
                slot_b[pos] = b
                slot_c[pos] = v
                touched_slots[pos] = None
            touched_words[wi] = None
        if touched_words:
            w = np.fromiter(touched_words, np.int32, len(touched_words))
            out["state"] = (w, state[w].copy())
        if touched_slots:
            s = np.fromiter(touched_slots, np.int32, len(touched_slots))
            out["slot_b"] = (s, slot_b[s].copy())
            out["slot_c"] = (s.copy(), slot_c[s].copy())
    for name, (idx, vals) in delta.updates.items():
        if name == "repl" and delta.algo == "memento":
            continue
        arr = mirror[name]
        idx = np.asarray(idx, np.int32)
        vals = np.asarray(vals)
        if np.issubdtype(arr.dtype, np.signedinteger) and vals.size and \
                int(vals.max(initial=0)) > np.iinfo(arr.dtype).max:
            return None  # value outgrew the narrowed dtype: repack
        cast = vals.astype(arr.dtype)
        arr[idx] = cast
        out[name] = (idx, cast)
    return out
