"""Batched consistent-hash lookups — pure-jnp data plane.

Bit-identical to the numpy/scalar host plane (``variant="32"`` states):
the shared 32-bit arithmetic lives in :mod:`repro.kernels.primitives` and
is consumed both here and by the Pallas kernels, so all three planes
(host / jnp / Pallas) agree exactly.  These functions are the oracle for
the kernels (``kernels/ref.py`` re-exports them) and the CPU fallback used
by the data/serving substrates for bulk routing.

One lookup per algorithm (Memento, Anchor, Dx, Jump) over its flat
:class:`~repro.core.protocol.DeviceImage`; :func:`lookup_image` dispatches.
All loops are lane-synchronous masked ``lax.while_loop``s: a whole key
block iterates until every lane settles.  Expected sweep counts: Memento
E[τ], E[σ] ≤ ln(n/w) (paper Props. VII.1-3); Anchor ≈ ln(a/w); Dx the
geometric O(a/w) probe count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.primitives import (fmix32, hash2, jump32, power32,
                                      step_u24 as _step_u24)

_U = jnp.uint32

# Back-compat alias: earlier revisions exposed ``hash2_32`` here.
hash2_32 = hash2


def memento_lookup(keys, repl, n):
    """Paper Alg. 4, vectorized: keys uint32 [...], repl int32 [cap], n int.

    Returns int32 bucket ids in [0, n) that are working buckets.
    """
    keys = jnp.asarray(keys).astype(_U)
    b = jump32(keys, n)

    def outer_cond(state):
        b = state
        return jnp.any(repl[b] >= 0)

    def outer_body(b):
        c = repl[b]
        active = c >= 0
        wb = jnp.where(active, c, 1)  # |W_b| (Prop. V.3); dummy 1 when settled
        h = hash2(keys, b)
        d = (h % wb.astype(_U)).astype(jnp.int32)

        def inner_cond(state):
            d = state
            u = repl[d]
            return jnp.any(active & (u >= 0) & (u >= wb))

        def inner_body(d):
            u = repl[d]
            follow = active & (u >= 0) & (u >= wb)  # only while u ≥ w_b (balance)
            return jnp.where(follow, u, d)

        d = jax.lax.while_loop(inner_cond, inner_body, d)
        return jnp.where(active, d, b)

    return jax.lax.while_loop(outer_cond, outer_body, b)


def anchor_lookup(keys, A, K, a):
    """AnchorHash lookup over the A/K image: keys uint32 [...], a dynamic int.

    Mirrors the host loop exactly: start at ``fmix32(key) % a``; while the
    bucket is removed, re-hash into its wrap set and follow K successors
    while the candidate was removed at-or-after it.
    """
    keys = jnp.asarray(keys).astype(_U)
    au = jnp.asarray(a).astype(_U)
    b = (fmix32(keys) % au).astype(jnp.int32)

    def outer_cond(b):
        return jnp.any(A[b] > 0)

    def outer_body(b):
        Ab = A[b]
        active = Ab > 0
        denom = jnp.where(active, Ab, 1).astype(_U)
        h = (hash2(keys, b) % denom).astype(jnp.int32)

        def inner_cond(h):
            return jnp.any(active & (A[h] >= Ab))

        def inner_body(h):
            follow = active & (A[h] >= Ab)  # h removed at-or-after b ⇒ wrap
            return jnp.where(follow, K[h], h)

        h = jax.lax.while_loop(inner_cond, inner_body, h)
        return jnp.where(active, h, b)

    return jax.lax.while_loop(outer_cond, outer_body, b)


def dx_lookup(keys, words, a, max_probes, fallback):
    """DxHash lookup over the packed active bitmap: first working bucket in
    the pseudo-random probe stream ``hash(key, i) % a``, i < max_probes;
    unsettled lanes take the precomputed first-working ``fallback``."""
    keys = jnp.asarray(keys).astype(_U)
    au = jnp.asarray(a).astype(_U)
    b0 = jnp.zeros(keys.shape, jnp.int32)
    found0 = jnp.zeros(keys.shape, jnp.bool_)

    def cond(state):
        i, _, found = state
        return (i < max_probes) & jnp.any(~found)

    def body(state):
        i, b, found = state
        cand = (hash2(keys, i) % au).astype(jnp.int32)
        w = words[cand >> 5]
        bit = (w >> (cand & 31).astype(_U)) & _U(1)
        hit = ~found & (bit == _U(1))
        return i + jnp.int32(1), jnp.where(hit, cand, b), found | hit

    _, b, found = jax.lax.while_loop(cond, body, (jnp.int32(0), b0, found0))
    return jnp.where(found, b, jnp.asarray(fallback, jnp.int32))


def lookup_dispatch(algo, keys, arrays, scalars):
    """Batched lookup from (arrays, layout-ordered scalars) — every operand
    may be traced, so one jitted program serves ANY epoch of a given shape
    (``n`` and friends travel as dynamic scalars, not compile-time
    constants)."""
    if algo == "memento":
        return memento_lookup(keys, arrays["repl"], scalars[0])
    if algo == "anchor":
        return anchor_lookup(keys, arrays["A"], arrays["K"], scalars[0])
    if algo == "dx":
        return dx_lookup(keys, arrays["words"], scalars[0], scalars[1],
                         scalars[2])
    if algo == "jump":
        return jump32(keys, scalars[0])
    if algo == "power":
        return power32(keys, scalars[0])
    raise ValueError(f"unknown device image algo {algo!r}")


def lookup_image(keys, image):
    """Dispatch a batched jnp lookup over any :class:`DeviceImage` (eager)."""
    from repro.core.protocol import image_scalar_vec

    keys = jnp.asarray(keys, dtype=jnp.uint32)
    arrays = {k: jnp.asarray(v) for k, v in image.arrays.items()}
    return lookup_dispatch(image.algo, keys, arrays, image_scalar_vec(image))


def lookup_image_jit(keys, image):
    """Jitted :func:`lookup_image` — now a shim over the unified engine's
    jnp configuration (kept for one release alongside the kernel shims):
    compiles once per (algo, shapes) and is reused across epochs, since
    the epoch store's stable 128-padded capacities make every churn event
    shape-preserving."""
    from repro.kernels.engine import engine_lookup

    return engine_lookup(keys, image, plane="jnp")


def memento_lookup_hosted(keys, memento_tables):
    """Convenience: run the data plane against a host `MementoTables`."""
    repl = jnp.asarray(memento_tables.repl)
    return memento_lookup(jnp.asarray(keys, dtype=jnp.uint32), repl, memento_tables.n)
