"""Batched MementoHash lookup — pure-jnp data plane.

Bit-identical to the numpy host plane (``jump.np_jump32`` / ``hashing``):
32-bit murmur mixing, 24-bit uniform variates, f32 divides.  These functions
are the oracle for the Pallas kernel (``kernels/ref.py`` re-exports them) and
the CPU fallback used by the data/serving substrates for bulk routing.

All loops are lane-synchronous masked ``lax.while_loop``s: a whole key block
iterates until every lane settles.  Expected sweep counts are bounded by the
paper's Props. VII.1-3 (E[τ], E[σ] ≤ ln(n/w)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .hashing import _C1_32, _C2_32, GOLDEN32

_U = jnp.uint32


def fmix32(h):
    h = h.astype(_U)
    h ^= h >> _U(16)
    h = h * _U(_C1_32)
    h ^= h >> _U(13)
    h = h * _U(_C2_32)
    h ^= h >> _U(16)
    return h


def hash2_32(keys, seed):
    """(key, seed) hash; seed may be a traced int32 array (e.g. bucket ids)."""
    s = fmix32(seed.astype(_U) * _U(GOLDEN32) + _U(1))
    return fmix32(keys.astype(_U) ^ s)


def _step_u24(keys, step):
    s = jnp.asarray(step).astype(_U)
    h = fmix32(keys.astype(_U) ^ (s * _U(GOLDEN32) + _U(0x2545F491)))
    return h >> _U(8)


def jump32(keys, n):
    """Vectorized TPU-native JumpHash: keys uint32 [...], n dynamic int."""
    nf = jnp.float32(n)
    b0 = jnp.zeros(keys.shape, jnp.int32)
    j0 = jnp.zeros(keys.shape, jnp.float32)

    def cond(state):
        _, j, _ = state
        return jnp.any(j < nf)

    def body(state):
        b, j, i = state
        active = j < nf
        b = jnp.where(active, j.astype(jnp.int32), b)
        u = _step_u24(keys, i)
        r = (u.astype(jnp.float32) + jnp.float32(1.0)) * jnp.float32(2.0 ** -24)
        jn = jnp.minimum(jnp.floor((b.astype(jnp.float32) + jnp.float32(1.0)) / r), nf)
        j = jnp.where(active, jn, j)
        return b, j, i + 1

    b, _, _ = jax.lax.while_loop(cond, body, (b0, j0, jnp.int32(0)))
    return b


def memento_lookup(keys, repl, n):
    """Paper Alg. 4, vectorized: keys uint32 [...], repl int32 [cap], n int.

    Returns int32 bucket ids in [0, n) that are working buckets.
    """
    keys = keys.astype(_U)
    b = jump32(keys, n)

    def outer_cond(state):
        b = state
        return jnp.any(repl[b] >= 0)

    def outer_body(b):
        c = repl[b]
        active = c >= 0
        wb = jnp.where(active, c, 1)  # |W_b| (Prop. V.3); dummy 1 when settled
        h = hash2_32(keys, b)
        d = (h % wb.astype(_U)).astype(jnp.int32)

        def inner_cond(state):
            d = state
            u = repl[d]
            return jnp.any(active & (u >= 0) & (u >= wb))

        def inner_body(d):
            u = repl[d]
            follow = active & (u >= 0) & (u >= wb)  # only while u ≥ w_b (balance)
            return jnp.where(follow, u, d)

        d = jax.lax.while_loop(inner_cond, inner_body, d)
        return jnp.where(active, d, b)

    return jax.lax.while_loop(outer_cond, outer_body, b)


def memento_lookup_hosted(keys, memento_tables):
    """Convenience: run the data plane against a host `MementoTables`."""
    repl = jnp.asarray(memento_tables.repl)
    return memento_lookup(jnp.asarray(keys, dtype=jnp.uint32), repl, memento_tables.n)
