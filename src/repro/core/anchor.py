"""AnchorHash (Mendelson et al., 2020) — in-place variant.

Fixed overall capacity ``a``; tracks every bucket (working and removed) with
four int arrays (Θ(a) memory):

  * ``A[b]`` — 0 if ``b`` is working, else the working-set size right after
    ``b`` was removed (removal "timestamps" are strictly decreasing sizes),
  * ``W[0..N-1]`` — the working buckets (order maintained by swap-removal),
  * ``L[b]`` — index of working bucket ``b`` inside ``W``,
  * ``K[b]`` — the bucket that replaced ``b`` in ``W`` when ``b`` was removed
    (the "wrap" successor used by the lookup inner loop).

Removals/additions must nest LIFO-per-bucket as in the original (random
removals allowed; additions restore the most recent removal — same contract
the AnchorHash paper uses for its stack-based resource management).
"""
from __future__ import annotations

import numpy as np

from .hashing import MASK32, MASK64, fmix32, fmix64, hash2_32, hash2_64
from .protocol import DeltaEmitter, DeviceImage, ReplicatedLookup, round_up


class AnchorHash(ReplicatedLookup, DeltaEmitter):
    name = "anchor"

    def __init__(self, capacity: int, initial_node_count: int, variant: str = "64"):
        if not (0 < initial_node_count <= capacity):
            raise ValueError("need 0 < initial_node_count <= capacity")
        if variant == "64":
            self._fmix, self._hash2, self._mask = fmix64, hash2_64, MASK64
        elif variant == "32":
            # TPU-native arithmetic — bit-identical to the device data plane.
            self._fmix, self._hash2, self._mask = fmix32, hash2_32, MASK32
        else:
            raise ValueError(f"unknown variant {variant!r}")
        self.variant = variant
        a = capacity
        self.a = a
        self.N = a
        self.A = [0] * a
        self.W = list(range(a))
        self.L = list(range(a))
        self.K = list(range(a))
        self.R: list[int] = []  # removal stack
        self._init_delta_log()
        for b in range(a - 1, initial_node_count - 1, -1):
            self.remove(b)

    # -- resource management ---------------------------------------------------
    def remove(self, b: int) -> None:
        if not (0 <= b < self.a) or self.A[b] != 0:
            raise ValueError(f"bucket {b} is not working")
        if self.N == 1:
            raise ValueError("cannot remove the last working bucket")
        self.R.append(b)
        self.N -= 1
        N = self.N
        self.A[b] = N
        moved = self.W[N]
        pos = self.L[b]
        self.W[pos] = moved
        self.L[moved] = pos
        self.K[b] = moved
        # W/L are host-only; the device image is exactly (A, K).
        self._record({"A": {b: N}, "K": {b: moved}}, self.a)

    def add(self) -> int:
        if not self.R:
            raise ValueError("AnchorHash capacity exhausted (fixed a)")
        b = self.R.pop()
        N = self.N
        moved = self.K[b]
        pos = self.L[moved]
        self.W[N] = moved
        self.L[moved] = N
        self.W[pos] = b
        self.L[b] = pos
        self.A[b] = 0
        self.K[b] = b
        self.N += 1
        self._record({"A": {b: 0}, "K": {b: b}}, self.a)
        return b

    def _image_n(self) -> int:
        return self.a

    # -- lookup -----------------------------------------------------------------
    def lookup(self, key: int) -> int:
        key &= self._mask
        A, K = self.A, self.K
        b = self._fmix(key) % self.a
        while A[b] > 0:  # b is removed
            h = self._hash2(key, b) % A[b]
            while A[h] >= A[b]:  # h removed at-or-after b ⇒ wrap back in time
                h = K[h]
            b = h
        return b

    # convenience for tests/benchmarks (mirrors MementoHash.lookup_trace)
    def lookup_trace(self, key: int) -> tuple[int, int, int]:
        """Lookup returning (bucket, external_iters, internal_iters)."""
        key &= self._mask
        A, K = self.A, self.K
        b = self._fmix(key) % self.a
        ext = inn = 0
        while A[b] > 0:
            ext += 1
            h = self._hash2(key, b) % A[b]
            while A[h] >= A[b]:
                inn += 1
                h = K[h]
            b = h
        return b, ext, inn

    def device_image(self, capacity: int | None = None) -> DeviceImage:
        """A/K image: removal timestamps + wrap successors (DESIGN.md §3.3).

        Lookup only ever gathers indices < a (start is ``fmix(key) % a``,
        probes are ``hash % A[b] < a``, and K values are bucket ids), so the
        alignment padding is never read.  ``capacity`` is accepted for
        protocol uniformity but the overall capacity ``a`` is fixed.
        """
        pad = round_up(max(self.a, capacity or 0))
        A = np.zeros((pad,), dtype=np.int32)
        A[: self.a] = self.A
        K = np.arange(pad, dtype=np.int32)
        K[: self.a] = self.K
        return DeviceImage(algo=self.name, n=self.a, arrays={"A": A, "K": K},
                           epoch=self._epoch)

    # -- introspection -------------------------------------------------------------
    @property
    def size(self) -> int:
        return self.a

    @property
    def working(self) -> int:
        return self.N

    def is_working(self, b: int) -> bool:
        return 0 <= b < self.a and self.A[b] == 0

    def working_set(self) -> set[int]:
        return set(self.W[: self.N])

    def memory_bytes(self) -> int:
        """Θ(a): four int32 arrays + the removal stack."""
        return 16 * self.a + 4 * len(self.R) + 8
