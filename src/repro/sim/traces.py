"""Declarative cluster-lifecycle traces (DESIGN.md §7.1).

A :class:`Trace` is a seeded, algorithm-agnostic script of
:class:`TraceEvent` records — the paper's evaluation scenarios (§VIII:
stable, one-shot removal, incremental removals) plus beyond-paper
lifecycles (flapping nodes, churn storms, correlated failure-domain
outages, staged scale-up/scale-down, Zipf-skewed traffic, session-affinity
serving with failovers) — that the replay driver
(:mod:`repro.sim.driver`) feeds through the real production stack.

The grammar is deliberately small:

  ===========  ==========================================================
  op           meaning (driver semantics in DESIGN.md §7.2)
  ===========  ==========================================================
  remove       ``count`` membership removals (victims picked by
               ``select``), then — if ``sync`` — ONE epoch sync, so a
               burst lands as one composed delta
  add          ``count`` additions (Memento restores LIFO), then sync
  lookup       a traffic batch of ``n_keys`` keys (``dist`` uniform or
               Zipf-``skew``), ``k`` replicas per key through the engine
  assign       bounded-load assignment of ``n_keys`` keys under
               ``cap_c`` (cap = ⌈cap_c · keys/working⌉)
  route        a session batch of ``n_keys`` ids through SessionRouter
  mark_failed  health-checker mark (failover BEFORE the delta lands)
  fail         SessionRouter.fail_replica (remove + delta + unmark)
  restore      SessionRouter.restore_replica / host add
  ===========  ==========================================================

Victim ``select`` policies: ``random`` (trace-rng uniform over working
buckets), ``lifo`` (highest id — the only legal choice for the LIFO-only
algorithms Jump and Power, which degrade every policy to it), ``first``
(lowest working id,
deterministic without consuming rng), ``domain`` (every working bucket of
failure domain ``domain``), or an explicit ``bucket``.

Traces serialize losslessly to JSON (:meth:`Trace.to_json` /
:meth:`Trace.from_json`): a captured churn trace replays bit-for-bit —
same victims, same traffic, same placements — on any plane, as long as
traffic runs at synced epochs (all built-ins do; with ``sync=False``
membership pending, the device planes deliberately serve the last synced
epoch while the host plane is live — see :mod:`repro.sim.driver`).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field


@dataclass
class TraceEvent:
    """One declarative lifecycle event (see the module grammar table)."""

    op: str
    count: int = 1
    select: str = "random"
    bucket: int | None = None
    domain: int | None = None
    n_keys: int = 0
    dist: str = "uniform"
    skew: float = 1.2
    k: int = 1
    cap_c: float | None = None
    sync: bool = True

    _OPS = ("remove", "add", "lookup", "assign", "route", "mark_failed",
            "fail", "restore")
    _SELECTS = ("random", "lifo", "first", "domain")

    def __post_init__(self):
        if self.op not in self._OPS:
            raise ValueError(f"unknown trace op {self.op!r}")
        if self.select not in self._SELECTS:
            raise ValueError(f"unknown victim policy {self.select!r}")
        if self.count < 1:
            raise ValueError("count must be ≥ 1")
        if self.select == "domain" and self.domain is None:
            raise ValueError("select='domain' needs a domain")
        if self.select == "domain" and self.op in ("fail", "mark_failed"):
            raise ValueError(f"{self.op} names ONE victim; select='domain' "
                             "is a remove-burst policy")
        if self.bucket is not None and self.count != 1:
            raise ValueError("an explicit bucket names exactly one victim "
                             "(count must be 1)")
        if self.op in ("lookup", "assign", "route") and self.n_keys < 1:
            raise ValueError(f"{self.op} needs n_keys ≥ 1")
        if self.op == "assign" and (self.cap_c is None or self.cap_c <= 1.0):
            raise ValueError("assign needs cap_c > 1")
        if self.dist not in ("uniform", "zipf"):
            raise ValueError(f"unknown key distribution {self.dist!r}")
        if self.dist == "zipf" and self.skew <= 1.0:
            raise ValueError("zipf skew must exceed 1")
        if self.k < 1:
            raise ValueError("k must be ≥ 1")


@dataclass
class Trace:
    """A named, seeded scenario script; replayable and JSON-round-trippable."""

    name: str
    seed: int
    initial_nodes: int
    events: list[TraceEvent] = field(default_factory=list)
    capacity_factor: int = 4   # a/w for the fixed-capacity baselines
    num_domains: int | None = None  # domain map: bucket % num_domains
    meta: dict = field(default_factory=dict)

    @property
    def membership_events(self) -> int:
        return sum(e.count for e in self.events
                   if e.op in ("remove", "add", "fail", "restore"))

    # -- serialization (replayable churn traces) ----------------------------
    def to_dict(self) -> dict:
        return {"name": self.name, "seed": self.seed,
                "initial_nodes": self.initial_nodes,
                "capacity_factor": self.capacity_factor,
                "num_domains": self.num_domains, "meta": self.meta,
                "events": [asdict(e) for e in self.events]}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_dict(cls, d: dict) -> "Trace":
        return cls(name=d["name"], seed=d["seed"],
                   initial_nodes=d["initial_nodes"],
                   capacity_factor=d.get("capacity_factor", 4),
                   num_domains=d.get("num_domains"),
                   meta=d.get("meta", {}),
                   events=[TraceEvent(**e) for e in d["events"]])

    @classmethod
    def from_json(cls, s: str) -> "Trace":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# The paper's §VIII scenarios
# ---------------------------------------------------------------------------

def stable_trace(seed: int = 0, *, w: int = 64, batches: int = 6,
                 n_keys: int = 2048, k: int = 1) -> Trace:
    """Paper stable clusters (Figs. 17/18): traffic only, no churn."""
    ev = [TraceEvent("lookup", n_keys=n_keys, k=k) for _ in range(batches)]
    return Trace("stable", seed, w, ev)


def oneshot_trace(seed: int = 0, *, w: int = 64, frac: float = 0.9,
                  n_keys: int = 2048) -> Trace:
    """Paper one-shot removal (Figs. 19–22): ``frac`` of the fleet dies at
    once — one burst, ONE composed epoch delta — then serving resumes."""
    removals = max(1, int(frac * w))
    ev = [TraceEvent("lookup", n_keys=n_keys),
          TraceEvent("remove", count=removals),
          TraceEvent("lookup", n_keys=n_keys),
          TraceEvent("lookup", n_keys=n_keys)]
    return Trace("oneshot", seed, w, ev, meta={"frac": frac})


def incremental_trace(seed: int = 0, *, w: int = 64,
                      fractions: tuple = (0.1, 0.2, 0.35, 0.5, 0.65,
                                          0.8, 0.9),
                      n_keys: int = 2048) -> Trace:
    """Paper incremental removals (Figs. 23–26): the fleet shrinks through
    the checkpoint fractions with traffic at each — the trace whose
    degradation profile shows the ~70 % knee (DESIGN.md §7.3)."""
    ev: list[TraceEvent] = [TraceEvent("lookup", n_keys=n_keys)]
    removed = 0
    for frac in fractions:
        step = int(frac * w) - removed
        if step < 1:
            continue
        removed += step
        ev.append(TraceEvent("remove", count=step))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
    return Trace("incremental", seed, w, ev,
                 meta={"fractions": list(fractions)})


# ---------------------------------------------------------------------------
# Beyond-paper lifecycles
# ---------------------------------------------------------------------------

def flapping_trace(seed: int = 0, *, w: int = 48, cycles: int = 5,
                   flappers: int = 3, n_keys: int = 1536) -> Trace:
    """Flapping nodes: the same buckets repeatedly fail and rejoin (LIFO
    restore brings back exactly the flapped buckets), traffic between
    flaps.  Exercises delta composition and epoch-flip stability under
    oscillating membership."""
    ev: list[TraceEvent] = []
    for _ in range(cycles):
        ev.append(TraceEvent("remove", count=flappers))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
        ev.append(TraceEvent("add", count=flappers))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
    return Trace("flapping", seed, w, ev, meta={"cycles": cycles,
                                                "flappers": flappers})


def churn_storm_trace(seed: int = 0, *, w: int = 96, storms: int = 4,
                      burst: int = 12, n_keys: int = 1536) -> Trace:
    """Churn storms: bursts of removals land as ONE composed delta each,
    partial recoveries between storms, traffic throughout."""
    ev: list[TraceEvent] = [TraceEvent("lookup", n_keys=n_keys)]
    for _ in range(storms):
        ev.append(TraceEvent("remove", count=burst))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
        ev.append(TraceEvent("add", count=max(1, burst // 2)))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
    return Trace("churn_storm", seed, w, ev, meta={"storms": storms,
                                                   "burst": burst})


def churn_storm_xl_trace(seed: int = 0, *, w: int = 100_000, storms: int = 3,
                         burst: int = 2_000, n_keys: int = 4096,
                         select: str = "lifo") -> Trace:
    """Churn storms at fleet scale (10⁵–10⁶ nodes): the trace behind the
    async-overlap and follower-replication measurements (DESIGN.md §9.4).

    Same storm grammar as :func:`churn_storm_trace` but the fleet is
    100k–1M buckets and each storm removes thousands of nodes as ONE
    composed delta, so the delta-apply scatter is big enough that hiding
    it behind lookup traffic (``sync_mode="overlap"``) is measurable, and
    the replicated frame stream carries real storm-sized payloads.
    ``select`` defaults to ``lifo`` — victim resolution stays O(burst)
    instead of O(w) rng draws, which matters at 10⁶ nodes — and the
    LIFO-only algorithms (Jump, Power) degrade to it anyway, so
    cross-algorithm cells stay comparable."""
    if not 10_000 <= w <= 1_000_000:
        raise ValueError("churn_storm_xl is the 1e4–1e6-node storm; use "
                         "churn_storm below 1e4")
    ev: list[TraceEvent] = [TraceEvent("lookup", n_keys=n_keys)]
    for _ in range(storms):
        ev.append(TraceEvent("remove", count=burst, select=select))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
        ev.append(TraceEvent("add", count=max(1, burst // 2)))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
    return Trace("churn_storm_xl", seed, w, ev,
                 meta={"storms": storms, "burst": burst, "select": select})


def domain_outage_trace(seed: int = 0, *, w: int = 64, num_domains: int = 8,
                        outages: int = 2, n_keys: int = 2048) -> Trace:
    """Correlated failure-domain outages: a whole rack/power-feed domain
    (bucket % num_domains) dies at once, then is restored — the scenario
    :func:`repro.runtime.elastic.domain_distinct_replicas` exists for."""
    ev: list[TraceEvent] = [TraceEvent("lookup", n_keys=n_keys)]
    for d in range(outages):
        domain = d % num_domains
        ev.append(TraceEvent("remove", select="domain", domain=domain))
        ev.append(TraceEvent("lookup", n_keys=n_keys, k=1))
        ev.append(TraceEvent("add", count=max(1, w // num_domains)))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
    return Trace("domain_outage", seed, w, ev, num_domains=num_domains,
                 meta={"outages": outages})


def staged_scaling_trace(seed: int = 0, *, w: int = 32, stages: int = 3,
                         step: int = 16, n_keys: int = 1536) -> Trace:
    """Staged scale-up then scale-down: capacity ramps in ``stages`` steps
    of ``step`` nodes and back (LIFO removals — every algorithm supports
    the scale-down leg, Jump included)."""
    ev: list[TraceEvent] = [TraceEvent("lookup", n_keys=n_keys)]
    for _ in range(stages):
        ev.append(TraceEvent("add", count=step))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
    for _ in range(stages):
        ev.append(TraceEvent("remove", count=step, select="lifo"))
        ev.append(TraceEvent("lookup", n_keys=n_keys))
    return Trace("staged_scaling", seed, w, ev,
                 meta={"stages": stages, "step": step})


def zipf_trace(seed: int = 0, *, w: int = 64, batches: int = 6,
               skew: float = 1.2, n_keys: int = 4096) -> Trace:
    """Zipf-skewed key traffic (hot keys dominate) across a mid-trace
    failure — balance of a consistent hash is over the KEY SPACE, so a
    skewed workload must still satisfy the placement guarantees while the
    per-bucket traffic is legitimately unequal."""
    ev: list[TraceEvent] = []
    for i in range(batches):
        ev.append(TraceEvent("lookup", n_keys=n_keys, dist="zipf", skew=skew))
        if i == batches // 2:
            ev.append(TraceEvent("remove", count=max(1, w // 8)))
    return Trace("zipf_traffic", seed, w, ev, meta={"skew": skew})


def session_affinity_trace(seed: int = 0, *, replicas: int = 8,
                           rounds: int = 6, sessions: int = 512,
                           fail_round: int = 2, restore_round: int = 4,
                           k: int = 2) -> Trace:
    """Session-affinity serving with failovers: a fixed session population
    routes every round through :class:`~repro.serve.router.SessionRouter`;
    mid-run a replica is marked failed (failover BEFORE the delta lands,
    DESIGN.md §4.3), then removed, then capacity is restored."""
    ev: list[TraceEvent] = []
    for rnd in range(rounds):
        if rnd == fail_round:
            ev.append(TraceEvent("mark_failed", select="first", sync=False))
            ev.append(TraceEvent("route", n_keys=sessions))  # failover path
            ev.append(TraceEvent("fail", select="first"))
        if rnd == restore_round:
            ev.append(TraceEvent("restore"))
        ev.append(TraceEvent("route", n_keys=sessions))
    return Trace("session_affinity", seed, replicas, ev,
                 meta={"sessions": sessions, "rounds": rounds,
                       "fail_round": fail_round, "replicas_k": k})


def serving_failure_trace(seed: int = 0, *, replicas: int = 4,
                          rounds: int = 6, fail_at: int = 3) -> Trace:
    """The churn script of ``examples/serve_cluster.py``: decode rounds
    with ONE mid-run replica failure (lowest id, the example's historical
    victim).  The example and the simulator replay this same trace, so the
    demo's churn path IS the scenario engine's."""
    ev: list[TraceEvent] = []
    for rnd in range(rounds):
        if rnd == fail_at:
            ev.append(TraceEvent("fail", select="first"))
        ev.append(TraceEvent("route", n_keys=1))  # one decode round
    return Trace("serving_failure", seed, replicas, ev,
                 meta={"rounds": rounds, "fail_at": fail_at})


#: name → generator registry; ``make_trace`` is the string-keyed entry the
#: benchmark and CLI use.  The first three are the paper's §VIII scenarios.
SCENARIOS = {
    "stable": stable_trace,
    "oneshot": oneshot_trace,
    "incremental": incremental_trace,
    "flapping": flapping_trace,
    "churn_storm": churn_storm_trace,
    "churn_storm_xl": churn_storm_xl_trace,
    "domain_outage": domain_outage_trace,
    "staged_scaling": staged_scaling_trace,
    "zipf_traffic": zipf_trace,
    "session_affinity": session_affinity_trace,
    "serving_failure": serving_failure_trace,
}


def make_trace(name: str, seed: int = 0, **kw) -> Trace:
    """Build a built-in scenario trace by name (see :data:`SCENARIOS`)."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r} "
                         f"(have: {', '.join(sorted(SCENARIOS))})")
    return SCENARIOS[name](seed, **kw)
