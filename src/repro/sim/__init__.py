"""Scenario engine — deterministic cluster-lifecycle simulation
(DESIGN.md §7).

The paper's evaluation is scenario-driven (§VIII: stable, one-shot
removal, incremental removals) and its headline claims are guarantees
under churn.  This package turns both into executable artifacts:

* :mod:`repro.sim.traces`   — declarative, seeded, JSON-replayable
  lifecycle scripts (the paper's three scenarios + flapping, churn
  storms, failure-domain outages, staged scaling, Zipf traffic,
  session-affinity serving),
* :mod:`repro.sim.driver`   — replays a trace through the REAL stack
  (host algorithms → epoch deltas → :class:`~repro.core.DeviceImageStore`
  → the unified engine / :class:`~repro.serve.router.SessionRouter` /
  :class:`~repro.serve.plane.ShardedLookupPlane`),
* :mod:`repro.sim.checkers` — per-event guarantee laws (minimal
  disruption, balance, replica stability, bounded-load caps) plus the
  graceful-degradation knee locator,
* :mod:`repro.sim.metrics`  — movement / delta-words / epoch-flip /
  throughput accumulation and the bit-for-bit replay fingerprint.

``benchmarks/bench_scenarios.py`` sweeps the registry across algorithms
and planes into ``BENCH_scenarios.json``.
"""
from .checkers import Violation, degradation_knee
from .driver import ScenarioDriver, ScenarioResult, pick_victim, replay, resolve_victims
from .metrics import EventRecord, ScenarioMetrics
from .traces import SCENARIOS, Trace, TraceEvent, make_trace

__all__ = [
    "EventRecord",
    "SCENARIOS",
    "ScenarioDriver",
    "ScenarioMetrics",
    "ScenarioResult",
    "Trace",
    "TraceEvent",
    "Violation",
    "degradation_knee",
    "make_trace",
    "pick_victim",
    "replay",
    "resolve_victims",
]
