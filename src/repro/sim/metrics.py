"""Scenario metrics — what one replay measured (DESIGN.md §7.4).

:class:`ScenarioMetrics` accumulates, per replayed trace:

* **movement** — probe keys moved per membership event (the engine's
  fused epoch diff), total and per event,
* **control plane** — 32-bit words transferred host→device per sync
  (delta vs snapshot, straight from ``DeviceImageStore``'s
  :class:`~repro.core.image_store.SyncStats`) and the epoch-flip latency,
* **data plane** — lookup/route throughput (µs/key) per traffic event,
* **degradation** — (fraction removed, mean host lookup steps) checkpoints
  for the graceful-degradation profile (paper Figs. 23–26),
* **fingerprint** — a running CRC over every data-plane result, the
  bit-for-bit replay-equivalence instrument (two replays agree iff every
  placement of every event agreed).

``summary()`` flattens it into the JSON-able dict
``benchmarks/bench_scenarios.py`` writes to ``BENCH_scenarios.json``.

Accounting rides the runtime telemetry primitives (DESIGN.md §11): the
accumulators are ``sim.*`` counters/histograms on a
:class:`~repro.obs.metrics.MetricRegistry` — the driver's scoped registry
when one is injected (``ScenarioDriver(telemetry=...)``), else a private
one — so replay summaries and live telemetry read the SAME numbers and
can never disagree.  With an injected live registry, ``summary()`` embeds
its full snapshot under ``"telemetry"``.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.obs.metrics import ensure_real


@dataclass
class EventRecord:
    """One replayed trace event (the replay log's unit)."""

    index: int
    op: str
    buckets: list[int] = field(default_factory=list)  # resolved victims/joiners
    moved: int = 0            # probe keys moved (membership events)
    sync_mode: str = ""       # "delta" | "snapshot" | "noop"
    sync_words: int = 0
    sync_us: float = 0.0      # epoch-flip latency (sync + device block)
    keys: int = 0             # traffic batch size (lookup/assign/route)
    us_per_key: float = 0.0
    violations: int = 0
    # overlapped sync (DESIGN.md §9): time to DISPATCH the async delta
    # apply (the only part the hot path pays) vs sync_us (the full
    # dispatch→flip→materialize latency); their gap is what overlap hides.
    dispatch_us: float = 0.0
    # cross-process replication: epochs the slowest follower was behind
    # when this event's publish round shipped (0 = already converged).
    follower_lag: int = 0
    # wire accounting for that publish round (launch/replicate.py): frames
    # the publisher encoded, bytes crossing any link (relays included),
    # and frame transmissions the LEADER paid — O(arity) per round under
    # the tree topology vs O(F) flat.
    wire_frames: int = 0
    wire_bytes: int = 0
    leader_sends: int = 0


class ScenarioMetrics:
    """Accumulator the driver feeds; one instance per replay.

    ``registry`` — a live :class:`~repro.obs.metrics.MetricRegistry` to
    accumulate on (the driver's telemetry plane); ``None`` gets a private
    one.  Either way the ``sim.*`` instruments on that registry ARE the
    accumulators ``summary()`` reads — there is no second bookkeeping.
    """

    #: membership ops whose movement/sync/wire fields feed the summary
    MEMBER_OPS = ("remove", "add", "fail", "restore")

    def __init__(self, registry=None) -> None:
        self.obs = ensure_real(registry)
        self._embed = registry is not None and getattr(registry, "active",
                                                       False)
        self.records: list[EventRecord] = []
        self.degradation: list[tuple[float, float]] = []
        self.followers = 0  # in-process replication followers attached
        self.fanout_depth = 0  # relay hops leader → farthest follower
        self._crc = 0
        # per-op traffic is labelled, not blended: lookup, assign, and
        # route timings are different code paths
        self._ops: set[str] = set()

    # -- feeding -----------------------------------------------------------
    def add_record(self, rec: EventRecord) -> None:
        self.records.append(rec)
        reg = self.obs
        reg.counter("sim.events").inc()
        if rec.violations:
            reg.counter("sim.violations").inc(rec.violations)
        if rec.op in self.MEMBER_OPS:
            reg.counter("sim.membership_events").inc(len(rec.buckets))
            if rec.moved:
                reg.counter("sim.moved_probe").inc(rec.moved)
            if rec.sync_mode == "delta":
                reg.counter("sim.delta_applies").inc()
                reg.counter("sim.delta_words").inc(rec.sync_words)
            elif rec.sync_mode == "snapshot":
                reg.counter("sim.snapshot_rebuilds").inc()
                reg.counter("sim.snapshot_words").inc(rec.sync_words)
            if rec.sync_mode:
                reg.histogram("sim.sync.us").observe(rec.sync_us)
                if rec.dispatch_us:
                    reg.histogram("sim.dispatch.us").observe(rec.dispatch_us)
            if rec.wire_frames:
                reg.counter("sim.wire_frames").inc(rec.wire_frames)
                reg.counter("sim.wire_bytes").inc(rec.wire_bytes)
                reg.counter("sim.leader_sends").inc(rec.leader_sends)
        if rec.keys and rec.us_per_key:
            self._ops.add(rec.op)
            reg.counter("sim.traffic_keys", op=rec.op).inc(rec.keys)
            reg.histogram("sim.traffic_s", op=rec.op).observe(
                rec.us_per_key * rec.keys / 1e6)

    def fingerprint_update(self, arr: np.ndarray) -> None:
        """Fold a data-plane result into the replay fingerprint."""
        a = np.ascontiguousarray(np.asarray(arr, dtype=np.int64))
        self._crc = zlib.crc32(a.tobytes(), self._crc)

    def add_degradation_point(self, frac_removed: float,
                              mean_steps: float) -> None:
        self.degradation.append((float(frac_removed), float(mean_steps)))

    # -- reading -----------------------------------------------------------
    @property
    def fingerprint(self) -> str:
        return f"{self._crc & 0xFFFFFFFF:08x}"

    def summary(self) -> dict:
        reg = self.obs

        def c(name: str, **labels) -> int:
            return reg.counter(name, **labels).value

        flips = reg.histogram("sim.sync.us")
        out = {
            "events": c("sim.events"),
            "membership_events": c("sim.membership_events"),
            "moved_probe_total": c("sim.moved_probe"),
            "delta_words_total": c("sim.delta_words"),
            "snapshot_words_total": c("sim.snapshot_words"),
            "snapshot_rebuilds": c("sim.snapshot_rebuilds"),
            "delta_applies": c("sim.delta_applies"),
            "epoch_flip_us_mean": flips.mean if flips.count else 0.0,
            "violations": c("sim.violations"),
            "fingerprint": self.fingerprint,
        }
        dispatch = reg.histogram("sim.dispatch.us")
        if dispatch.count:
            out["sync_dispatch_us_mean"] = dispatch.mean
        if self.followers:
            lags = [r.follower_lag for r in self.records
                    if r.op in self.MEMBER_OPS]
            out["followers"] = self.followers
            out["follower_lag_max"] = int(max(lags, default=0))
            out["follower_lag_mean"] = float(np.mean(lags)) if lags else 0.0
            out["fanout_depth"] = self.fanout_depth
            out["wire_frames_total"] = c("sim.wire_frames")
            out["wire_bytes_total"] = c("sim.wire_bytes")
            out["leader_sends_total"] = c("sim.leader_sends")
        for op in sorted(self._ops):
            keys = c("sim.traffic_keys", op=op)
            out[f"{op}_keys_total"] = keys
            out[f"{op}_us_per_key"] = (
                reg.histogram("sim.traffic_s", op=op).sum / keys * 1e6)
        if self.degradation:
            out["degradation"] = [[f, s] for f, s in self.degradation]
        if self._embed:
            # the full serving-stack registry snapshot rides along into
            # BENCH_scenarios.json (the ISSUE's telemetry-snapshot table)
            out["telemetry"] = self.obs.snapshot()
        return out
