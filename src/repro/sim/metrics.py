"""Scenario metrics — what one replay measured (DESIGN.md §7.4).

:class:`ScenarioMetrics` accumulates, per replayed trace:

* **movement** — probe keys moved per membership event (the engine's
  fused epoch diff), total and per event,
* **control plane** — 32-bit words transferred host→device per sync
  (delta vs snapshot, straight from ``DeviceImageStore``'s
  :class:`~repro.core.image_store.SyncStats`) and the epoch-flip latency,
* **data plane** — lookup/route throughput (µs/key) per traffic event,
* **degradation** — (fraction removed, mean host lookup steps) checkpoints
  for the graceful-degradation profile (paper Figs. 23–26),
* **fingerprint** — a running CRC over every data-plane result, the
  bit-for-bit replay-equivalence instrument (two replays agree iff every
  placement of every event agreed).

``summary()`` flattens it into the JSON-able dict
``benchmarks/bench_scenarios.py`` writes to ``BENCH_scenarios.json``.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np


@dataclass
class EventRecord:
    """One replayed trace event (the replay log's unit)."""

    index: int
    op: str
    buckets: list[int] = field(default_factory=list)  # resolved victims/joiners
    moved: int = 0            # probe keys moved (membership events)
    sync_mode: str = ""       # "delta" | "snapshot" | "noop"
    sync_words: int = 0
    sync_us: float = 0.0      # epoch-flip latency (sync + device block)
    keys: int = 0             # traffic batch size (lookup/assign/route)
    us_per_key: float = 0.0
    violations: int = 0
    # overlapped sync (DESIGN.md §9): time to DISPATCH the async delta
    # apply (the only part the hot path pays) vs sync_us (the full
    # dispatch→flip→materialize latency); their gap is what overlap hides.
    dispatch_us: float = 0.0
    # cross-process replication: epochs the slowest follower was behind
    # when this event's publish round shipped (0 = already converged).
    follower_lag: int = 0
    # wire accounting for that publish round (launch/replicate.py): frames
    # the publisher encoded, bytes crossing any link (relays included),
    # and frame transmissions the LEADER paid — O(arity) per round under
    # the tree topology vs O(F) flat.
    wire_frames: int = 0
    wire_bytes: int = 0
    leader_sends: int = 0


class ScenarioMetrics:
    """Accumulator the driver feeds; one instance per replay."""

    def __init__(self) -> None:
        self.records: list[EventRecord] = []
        self.degradation: list[tuple[float, float]] = []
        self.followers = 0  # in-process replication followers attached
        self.fanout_depth = 0  # relay hops leader → farthest follower
        self._crc = 0
        # per-op traffic accumulators: lookup, assign, and route timings
        # are different code paths and must not blend into one number
        self._keys: dict[str, int] = {}
        self._secs: dict[str, float] = {}

    # -- feeding -----------------------------------------------------------
    def add_record(self, rec: EventRecord) -> None:
        self.records.append(rec)
        if rec.keys and rec.us_per_key:
            self._keys[rec.op] = self._keys.get(rec.op, 0) + rec.keys
            self._secs[rec.op] = (self._secs.get(rec.op, 0.0)
                                  + rec.us_per_key * rec.keys / 1e6)

    def fingerprint_update(self, arr: np.ndarray) -> None:
        """Fold a data-plane result into the replay fingerprint."""
        a = np.ascontiguousarray(np.asarray(arr, dtype=np.int64))
        self._crc = zlib.crc32(a.tobytes(), self._crc)

    def add_degradation_point(self, frac_removed: float,
                              mean_steps: float) -> None:
        self.degradation.append((float(frac_removed), float(mean_steps)))

    # -- reading -----------------------------------------------------------
    @property
    def fingerprint(self) -> str:
        return f"{self._crc & 0xFFFFFFFF:08x}"

    def summary(self) -> dict:
        recs = self.records
        member = [r for r in recs if r.op in ("remove", "add", "fail",
                                              "restore")]
        syncs = [r for r in member if r.sync_mode]
        out = {
            "events": len(recs),
            "membership_events": sum(len(r.buckets) for r in member),
            "moved_probe_total": sum(r.moved for r in member),
            "delta_words_total": sum(r.sync_words for r in syncs
                                     if r.sync_mode == "delta"),
            "snapshot_words_total": sum(r.sync_words for r in syncs
                                        if r.sync_mode == "snapshot"),
            "snapshot_rebuilds": sum(r.sync_mode == "snapshot" for r in syncs),
            "delta_applies": sum(r.sync_mode == "delta" for r in syncs),
            "epoch_flip_us_mean": (float(np.mean([r.sync_us for r in syncs]))
                                   if syncs else 0.0),
            "violations": sum(r.violations for r in recs),
            "fingerprint": self.fingerprint,
        }
        overlapped = [r for r in syncs if r.dispatch_us]
        if overlapped:
            out["sync_dispatch_us_mean"] = float(
                np.mean([r.dispatch_us for r in overlapped]))
        if self.followers:
            lags = [r.follower_lag for r in member]
            out["followers"] = self.followers
            out["follower_lag_max"] = int(max(lags, default=0))
            out["follower_lag_mean"] = float(np.mean(lags)) if lags else 0.0
            out["fanout_depth"] = self.fanout_depth
            out["wire_frames_total"] = sum(r.wire_frames for r in member)
            out["wire_bytes_total"] = sum(r.wire_bytes for r in member)
            out["leader_sends_total"] = sum(r.leader_sends for r in member)
        for op, keys in self._keys.items():
            out[f"{op}_keys_total"] = keys
            out[f"{op}_us_per_key"] = self._secs[op] / keys * 1e6
        if self.degradation:
            out["degradation"] = [[f, s] for f, s in self.degradation]
        return out
