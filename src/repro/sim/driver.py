"""Trace replay through the real production stack (DESIGN.md §7.2).

:class:`ScenarioDriver` feeds a :class:`~repro.sim.traces.Trace` event by
event through the exact objects that serve traffic in this repo — never a
parallel reimplementation:

* membership events mutate the host :class:`~repro.core.ConsistentHash`
  (its :class:`~repro.core.protocol.DeltaEmitter` log records the deltas),
* every sync drains ``device_delta()`` into the driver's
  :class:`~repro.core.DeviceImageStore` (double-buffered epoch flip),
* traffic runs the unified engine (``store.lookup`` → one jitted jnp
  program or one Pallas launch; ``plane="host"`` runs the scalar host
  control plane instead), bounded assignment runs
  :func:`repro.kernels.engine.bounded_assign`, session traffic runs a
  :class:`~repro.serve.router.SessionRouter` **sharing the driver's
  store**, and ``sharded=True`` fans lookups through a
  :class:`~repro.serve.plane.ShardedLookupPlane`,
* after each synced membership event the guarantee checkers
  (:mod:`repro.sim.checkers`) interrogate the engine's fused epoch diff
  over a fixed probe batch.

Determinism: victims come from one seeded stream, traffic keys from a
second (both derived from ``trace.seed``), so a replay of the **resolved**
trace (explicit victims, no membership randomness) draws identical
traffic and reproduces every placement bit-for-bit —
``result.metrics.fingerprint`` is the equality instrument.

Cross-plane equality holds whenever traffic runs at a synced epoch (every
built-in trace).  During an *unsynced* window (``sync=False`` membership
still pending) the planes intentionally diverge the way production does
(DESIGN.md §3.5): the host control plane answers from the live membership
while the device planes keep serving the last synced epoch — stale but
consistent.  The epoch catches up at the next sync, after which the
fingerprints track again only if both sides looked up the same epochs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import DeviceImageStore, make_hash
from repro.core.hashing import np_fmix32
from repro.core.protocol import ALGORITHM_REGISTRY, replica_sets

from .checkers import (Violation, candidate_hits, check_balance,
                       check_cap_invariant, check_follower_convergence,
                       check_minimal_disruption, check_replica_stability)
from .metrics import EventRecord, ScenarioMetrics
from .traces import Trace, TraceEvent

PLANES = ("host", "jnp", "pallas")


def pick_victim(h, select: str, rng: np.random.Generator,
                bucket: int | None = None) -> int:
    """Resolve ONE removal victim against the live working set.

    The single churn-victim rule shared by the scenario driver and
    ``examples/serve_cluster.py``.  LIFO-only algorithms (Jump, Power)
    degrade every policy to LIFO (their only legal removal); explicit
    ``bucket`` wins over any policy.
    """
    if bucket is not None:
        return bucket
    if ALGORITHM_REGISTRY[h.name].lifo_only:
        return h.size - 1
    ws = sorted(h.working_set())
    if select == "lifo":
        return ws[-1]
    if select == "first":
        return ws[0]
    if select == "random":
        return ws[int(rng.integers(len(ws)))]
    raise ValueError(f"unresolvable victim policy {select!r}")


def resolve_victims(h, ev: TraceEvent, rng: np.random.Generator,
                    num_domains: int | None = None) -> list[int]:
    """The whole burst's victims, resolved BEFORE any removal mutates the
    state (so replica-stability candidates can be walked on the pre-event
    state).  Always leaves at least one working bucket."""
    budget = h.working - 1
    if ev.select == "domain":
        nd = num_domains or 1
        members = [b for b in sorted(h.working_set()) if b % nd == ev.domain]
        if ALGORITHM_REGISTRY[h.name].lifo_only:  # no arbitrary victims: a
            # LIFO burst of the same size keeps the lifecycle comparable
            return [h.size - 1 - i for i in range(min(len(members), budget))]
        return members[:budget]
    count = min(ev.count, budget)
    if ev.bucket is not None:
        return [ev.bucket]
    if ALGORITHM_REGISTRY[h.name].lifo_only:
        return [h.size - 1 - i for i in range(count)]
    ws = np.asarray(sorted(h.working_set()))
    if ev.select == "random":
        return [int(b) for b in rng.choice(ws, size=count, replace=False)]
    if ev.select == "lifo":
        return [int(b) for b in ws[::-1][:count]]
    if ev.select == "first":
        return [int(b) for b in ws[:count]]
    raise ValueError(f"unresolvable victim policy {ev.select!r}")


@dataclass
class ScenarioResult:
    """One replay: metrics, violations, and the resolved (replayable) trace."""

    trace: Trace
    algo: str
    plane: str
    metrics: ScenarioMetrics
    violations: list[Violation] = field(default_factory=list)
    resolved: Trace | None = None
    final_working: int = 0
    final_epoch: int = 0

    @property
    def fingerprint(self) -> str:
        return self.metrics.fingerprint

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> dict:
        out = {"scenario": self.trace.name, "algo": self.algo,
               "plane": self.plane, "seed": self.trace.seed,
               "initial_nodes": self.trace.initial_nodes,
               "final_working": self.final_working,
               "final_epoch": self.final_epoch}
        out.update(self.metrics.summary())
        return out


class ScenarioDriver:
    """Replay one trace over one algorithm on one plane (see module doc)."""

    def __init__(self, trace: Trace, *, algo: str = "memento",
                 plane: str = "jnp", probe_keys: int = 2048,
                 replica_k: int = 1, check: bool = True,
                 sharded: bool = False, step_sample: int = 256,
                 balance_tol: float = 6.0, sync_mode: str = "block",
                 followers: int = 0, repl_config: dict | None = None,
                 telemetry=False):
        if plane not in PLANES:
            raise ValueError(f"unknown plane {plane!r} (have {PLANES})")
        if sync_mode not in ("block", "overlap"):
            raise ValueError(f"unknown sync_mode {sync_mode!r}")
        self.trace = trace
        self.algo = algo
        self.plane = plane
        self.check = check
        self.replica_k = replica_k
        self.balance_tol = balance_tol
        # "overlap": membership syncs dispatch via sync_async() and the
        # driver commits at the checker boundary — records both dispatch_us
        # (the hot path's cost) and sync_us (the full flip latency), with
        # checker semantics and replay fingerprints unchanged vs "block".
        self.sync_mode = sync_mode
        # telemetry plane (DESIGN.md §11): False → off (every component
        # falls through to the process default, normally a NullRegistry);
        # True → a fresh scoped MetricRegistry; a registry object → used
        # as-is.  The scoped registry is injected into every serving
        # component below AND installed as the process default for the
        # duration of run(), so module-level instrumentation (engine
        # dispatch, autotune) lands on it too.
        if telemetry:
            from repro.obs.metrics import MetricRegistry
            self.obs = (telemetry if getattr(telemetry, "active", False)
                        else MetricRegistry())
        else:
            self.obs = None
        self.h = make_hash(algo, trace.initial_nodes,
                           capacity=trace.capacity_factor * trace.initial_nodes,
                           variant="32")
        # the ONE store every consumer shares (router included); the host
        # plane still needs it for delta bookkeeping and the epoch diff
        self.store = DeviceImageStore(
            self.h, plane="jnp" if plane == "host" else plane,
            registry=self.obs)
        # independent streams: membership victims vs traffic keys — a
        # resolved-trace replay consumes no membership randomness yet must
        # draw identical traffic (see module doc)
        self._rng_member = np.random.default_rng([trace.seed, 0])
        self._rng_traffic = np.random.default_rng([trace.seed, 1])
        self.probe = np.random.default_rng([trace.seed, 2]).integers(
            0, 2**32, size=probe_keys, dtype=np.uint32)
        self._step_sample = self.probe[:step_sample]
        self.metrics = ScenarioMetrics(registry=self.obs)
        self.violations: list[Violation] = []
        self._router = None
        self._sharded = sharded
        self._planes_sharded: dict = {}  # k → ShardedLookupPlane
        # membership applied since the last sync (checker comparands)
        self._pending_removed: set[int] = set()
        self._pending_added: set[int] = set()
        self._pending_hits: np.ndarray | None = None
        self._resolved_events: list[TraceEvent] = []
        self._route_prev: np.ndarray | None = None
        # in-process follower replicas (launch/replicate.py): every synced
        # membership event publishes the pending epochs and the convergence
        # checker compares fingerprints leader-vs-follower.  repl_config
        # passes topology/batching/packing straight to ReplicationGroup
        # (e.g. {"topology": "tree", "arity": 4, "batch_epochs": 0,
        # "packed": True}).
        self._repl = None
        if followers:
            from repro.launch.replicate import ReplicationGroup
            self._repl = ReplicationGroup(
                self.h, followers,
                plane="jnp" if plane == "host" else plane,
                registry=self.obs,
                **(repl_config or {}))
            self._repl.publish()  # initial snapshot frame
            self.metrics.followers = followers
            self.metrics.fanout_depth = self._repl.depth

    # -- consumers ----------------------------------------------------------
    @property
    def router(self):
        """Lazy SessionRouter sharing the driver's host state AND store, so
        router-driven membership events ride the same epoch deltas."""
        if self._router is None:
            from repro.serve.router import SessionRouter
            self._router = SessionRouter(
                0, algo=self.h, store=self.store,
                use_device_plane=(self.plane == "pallas"),
                replicas_k=self.trace.meta.get("replicas_k", 1),
                sync_mode=self.sync_mode, registry=self.obs)
        return self._router

    # -- traffic ------------------------------------------------------------
    def _draw_keys(self, ev: TraceEvent) -> np.ndarray:
        if ev.dist == "zipf":
            ranks = self._rng_traffic.zipf(ev.skew, size=ev.n_keys)
            return np_fmix32((ranks % (2**32)).astype(np.uint32))
        return self._rng_traffic.integers(0, 2**32, size=ev.n_keys,
                                          dtype=np.uint32)

    def _lookup(self, keys: np.ndarray, k: int = 1) -> np.ndarray:
        k = min(k, self.h.working)
        if self.plane == "host":
            if k == 1:
                return np.asarray([self.h.lookup(int(x)) for x in keys],
                                  dtype=np.int32)
            return replica_sets(self.h, keys, k)
        if self._sharded:
            plane = self._planes_sharded.get(k)
            if plane is None:
                from repro.serve.plane import ShardedLookupPlane
                plane = self._planes_sharded[k] = ShardedLookupPlane(
                    self.store, k=k, plane=self.plane,  # host returned above
                    registry=self.obs)
            return np.asarray(plane.lookup(keys))
        return self.store.lookup(keys, k=k)

    # -- the event loop ------------------------------------------------------
    def run(self) -> ScenarioResult:
        # install the scoped telemetry registry as the process default for
        # the replay so module-level instrumentation (engine_lookup,
        # autotune) records here too; always restored on the way out.
        prev = None
        if self.obs is not None:
            from repro.obs.metrics import set_default_registry
            prev = set_default_registry(self.obs)
        try:
            for i, ev in enumerate(self.trace.events):
                handler = getattr(self, f"_do_{ev.op}")
                handler(i, ev)
        finally:
            if self.obs is not None:
                from repro.obs.metrics import set_default_registry
                set_default_registry(prev)
        res = ScenarioResult(
            trace=self.trace, algo=self.algo, plane=self.plane,
            metrics=self.metrics, violations=self.violations,
            resolved=Trace(name=f"{self.trace.name}/resolved",
                           seed=self.trace.seed,
                           initial_nodes=self.trace.initial_nodes,
                           capacity_factor=self.trace.capacity_factor,
                           num_domains=self.trace.num_domains,
                           meta=dict(self.trace.meta),
                           events=self._resolved_events),
            final_working=self.h.working,
            final_epoch=self.h.epoch)
        return res

    # -- membership ----------------------------------------------------------
    def _do_remove(self, i: int, ev: TraceEvent) -> None:
        victims = resolve_victims(self.h, ev, self._rng_member,
                                  self.trace.num_domains)
        self._pre_membership(set(victims))
        for j, b in enumerate(victims):
            self.h.remove(b)
            self._resolved_events.append(TraceEvent(
                "remove", bucket=b, sync=ev.sync and j == len(victims) - 1))
        if not victims:
            # a collapsed fleet clamps the burst to nothing, but the event's
            # sync must survive into the resolved trace (it may flush EARLIER
            # unsynced removals); re-emitting the abstract event resolves to
            # zero victims again on replay, then syncs identically
            self._resolved_events.append(TraceEvent(
                "remove", count=ev.count, select=ev.select, bucket=ev.bucket,
                domain=ev.domain, sync=ev.sync))
        self._pending_removed.update(victims)
        self._finish_membership(i, "remove", victims, ev.sync)

    def _do_add(self, i: int, ev: TraceEvent) -> None:
        joiners = []
        for _ in range(ev.count):
            try:
                joiners.append(self.h.add())
            except ValueError:
                break  # fixed-capacity baseline exhausted: recorded no-op
        self._resolved_events.append(TraceEvent(
            "add", count=max(len(joiners), 1), sync=ev.sync))
        self._pending_added.update(joiners)
        # a restore of a bucket whose removal is still pending cancels it
        self._pending_removed -= set(joiners)
        self._finish_membership(i, "add", joiners, ev.sync)

    def _do_fail(self, i: int, ev: TraceEvent) -> None:
        b = pick_victim(self.h, ev.select, self._rng_member, ev.bucket)
        self._pre_membership({b})
        t0 = time.perf_counter()  # the flip happens inside fail_replica
        self.router.fail_replica(b)  # removes + syncs the shared store
        self._resolved_events.append(TraceEvent("fail", bucket=b))
        self._pending_removed.add(b)
        self._finish_membership(i, "fail", [b], sync=True, synced=True,
                                t0=t0)

    def _do_restore(self, i: int, ev: TraceEvent) -> None:
        joiners = []
        t0 = time.perf_counter()  # the flips happen inside restore_replica
        for _ in range(ev.count):
            try:
                joiners.append(self.router.restore_replica())  # adds + syncs
            except ValueError:
                break
        self._resolved_events.append(TraceEvent(
            "restore", count=max(len(joiners), 1)))
        self._pending_added.update(joiners)
        self._pending_removed -= set(joiners)
        self._finish_membership(i, "restore", joiners, sync=True,
                                synced=True, t0=t0)

    def _do_mark_failed(self, i: int, ev: TraceEvent) -> None:
        b = pick_victim(self.h, ev.select, self._rng_member, ev.bucket)
        self.router.mark_failed(b)
        self._resolved_events.append(TraceEvent("mark_failed", bucket=b,
                                                sync=False))
        self.metrics.add_record(EventRecord(i, "mark_failed", buckets=[b]))

    def _pre_membership(self, victims: set[int]) -> None:
        """Walk the replica-stability candidates on the PRE-event state."""
        if self.check and self.replica_k > 1 and not self._pending_added:
            hits = candidate_hits(self.h, self.probe, self.replica_k, victims)
            if self._pending_hits is None:
                self._pending_hits = hits
            else:
                self._pending_hits |= hits

    def _finish_membership(self, i: int, op: str, buckets: list[int],
                           sync: bool, synced: bool = False,
                           t0: float | None = None) -> None:
        """``t0`` lets router-driven events (whose store sync already ran
        inside fail_replica/restore_replica) start the flip clock before
        that call, so sync_us means the same thing for every event kind."""
        rec = EventRecord(i, op, buckets=list(buckets))
        if sync:
            if t0 is None:
                t0 = time.perf_counter()
            if not synced:
                if self.sync_mode == "overlap":
                    # dispatch without flipping: dispatch_us is all the hot
                    # path would pay; the commit below closes the epoch at
                    # the checker boundary so semantics match "block".
                    self.store.sync_async()
                    rec.dispatch_us = (time.perf_counter() - t0) * 1e6
                else:
                    self.store.sync()
            # router-driven events in overlap mode also leave a pending
            # handle (the router's _push_delta is async): land it before
            # the checkers interrogate the flipped image.
            self.store.flush()
            for arr in self.store.image().arrays.values():
                if hasattr(arr, "block_until_ready"):
                    arr.block_until_ready()
            rec.sync_us = (time.perf_counter() - t0) * 1e6
            st = self.store.last_sync
            if st is not None:
                rec.sync_mode, rec.sync_words = st.mode, st.words
            conv: list[Violation] = []
            if self._repl is not None:
                rec.follower_lag = max(self._repl.publish(), default=0)
                last = self._repl.last_publish
                rec.wire_frames = last["frames"]
                rec.wire_bytes = last["bytes"]
                rec.leader_sends = last["leader_sends"]
                if self.check:
                    conv = check_follower_convergence(
                        i, self.store.image(), self._repl.followers)
                    self.violations.extend(conv)
            rec.violations = len(self._run_checkers(i, rec)) + len(conv)
            self._degradation_point()
            self._pending_removed.clear()
            self._pending_added.clear()
            self._pending_hits = None
        self.metrics.add_record(rec)

    # -- checkers ------------------------------------------------------------
    def _run_checkers(self, i: int, rec: EventRecord) -> list[Violation]:
        if not (self._pending_removed or self._pending_added):
            return []
        diff_plane = "jnp" if self.plane == "host" else self.plane
        if self.store.previous_image() is None:
            return []
        d = self.store.migration_diff(self.probe, plane=diff_plane)
        rec.moved = int(d.num_moved)
        self.metrics.fingerprint_update(np.asarray(d.new))
        if not self.check:
            return []
        found = check_minimal_disruption(i, d.old, d.new,
                                         self._pending_removed,
                                         self._pending_added)
        found += check_balance(i, d.new, sorted(self.h.working_set()),
                               tol_sigma=self.balance_tol)
        if (self.replica_k > 1 and self._pending_hits is not None
                and not self._pending_added
                and self.h.working >= self.replica_k):
            dk = self.store.migration_diff(self.probe, plane=diff_plane,
                                           k=self.replica_k)
            found += check_replica_stability(i, dk.moved, self._pending_hits)
        self.violations.extend(found)
        return found

    def _degradation_point(self) -> None:
        """(fraction removed, mean host lookup steps) — the graceful-
        degradation profile instrument (paper Figs. 23–26).  The fraction
        is of the initial working fleet (not the fixed-capacity ``a``),
        clamped at 0 when a scale-up grew past it."""
        w0 = max(self.trace.initial_nodes, 1)
        frac = max(0.0, 1.0 - self.h.working / w0)
        steps = [sum(self.h.lookup_trace(int(x))[1:])
                 for x in self._step_sample]
        self.metrics.add_degradation_point(frac, float(np.mean(steps)))

    # -- traffic events --------------------------------------------------------
    def _do_lookup(self, i: int, ev: TraceEvent) -> None:
        keys = self._draw_keys(ev)
        t0 = time.perf_counter()
        out = self._lookup(keys, k=ev.k)
        out = np.asarray(out)
        us = (time.perf_counter() - t0) / max(len(keys), 1) * 1e6
        self.metrics.fingerprint_update(out)
        self._resolved_events.append(ev)
        self.metrics.add_record(EventRecord(i, "lookup", keys=len(keys),
                                            us_per_key=us))

    def _do_assign(self, i: int, ev: TraceEvent) -> None:
        from repro.core.bounded import bounded_assign_ref
        from repro.kernels.engine import bounded_assign, bounded_load_len

        keys = self._draw_keys(ev)
        cap = int(np.ceil(ev.cap_c * len(keys) / self.h.working))
        image = self.store.image()
        load0 = np.zeros(bounded_load_len(image), np.int32)
        t0 = time.perf_counter()
        if self.plane == "host":
            out, load = bounded_assign_ref(self.h, keys, load0, cap)
        else:
            out, load = bounded_assign(keys, image, load0, cap,
                                       plane=self.plane)
        us = (time.perf_counter() - t0) / max(len(keys), 1) * 1e6
        self.metrics.fingerprint_update(np.asarray(out))
        found = check_cap_invariant(i, out, load, cap) if self.check else []
        self.violations.extend(found)
        self._resolved_events.append(ev)
        self.metrics.add_record(EventRecord(i, "assign", keys=len(keys),
                                            us_per_key=us,
                                            violations=len(found)))

    def _do_route(self, i: int, ev: TraceEvent) -> None:
        ids = np.arange(ev.n_keys, dtype=np.uint64)  # fixed session fleet
        t0 = time.perf_counter()
        if self.plane == "host":
            out = np.asarray([self.router.route(int(s)) for s in ids],
                             dtype=np.int32)
        else:
            out = np.asarray(self.router.route_batch(ids))
            self.router.stats.routed += len(ids)  # the bulk path skips this
        us = (time.perf_counter() - t0) / max(len(ids), 1) * 1e6
        self.metrics.fingerprint_update(out)
        rec = EventRecord(i, "route", keys=len(ids), us_per_key=us)
        # session affinity: how many sessions changed replica vs the
        # previous round (0 between uneventful rounds = warm KV caches)
        if self._route_prev is not None and len(self._route_prev) == len(out):
            rec.moved = int((out != self._route_prev).sum())
        self._route_prev = out
        self._resolved_events.append(ev)
        self.metrics.add_record(rec)


def replay(trace: Trace, *, algo: str = "memento", plane: str = "jnp",
           **kw) -> ScenarioResult:
    """One-call replay: build a :class:`ScenarioDriver` and run it."""
    return ScenarioDriver(trace, algo=algo, plane=plane, **kw).run()
