"""Guarantee checkers — the paper's theorems as executable per-event laws.

Each checker inspects one replayed membership event through the device
plane's own instruments (the engine's fused epoch diff, the store's sync
stats, the host ``lookup_k_trace`` candidate walk) and returns a list of
:class:`Violation` records — empty means the guarantee held exactly.

The laws (DESIGN.md §7.3, keyed to the paper):

* **minimal disruption** (paper Thm. VI.2 / §II): between two epochs
  separated by removals ``D`` and additions ``A``, a key moves **iff** its
  old bucket is in ``D`` (those MUST move) or its new bucket is in ``A``
  (monotonicity: joiners only steal, leavers only shed), and no key may
  land on a removed bucket.
* **balance** (paper Thm. VI.1 / §II): placements of a fixed probe batch
  are multinomial-uniform over working buckets — every bucket's count
  stays within ``tol_sigma`` binomial standard deviations (+ a small
  absolute slack) of the mean, and the normalized coefficient of variation
  (observed CV ÷ multinomial CV ``sqrt(w/n)``) is recorded.
* **replica stability** (DESIGN.md §4.1 disruption bound): a key's
  k-replica set may change on a removal only if the removed bucket
  appeared among its salted-walk *candidates* (``lookup_k_trace``) — the
  per-slot analogue of minimal disruption.
* **bounded-load cap** (Mirrokni et al., PAPERS.md): after an assignment
  no bucket exceeds ``cap``, and every returned bucket was below the cap.
* **degradation profile** (paper §VIII / Fig. 23–26): mean host lookup
  steps vs fraction removed; :func:`degradation_knee` locates the knee —
  the paper's worst-case story keeps Memento flat to ~70 % removed.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Violation:
    """One broken guarantee at one replayed event."""

    event: int       # trace event index
    checker: str     # "minimal_disruption" | "balance" | ...
    detail: str

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"[event {self.event}] {self.checker}: {self.detail}"


# ---------------------------------------------------------------------------
# minimal disruption / monotonicity (exact)
# ---------------------------------------------------------------------------

def check_minimal_disruption(event: int, old: np.ndarray, new: np.ndarray,
                             removed: set[int], added: set[int]) -> list[Violation]:
    """Exact per-event law over a probe batch's two-epoch placements.

    ``old``/``new`` are the engine diff's per-key buckets (k=1).  For a
    pure removal burst ``added`` is empty and the law collapses to the
    paper's minimal disruption: moved == {old ∈ removed}; for a pure
    addition it is monotonicity: moved ⊆ {new ∈ added}; a mixed burst
    composes both.
    """
    old = np.asarray(old).reshape(-1)
    new = np.asarray(new).reshape(-1)
    moved = old != new
    out: list[Violation] = []
    must_move = np.isin(old, sorted(removed)) if removed else np.zeros(len(old), bool)
    may_move = must_move | (np.isin(new, sorted(added)) if added
                            else np.zeros(len(old), bool))
    stranded = int((must_move & ~moved).sum())
    if stranded:
        out.append(Violation(event, "minimal_disruption",
                             f"{stranded} keys stayed on removed buckets"))
    extra = int((moved & ~may_move).sum())
    if extra:
        out.append(Violation(event, "minimal_disruption",
                             f"{extra} keys moved without their bucket "
                             "leaving or a joiner claiming them"))
    if removed:
        landed = int(np.isin(new, sorted(removed)).sum())
        if landed:
            out.append(Violation(event, "minimal_disruption",
                                 f"{landed} keys landed ON removed buckets"))
    return out


# ---------------------------------------------------------------------------
# balance (ε-of-uniform over a fixed probe batch)
# ---------------------------------------------------------------------------

def balance_profile(placements: np.ndarray, working: list[int] | np.ndarray
                    ) -> dict:
    """Per-bucket counts + normalized CV of a placement batch.

    ``cv_normalized`` divides the observed CV by the multinomial CV
    ``sqrt(w/n)`` — ≈ 1 is hash-noise-level balance (the normalization the
    repo's quality benchmark uses)."""
    working = np.asarray(sorted(working), dtype=np.int64)
    placements = np.asarray(placements).reshape(-1)
    counts = np.bincount(placements, minlength=int(working.max()) + 1)[working]
    n, w = len(placements), len(working)
    mean = n / w
    cv = float(counts.std() / mean) if mean else 0.0
    return {"counts": counts, "mean": mean,
            "cv_normalized": cv / float(np.sqrt(w / n)) if n else 0.0}


def check_balance(event: int, placements: np.ndarray,
                  working: list[int] | np.ndarray, *, tol_sigma: float = 6.0,
                  slack: int = 8, min_mean: float = 8.0) -> list[Violation]:
    """No working bucket holds more than ``mean + tol_sigma·√mean + slack``
    probe keys.  The binomial 6σ tail is ≈ 1e-9 per bucket, so on a correct
    algorithm this never fires; skipped when the probe batch is too small
    for the bound to mean anything (``mean < min_mean``)."""
    prof = balance_profile(placements, working)
    if prof["mean"] < min_mean:
        return []
    bound = prof["mean"] + tol_sigma * np.sqrt(prof["mean"]) + slack
    peak = int(prof["counts"].max())
    if peak > bound:
        return [Violation(event, "balance",
                          f"peak bucket holds {peak} keys > ε-bound "
                          f"{bound:.1f} (mean {prof['mean']:.1f}, "
                          f"cv_norm {prof['cv_normalized']:.2f})")]
    return []


# ---------------------------------------------------------------------------
# replica-set stability (bound via the candidate walk)
# ---------------------------------------------------------------------------

def candidate_hits(h, probe_keys: np.ndarray, k: int,
                   victims: set[int]) -> np.ndarray:
    """Which probe keys' salted-walk candidates include a victim bucket —
    computed on the PRE-event host state with the production instrument
    ``lookup_k_trace`` (protocol.py).  A superset mask of the keys whose
    replica set is allowed to change when ``victims`` are removed."""
    kk = min(k, h.working)
    hits = np.zeros(len(probe_keys), bool)
    for i, key in enumerate(np.asarray(probe_keys)):
        _, cands = h.lookup_k_trace(int(key), kk)
        hits[i] = any(c in victims for c in cands)
    return hits


def check_replica_stability(event: int, moved: np.ndarray,
                            hits: np.ndarray) -> list[Violation]:
    """Replica sets changed ⊆ keys whose candidate walk touched a victim."""
    moved = np.asarray(moved).astype(bool)
    rogue = int((moved & ~hits).sum())
    if rogue:
        return [Violation(event, "replica_stability",
                          f"{rogue} keys' replica sets changed although no "
                          "walk candidate touched a removed bucket")]
    return []


# ---------------------------------------------------------------------------
# follower convergence (cross-process replication, DESIGN.md §9.3)
# ---------------------------------------------------------------------------

def check_follower_convergence(event: int, leader_image,
                               followers) -> list[Violation]:
    """Eventual-epoch convergence: after a publish round, every follower's
    replicated image must sit at the leader's epoch with a bit-identical
    fingerprint (:func:`repro.core.protocol.image_fingerprint` — every word
    a lookup can gather, capacity padding excluded).  Followers behind on
    epoch get an ``epoch lag`` violation; followers AT the epoch with
    different words get the (far worse) ``diverged`` one — a replication
    bug, not a lag."""
    from repro.core.protocol import image_fingerprint

    want = image_fingerprint(leader_image)
    out: list[Violation] = []
    for idx, f in enumerate(followers):
        if f.epoch != leader_image.epoch:
            out.append(Violation(event, "follower_convergence",
                                 f"follower {idx} at epoch {f.epoch} != "
                                 f"leader {leader_image.epoch} (lag)"))
        elif f.fingerprint() != want:
            out.append(Violation(event, "follower_convergence",
                                 f"follower {idx} DIVERGED at epoch "
                                 f"{f.epoch}: {f.fingerprint()} != {want}"))
    return out


# ---------------------------------------------------------------------------
# bounded-load cap invariant
# ---------------------------------------------------------------------------

def check_cap_invariant(event: int, assignments: np.ndarray,
                        load: np.ndarray, cap: int) -> list[Violation]:
    out: list[Violation] = []
    load = np.asarray(load)
    over = int((load > cap).sum())
    if over:
        out.append(Violation(event, "cap_invariant",
                             f"{over} buckets exceed cap={cap} "
                             f"(peak {int(load.max())})"))
    if np.asarray(assignments).min(initial=0) < 0:
        out.append(Violation(event, "cap_invariant",
                             "unassigned keys left in the batch"))
    return out


# ---------------------------------------------------------------------------
# degradation profile (graceful-degradation knee)
# ---------------------------------------------------------------------------

def degradation_knee(profile: list[tuple[float, float]]) -> float | None:
    """Scale-free knee of a degradation profile: the checkpoint of maximum
    (normalized) deviation below the chord joining the profile's first and
    last points — the standard elbow locator for a convex cost curve.

    Memento's worst-case step count grows superlinearly in the removed
    fraction (E[τ]+E[σ] ~ ln(n/w) sweeps whose replacement chains also
    lengthen, paper Props. VII.1–3), so the curve stays near its cheap
    baseline and then turns hard upward; on the measured incremental
    profile the turn sits at ~0.65–0.7 removed — the paper's "graceful up
    to ~70 % failures" story (Figs. 23–26) as one executable number.
    Returns None when the profile is too short or never degrades."""
    if len(profile) < 3:
        return None
    f = np.asarray([p[0] for p in profile], float)
    s = np.asarray([p[1] for p in profile], float)
    if s[-1] <= s[0]:
        return None
    fn = (f - f[0]) / (f[-1] - f[0])       # normalize both axes so the
    sn = (s - s[0]) / (s[-1] - s[0])       # chord is y = x
    dev = fn - sn                          # convex curve ⇒ dev ≥ 0 at knee
    if dev.max() <= 0:
        return None
    return float(f[int(dev.argmax())])
