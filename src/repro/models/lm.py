"""Decoder-only LM assembled from heterogeneous blocks.

Layers follow ``cfg.layer_pattern`` repeated over depth.  One *superblock* =
one pattern period; full periods are stacked and applied with
``jax.lax.scan`` (small HLO, fast 512-device compiles), remainder layers run
unrolled as the "tail".  The same structure drives init (smoke tests),
``jax.eval_shape`` param shapes (dry-run), PartitionSpecs (via logical axis
names), training forward, prefill, and one-token decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_map

from . import attention, mlp, rglru, ssm
from .common import PSpec, init_tree, rms_norm, shape_tree, spec_tree, stack

COMPUTE_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


# ---------------------------------------------------------------------------
# Per-layer blocks
# ---------------------------------------------------------------------------

def block_desc(cfg, kind: str) -> dict:
    D = cfg.d_model
    ln = lambda: PSpec((D,), (None,), init="zeros")
    if kind in ("attn", "local"):
        d = {"ln1": ln(), "attn": attention.attn_desc(cfg), "ln2": ln()}
        if cfg.num_experts:
            d["moe"] = mlp.moe_desc(cfg)
        else:
            d["mlp"] = mlp.mlp_desc(cfg)
        return d
    if kind == "ssm":
        return {"ln1": ln(), "ssm": ssm.ssm_desc(cfg)}
    if kind == "rglru":
        return {"ln1": ln(), "rglru": rglru.rglru_desc(cfg), "ln2": ln(),
                "mlp": mlp.mlp_desc(cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def _zero_aux():
    return {"load_balance": jnp.float32(0.0), "router_z": jnp.float32(0.0)}


def block_apply(cfg, kind, p, x, positions, *, chunk=None, rules=None,
                moe_impl="global"):
    from jax.ad_checkpoint import checkpoint_name
    aux = _zero_aux()
    window = cfg.sliding_window if kind == "local" else None
    if kind in ("attn", "local"):
        h = attention.attn_apply(cfg, p["attn"], rms_norm(x, p["ln1"]), positions,
                                 window=window, chunk=chunk, rules=rules)
        x = x + checkpoint_name(h, "attn_out")
        hin = rms_norm(x, p["ln2"])
        if cfg.num_experts:
            h, aux = mlp.moe_apply(cfg, p["moe"], hin, rules=rules, impl=moe_impl)
        else:
            h = mlp.mlp_apply(cfg, p["mlp"], hin)
        return x + checkpoint_name(h, "mlp_out"), aux
    if kind == "ssm":
        h = ssm.ssm_apply(cfg, p["ssm"], rms_norm(x, p["ln1"]))
        return x + checkpoint_name(h, "ssm_out"), aux
    if kind == "rglru":
        x = x + checkpoint_name(
            rglru.rglru_apply(cfg, p["rglru"], rms_norm(x, p["ln1"])), "rnn_out")
        x = x + checkpoint_name(
            mlp.mlp_apply(cfg, p["mlp"], rms_norm(x, p["ln2"])), "mlp_out")
        return x, aux
    raise ValueError(kind)


def block_cache_desc(cfg, kind, batch: int, max_len: int,
                     cache_dtype: str = "bfloat16") -> dict:
    if kind == "attn":
        return attention.cache_desc(cfg, batch, max_len, cache_dtype=cache_dtype)
    if kind == "local":
        return attention.cache_desc(cfg, batch, max_len, window=cfg.sliding_window,
                                    cache_dtype=cache_dtype)
    if kind == "ssm":
        return ssm.ssm_cache_desc(cfg, batch)
    if kind == "rglru":
        return rglru.rglru_cache_desc(cfg, batch)
    raise ValueError(kind)


def block_decode(cfg, kind, p, cache, x, pos, *, rules=None):
    window = cfg.sliding_window if kind == "local" else None
    if kind in ("attn", "local"):
        c, h = attention.attn_decode(cfg, p["attn"], cache, rms_norm(x, p["ln1"]),
                                     pos, window=window, rules=rules)
        x = x + h
        hin = rms_norm(x, p["ln2"])
        if cfg.num_experts:
            h, _ = mlp.moe_apply(cfg, p["moe"], hin)
        else:
            h = mlp.mlp_apply(cfg, p["mlp"], hin)
        return c, x + h
    if kind == "ssm":
        c, h = ssm.ssm_decode(cfg, p["ssm"], cache, rms_norm(x, p["ln1"]), pos)
        return c, x + h
    if kind == "rglru":
        c, h = rglru.rglru_decode(cfg, p["rglru"], cache, rms_norm(x, p["ln1"]), pos)
        x = x + h
        x = x + mlp.mlp_apply(cfg, p["mlp"], rms_norm(x, p["ln2"]))
        return c, x
    raise ValueError(kind)


def block_prefill(cfg, kind, p, x, positions, max_len, *, chunk=None, rules=None,
                  cache_dtype: str = "bfloat16"):
    window = cfg.sliding_window if kind == "local" else None
    if kind in ("attn", "local"):
        c, h = attention.attn_prefill(cfg, p["attn"], rms_norm(x, p["ln1"]), positions,
                                      max_len, window=window, chunk=chunk, rules=rules,
                                      cache_dtype=cache_dtype)
        x = x + h
        hin = rms_norm(x, p["ln2"])
        if cfg.num_experts:
            h, _ = mlp.moe_apply(cfg, p["moe"], hin)
        else:
            h = mlp.mlp_apply(cfg, p["mlp"], hin)
        return c, x + h
    if kind == "ssm":
        c, h = ssm.ssm_apply(cfg, p["ssm"], rms_norm(x, p["ln1"]), return_cache=True)
        return c, x + h
    if kind == "rglru":
        c, h = rglru.rglru_apply(cfg, p["rglru"], rms_norm(x, p["ln1"]), return_cache=True)
        x = x + h
        x = x + mlp.mlp_apply(cfg, p["mlp"], rms_norm(x, p["ln2"]))
        return c, x
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

class LM:
    def __init__(self, cfg, *, attn_chunk: int | None = None, remat: str = "full",
                 rules=None, moe_impl: str = "global",
                 cache_dtype: str = "bfloat16"):
        self.cfg = cfg
        self.attn_chunk = attn_chunk
        self.remat = remat
        self.rules = rules
        self.moe_impl = moe_impl
        self.cache_dtype = cache_dtype
        self.period_kinds = cfg.layer_pattern
        self.n_periods = cfg.full_periods
        self.tail_kinds = cfg.tail_layers

    # ---- parameter descriptors ------------------------------------------
    def desc(self) -> dict:
        cfg = self.cfg
        sb = {str(i): block_desc(cfg, k) for i, k in enumerate(self.period_kinds)}
        d = {
            # untied: the input table is vocab-sharded and gathered via a
            # Megatron-style shard_map (each shard takes its own vocab range,
            # psum over the TP axis); the unembed is vocab-sharded so the
            # logits matmul partitions as a plain contraction.  A naive
            # jnp.take on a sharded table makes GSPMD replicate the whole
            # table per microbatch ("involuntary full rematerialization").
            "embed": PSpec((cfg.padded_vocab, cfg.d_model), ("vocab", None),
                           scale=1.0),
            "unembed": PSpec((cfg.padded_vocab, cfg.d_model), ("vocab", "fsdp"),
                             scale=cfg.d_model ** -0.5),
            "final_norm": PSpec((cfg.d_model,), (None,), init="zeros"),
        }
        if self.n_periods:
            d["blocks"] = stack(sb, self.n_periods)
        if self.tail_kinds:
            d["tail"] = {str(i): block_desc(cfg, k)
                         for i, k in enumerate(self.tail_kinds)}
        return d

    def init(self, key):
        return init_tree(self.desc(), key, COMPUTE_DTYPES[self.cfg.param_dtype])

    def param_shapes(self):
        return shape_tree(self.desc(), COMPUTE_DTYPES[self.cfg.param_dtype])

    def param_specs(self, rules):
        return spec_tree(self.desc(), rules)

    def param_count(self) -> int:
        import numpy as np
        return int(sum(np.prod(s.shape) for s in jax.tree.leaves(self.param_shapes())))

    # ---- embedding ---------------------------------------------------------
    def _embed(self, params, tokens):
        """Token embedding lookup, vocab-parallel when rules carry a mesh."""
        cdt = COMPUTE_DTYPES[self.cfg.dtype]
        table = params["embed"].astype(cdt)
        rules = self.rules
        if rules is None or rules.mesh is None:
            return table[tokens]
        vocab_axes = tuple(a for a in rules.rules.get("vocab", ())
                           if rules.mesh_axis_sizes.get(a, 1) > 1)
        if not vocab_axes or table.shape[0] % rules.mesh_axis_sizes[vocab_axes[0]]:
            return table[tokens]
        assert len(vocab_axes) == 1, vocab_axes
        (vax,) = vocab_axes
        batch_axes = rules.rules.get("batch", ())
        from jax.sharding import PartitionSpec as P

        bsize = 1
        for a in batch_axes:
            bsize *= rules.mesh_axis_sizes.get(a, 1)
        if batch_axes and tokens.shape[0] % max(bsize, 1) != 0:
            batch_axes = ()  # tiny batch (e.g. long-context B=1): replicate
        bspec = (batch_axes if len(batch_axes) > 1 else
                 (batch_axes[0] if batch_axes else None))

        def body(tab, tok):  # tab (V/tp, D) local shard, tok (B/dp, S)
            vshard = tab.shape[0]
            start = jax.lax.axis_index(vax) * vshard
            loc = tok - start
            ok = (loc >= 0) & (loc < vshard)
            rows = jnp.take(tab, jnp.clip(loc, 0, vshard - 1), axis=0)
            rows = jnp.where(ok[..., None], rows, jnp.zeros((), tab.dtype))
            return jax.lax.psum(rows, vax)

        return shard_map(
            body, mesh=rules.mesh,
            in_specs=(P(vax, None), P(bspec, None)),
            out_specs=P(bspec, None, None))(table, tokens)

    # ---- forward ----------------------------------------------------------
    def _superblock(self, params, x, positions):
        aux = _zero_aux()
        for i, kind in enumerate(self.period_kinds):
            x, a = block_apply(self.cfg, kind, params[str(i)], x, positions,
                               chunk=self.attn_chunk, rules=self.rules,
                               moe_impl=self.moe_impl)
            aux = jax.tree.map(jnp.add, aux, a)
        return x, aux

    def forward(self, params, tokens=None, embeds=None, positions=None):
        """→ (logits f32 (B,S,Vp), aux). Feed `embeds` for vlm/audio stubs."""
        cfg = self.cfg
        cdt = COMPUTE_DTYPES[cfg.dtype]
        if embeds is None:
            h = self._embed(params, tokens)
        else:
            h = embeds.astype(cdt)
        B, S = h.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        aux = _zero_aux()
        if self.n_periods:
            body = self._superblock
            if self.remat == "full":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            elif self.remat == "names":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.save_only_these_names(
                        "attn_out", "mlp_out", "ssm_out", "rnn_out", "kv_flat"))

            def scan_fn(carry, blk):
                h, aux = carry
                h, a = body(blk, h, positions)
                return (h, jax.tree.map(jnp.add, aux, a)), None

            (h, aux), _ = jax.lax.scan(scan_fn, (h, aux), params["blocks"])
        for i, kind in enumerate(self.tail_kinds):
            h, a = block_apply(cfg, kind, params["tail"][str(i)], h, positions,
                               chunk=self.attn_chunk, rules=self.rules,
                               moe_impl=self.moe_impl)
            aux = jax.tree.map(jnp.add, aux, a)

        h = rms_norm(h, params["final_norm"])
        logits = jnp.einsum("bsd,vd->bsv", h, params["unembed"].astype(cdt),
                            preferred_element_type=jnp.float32)
        return logits, aux

    def loss(self, params, batch):
        """Cross-entropy (+ MoE aux). batch: tokens|embeds, labels (B,S)."""
        logits, aux = self.forward(
            params, tokens=batch.get("tokens"), embeds=batch.get("embeds"))
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        # CE via fused one-hot (a take_along_axis over the model-sharded vocab
        # dim would trigger an SPMD gather; iota-compare-reduce partitions
        # cleanly and XLA fuses it without materializing the one-hot).
        lse = jax.nn.logsumexp(logits, axis=-1)
        vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
        onehot = (safe[..., None] == vocab_iota).astype(logits.dtype)
        true_logit = jnp.sum(logits * onehot, axis=-1)
        nll = lse - true_logit
        ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = ce + 0.01 * aux["load_balance"] + 0.001 * aux["router_z"]
        return total, {"ce": ce, **aux}

    # ---- serving ----------------------------------------------------------
    def cache_desc(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        sb = {str(i): block_cache_desc(cfg, k, batch, max_len, self.cache_dtype)
              for i, k in enumerate(self.period_kinds)}
        d = {}
        if self.n_periods:
            d["blocks"] = stack(sb, self.n_periods)
        if self.tail_kinds:
            d["tail"] = {str(i): block_cache_desc(cfg, k, batch, max_len,
                                                  self.cache_dtype)
                         for i, k in enumerate(self.tail_kinds)}
        return d

    def init_cache(self, batch: int, max_len: int):
        return init_tree(self.cache_desc(batch, max_len), jax.random.PRNGKey(0),
                         COMPUTE_DTYPES[self.cfg.dtype])

    def cache_shapes(self, batch: int, max_len: int):
        return shape_tree(self.cache_desc(batch, max_len),
                          COMPUTE_DTYPES[self.cfg.dtype])

    def cache_specs(self, batch: int, max_len: int, rules):
        return spec_tree(self.cache_desc(batch, max_len), rules)

    def decode_step(self, params, cache, tokens, pos):
        """One token for every sequence. tokens (B,1) int32, pos scalar."""
        cfg = self.cfg
        cdt = COMPUTE_DTYPES[cfg.dtype]
        h = self._embed(params, tokens)

        if self.n_periods:
            def scan_fn(h, inp):
                blk_p, blk_c = inp
                new_c = {}
                for i, kind in enumerate(self.period_kinds):
                    new_c[str(i)], h = block_decode(cfg, kind, blk_p[str(i)],
                                                    blk_c[str(i)], h, pos,
                                                    rules=self.rules)
                return h, new_c

            h, new_blocks = jax.lax.scan(scan_fn, h, (params["blocks"], cache["blocks"]))
            new_cache = dict(cache)
            new_cache["blocks"] = new_blocks
        else:
            new_cache = dict(cache)
        if self.tail_kinds:
            tail = {}
            for i, kind in enumerate(self.tail_kinds):
                tail[str(i)], h = block_decode(cfg, kind, params["tail"][str(i)],
                                               cache["tail"][str(i)], h, pos,
                                               rules=self.rules)
            new_cache["tail"] = tail

        h = rms_norm(h, params["final_norm"])
        logits = jnp.einsum("bsd,vd->bsv", h, params["unembed"].astype(cdt),
                            preferred_element_type=jnp.float32)
        return new_cache, logits

    def prefill(self, params, tokens=None, embeds=None, max_len: int | None = None):
        """Full-sequence prefill → (cache, last-token logits)."""
        cfg = self.cfg
        cdt = COMPUTE_DTYPES[cfg.dtype]
        h = self._embed(params, tokens) if embeds is None else embeds.astype(cdt)
        B, S = h.shape[:2]
        max_len = max_len or S
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        new_cache = {}
        if self.n_periods:
            def scan_fn(h, blk_p):
                cs = {}
                for i, kind in enumerate(self.period_kinds):
                    cs[str(i)], h = block_prefill(cfg, kind, blk_p[str(i)], h,
                                                  positions, max_len,
                                                  chunk=self.attn_chunk,
                                                  rules=self.rules,
                                                  cache_dtype=self.cache_dtype)
                return h, cs

            h, new_cache["blocks"] = jax.lax.scan(scan_fn, h, params["blocks"])
        if self.tail_kinds:
            tail = {}
            for i, kind in enumerate(self.tail_kinds):
                tail[str(i)], h = block_prefill(cfg, kind, params["tail"][str(i)], h,
                                                positions, max_len, chunk=self.attn_chunk,
                                                rules=self.rules,
                                                cache_dtype=self.cache_dtype)
            new_cache["tail"] = tail

        h = rms_norm(h[:, -1:], params["final_norm"])
        logits = jnp.einsum("bsd,vd->bsv", h, params["unembed"].astype(cdt),
                            preferred_element_type=jnp.float32)
        return new_cache, logits
