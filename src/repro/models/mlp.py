"""Dense MLPs (SwiGLU / GeGLU / GELU) and the top-k MoE layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_map

from .common import ACTIVATIONS, PSpec


def mlp_desc(cfg, d_ff: int | None = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    d = {
        "wi": PSpec((D, F), ("fsdp", "d_ff")),
        "wo": PSpec((F, D), ("d_ff", "fsdp")),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        d["wg"] = PSpec((D, F), ("fsdp", "d_ff"))
    return d


def mlp_apply(cfg, p, x):
    dt = x.dtype
    act = ACTIVATIONS[cfg.mlp]
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
    if "wg" in p:
        h = act(jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))) * h
    else:
        h = act(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, capacity-bounded, exact combine)
# ---------------------------------------------------------------------------

def moe_desc(cfg) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    d = {
        "router": PSpec((D, E), ("fsdp", None), scale=D ** -0.5),
        "wi": PSpec((E, D, F), ("experts", "fsdp", None)),
        "wo": PSpec((E, F, D), ("experts", None, "fsdp")),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        d["wg"] = PSpec((E, D, F), ("experts", "fsdp", None))
    return d


def moe_apply(cfg, p, x, *, rules=None, impl: str = "global"):
    """Top-k MoE. ``impl``:

    * ``global`` — paper-faithful-to-GShard pjit dispatch: one argsort over
      the *global* token stream; GSPMD inserts the (expensive) cross-device
      collectives.  The baseline in EXPERIMENTS.md §Perf.
    * ``local``  — shard_map dispatch: every device sorts only its own
      tokens into buffers for its *local* experts; the only collective is
      one (B,S,D) psum over the expert (model) axis per layer.
    """
    if impl == "local" and rules is not None and rules.mesh is not None:
        return _moe_apply_local(cfg, p, x, rules)
    return _moe_apply_global(cfg, p, x)


def _moe_apply_global(cfg, p, x):
    """Sort-based dispatch: tokens → (E, C) buffers → grouped matmul → combine.

    Exact (no approximation beyond the capacity drop at C = cf·N·k/E, the
    standard GShard-style bound).  Returns (y, aux) with the load-balance and
    router-z losses.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    N = B * S
    dt = x.dtype
    act = ACTIVATIONS[cfg.mlp]
    xf = x.reshape(N, D)

    logits = jnp.einsum("nd,de->ne", xf, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = {
        "load_balance": E * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }

    C = max(128, int(cfg.moe_capacity_factor * N * K / E + 127) // 128 * 128)
    C = min(C, N)

    flat_e = gate_idx.reshape(-1)                             # (N·K,)
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    offsets = jnp.cumsum(counts) - counts                     # exclusive
    rank = jnp.arange(N * K, dtype=jnp.int32) - offsets[sorted_e].astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, sorted_e.astype(jnp.int32) * C + rank, E * C)

    token = (order // K).astype(jnp.int32)
    buf = jnp.zeros((E * C + 1, D), dt).at[slot].set(xf[token])
    buf = buf[: E * C].reshape(E, C, D)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dt))
    if "wg" in p:
        h = act(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dt))) * h
    else:
        h = act(h)
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))   # (E, C, D)

    flat_out = jnp.concatenate([out.reshape(E * C, D), jnp.zeros((1, D), dt)], axis=0)
    gathered = flat_out[slot]                                  # (N·K, D) routed copies
    w = (gate_vals.reshape(-1)[order] * keep).astype(dt)       # dropped → 0
    y = jnp.zeros((N, D), dt).at[token].add(gathered * w[:, None])
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# shard_map local dispatch (EXPERIMENTS.md §Perf: the MoE hillclimb)
# ---------------------------------------------------------------------------

def _moe_apply_local(cfg, p, x, rules):
    """Per-device dispatch: each device routes its token shard into buffers
    for its local expert shard; partial outputs psum over the expert axis."""
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    sizes = rules.mesh_axis_sizes
    ep_axes = tuple(a for a in rules.rules.get("experts", ())
                    if sizes.get(a, 1) > 1 and cfg.num_experts % sizes[a] == 0)
    batch_axes = tuple(a for a in rules.rules.get("batch", ())
                       if sizes.get(a, 1) > 1)
    bsz = 1
    for a in batch_axes:
        bsz *= sizes[a]
    if x.shape[0] % max(bsz, 1):
        batch_axes = ()
    bspec = (batch_axes if len(batch_axes) > 1 else
             (batch_axes[0] if batch_axes else None))
    ep = ep_axes if len(ep_axes) != 1 else ep_axes[0]
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    act = ACTIVATIONS[cfg.mlp]
    has_gate = "wg" in p

    def body(xs, router, wi, wo, wg):
        B_loc, S, D = xs.shape
        N = B_loc * S
        dt = xs.dtype
        xf = xs.reshape(N, D)
        logits = jnp.einsum("nd,de->ne", xf, router.astype(dt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
        if batch_axes:
            me = jax.lax.pmean(me, batch_axes)
            ce = jax.lax.pmean(ce, batch_axes)
        zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        if batch_axes:
            zl = jax.lax.pmean(zl, batch_axes)
        aux = {"load_balance": E * jnp.sum(me * ce), "router_z": zl}

        E_loc = wi.shape[0]
        lo = (jax.lax.axis_index(ep) * E_loc) if ep_axes else 0
        C = max(16, int(cfg.moe_capacity_factor * N * K / E + 15) // 16 * 16)
        C = min(C, N)

        ids = gate_idx.reshape(-1) - lo                      # local coords
        ids = jnp.where((ids >= 0) & (ids < E_loc), ids, E_loc)  # E_loc = not mine
        order = jnp.argsort(ids)
        sorted_ids = ids[order]
        counts = jnp.bincount(ids, length=E_loc + 1)
        offsets = jnp.cumsum(counts) - counts
        rank = jnp.arange(N * K, dtype=jnp.int32) - offsets[sorted_ids].astype(jnp.int32)
        keep = (sorted_ids < E_loc) & (rank < C)
        slot = jnp.where(keep, sorted_ids.astype(jnp.int32) * C + rank, E_loc * C)
        token = (order // K).astype(jnp.int32)

        buf = jnp.zeros((E_loc * C + 1, D), dt).at[slot].set(xf[token])
        buf = buf[: E_loc * C].reshape(E_loc, C, D)
        h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt))
        if has_gate:
            h = act(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))) * h
        else:
            h = act(h)
        out = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))

        flat_out = jnp.concatenate([out.reshape(E_loc * C, D),
                                    jnp.zeros((1, D), dt)], axis=0)
        gathered = flat_out[slot]
        w = (gate_vals.reshape(-1)[order] * keep).astype(dt)
        y = jnp.zeros((N, D), dt).at[token].add(gathered * w[:, None])
        if ep_axes:
            y = jax.lax.psum(y, ep)                           # combine experts
        return y.reshape(B_loc, S, D), aux

    espec = ep if ep_axes else None
    wg = p.get("wg", p["wi"])  # dummy when ungated (ignored in body)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P(espec, None, None), P(espec, None, None), P(espec, None, None)),
        out_specs=(P(bspec, None, None), P()),
    )(x, p["router"], p["wi"], p["wo"], wg)
    return y, aux
