"""GQA/MQA attention: chunked (flash-style) training path + KV-cache decode.

Distribution (DESIGN.md §5): query heads are padded to ``cfg.padded_heads``
(a multiple of the TP degree), and explicit sharding constraints steer GSPMD
into one of three collective-free score layouts:

  * ``kv``    — KV-head dim divides TP: shard q/k/v on KV heads.
  * ``group`` — q-per-kv group divides TP (MQA-style): shard q on the group
                dim, replicate the (tiny) k/v.
  * ``flat``  — neither divides (e.g. 8 kv × 6 groups on TP=16): expand k/v
                to flat padded heads (a *local* slice under the constraint —
                each shard materializes only its own heads) and shard the
                flat head dim.

Without these constraints GSPMD shards the QK contraction over head_dim and
all-reduces the (chunk × S) score matrices every layer — measured 540 GiB of
ring traffic per step on gemma-2b train_4k (EXPERIMENTS.md §Perf, iteration 0).

Decode uses ``kv`` when it divides, else leaves heads replicated and shards
the cache's sequence dim (rules: ``seq_kv → model``); GSPMD then executes a
flash-decode-style partial-softmax combine with only scalar-sized psums.

The training/prefill path scans over query chunks so scores never materialize
at (S × S); sliding-window ("local") layers slice K/V to the window.  Decode
keeps a (B, KV, S_max, hd) cache for global layers and a ring buffer of
``window`` slots for local layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_constraint
from .common import PSpec, rope

NEG_INF = -1e30


def attn_desc(cfg) -> dict:
    D, Hp, KV, hd = cfg.d_model, cfg.padded_heads, cfg.padded_kv_heads, cfg.head_dim
    d = {
        "wq": PSpec((D, Hp, hd), ("fsdp", "heads", None)),
        "wk": PSpec((D, KV, hd), ("fsdp", "kv_heads", None)),
        "wv": PSpec((D, KV, hd), ("fsdp", "kv_heads", None)),
        "wo": PSpec((Hp, hd, D), ("heads", None, "fsdp")),
    }
    if cfg.qkv_bias:
        d["bq"] = PSpec((Hp, hd), ("heads", None), init="zeros")
        d["bk"] = PSpec((KV, hd), ("kv_heads", None), init="zeros")
        d["bv"] = PSpec((KV, hd), ("kv_heads", None), init="zeros")
    return d


def _tp_degree(rules) -> int:
    if rules is None:
        return 1
    size = 1
    for a in rules.rules.get("heads", ()):
        size *= rules.mesh_axis_sizes.get(a, 1)
    return size


def head_mode(cfg, rules) -> str:
    tp = _tp_degree(rules)
    if tp <= 1:
        return "kv"
    if cfg.padded_kv_heads % tp == 0:
        return "kv"
    if cfg.q_per_kv % tp == 0:
        return "group"
    return "flat"  # padded_heads % tp == 0 by construction


def _qkv(cfg, p, x, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scores_softmax_combine(cfg, q, k, v, qpos, kpos, window):
    """q (B,C,KV,G,hd) vs k/v (B,T,KV,hd) with causal+window mask → (B,C,KV,G,hd)."""
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqkgh,btkh->bkgqt", q, k).astype(jnp.float32) * scale
    mask = kpos[..., None, :] <= qpos[..., :, None]           # causal
    if window is not None:
        mask &= (qpos[..., :, None] - kpos[..., None, :]) < window
    mask &= kpos[..., None, :] >= 0                           # padding slots
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqt,btkh->bqkgh", a, v)


def attn_apply(cfg, p, x, positions, *, window=None, chunk=None, rules=None):
    """Training attention. x (B,S,D), positions (B,S) int32."""
    q, k, v = _qkv(cfg, p, x, positions)
    out = _attention(cfg, q, k, v, positions, window=window, chunk=chunk,
                     rules=rules)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return shard_constraint(y, ("batch", None, None), rules) if rules else y


def _attention(cfg, q, k, v, positions, *, window=None, chunk=None, rules=None):
    """Chunked causal attention core. q (B,S,Hp,hd), k/v (B,S,KV,hd)
    → (B,S,Hp,hd)."""
    B, S = q.shape[:2]
    KV, G, Hp = cfg.padded_kv_heads, cfg.q_per_kv, cfg.padded_heads
    mode = head_mode(cfg, rules)

    if mode == "flat":
        # Stage unexpanded k/v seq-sharded (window-free layers): the forward
        # pays a small bf16 all-gather; the backward reduce-scatters dk/dv at
        # the UNEXPANDED size.  Expanding from replicated k/v instead makes
        # the backward all-reduce the G×-expanded f32 cotangent (measured
        # 318 GiB on phi3.5 train_4k — §Perf iteration 2).
        if rules is not None and window is None and getattr(rules, "kv_seq_stage", False):
            k = shard_constraint(k, ("batch", "seq_kv", None, None), rules)
            v = shard_constraint(v, ("batch", "seq_kv", None, None), rules)
        # expand to flat padded heads; under the head-sharding constraint
        # each device then slices only its own heads.
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        from jax.ad_checkpoint import checkpoint_name
        k = checkpoint_name(k, "kv_flat")
        v = checkpoint_name(v, "kv_flat")
        KV, G = Hp, 1
        ax = ("batch", None, "heads", None, None)
        kv_ax = ("batch", None, "heads", None)
    elif mode == "kv":
        ax = ("batch", None, "kv_heads", None, None)
        kv_ax = ("batch", None, "kv_heads", None)
    else:  # group
        ax = ("batch", None, None, "heads", None)
        kv_ax = ("batch", None, None, None)

    q = q.reshape(B, S, KV, G, cfg.head_dim)
    if rules is not None:
        q = shard_constraint(q, ax, rules)
        k = shard_constraint(k, kv_ax, rules)
        v = shard_constraint(v, kv_ax, rules)

    chunk = min(chunk or 512, S)
    n_chunks = -(-S // chunk)
    assert S % chunk == 0, (S, chunk)

    if window is not None and window < S:
        # Pad K/V in front by `window` so each query chunk sees a static slice.
        pad = ((0, 0), (window, 0), (0, 0), (0, 0))
        kp = jnp.pad(k, pad)
        vp = jnp.pad(v, pad)
        kposp = jnp.pad(positions, ((0, 0), (window, 0)), constant_values=-1)

        def body(_, qc_idx):
            q0 = qc_idx * chunk
            qc = jax.lax.dynamic_slice_in_dim(q, q0, chunk, axis=1)
            qp = jax.lax.dynamic_slice_in_dim(positions, q0, chunk, axis=1)
            ks = jax.lax.dynamic_slice_in_dim(kp, q0, window + chunk, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, q0, window + chunk, axis=1)
            kps = jax.lax.dynamic_slice_in_dim(kposp, q0, window + chunk, axis=1)
            o = _scores_softmax_combine(cfg, qc, ks, vs, qp, kps, window)
            return None, o
    else:
        def body(_, qc_idx):
            q0 = qc_idx * chunk
            qc = jax.lax.dynamic_slice_in_dim(q, q0, chunk, axis=1)
            qp = jax.lax.dynamic_slice_in_dim(positions, q0, chunk, axis=1)
            o = _scores_softmax_combine(cfg, qc, k, v, qp, positions, window)
            return None, o

    if n_chunks == 1:
        _, out = body(None, jnp.int32(0))
        out = out[None]
    else:
        _, out = jax.lax.scan(body, None, jnp.arange(n_chunks, dtype=jnp.int32))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, Hp, cfg.head_dim)


# ---------------------------------------------------------------------------
# Prefill: attention + KV-cache construction (qkv computed once)
# ---------------------------------------------------------------------------

def attn_prefill(cfg, p, x, positions, max_len, *, window=None, chunk=None,
                 rules=None, cache_dtype: str = "bfloat16"):
    """Returns (cache, y). Global layers fill slots [0,S); local layers fill
    the ring buffer with the last `window` keys at slot = pos % window."""
    B, S, _ = x.shape
    KV, hd = cfg.padded_kv_heads, cfg.head_dim
    q, k, v = _qkv(cfg, p, x, positions)
    out = _attention(cfg, q, k, v, positions, window=window, chunk=chunk,
                     rules=rules)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))

    kc = k.transpose(0, 2, 1, 3)  # (B,KV,S,hd)
    vc = v.transpose(0, 2, 1, 3)
    if window and window < max_len:
        W = window
        lastk, lastv = kc[:, :, -W:], vc[:, :, -W:]
        lastpos = positions[0, -W:]          # same positions across batch
        slots = lastpos % W
        ck = jnp.zeros((B, KV, W, hd), k.dtype).at[:, :, slots].set(lastk)
        cv = jnp.zeros((B, KV, W, hd), v.dtype).at[:, :, slots].set(lastv)
    else:
        T = max_len
        ck = jnp.zeros((B, KV, T, hd), k.dtype).at[:, :, :S].set(kc)
        cv = jnp.zeros((B, KV, T, hd), v.dtype).at[:, :, :S].set(vc)
    if rules is not None:
        ck = shard_constraint(ck, ("batch", "kv_heads", "seq_kv", None), rules)
        cv = shard_constraint(cv, ("batch", "kv_heads", "seq_kv", None), rules)
    if cache_dtype == "int8":
        kq, ks = _quantize(ck)
        vq, vs = _quantize(cv)
        return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}, y
    return {"k": ck, "v": cv}, y


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------

def cache_desc(cfg, batch: int, max_len: int, *, window=None,
               cache_dtype: str = "bfloat16") -> dict:
    T = min(max_len, window) if window else max_len
    KV, hd = cfg.padded_kv_heads, cfg.head_dim
    kv_ax = ("batch", "kv_heads", "seq_kv", None)
    if cache_dtype == "int8":
        # per-(position, head) symmetric quantization; bf16 scales — halves
        # true cache-read bandwidth vs bf16 (EXPERIMENTS.md §Perf, decode)
        return {
            "k": PSpec((batch, KV, T, hd), kv_ax, init="zeros", dtype="int8"),
            "v": PSpec((batch, KV, T, hd), kv_ax, init="zeros", dtype="int8"),
            "k_scale": PSpec((batch, KV, T), kv_ax[:3], init="zeros", dtype="bfloat16"),
            "v_scale": PSpec((batch, KV, T), kv_ax[:3], init="zeros", dtype="bfloat16"),
        }
    return {
        "k": PSpec((batch, KV, T, hd), kv_ax, init="zeros"),
        "v": PSpec((batch, KV, T, hd), kv_ax, init="zeros"),
    }


def _quantize(x):
    """x (..., hd) → (int8 values, bf16 scales (...,))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def attn_decode(cfg, p, cache, x, pos, *, window=None, rules=None):
    """One-token decode. x (B,1,D); pos scalar int32; returns (cache, y)."""
    B = x.shape[0]
    KV, G, hd = cfg.padded_kv_heads, cfg.q_per_kv, cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(cfg, p, x, positions)
    q = q.reshape(B, 1, KV, G, hd)
    if rules is not None:
        # kv-sharded when KV divides TP (rule order), else heads replicated
        # and the cache's seq dim sharded → GSPMD flash-decode combine.
        q = shard_constraint(q, ("batch", None, "kv_heads", None, None), rules)

    T = cache["k"].shape[2]
    slot = pos % T  # identity while pos < T; ring wrap for window layers
    quantized = "k_scale" in cache
    kc, vc = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    new_cache = {}
    if quantized:
        kq, ks = _quantize(kc)
        vq, vs = _quantize(vc)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=2)
        cks = jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, slot, axis=2)
        cvs = jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, slot, axis=2)
        new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        ck_f = ck.astype(q.dtype) * cks.astype(q.dtype)[..., None]
        cv_f = cv.astype(q.dtype) * cvs.astype(q.dtype)[..., None]
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, slot, axis=2)
        new_cache = {"k": ck, "v": cv}
        ck_f, cv_f = ck.astype(q.dtype), cv.astype(q.dtype)

    # slot s holds absolute position pos − ((pos − s) mod T); < 0 ⇒ unwritten
    slots = jnp.arange(T, dtype=jnp.int32)
    kpos = pos - ((pos - slots) % T)
    valid = (kpos >= 0) & (kpos <= pos)
    if window:
        valid &= (pos - kpos) < window

    scale = hd ** -0.5
    s = jnp.einsum("bqkgh,bkth->bkgqt", q, ck_f).astype(jnp.float32) * scale
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqt,bkth->bqkgh", a, cv_f)
    o = o.reshape(B, 1, KV * G, hd)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return new_cache, y
