"""Mamba2 — SSD (state-space duality) block, chunked scan + O(1) decode.

Training/prefill runs the chunked dual form (intra-chunk attention-like
matmuls + inter-chunk state recurrence via lax.scan): TPU-friendly MXU work
instead of a length-L sequential scan.  Decode updates a (B, H, P, N) state
and a width-(w−1) conv ring — O(1) in sequence length, which is why the
``long_500k`` cell runs for this family (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import PSpec, rms_norm


def ssm_desc(cfg) -> dict:
    D, di, N, H, W = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    ch = di + 2 * N
    return {
        "wz": PSpec((D, di), ("fsdp", "ssm_inner")),
        "wx": PSpec((D, di), ("fsdp", "ssm_inner")),
        "wB": PSpec((D, N), ("fsdp", None)),
        "wC": PSpec((D, N), ("fsdp", None)),
        "wdt": PSpec((D, H), ("fsdp", None)),
        "dt_bias": PSpec((H,), (None,), init="zeros"),
        "A_log": PSpec((H,), (None,), init="zeros"),
        "D_skip": PSpec((H,), (None,), init="ones"),
        "conv_w": PSpec((W, ch), (None, "ssm_inner"), scale=W ** -0.5),
        "conv_b": PSpec((ch,), ("ssm_inner",), init="zeros"),
        "norm": PSpec((di,), ("ssm_inner",), init="zeros"),
        "out": PSpec((di, D), ("ssm_inner", "fsdp")),
    }


def _proj(cfg, p, x):
    dt = x.dtype
    z = jnp.einsum("bld,de->ble", x, p["wz"].astype(dt))
    xin = jnp.einsum("bld,de->ble", x, p["wx"].astype(dt))
    Bv = jnp.einsum("bld,dn->bln", x, p["wB"].astype(dt))
    Cv = jnp.einsum("bld,dn->bln", x, p["wC"].astype(dt))
    dtv = jnp.einsum("bld,dh->blh", x, p["wdt"].astype(dt))
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, jnp.concatenate([xin, Bv, Cv], axis=-1), dtv


def _causal_conv(p, xBC, W):
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        jax.lax.dynamic_slice_in_dim(pad, i, xBC.shape[1], axis=1)
        * p["conv_w"][i].astype(xBC.dtype)
        for i in range(W)
    )
    return jax.nn.silu(out + p["conv_b"].astype(xBC.dtype))


def ssm_apply(cfg, p, x, *, return_cache: bool = False):
    """SSD chunked forward. x (B,L,D) → (B,L,D); L % chunk == 0."""
    B, L, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    dt = x.dtype

    z, xBC_raw, dtv = _proj(cfg, p, x)
    xBC = _causal_conv(p, xBC_raw, cfg.ssm_conv_width)
    xin, Bv, Cv = xBC[..., :di], xBC[..., di:di + N], xBC[..., di + N:]

    xh = xin.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bc = Bv.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cv.reshape(B, nc, Q, N).astype(jnp.float32)
    dtc = dtv.reshape(B, nc, Q, H)                                  # f32
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (H,) < 0
    dtA = dtc * A                                                   # ≤ 0
    cs = jnp.cumsum(dtA, axis=2)                                    # (B,nc,Q,H)

    # intra-chunk (dual/attention-like) term
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)                  # (B,nc,Q,Q)
    decay = jnp.exp(cs[:, :, :, None] - cs[:, :, None, :])          # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, :, :, None], scores[..., None] * decay, 0.0)
    M = M * dtc[:, :, None, :, :]                                   # × dt_j
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", M, xh)

    # inter-chunk recurrence over chunk states
    seg = jnp.exp(cs[:, :, -1:, :] - cs)                            # (B,nc,Q,H)
    chunk_state = jnp.einsum("bckn,bckh,bckhp->bchpn", Bc, seg * dtc, xh)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                          # (B,nc,H)

    def step(carry, inp):
        st = carry                                                  # (B,H,P,N)
        state_c, decay_c = inp
        out = st
        st = decay_c[:, :, None, None] * st + state_c
        return st, out

    xs = (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    st0 = jnp.zeros((B, H, P, N), jnp.float32)
    st_final, prev_states = jax.lax.scan(step, st0, xs)             # (nc,B,H,P,N)
    prev_states = jnp.moveaxis(prev_states, 0, 1)                   # (B,nc,H,P,N)

    y_off = jnp.einsum("bcqn,bchpn->bcqhp", Cc, prev_states) * jnp.exp(cs)[..., None]
    y = (y_diag + y_off + p["D_skip"].astype(jnp.float32)[:, None] * xh)
    y = y.reshape(B, L, di).astype(dt)

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt), p["norm"])
    y = jnp.einsum("ble,ed->bld", y, p["out"].astype(dt))
    if return_cache:
        W = cfg.ssm_conv_width
        cache = {"conv": xBC_raw[:, L - (W - 1):], "state": st_final}
        return cache, y
    return y


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def ssm_cache_desc(cfg, batch: int) -> dict:
    di, N, H, P, W = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv_width
    return {
        "conv": PSpec((batch, W - 1, di + 2 * N), ("batch", None, "ssm_inner"), init="zeros"),
        "state": PSpec((batch, H, P, N), ("batch", "ssm_inner", None, None), init="zeros"),
    }


def ssm_decode(cfg, p, cache, x, pos):
    """One-token decode. x (B,1,D) → (cache, y (B,1,D))."""
    del pos
    B = x.shape[0]
    di, N, H, P, W = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv_width
    dt = x.dtype

    z, xBC, dtv = _proj(cfg, p, x)                                  # (B,1,·)
    hist = jnp.concatenate([cache["conv"], xBC], axis=1)            # (B,W,ch)
    conv = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))      # (B,ch)
    xin, Bv, Cv = conv[:, :di], conv[:, di:di + N], conv[:, di + N:]

    xh = xin.reshape(B, H, P)
    dt1 = dtv[:, 0]                                                 # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * A)                                        # (B,H)
    st = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhpn", Bv, xh * dt1[..., None])
    y = jnp.einsum("bn,bhpn->bhp", Cv, st) + p["D_skip"].astype(jnp.float32)[:, None] * xh

    y = y.reshape(B, 1, di).astype(dt)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt), p["norm"])
    y = jnp.einsum("ble,ed->bld", y, p["out"].astype(dt))
    return {"conv": hist[:, 1:], "state": st}, y
