"""Parameter-descriptor machinery for the pure-JAX model zoo.

Each module declares its parameters as a pytree of :class:`PSpec` descriptors
(shape + logical axis names + init).  From one descriptor tree we derive:

  * random initialization          (``init_tree`` — smoke tests/examples),
  * ShapeDtypeStructs              (``shape_tree`` — the dry-run, no alloc),
  * PartitionSpecs                 (via ``repro.sharding.logical_to_spec``),
  * stacked variants for scan-over-layers (``stack``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from repro.sharding.rules import AxisRules, logical_to_spec


@dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float | None = None    # None → 1/sqrt(fan_in) with fan_in=shape[0]
    dtype: str | None = None      # None → the tree-level default dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def stack(desc, n: int):
    """Prefix every descriptor with a scan ('stack') dimension of size n."""
    return jax.tree.map(
        lambda p: replace(p, shape=(n, *p.shape), logical=("stack", *p.logical)),
        desc, is_leaf=is_pspec)


def init_tree(desc, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(desc, is_leaf=is_pspec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def one(p: PSpec, k):
        dt = jnp.dtype(p.dtype) if p.dtype else dtype
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else max(p.shape[-1], 1)
        scale = p.scale if p.scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, p.shape, jnp.float32) * scale).astype(dt)

    return jax.tree.unflatten(treedef, [one(p, k) for p, k in zip(leaves, keys)])


def shape_tree(desc, dtype=jnp.float32):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype) if p.dtype else dtype),
        desc, is_leaf=is_pspec)


def spec_tree(desc, rules: AxisRules):
    return jax.tree.map(
        lambda p: logical_to_spec(p.logical, rules, p.shape), desc, is_leaf=is_pspec)


# ---------------------------------------------------------------------------
# Small shared layers
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding over the last dim. x: (..., S, H, hd), positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {
    "swiglu": jax.nn.silu,
    "geglu": gelu,
    "gelu": gelu,
}
