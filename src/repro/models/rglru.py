"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Training/prefill uses ``jax.lax.associative_scan`` over the gated linear
recurrence h_t = a_t·h_{t−1} + b_t (log-depth, TPU-friendly); decode is an
O(1) state update — the hybrid runs the ``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import PSpec, gelu

_C = 8.0  # Griffin's fixed gate sharpness


def rglru_desc(cfg) -> dict:
    D, W, CW = cfg.d_model, cfg.rnn_width, cfg.lru_conv_width
    return {
        "wx_in": PSpec((D, W), ("fsdp", "rnn_width")),
        "wg_in": PSpec((D, W), ("fsdp", "rnn_width")),
        "conv_w": PSpec((CW, W), (None, "rnn_width"), scale=CW ** -0.5),
        "conv_b": PSpec((W,), ("rnn_width",), init="zeros"),
        "wa": PSpec((W, W), ("rnn_width", None)),
        "ba": PSpec((W,), (None,), init="zeros"),
        "wi": PSpec((W, W), ("rnn_width", None)),
        "bi": PSpec((W,), (None,), init="zeros"),
        "lam": PSpec((W,), (None,), init="ones"),
        "out": PSpec((W, D), ("rnn_width", "fsdp")),
    }


def _branches(cfg, p, x):
    dt = x.dtype
    xb = jnp.einsum("bld,dw->blw", x, p["wx_in"].astype(dt))
    gate = jnp.einsum("bld,dw->blw", x, p["wg_in"].astype(dt))
    return xb, gate


def _conv(p, xb, CW, hist=None):
    if hist is None:
        padded = jnp.pad(xb, ((0, 0), (CW - 1, 0), (0, 0)))
    else:
        padded = jnp.concatenate([hist, xb], axis=1)
    out = sum(
        jax.lax.dynamic_slice_in_dim(padded, i, xb.shape[1], axis=1)
        * p["conv_w"][i].astype(xb.dtype)
        for i in range(CW)
    )
    return out + p["conv_b"].astype(xb.dtype)


def _gates(p, xc):
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xf, p["wa"].astype(jnp.float32))
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xf, p["wi"].astype(jnp.float32))
                       + p["bi"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, b


def rglru_apply(cfg, p, x, *, return_cache: bool = False):
    """x (B,L,D) → (B,L,D) via associative scan over the recurrence."""
    dt = x.dtype
    xb, gate = _branches(cfg, p, x)
    xc = _conv(p, xb, cfg.lru_conv_width)
    a, b = _gates(p, xc)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(dt) * gelu(gate))
    y = jnp.einsum("blw,wd->bld", y, p["out"].astype(dt))
    if return_cache:
        CW = cfg.lru_conv_width
        cache = {"conv": xb[:, x.shape[1] - (CW - 1):], "state": h[:, -1]}
        return cache, y
    return y


def rglru_cache_desc(cfg, batch: int) -> dict:
    W, CW = cfg.rnn_width, cfg.lru_conv_width
    return {
        "conv": PSpec((batch, CW - 1, W), ("batch", None, "rnn_width"), init="zeros"),
        "state": PSpec((batch, W), ("batch", "rnn_width"), init="zeros"),
    }


def rglru_decode(cfg, p, cache, x, pos):
    """One-token decode. x (B,1,D) → (cache, y)."""
    del pos
    dt = x.dtype
    xb, gate = _branches(cfg, p, x)
    hist = jnp.concatenate([cache["conv"], xb], axis=1)
    xc = _conv(p, xb, cfg.lru_conv_width, hist=cache["conv"])
    a, b = _gates(p, xc)                                    # (B,1,W)
    h = a[:, 0] * cache["state"] + b[:, 0]
    y = (h[:, None, :].astype(dt) * gelu(gate))
    y = jnp.einsum("blw,wd->bld", y, p["out"].astype(dt))
    return {"conv": hist[:, 1:], "state": h}, y
