"""recurrentgemma-9b — exact assigned config (defined in registry.py).

Select with ``--arch recurrentgemma-9b`` or ``get_config("recurrentgemma-9b")``;
reduced smoke twin via ``smoke_config("recurrentgemma-9b")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("recurrentgemma-9b")
SMOKE = smoke_config("recurrentgemma-9b")
