"""Model/run configuration dataclasses and the assigned input shapes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    mlp: str = "swiglu"              # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # layer pattern, repeated over depth: entries in {"attn","local","ssm","rglru"}
    layer_pattern: tuple[str, ...] = ("attn",)
    sliding_window: int = 1024       # for "local" layers
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # Mamba2 / SSD
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    # RG-LRU (RecurrentGemma / Griffin)
    lru_width: int = 0               # 0 → d_model
    lru_conv_width: int = 4
    # modality frontend stub ("vision" | "audio" | None): inputs are
    # precomputed frame/patch embeddings per the brief
    frontend: str | None = None
    # numerics / distribution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    tp_multiple: int = 16            # pad query heads to a multiple of this for TP
    vocab_pad_multiple: int = 128

    # ---- derived -----------------------------------------------------------
    @property
    def padded_heads(self) -> int:
        """Query heads padded for TP divisibility (zero out-proj rows ⇒ exact)."""
        if self.num_heads == 0:
            return 0
        m = self.tp_multiple
        return -(-self.num_heads // m) * m

    @property
    def padded_kv_heads(self) -> int:
        """KV heads; padded along with q for MHA (kv == q) archs so the
        padded q heads still group evenly."""
        if self.num_kv_heads == 0:
            return 0
        if self.num_kv_heads == self.num_heads:
            return self.padded_heads
        assert self.padded_heads % self.num_kv_heads == 0, self
        return self.num_kv_heads

    @property
    def q_per_kv(self) -> int:
        return self.padded_heads // max(self.padded_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def d_inner(self) -> int:        # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rnn_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def pattern(self) -> tuple[str, ...]:
        """Per-layer block kinds, length == num_layers."""
        p = self.layer_pattern
        reps = -(-self.num_layers // len(p))
        return tuple((p * reps)[: self.num_layers])

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def full_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def tail_layers(self) -> tuple[str, ...]:
        return self.pattern[self.full_periods * self.period:]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once; see notes)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        n = 0
        per_kind: dict[str, int] = {}
        hd = self.head_dim
        attn = d * self.padded_heads * hd + 2 * d * self.padded_kv_heads * hd + self.padded_heads * hd * d
        mlp_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        dense_mlp = mlp_mats * d * f
        per_kind["attn"] = attn + dense_mlp + 2 * d
        per_kind["local"] = per_kind["attn"]
        if self.num_experts:
            router = d * self.num_experts
            experts = self.num_experts * mlp_mats * d * f
            per_kind["attn"] = attn + router + experts + 2 * d
            per_kind["local"] = per_kind["attn"]
        if self.ssm_state:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per_kind["ssm"] = (d * (2 * di + 2 * ns + nh)  # in_proj(x,z,B,C,dt)
                               + self.ssm_conv_width * (di + 2 * ns)
                               + 2 * nh + di * d + d)
        if "rglru" in self.layer_pattern:
            w = self.rnn_width
            # in_proj (x+gate) + conv + RG-LRU gates (Wx, Wa) + Λ + out_proj + mlp + norms
            per_kind["rglru"] = (2 * d * w + self.lru_conv_width * w
                                 + 2 * w * w + w + w * d + dense_mlp + 2 * d)
        for kind in self.pattern:
            n += per_kind[kind]
        n += 2 * v * d  # untied embedding + unembedding
        n += d          # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        inactive = (self.num_experts - self.num_experts_per_tok) * mlp_mats * d * f
        return self.param_count() - inactive * self.num_layers


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs whose long-context cell runs (sub-quadratic sequence mixing).  All
# others skip `long_500k` per the brief (recorded in DESIGN.md §5).
LONG_CONTEXT_ARCHS = {"mamba2-780m", "recurrentgemma-9b"}


def replace(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, **kw)
