"""gemma3-12b — exact assigned config (defined in registry.py).

Select with ``--arch gemma3-12b`` or ``get_config("gemma3-12b")``;
reduced smoke twin via ``smoke_config("gemma3-12b")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("gemma3-12b")
SMOKE = smoke_config("gemma3-12b")
