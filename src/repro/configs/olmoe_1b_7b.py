"""olmoe-1b-7b — exact assigned config (defined in registry.py).

Select with ``--arch olmoe-1b-7b`` or ``get_config("olmoe-1b-7b")``;
reduced smoke twin via ``smoke_config("olmoe-1b-7b")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("olmoe-1b-7b")
SMOKE = smoke_config("olmoe-1b-7b")
