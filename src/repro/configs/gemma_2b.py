"""gemma-2b — exact assigned config (defined in registry.py).

Select with ``--arch gemma-2b`` or ``get_config("gemma-2b")``;
reduced smoke twin via ``smoke_config("gemma-2b")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("gemma-2b")
SMOKE = smoke_config("gemma-2b")
