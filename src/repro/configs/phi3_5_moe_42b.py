"""phi3.5-moe-42b-a6.6b — exact assigned config (defined in registry.py).

Select with ``--arch phi3.5-moe-42b-a6.6b`` or ``get_config("phi3.5-moe-42b-a6.6b")``;
reduced smoke twin via ``smoke_config("phi3.5-moe-42b-a6.6b")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("phi3.5-moe-42b-a6.6b")
SMOKE = smoke_config("phi3.5-moe-42b-a6.6b")
