"""qwen2.5-14b — exact assigned config (defined in registry.py).

Select with ``--arch qwen2.5-14b`` or ``get_config("qwen2.5-14b")``;
reduced smoke twin via ``smoke_config("qwen2.5-14b")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("qwen2.5-14b")
SMOKE = smoke_config("qwen2.5-14b")
