from .base import LONG_CONTEXT_ARCHS, ModelConfig, SHAPES, ShapeConfig, replace
from .registry import ARCHS, get_config, smoke_config

__all__ = [
    "ARCHS",
    "LONG_CONTEXT_ARCHS",
    "ModelConfig",
    "SHAPES",
    "ShapeConfig",
    "get_config",
    "replace",
    "smoke_config",
]
