"""llava-next-34b — exact assigned config (defined in registry.py).

Select with ``--arch llava-next-34b`` or ``get_config("llava-next-34b")``;
reduced smoke twin via ``smoke_config("llava-next-34b")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("llava-next-34b")
SMOKE = smoke_config("llava-next-34b")
