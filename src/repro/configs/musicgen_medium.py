"""musicgen-medium — exact assigned config (defined in registry.py).

Select with ``--arch musicgen-medium`` or ``get_config("musicgen-medium")``;
reduced smoke twin via ``smoke_config("musicgen-medium")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("musicgen-medium")
SMOKE = smoke_config("musicgen-medium")
