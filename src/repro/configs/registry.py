"""The 10 assigned architectures (exact public configs) + reduced smoke twins.

Sources per the brief; `[source]` notes in ARCHS.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation); smoke tests use
``smoke_config(name)`` — same family/pattern, tiny dims.
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig

ARCHS: dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- MoE --------------------------------------------------------------------
_reg(ModelConfig(  # [hf:microsoft/Phi-3.5-MoE-instruct]
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=6400, vocab_size=32064,
    mlp="swiglu", num_experts=16, num_experts_per_tok=2, rope_theta=10_000.0))

_reg(ModelConfig(  # [arXiv:2409.02060]
    name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1024, vocab_size=50304,
    mlp="swiglu", num_experts=64, num_experts_per_tok=8, rope_theta=10_000.0))

# --- SSM --------------------------------------------------------------------
_reg(ModelConfig(  # [arXiv:2405.21060]
    name="mamba2-780m", family="ssm", num_layers=48, d_model=1536,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=50280,
    layer_pattern=("ssm",), ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=128))

# --- VLM / audio (stub frontends per the brief) ------------------------------
_reg(ModelConfig(  # [hf:llava-hf/llava-v1.6 (34B variant)]
    name="llava-next-34b", family="vlm", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480, vocab_size=64000,
    mlp="swiglu", frontend="vision", rope_theta=1_000_000.0))

_reg(ModelConfig(  # [arXiv:2306.05284]
    name="musicgen-medium", family="audio", num_layers=48, d_model=1536,
    num_heads=24, num_kv_heads=24, head_dim=64, d_ff=6144, vocab_size=2048,
    mlp="gelu", frontend="audio", rope_theta=10_000.0))

# --- dense -------------------------------------------------------------------
_reg(ModelConfig(  # [arXiv:2412.08905]
    name="phi4-mini-3.8b", family="dense", num_layers=32, d_model=3072,
    num_heads=24, num_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=200064,
    mlp="swiglu", rope_theta=10_000.0))

_reg(ModelConfig(  # [hf:google/gemma-3 family] 5:1 local:global
    name="gemma3-12b", family="dense", num_layers=48, d_model=3840,
    num_heads=16, num_kv_heads=8, head_dim=256, d_ff=15360, vocab_size=262144,
    mlp="geglu", layer_pattern=("local", "local", "local", "local", "local", "attn"),
    sliding_window=1024, rope_theta=1_000_000.0))

_reg(ModelConfig(  # [arXiv:2403.08295] MQA, GeGLU, head_dim 256
    name="gemma-2b", family="dense", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384, vocab_size=256000,
    mlp="geglu", rope_theta=10_000.0))

_reg(ModelConfig(  # [hf:Qwen/Qwen2.5 family] QKV bias
    name="qwen2.5-14b", family="dense", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=13824, vocab_size=152064,
    mlp="swiglu", qkv_bias=True, rope_theta=1_000_000.0))

# --- hybrid -------------------------------------------------------------------
_reg(ModelConfig(  # [arXiv:2402.19427] RG-LRU + local attn, (R,R,A) pattern
    name="recurrentgemma-9b", family="hybrid", num_layers=38, d_model=4096,
    num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288, vocab_size=256000,
    mlp="geglu", layer_pattern=("rglru", "rglru", "local"), sliding_window=2048,
    lru_width=4096, rope_theta=10_000.0))


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family twin: tiny dims, 1-device friendly, no TP padding."""
    cfg = get_config(name)
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=2 * cfg.period,
        d_model=64,
        vocab_size=512,
        tp_multiple=1,
        vocab_pad_multiple=8,
        sliding_window=8,
        rope_theta=cfg.rope_theta,
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2), head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.num_experts:
        kw.update(num_experts=4, num_experts_per_tok=min(cfg.num_experts_per_tok, 2))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.lru_width:
        kw.update(lru_width=32)
    return dataclasses.replace(cfg, **kw)
