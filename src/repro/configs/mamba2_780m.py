"""mamba2-780m — exact assigned config (defined in registry.py).

Select with ``--arch mamba2-780m`` or ``get_config("mamba2-780m")``;
reduced smoke twin via ``smoke_config("mamba2-780m")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("mamba2-780m")
SMOKE = smoke_config("mamba2-780m")
