"""phi4-mini-3.8b — exact assigned config (defined in registry.py).

Select with ``--arch phi4-mini-3.8b`` or ``get_config("phi4-mini-3.8b")``;
reduced smoke twin via ``smoke_config("phi4-mini-3.8b")``.
"""
from .registry import get_config, smoke_config

CONFIG = get_config("phi4-mini-3.8b")
SMOKE = smoke_config("phi4-mini-3.8b")
