"""Runtime metric primitives — counters, gauges, log-bucketed histograms
(DESIGN.md §11.1).

The serving stack (engine dispatch, :class:`~repro.core.DeviceImageStore`
syncs, :class:`~repro.serve.router.SessionRouter`,
:class:`~repro.serve.plane.ShardedLookupPlane`,
:mod:`repro.launch.replicate`) reports through ONE
:class:`MetricRegistry`:

* :class:`Counter`   — a monotonically-increasing **integer**.  Counters
  count events, keys, words, and bytes — never wall-clock — so a counter
  snapshot of a deterministic replay is bit-identical across runs (the
  telemetry determinism gate, ``benchmarks/bench_obs.py``).
* :class:`Gauge`     — a point-in-time value (pending handles, follower
  lag).  Gauges are set from deterministic state, same property.
* :class:`Histogram` — a **log-bucketed** latency/size distribution:
  observations land in buckets at ``2^(i/4)`` boundaries (4 per octave,
  ≤ 19 % relative quantile error) held as a sparse ``index → count``
  dict, so p50/p95/p99/max come out of O(buckets) state without storing
  samples, and two histograms merge associatively (bucket-count adds).

Enable/disable is a *registry swap*, not per-call flags: the process
default starts as the strict no-op :class:`NullRegistry` (``active``
False, every instrument a shared do-nothing singleton), so disabled
telemetry costs the instrumented path one attribute lookup and a falsy
check.  ``enable()`` installs a real registry;
:class:`~repro.sim.driver.ScenarioDriver`'s ``telemetry=`` scopes one to
a replay.  All mutation is lock-protected — registries are shared by
serving threads racing epoch flips (tests/test_obs.py hammers this the
way test_image_store hammers the store).
"""
from __future__ import annotations

import math
import threading

#: log-bucket resolution: 4 buckets per power of two (factor 2^0.25).
BUCKETS_PER_OCTAVE = 4
#: smallest representable observation (values at or below clamp here)
MIN_EXP = -16 * BUCKETS_PER_OCTAVE   # 2^-16
#: largest bucket index (values above clamp; 2^48 µs ≈ 8.9 years)
MAX_EXP = 48 * BUCKETS_PER_OCTAVE


def bucket_index(value: float) -> int:
    """The histogram bucket of ``value``: ``floor(log2(v) · 4)`` clamped
    to [MIN_EXP, MAX_EXP].  Bucket ``i`` covers ``(2^(i/4), 2^((i+1)/4)]``
    exactly at the representable boundaries, so the bucket math is a pure
    function tests can pin."""
    if value <= 2.0 ** (MIN_EXP / BUCKETS_PER_OCTAVE):
        return MIN_EXP
    idx = math.floor(math.log2(value) * BUCKETS_PER_OCTAVE)
    # land exact boundaries 2^(i/4) in the bucket BELOW (half-open above)
    if 2.0 ** (idx / BUCKETS_PER_OCTAVE) >= value:
        idx -= 1
    return min(idx, MAX_EXP)


def bucket_upper(index: int) -> float:
    """Inclusive upper edge of bucket ``index``: ``2^((index+1)/4)``."""
    return 2.0 ** ((index + 1) / BUCKETS_PER_OCTAVE)


class Counter:
    """Thread-safe monotonically-increasing integer."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up (use a Gauge)")
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Thread-safe point-in-time value (int or float)."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self._value = 0

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def add(self, n=1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value


class Histogram:
    """Sparse log-bucketed distribution: quantiles without samples.

    ``observe(v)`` increments the ``bucket_index(v)`` count and folds
    ``v`` into exact ``sum``/``min``/``max`` running aggregates.
    ``quantile(q)`` walks the cumulative bucket counts and returns the
    containing bucket's upper edge clipped to the observed max — a
    deterministic function of the bucket state, in error by at most one
    bucket width (≤ 2^0.25 ≈ 1.19×).
    """

    __slots__ = ("name", "labels", "_lock", "buckets", "count", "sum",
                 "min", "max")

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        idx = bucket_index(value)
        with self._lock:
            self.buckets[idx] = self.buckets.get(idx, 0) + 1
            self.count += 1
            self.sum += value
            if value < self.min:
                self.min = value
            if value > self.max:
                self.max = value

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into this histogram (associative and
        commutative over the bucket state, up to float-sum ordering)."""
        with self._lock:
            for idx, c in other.buckets.items():
                self.buckets[idx] = self.buckets.get(idx, 0) + c
            self.count += other.count
            self.sum += other.sum
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)
        return self

    def quantile(self, q: float) -> float:
        if not 0.0 <= q <= 1.0:
            raise ValueError("quantile q must be in [0, 1]")
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        cum = 0
        for idx in sorted(self.buckets):
            cum += self.buckets[idx]
            if cum >= rank:
                return min(bucket_upper(idx), self.max)
        return self.max  # unreachable unless racing observers

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentiles(self) -> dict[str, float]:
        """The snapshot quartet: p50/p95/p99/max."""
        return {"p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99),
                "max": self.max if self.count else 0.0}


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricRegistry:
    """Thread-safe name → instrument map, plus the attached tracer/sink.

    ``counter/gauge/histogram`` get-or-create by (name, labels) — hot
    paths may call them per batch; after first creation the cost is one
    locked dict hit.  ``snapshot()`` flattens everything into the
    JSON-able dict ``obs/export.py`` renders and
    ``BENCH_scenarios.json`` embeds.
    """

    active = True

    def __init__(self, *, max_spans: int = 4096, max_events: int = 8192):
        from .export import TelemetrySink
        from .trace import Tracer

        self._lock = threading.Lock()
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self.sink = TelemetrySink(max_events=max_events)
        self.tracer = Tracer(max_spans=max_spans, sink=self.sink)

    def _get(self, cls, name: str, labels: dict):
        key = _key(name, labels)
        m = self._metrics.get(key)  # GIL-safe fast path
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = self._metrics[key] = cls(name, labels)
        if not isinstance(m, cls):
            raise TypeError(f"metric {key!r} is a {type(m).__name__}, "
                            f"not a {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def span(self, name: str, **attrs):
        """Open a trace span on this registry's tracer (obs/trace.py)."""
        return self.tracer.span(name, **attrs)

    def metrics(self) -> dict:
        with self._lock:
            return dict(self._metrics)

    def snapshot(self) -> dict:
        """Flatten to ``{"counters", "gauges", "histograms"}`` with sorted
        keys.  Counters and gauges of a deterministic replay are
        bit-identical across runs; histogram COUNTS are deterministic too
        (one observation per timed event) while their bucket spread is
        wall-clock-dependent — the determinism gate compares the former
        and only requires the latter populated."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for key, m in sorted(self.metrics().items()):
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = m.value
            else:
                out["histograms"][key] = {
                    "count": m.count, "sum": m.sum, "mean": m.mean,
                    **m.percentiles(),
                    "buckets": {str(i): m.buckets[i]
                                for i in sorted(m.buckets)}}
        return out


class _NullMetric:
    """The do-nothing instrument every NullRegistry call returns."""

    __slots__ = ()
    name = ""
    labels: dict = {}
    value = 0
    count = 0
    sum = 0.0
    mean = 0.0
    min = 0.0
    max = 0.0
    buckets: dict = {}

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def add(self, n=1) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def merge(self, other):
        return self

    def quantile(self, q: float) -> float:
        return 0.0

    def percentiles(self) -> dict:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "max": 0.0}


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Strict no-op registry: telemetry off.

    Every accessor returns the shared :class:`_NullMetric` singleton, the
    tracer/sink are their null twins, and ``active`` is False so
    instrumented hot paths skip their ``perf_counter`` bookkeeping
    entirely — the disabled cost is one attribute lookup plus a falsy
    check (bench_obs gates this stays within noise of no
    instrumentation)."""

    active = False

    def __init__(self):
        from .export import NullSink
        from .trace import NullTracer

        self.sink = NullSink()
        self.tracer = NullTracer()

    def counter(self, name: str, **labels) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str, **labels) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, name: str, **labels) -> _NullMetric:
        return _NULL_METRIC

    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    def metrics(self) -> dict:
        return {}

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}


_NULL_REGISTRY = NullRegistry()
_default: MetricRegistry | NullRegistry = _NULL_REGISTRY
_default_lock = threading.Lock()


def default_registry() -> MetricRegistry | NullRegistry:
    """The process-global registry instrumented modules consult when no
    registry was injected (starts as the NullRegistry — telemetry off)."""
    return _default


def set_default_registry(reg) -> MetricRegistry | NullRegistry:
    """Install ``reg`` (None → the NullRegistry) as the process default;
    returns the previous one so scoped callers can restore it."""
    global _default
    with _default_lock:
        prev = _default
        _default = reg if reg is not None else _NULL_REGISTRY
    return prev


def enable(registry: MetricRegistry | None = None) -> MetricRegistry:
    """Turn process-wide telemetry on; returns the installed registry."""
    reg = registry if registry is not None else MetricRegistry()
    set_default_registry(reg)
    return reg


def disable() -> None:
    """Back to the NullRegistry (telemetry off)."""
    set_default_registry(None)


def ensure_real(registry=None) -> MetricRegistry:
    """A registry guaranteed to record: the one given (if active), else a
    private :class:`MetricRegistry`.  Components whose counters are part
    of their public API (router stats, replication lag gauges) use this
    so the API works with telemetry globally off while still landing on
    the shared registry when one is injected."""
    if registry is not None and getattr(registry, "active", False):
        return registry
    return MetricRegistry()
