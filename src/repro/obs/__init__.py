"""Runtime telemetry plane (DESIGN.md §11).

One low-overhead subsystem threaded through every serving layer:

* :mod:`repro.obs.metrics` — thread-safe :class:`MetricRegistry` of
  counters, gauges, and log-bucketed histograms (p50/p95/p99/max without
  stored samples), a process-global default, and the strict no-op
  :class:`NullRegistry` so disabled telemetry costs one attribute lookup;
* :mod:`repro.obs.trace`   — nested ``span("sync.flip")`` tracing with
  monotonic stamps that also enters ``jax.profiler`` named scopes, so
  wall-clock spans line up with XLA device traces;
* :mod:`repro.obs.export`  — Prometheus-style text exposition plus a
  bounded JSONL :class:`TelemetrySink` benchmarks and CI snapshot
  deterministically.

Instrumented layers: the kernel engine dispatch, the autotune cache,
:class:`~repro.core.DeviceImageStore` syncs,
:class:`~repro.serve.router.SessionRouter`,
:class:`~repro.serve.plane.ShardedLookupPlane`, and
:mod:`repro.launch.replicate`.  ``ScenarioDriver(telemetry=True)`` scopes
a registry to one replay; ``obs.enable()`` turns the process-global
default on.
"""
from .export import (NullSink, TelemetrySink, render_prometheus,
                     snapshot_text)
from .metrics import (Counter, Gauge, Histogram, MetricRegistry,
                      NullRegistry, bucket_index, bucket_upper,
                      default_registry, disable, enable, ensure_real,
                      set_default_registry)
from .trace import NullTracer, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricRegistry", "NullRegistry",
    "NullSink", "NullTracer", "Span", "TelemetrySink", "Tracer",
    "bucket_index", "bucket_upper", "default_registry", "disable",
    "enable", "ensure_real", "render_prometheus", "set_default_registry",
    "snapshot_text",
]
