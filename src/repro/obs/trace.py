"""Span-based runtime tracing (DESIGN.md §11.2).

A :class:`Span` is one timed region of the serving stack —
``span("store.sync")`` around an epoch flip, ``span("repl.publish")``
around a replication round — with monotonic
(``time.perf_counter_ns``) start/duration stamps and parent/child
nesting carried by a ``contextvars`` token, so spans opened inside an
open span become its children automatically (including across the
driver's nested store → kernel call chains, and per *logical* context
in threaded servers).

Every completed span is appended to the owning :class:`Tracer`'s bounded
ring and emitted as a ``kind="span"`` event on the registry's
:class:`~repro.obs.export.TelemetrySink` JSONL log.  When a span opens,
the tracer also enters a ``jax.profiler.TraceAnnotation`` named scope,
so spans line up with XLA device traces in TensorBoard/perfetto: the
wall-clock span tree and the device timeline share names.

Determinism: span *structure* (names, nesting, order of completion) is a
pure function of the replayed control flow; only the timestamps are
wall-clock.  tests/test_obs.py pins the structure.
"""
from __future__ import annotations

import contextvars
import itertools
import threading
import time
from dataclasses import dataclass, field

#: the open-span context (span id of the innermost open span, 0 = root)
_CURRENT: contextvars.ContextVar[int] = contextvars.ContextVar(
    "repro_obs_span", default=0)


def _profiler_scope(name: str):
    """A ``jax.profiler`` named scope, or None when jax is unavailable —
    tracing must never make telemetry a hard jax dependency."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:  # pragma: no cover - jax is present in this repo
        return None
    return TraceAnnotation(name)


@dataclass
class Span:
    """One completed (or open) trace region."""

    name: str
    id: int
    parent: int          # 0 = top-level
    depth: int
    start_us: float      # monotonic, relative to the tracer's epoch
    dur_us: float = 0.0
    attrs: dict = field(default_factory=dict)


class Tracer:
    """Bounded completed-span ring + the nesting machinery.

    ``span(name)`` is a context manager AND re-entrant: nested ``with``
    blocks chain parent ids.  The ring keeps the most recent
    ``max_spans`` completed spans (oldest dropped, ``dropped`` counts
    them) — telemetry must stay O(1) memory under million-event storms.
    """

    def __init__(self, *, max_spans: int = 4096, sink=None):
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._epoch_ns = time.perf_counter_ns()
        self.max_spans = max_spans
        self.spans: list[Span] = []
        self.dropped = 0
        self.sink = sink

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._epoch_ns) / 1e3

    def span(self, name: str, **attrs) -> "_SpanContext":
        return _SpanContext(self, name, attrs)

    def _complete(self, span: Span) -> None:
        with self._lock:
            self.spans.append(span)
            if len(self.spans) > self.max_spans:
                drop = len(self.spans) - self.max_spans
                del self.spans[:drop]
                self.dropped += drop
        if self.sink is not None:
            self.sink.emit("span", name=span.name, id=span.id,
                           parent=span.parent, depth=span.depth,
                           start_us=round(span.start_us, 3),
                           dur_us=round(span.dur_us, 3), **span.attrs)

    # -- reading ------------------------------------------------------------
    def completed(self, name: str | None = None) -> list[Span]:
        with self._lock:
            spans = list(self.spans)
        return spans if name is None else [s for s in spans if s.name == name]

    def children_of(self, span: Span) -> list[Span]:
        return [s for s in self.completed() if s.parent == span.id]

    def tree(self) -> list[tuple[int, str, float]]:
        """(depth, name, dur_us) rows in completion order — the compact
        text rendering quickstarts print."""
        return [(s.depth, s.name, s.dur_us) for s in self.completed()]


class _SpanContext:
    """The ``with tracer.span("..."):`` guard."""

    __slots__ = ("_tracer", "_name", "_attrs", "_span", "_token",
                 "_depth_token", "_scope")

    def __init__(self, tracer: Tracer, name: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._span: Span | None = None
        self._token = None
        self._scope = None

    def __enter__(self) -> Span:
        t = self._tracer
        parent = _CURRENT.get()
        span = Span(name=self._name, id=next(t._ids), parent=parent,
                    depth=0, start_us=t._now_us(), attrs=self._attrs)
        # depth = chain length to the root; the parent is still open (not
        # in the completed ring), so it rides its own contextvar.
        span.depth = _DEPTH.get() + 1
        self._span = span
        self._token = _CURRENT.set(span.id)
        self._depth_token = _DEPTH.set(span.depth)
        self._scope = _profiler_scope(self._name)
        if self._scope is not None:
            self._scope.__enter__()
        return span

    def __exit__(self, *exc) -> None:
        if self._scope is not None:
            self._scope.__exit__(*exc)
        span = self._span
        span.dur_us = self._tracer._now_us() - span.start_us
        _CURRENT.reset(self._token)
        _DEPTH.reset(self._depth_token)
        self._tracer._complete(span)


_DEPTH: contextvars.ContextVar[int] = contextvars.ContextVar(
    "repro_obs_depth", default=0)


class _NullSpan:
    name = ""
    id = 0
    parent = 0
    depth = 0
    start_us = 0.0
    dur_us = 0.0
    attrs: dict = {}


_NULL_SPAN = _NullSpan()


class _NullSpanContext:
    __slots__ = ()

    def __enter__(self):
        return _NULL_SPAN

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN_CTX = _NullSpanContext()


class NullTracer:
    """No-op tracer: ``span()`` returns a shared do-nothing context."""

    max_spans = 0
    spans: list = []
    dropped = 0
    sink = None

    def span(self, name: str, **attrs) -> _NullSpanContext:
        return _NULL_SPAN_CTX

    def completed(self, name: str | None = None) -> list:
        return []

    def children_of(self, span) -> list:
        return []

    def tree(self) -> list:
        return []
