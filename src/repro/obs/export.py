"""Telemetry export: Prometheus-style exposition + bounded JSONL events
(DESIGN.md §11.3).

Two complementary outputs of one :class:`~repro.obs.metrics.MetricRegistry`:

* :func:`render_prometheus` — the text exposition format scrape
  endpoints speak: ``# TYPE`` headers, sanitized metric names
  (``store.sync.us`` → ``repro_store_sync_us``), cumulative
  ``_bucket{le="..."}`` lines derived from the registry's log buckets,
  ``_sum``/``_count``, sorted deterministically so two snapshots of the
  same counters render byte-identically.
* :class:`TelemetrySink` — a bounded in-memory JSONL event log (span
  completions from :mod:`repro.obs.trace`, sync/publish events from the
  instrumented layers).  Bounded means a million-event churn storm costs
  O(max_events) host memory; ``dropped`` counts the overflow honestly.

``snapshot_text`` and ``TelemetrySink.to_jsonl`` are what
``benchmarks/bench_obs.py`` writes as CI artifacts — a replay's telemetry
you can diff.
"""
from __future__ import annotations

import json
import re
import threading
from collections import deque

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")
#: every exposed metric name is prefixed — the repo is one job to a scraper
PREFIX = "repro_"


def prom_name(name: str) -> str:
    """Sanitize a registry metric name for the exposition format."""
    return PREFIX + _NAME_RE.sub("_", name)


def _labels_text(labels: dict, extra: dict | None = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(f'{k}="{merged[k]}"' for k in sorted(merged))
    return "{" + inner + "}"


def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, float):
        if v != v:  # NaN
            return "NaN"
        if v in (float("inf"), float("-inf")):
            return "+Inf" if v > 0 else "-Inf"
        return repr(v)
    return str(v)


def render_prometheus(registry) -> str:
    """The registry as Prometheus text exposition (deterministic order:
    counters, gauges, histograms, each name-sorted)."""
    from .metrics import Counter, Gauge, Histogram, bucket_upper

    counters: dict[str, list] = {}
    gauges: dict[str, list] = {}
    hists: dict[str, list] = {}
    for m in registry.metrics().values():
        group = (counters if isinstance(m, Counter) else
                 gauges if isinstance(m, Gauge) else
                 hists if isinstance(m, Histogram) else None)
        if group is not None:
            group.setdefault(m.name, []).append(m)
    lines: list[str] = []
    for kind, group in (("counter", counters), ("gauge", gauges)):
        for name in sorted(group):
            pname = prom_name(name)
            lines.append(f"# TYPE {pname} {kind}")
            for m in sorted(group[name], key=lambda m: sorted(m.labels.items())):
                lines.append(f"{pname}{_labels_text(m.labels)} {_fmt(m.value)}")
    for name in sorted(hists):
        pname = prom_name(name)
        lines.append(f"# TYPE {pname} histogram")
        for m in sorted(hists[name], key=lambda m: sorted(m.labels.items())):
            cum = 0
            for idx in sorted(m.buckets):
                cum += m.buckets[idx]
                le = _labels_text(m.labels, {"le": f"{bucket_upper(idx):g}"})
                lines.append(f"{pname}_bucket{le} {cum}")
            inf = _labels_text(m.labels, {"le": "+Inf"})
            lines.append(f"{pname}_bucket{inf} {m.count}")
            lt = _labels_text(m.labels)
            lines.append(f"{pname}_sum{lt} {_fmt(m.sum)}")
            lines.append(f"{pname}_count{lt} {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def snapshot_text(registry) -> str:
    """``registry.snapshot()`` as canonical (sorted, indented) JSON — the
    deterministic artifact two replays of one resolved trace must agree
    on over counters/gauges."""
    return json.dumps(registry.snapshot(), indent=2, sort_keys=True) + "\n"


class TelemetrySink:
    """Bounded JSONL event log (thread-safe append, FIFO eviction)."""

    def __init__(self, max_events: int = 8192):
        self._lock = threading.Lock()
        self._events: deque[dict] = deque(maxlen=max_events)
        self.max_events = max_events
        self.emitted = 0     # total ever emitted (evictions included)

    @property
    def dropped(self) -> int:
        return self.emitted - len(self._events)

    def emit(self, kind: str, **fields) -> None:
        event = {"kind": kind, **fields}
        with self._lock:
            self._events.append(event)
            self.emitted += 1

    def events(self, kind: str | None = None) -> list[dict]:
        with self._lock:
            evs = list(self._events)
        return evs if kind is None else [e for e in evs if e["kind"] == kind]

    def to_jsonl(self) -> str:
        return "".join(json.dumps(e, sort_keys=True) + "\n"
                       for e in self.events())

    @staticmethod
    def parse_jsonl(text: str) -> list[dict]:
        """Round-trip reader for the artifact tests/CI wrote."""
        return [json.loads(line) for line in text.splitlines() if line]


class NullSink:
    """Do-nothing sink (the NullRegistry's)."""

    max_events = 0
    emitted = 0
    dropped = 0

    def emit(self, kind: str, **fields) -> None:
        pass

    def events(self, kind: str | None = None) -> list:
        return []

    def to_jsonl(self) -> str:
        return ""

    parse_jsonl = staticmethod(TelemetrySink.parse_jsonl)
