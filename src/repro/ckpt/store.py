"""Checkpointing: Memento-placed shard files, manifest, async writer.

Every leaf of the state pytree is a *checkpoint shard* keyed by its tree
path; MementoHash assigns shards → storage buckets (one ``bucket_XXXX.npz``
per bucket, mirroring hosts/volumes in a real deployment).  Because the
placement is consistent, growing or shrinking the storage fleet between
save and restore relocates only the necessary shards; restore only needs
the manifest (which records the Memento state ⟨n, R, l⟩ it was saved with).

``AsyncCheckpointer`` runs saves on a writer thread so the train loop never
blocks on I/O (device→host transfer happens on the caller's thread via
``np.asarray``, the serialization + fsync on the writer's).
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

from repro.core import MementoHash
from repro.core.hashing import key_to_u64


def _flatten(tree, prefix=()) -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
        return out
    out["/".join(prefix)] = np.asarray(tree)
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> dict:
    root: dict = {}
    for path, val in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_checkpoint(state, step: int, directory, *, num_buckets: int = 4,
                    memento: MementoHash | None = None) -> Path:
    directory = Path(directory)
    ckpt_dir = directory / f"step_{step:08d}"
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    m = memento or MementoHash(num_buckets)

    buckets: dict[int, dict[str, np.ndarray]] = {}
    manifest = {"step": step,
                "memento": {"n": m.n, "l": m.l,
                            "R": {str(k): list(v) for k, v in m.R.items()}},
                "shards": {}}
    for path, arr in flat.items():
        b = m.lookup(key_to_u64(path))
        buckets.setdefault(b, {})[path] = arr
        manifest["shards"][path] = {
            "bucket": b, "shape": list(arr.shape), "dtype": str(arr.dtype)}

    for b, items in buckets.items():
        np.savez(ckpt_dir / f"bucket_{b:04d}.npz",
                 **{p.replace("/", "|"): a for p, a in items.items()})
    (ckpt_dir / "manifest.json").write_text(json.dumps(manifest))
    (ckpt_dir / "_DONE").write_text(str(time.time()))  # commit marker
    return ckpt_dir


def latest_step(directory) -> int | None:
    directory = Path(directory)
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if (p / "_DONE").exists()]
    return max(steps) if steps else None


def restore_checkpoint(directory, step: int | None = None):
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
    ckpt_dir = directory / f"step_{step:08d}"
    manifest = json.loads((ckpt_dir / "manifest.json").read_text())
    flat = {}
    by_bucket: dict[int, list[str]] = {}
    for path, info in manifest["shards"].items():
        by_bucket.setdefault(info["bucket"], []).append(path)
    for b, paths in by_bucket.items():
        with np.load(ckpt_dir / f"bucket_{b:04d}.npz") as z:
            for p in paths:
                flat[p] = z[p.replace("/", "|")]
    return _unflatten(flat), manifest


class AsyncCheckpointer:
    def __init__(self, directory, *, num_buckets: int = 4, keep: int = 3):
        self.directory = Path(directory)
        self.num_buckets = num_buckets
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, state, step: int) -> None:
        self.wait()  # one in-flight save at a time
        host_state = _flatten(state)  # device→host on caller thread

        def _write():
            try:
                save_checkpoint(_unflatten(host_state), step, self.directory,
                                num_buckets=self.num_buckets)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*")
                       if (p / "_DONE").exists())
        for s in steps[: -self.keep]:
            d = self.directory / f"step_{s:08d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()
