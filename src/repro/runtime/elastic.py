"""Elastic cluster controller + straggler mitigation (virtualized).

One ConsistentHash instance per resource class (data shards, checkpoint
buckets, serving sessions) keeps every placement consistent through node
churn; both the shard AND the checkpoint-bucket placement follow the one
`algo=` choice (Memento by default, Anchor/Dx for fixed-capacity fleets),
and movement plans come from the device-plane epoch diff — one fused
launch of the unified lookup engine (DESIGN.md §6), which
:meth:`ElasticCluster.replica_movement` extends to whole k-replica sets —
on TPU-native states.  The controller is the
piece a real deployment would wire to its health checker: `fail(host)` →
Θ(1) state update + minimal re-placement; `join()` → restores the most
recent failure first (the paper's recommended LIFO discipline keeps R
small, so lookups stay at Jump speed).

StragglerMonitor implements deadline-based gradient skipping: hosts whose
step latency exceeds μ + k·σ get their microbatch contribution dropped and
the gradient rescaled by participating/total — the standard backup-worker
trick, simulated deterministically for tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import MementoHash, make_hash
from repro.data.pipeline import ShardPlacement


@dataclass
class ClusterEvent:
    kind: str      # "fail" | "join"
    host: int
    moved: int     # resources relocated by the event


def domain_distinct_replicas(ch, key: int, k: int, domain_of) -> list[int]:
    """k working buckets for ``key`` with pairwise-distinct failure domains.

    The ``lookup_k`` salted walk (DESIGN.md §4.1, via
    ``ReplicatedLookup.lookup_k_filtered`` so there is exactly one walk
    implementation) with one extra skip rule: candidates whose domain is
    already represented are rejected like duplicates, so a whole-domain
    outage (rack, power feed) can never take out more than one replica of a
    shard.  Requires ``k`` ≤ the number of distinct domains among working
    buckets.
    """
    domains_avail = {domain_of(b) for b in ch.working_set()}
    if k > len(domains_avail):
        raise ValueError(f"k={k} exceeds the {len(domains_avail)} distinct "
                         "failure domains among working buckets")

    def reject(cand, chosen):
        return cand in chosen or domain_of(cand) in {domain_of(b)
                                                     for b in chosen}

    return ch.lookup_k_filtered(key, k, reject)


class ElasticCluster:
    def __init__(self, num_hosts: int, *, num_shards: int = 256,
                 ckpt_buckets: int | None = None, algo: str = "memento",
                 capacity: int | None = None, replica_k: int = 1,
                 num_domains: int | None = None, domain_of=None):
        self.placement = ShardPlacement(num_shards, num_hosts,
                                        algo=algo, capacity=capacity)
        # checkpoint-bucket placement follows the SAME algo= choice as the
        # shard placement (it used to hardwire MementoHash).
        nb = ckpt_buckets or max(num_hosts // 2, 2)
        self.ckpt_ch = make_hash(algo, nb, capacity=capacity and max(capacity, nb))
        self.events: list[ClusterEvent] = []
        # replica-aware placement (DESIGN.md §4.3): shards live on replica_k
        # hosts whose failure domains are pairwise distinct.  Default domain
        # map: host % num_domains (rack-striped ids); with neither given,
        # every host is its own domain (plain distinctness).
        self.replica_k = replica_k
        if domain_of is not None:
            self.domain_of = domain_of
        elif num_domains is not None:
            self.domain_of = lambda host: host % num_domains
        else:
            self.domain_of = lambda host: host

    @property
    def ckpt_memento(self):
        """Back-compat alias from the Memento-only controller."""
        return self.ckpt_ch

    @property
    def hosts(self) -> set[int]:
        return self.placement.ch.working_set()

    def fail(self, host: int) -> dict:
        plan = self.placement.fail_host(host)
        assert plan["minimal"], "non-minimal data movement on failure!"
        self.events.append(ClusterEvent("fail", host, len(plan["moved"])))
        return plan

    def join(self) -> dict:
        plan = self.placement.add_host()
        assert plan["monotone"], "non-monotone movement on join!"
        self.events.append(ClusterEvent("join", plan["host"], len(plan["moved"])))
        return plan

    def movement_total(self) -> int:
        return sum(e.moved for e in self.events)

    # -- replica-aware placement (DESIGN.md §4.3) ----------------------------
    def replica_movement(self, k: int | None = None) -> dict[int, dict]:
        """Replica-set churn of the last membership event, planned on the
        device plane: ONE fused engine launch (DESIGN.md §6) diffs every
        shard's k-replica set between the retained and the front epoch of
        the placement's image store.  Returns shard → {"old", "new"}
        replica lists for exactly the shards whose set changed.

        Covers the plain dedup replica sets (``lookup_k``); the
        domain-distinct placement (:meth:`replica_hosts`) coincides with it
        under the default identity domain map and stays host-planned
        otherwise.
        """
        store = self.placement.image_store()
        if store.previous_image() is None:
            return {}
        keys = np.arange(self.placement.num_shards, dtype=np.uint32)
        d = store.migration_diff(keys, plane=self.placement.plane,
                                 k=k or self.replica_k)
        old = np.atleast_2d(d.old.T).T
        new = np.atleast_2d(d.new.T).T
        return {int(s): {"old": old[s].tolist(), "new": new[s].tolist()}
                for s in np.nonzero(d.moved)[0]}

    def replica_hosts(self, shard: int, k: int | None = None) -> list[int]:
        """The shard's replica set: k hosts on pairwise-distinct failure
        domains (host 0 of the list is the classic single-host placement)."""
        return domain_distinct_replicas(self.placement.ch, shard,
                                        k or self.replica_k, self.domain_of)

    def replica_placement(self, k: int | None = None) -> dict[int, list[int]]:
        """shard → replica hosts for every shard (distinct domains each)."""
        return {s: self.replica_hosts(s, k)
                for s in range(self.placement.num_shards)}

    def state(self) -> dict:
        """Protocol-generic controller state (plus Memento's ⟨n, R, l⟩)."""
        m = self.placement.ch
        st = {"algo": m.name, "size": m.size, "working": m.working,
              "epoch": getattr(m, "epoch", 0),
              "ckpt": {"algo": self.ckpt_ch.name, "size": self.ckpt_ch.size,
                       "working": self.ckpt_ch.working}}
        if isinstance(m, MementoHash):  # ⟨n, R, l⟩ (paper state)
            st.update({"n": m.n, "l": m.l, "R": dict(m.R)})
        return st


class StragglerMonitor:
    def __init__(self, *, k_sigma: float = 3.0, window: int = 50,
                 min_participation: float = 0.5):
        self.k = k_sigma
        self.window = window
        self.min_participation = min_participation
        self._lat: list[float] = []

    def deadline(self) -> float:
        if len(self._lat) < 8:
            return float("inf")
        arr = np.asarray(self._lat[-self.window:])
        return float(arr.mean() + self.k * arr.std())

    def observe(self, latency: float) -> None:
        self._lat.append(latency)

    def filter_step(self, host_latencies: dict[int, float]) -> dict:
        """Which hosts make the deadline; gradient rescale factor."""
        dl = self.deadline()
        for v in host_latencies.values():
            self.observe(v)
        ok = {h for h, v in host_latencies.items() if v <= dl}
        total = len(host_latencies)
        if len(ok) < self.min_participation * total:
            ok = set(host_latencies)  # too many stragglers ⇒ wait for all
        scale = total / max(len(ok), 1)
        return {"participants": ok, "skipped": set(host_latencies) - ok,
                "grad_scale": scale, "deadline": dl}
