from .elastic import ElasticCluster, StragglerMonitor

__all__ = ["ElasticCluster", "StragglerMonitor"]
