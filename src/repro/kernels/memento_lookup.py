"""Pallas TPU kernel: batched MementoHash lookup (paper Alg. 4).

The hot spot the paper optimizes is the *lookup*: the data plane routes
millions of keys (tokens→data-shards, sessions→replicas, ckpt-keys→hosts)
per step.  On TPU we express this as a block-parallel kernel:

  * grid over key blocks of ``(BLOCK_ROWS, 128)`` uint32 keys (VMEM),
  * the replacement table resident in VMEM for every program — either the
    **dense** int32 image (``repl[b] = c | -1``, Θ(n) bytes) or the
    **compact** open-addressing image (Θ(r) bytes, beyond-paper, for
    r ≪ n clusters where the dense table would not fit VMEM),
  * lane-synchronous bounded while-loops: every lane follows its own
    replacement chain; a block settles in max-over-lanes sweeps which the
    paper bounds by E[τ],E[σ] ≤ ln(n/w) (Props. VII.1-3).

TPU adaptation notes (arithmetic: DESIGN.md §3.1; dense/compact table
layouts: §3.2; kernel structure: §3.4): JumpHash's 64-bit LCG is replaced
by a murmur3-mixed (key, step) variate quantized to 24 bits so every
divide is an exact f32 op; the replacement "hash table" becomes vector
gathers.  Chain following is a gather off the same table — no pointer
chasing.  The hash arithmetic is shared with the jnp oracle via
``kernels/primitives.py``.

Validated in ``interpret=True`` mode on CPU against ``ref.py`` (the pure-jnp
oracle, itself bit-identical to the numpy host plane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashing import GOLDEN32, np_fmix32
from .primitives import fmix32, gather1d, hash2, jump32

_U = jnp.uint32

DEFAULT_BLOCK_ROWS = 8  # (8, 128) keys per program = 1024 lookups


# ---------------------------------------------------------------------------
# Dense-table kernel
# ---------------------------------------------------------------------------

def dense_body(keys, repl, n):
    """Kernel-side dense lookup body: keys block + flat VMEM repl + dynamic n.

    Shared between the lookup kernel and the fused migration-diff kernel
    (``kernels/migrate.py``), which runs it once per epoch image.
    """
    b = jump32(keys, n)

    def outer_cond(b):
        return jnp.any(gather1d(repl, b) >= 0)

    def outer_body(b):
        c = gather1d(repl, b)
        active = c >= 0
        wb = jnp.where(active, c, 1)  # |W_b| after b was removed (Prop. V.3)
        d = (hash2(keys, b) % wb.astype(_U)).astype(jnp.int32)

        def inner_cond(d):
            u = gather1d(repl, d)
            return jnp.any(active & (u >= 0) & (u >= wb))

        def inner_body(d):
            u = gather1d(repl, d)
            follow = active & (u >= 0) & (u >= wb)  # follow only while u ≥ w_b
            return jnp.where(follow, u, d)

        d = jax.lax.while_loop(inner_cond, inner_body, d)
        return jnp.where(active, d, b)

    return jax.lax.while_loop(outer_cond, outer_body, b)


def _dense_kernel(n_ref, keys_ref, repl_ref, out_ref):
    keys = keys_ref[...].astype(_U)
    repl = repl_ref[...].reshape(-1)  # (cap,) int32, -1 = working
    out_ref[...] = dense_body(keys, repl, n_ref[0])


# ---------------------------------------------------------------------------
# Compact-table kernel (beyond-paper): Θ(r) VMEM open-addressing image
# ---------------------------------------------------------------------------

def _compact_kernel(n_ref, keys_ref, slot_b_ref, slot_c_ref, out_ref):
    n = n_ref[0]
    keys = keys_ref[...].astype(_U)
    slot_b = slot_b_ref[...].reshape(-1)  # removed bucket id per slot, -1 empty
    slot_c = slot_c_ref[...].reshape(-1)  # its replacement c
    nslots = slot_b.shape[0]  # power of two
    mask = _U(nslots - 1)

    def probe(idx):
        """repl[idx] via linear probing: returns c or -1 (working)."""
        h0 = (fmix32(idx.astype(_U) * _U(GOLDEN32) + _U(5)) & mask).astype(jnp.int32)

        def cond(state):
            pos, done, _ = state
            return jnp.any(~done)

        def body(state):
            pos, done, val = state
            sb = gather1d(slot_b, pos)
            hit = sb == idx
            empty = sb < 0
            val = jnp.where(~done & hit, gather1d(slot_c, pos), val)
            done = done | hit | empty
            pos = jnp.where(done, pos, (pos + 1) % nslots)
            return pos, done, val

        val0 = jnp.full(idx.shape, -1, jnp.int32)
        done0 = jnp.zeros(idx.shape, jnp.bool_)
        _, _, val = jax.lax.while_loop(cond, body, (h0, done0, val0))
        return val

    b = jump32(keys, n)

    def outer_cond(b):
        return jnp.any(probe(b) >= 0)

    def outer_body(b):
        c = probe(b)
        active = c >= 0
        wb = jnp.where(active, c, 1)
        d = (hash2(keys, b) % wb.astype(_U)).astype(jnp.int32)

        def inner_cond(d):
            u = probe(d)
            return jnp.any(active & (u >= 0) & (u >= wb))

        def inner_body(d):
            u = probe(d)
            follow = active & (u >= 0) & (u >= wb)
            return jnp.where(follow, u, d)

        d = jax.lax.while_loop(inner_cond, inner_body, d)
        return jnp.where(active, d, b)

    out_ref[...] = jax.lax.while_loop(outer_cond, outer_body, b)


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------

def _pad_rows(x, cols=128):
    k = x.shape[0]
    rows = max(1, -(-k // cols))
    padded = jnp.zeros((rows * cols,), x.dtype).at[:k].set(x)
    return padded.reshape(rows, cols), k


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def dense_lookup(keys, repl, n, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """Batched lookup with the dense Θ(n)-int32 table in VMEM."""
    keys2d, k = _pad_rows(keys.astype(_U))
    rows = keys2d.shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    cap = repl.shape[0]
    repl2d = repl.reshape(-1, 128) if cap % 128 == 0 else repl.reshape(cap, 1)

    out = pl.pallas_call(
        _dense_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_rows, 128), lambda i, n_s: (i, 0)),
                pl.BlockSpec(repl2d.shape, lambda i, n_s: (0, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, 128), lambda i, n_s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(keys2d.shape, jnp.int32),
        interpret=interpret,
    )(jnp.asarray([n], jnp.int32), keys2d, repl2d)
    return out.reshape(-1)[:k]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def compact_lookup(keys, slot_b, slot_c, n, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """Batched lookup with the Θ(r) open-addressing table in VMEM."""
    keys2d, k = _pad_rows(keys.astype(_U))
    rows = keys2d.shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    nslots = slot_b.shape[0]
    shape2d = (-(-nslots // 128), 128) if nslots % 128 == 0 else (nslots, 1)
    sb2d, sc2d = slot_b.reshape(shape2d), slot_c.reshape(shape2d)

    out = pl.pallas_call(
        _compact_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_rows, 128), lambda i, n_s: (i, 0)),
                pl.BlockSpec(shape2d, lambda i, n_s: (0, 0)),
                pl.BlockSpec(shape2d, lambda i, n_s: (0, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, 128), lambda i, n_s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(keys2d.shape, jnp.int32),
        interpret=interpret,
    )(jnp.asarray([n], jnp.int32), keys2d, sb2d, sc2d)
    return out.reshape(-1)[:k]


def build_compact_table(repl) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Host-side: dense repl image → open-addressing (slot_b, slot_c) arrays.

    Slots = next power of two ≥ max(2r, 128) → load factor ≤ 0.5, so the
    expected probe chain is ~1.5 and the VMEM working set is Θ(r).

    Insertion is vectorized: each round, every still-unplaced key whose
    current slot is free claims it (first pending key per slot wins); the
    rest advance one slot.  Slots only ever fill, so every slot a key
    skipped is occupied in the final table — the kernel's probe loop
    (scan from h0 until hit or empty) finds every key.
    """
    repl = np.asarray(repl)
    removed = np.nonzero(repl >= 0)[0].astype(np.int64)
    r = int(removed.size)
    nslots = 128
    while nslots < 2 * max(r, 1):
        nslots *= 2
    slot_b = np.full((nslots,), -1, np.int32)
    slot_c = np.full((nslots,), -1, np.int32)
    mask = nslots - 1
    with np.errstate(over="ignore"):
        pos = np_fmix32(removed.astype(np.uint32) * np.uint32(GOLDEN32)
                        + np.uint32(5)).astype(np.int64) & mask
    pending = np.arange(r)
    while pending.size:
        p = pos[pending]
        free = slot_b[p] < 0
        cand = pending[free]
        _, first = np.unique(p[free], return_index=True)
        win = cand[first]
        slot_b[pos[win]] = removed[win].astype(np.int32)
        slot_c[pos[win]] = repl[removed[win]].astype(np.int32)
        pending = np.setdiff1d(pending, win, assume_unique=True)
        pos[pending] = (pos[pending] + 1) & mask
    return jnp.asarray(slot_b), jnp.asarray(slot_c)
