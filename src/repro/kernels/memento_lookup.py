"""Memento lookup — re-export shim over :mod:`repro.kernels.engine`.

The Pallas TPU kernel bodies that used to live here (paper Alg. 4 over the
dense Θ(n) table and the beyond-paper Θ(r) compact table) are now the
``memento`` configuration of the unified lookup engine (DESIGN.md §6).
This module is kept for one release so existing imports keep working;
new code should target :mod:`repro.kernels.engine` /
:func:`repro.kernels.ops.device_lookup`.
"""
from __future__ import annotations

from .engine import (  # noqa: F401
    DEFAULT_BLOCK_ROWS,
    _pad_rows,
    build_compact_table,
    compact_lookup,
    dense_body,
    dense_lookup,
    memento_body,
)
