"""Pallas TPU kernel: apply an :class:`~repro.core.protocol.ImageDelta`
scatter to a flat device table (DESIGN.md §3.5).

The control-plane hot path under churn: instead of re-transferring an O(n)
snapshot after every ``remove()``/``add()``, the host ships O(changed-words)
``(index, value)`` pairs and the device edits its resident table.  The
kernel is deliberately out-of-place — output = copy of the input table with
the scatter applied — because the image store double-buffers epochs: the
epoch-N buffer must stay intact (and keep serving lookups) while epoch N+1
is materialized.

Scatter layout: the update indices/values ride in the scalar-prefetch
operand (SMEM), bounded by a dynamic ``count`` so one compiled kernel
serves any delta up to the padded width; each update turns into a masked
vector select over the (rows, 128) table block — O(count · n/8·128 VPU
steps), which for the O(1)-word deltas the algorithms emit is a handful of
vector ops.  uint32 tables (the Dx bitmap) are bit-cast through int32 so
the one kernel covers every image array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .primitives import table_shape2d


def _apply_kernel(meta_ref, table_ref, out_ref):
    # meta = [count, idx_0..idx_{P-1}, val_0..val_{P-1}] (int32, SMEM)
    count = meta_ref[0]
    pad = (meta_ref.shape[0] - 1) // 2
    tab = table_ref[...]
    rows, cols = tab.shape
    flat = (lax.broadcasted_iota(jnp.int32, (rows, cols), 0) * cols
            + lax.broadcasted_iota(jnp.int32, (rows, cols), 1))

    def body(j, acc):
        idx = meta_ref[1 + j]
        val = meta_ref[1 + pad + j]
        return jnp.where(flat == idx, val, acc)

    out_ref[...] = lax.fori_loop(0, count, body, tab)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _apply_scatter_i32(meta, table2d, *, interpret: bool = True):
    return pl.pallas_call(
        _apply_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(1,),
            in_specs=[pl.BlockSpec(table2d.shape, lambda i, m: (0, 0))],
            out_specs=pl.BlockSpec(table2d.shape, lambda i, m: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(table2d.shape, jnp.int32),
        interpret=interpret,
    )(meta, table2d)


def _pad_updates(idx, vals, sentinel: int, pad_to: int = 8):
    """Pad (idx, vals) to a power-of-two width ≥ ``pad_to`` so the jitted
    kernels see a handful of shapes, not one per delta size.  Padded slots
    carry ``sentinel`` as their index: -1 for the Pallas kernel (never
    matches a flat position iota), INT32_MAX for the jnp scatter (out of
    bounds for any table, dropped by ``mode="drop"``)."""
    import numpy as np

    k = len(idx)
    width = pad_to
    while width < k:
        width *= 2
    pidx = np.full((width,), sentinel, np.int32)
    pval = np.zeros((width,), np.int32)
    pidx[:k] = idx
    pval[:k] = np.asarray(vals).astype(np.int64).astype(np.int32)
    return pidx, pval, k


@jax.jit
def _scatter_jnp(table, meta):
    # meta = [idx_0..idx_{P-1}, val_0..val_{P-1}] in ONE int32 array: the
    # host→device hop has a fixed per-transfer cost that dwarfs these few
    # words, so the whole delta rides one device_put.  Padded idx slots
    # hold INT32_MAX → dropped.  Compiled once per (table shape, padded
    # width) and reused for every churn event.
    width = meta.shape[0] // 2
    idx, vals = meta[:width], meta[width:]
    return table.at[idx].set(vals.astype(table.dtype), mode="drop")


def compose_updates(update_seq) -> dict:
    """Last-write-wins composition of a sequence of per-array scatter dicts
    (each ``{name: (idx, vals)}``) into ONE such dict.

    The follower-side half of cross-epoch delta batching
    (``launch/replicate.py``): a drained batch of chained frames collapses
    into a single :func:`apply_updates` scatter — one device dispatch per
    drain instead of one per epoch — and positions written by several
    epochs keep only their final value, exactly the dedup rule the leader's
    ``device_delta`` composition applies.  Order within the sequence is the
    epoch order; later writes win.
    """
    import numpy as np

    merged: dict[str, dict[int, int]] = {}
    for updates in update_seq:
        for name, (idx, vals) in updates.items():
            slots = merged.setdefault(name, {})
            for i, v in zip(np.asarray(idx).tolist(),
                            np.asarray(vals).tolist()):
                slots[i] = v
    return {
        name: (np.fromiter(slots.keys(), np.int32, len(slots)),
               np.fromiter(slots.values(), np.int64,
                           len(slots)).astype(np.int32))
        for name, slots in merged.items()
    }


def apply_updates(arrays: dict, updates: dict, *, plane: str = "jnp",
                  interpret: bool = True) -> dict:
    """Apply per-array ``{name: (idx, vals)}`` scatters to an image's
    ``arrays`` dict, out of place.

    Untouched arrays (and empty update lists) pass through by reference —
    they stay shared with the previous epoch's image, which is what makes
    double buffering O(changed-words) instead of O(n).  Shared by the
    leader store's delta apply and the follower replica's wire-frame apply,
    so both sides run bit-identical scatter code.
    """
    out = {}
    for name, arr in arrays.items():
        upd = updates.get(name)
        if upd is not None and len(upd[0]):
            out[name] = scatter_update(arr, upd[0], upd[1], plane=plane,
                                       interpret=interpret)
        else:
            out[name] = arr
    return out


def scatter_update(table, idx, vals, *, plane: str = "jnp",
                   interpret: bool = True):
    """Out-of-place scatter ``table[idx] = vals`` → new device array.

    ``plane='jnp'`` uses a functional ``.at[].set`` (any backend);
    ``plane='pallas'`` runs the apply-delta kernel (interpret off-TPU).
    Either way the input buffer is preserved — the caller keeps it as the
    previous-epoch half of its double buffer.
    """
    table = jnp.asarray(table)
    if plane == "jnp":
        import numpy as np

        pidx, pval, _ = _pad_updates(np.asarray(idx), np.asarray(vals),
                                     sentinel=np.iinfo(np.int32).max)
        # hand the numpy meta straight to jit: ONE dispatch covers the
        # host→device hop and the scatter (the churn hot path).
        return _scatter_jnp(table, np.concatenate([pidx, pval]))
    if plane != "pallas":
        raise ValueError(f"unknown plane {plane!r}")
    import numpy as np

    if table.dtype.itemsize != 4:
        # dtype-narrowed packed tables (int16/int8) cannot bit-cast through
        # the int32 apply kernel (widths differ); their scatter payloads are
        # O(1) words, so the functional path serves them on every backend.
        return scatter_update(table, idx, vals, plane="jnp")
    pidx, pval, k = _pad_updates(np.asarray(idx), np.asarray(vals), sentinel=-1)
    meta = jnp.asarray(np.concatenate([[k], pidx, pval]).astype(np.int32))
    tab_i32 = lax.bitcast_convert_type(table, jnp.int32)
    out = _apply_scatter_i32(meta, tab_i32.reshape(table_shape2d(table.shape[0])),
                             interpret=interpret)
    return lax.bitcast_convert_type(out.reshape(-1), table.dtype)
