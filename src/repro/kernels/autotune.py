"""Engine autotuner — tuned tiles and plane choice per EngineOp (DESIGN.md §8.1).

The engine runs every configuration at a hard-coded ``(8, 128)`` tile and
leaves jnp-vs-Pallas to the caller.  This module searches, per
:class:`~repro.kernels.engine.EngineOp`, over ``block_rows`` (the tile
height) and the execution plane across a (batch size × table size) grid,
and persists the winners in a deterministic JSON cache
(``benchmarks/results/TUNE_engine.json``) that the engine consults at
dispatch time:

* **grid key** — ``backend/op-tag/keys<2^i>/n<2^j>``: batch and table
  sizes bucket to the next power of two, so one measurement covers its
  whole size band and dispatch-time resolution is a pure dict lookup —
  a cache hit can NEVER retrace (the resolved ``block_rows`` is the same
  static jit key every time).
* **override** — an explicit ``block_rows=`` at any entry point always
  wins; an absent cache entry falls back to
  :data:`~repro.kernels.engine.DEFAULT_BLOCK_ROWS` (and the Pallas plane
  on TPU / jnp elsewhere for ``plane="auto"`` callers).
* **correctness** — every candidate's output is asserted bit-identical to
  the default configuration before it may win; tuning can change *time*,
  never placement.

The cache path can be redirected with ``REPRO_TUNE_CACHE=/path.json``
(tests point it at a tmpdir; ``REPRO_TUNE_CACHE=`` disables loading).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass
from pathlib import Path

import numpy as np

from repro.obs.metrics import default_registry as _obs_registry

CACHE_ENV = "REPRO_TUNE_CACHE"
CACHE_VERSION = 1
DEFAULT_CACHE_PATH = (Path(__file__).resolve().parents[3]
                      / "benchmarks" / "results" / "TUNE_engine.json")

#: tile heights searched (rows of 128 lanes per Pallas program instance)
BLOCK_ROWS_GRID = (1, 2, 4, 8, 16, 32)
PLANES = ("jnp", "pallas")


@dataclass(frozen=True)
class TunedConfig:
    """One grid cell's winner: the tile height for the Pallas launch, the
    faster plane at that shape, and the measured µs/key at tuning time
    (advisory — retiming happens in bench_engine, not at dispatch)."""

    block_rows: int = 8
    plane: str = "pallas"
    us_per_key: float = 0.0


def _backend() -> str:
    import jax
    return jax.default_backend()


def op_tag(op) -> str:
    """Stable textual identity of an EngineOp (duck-typed: anything with
    the op's fields works, so this module never imports the engine)."""
    tag = f"{op.algo}.{op.mode}.k{op.k}"
    if op.bounded:
        tag += ".bounded"
    if op.diff:
        tag += ".diff"
    return f"{tag}.{op.table}"


def size_bucket(x: int) -> int:
    """Next power of two ≥ max(x, 1) — one tuning cell per size band."""
    b = 1
    while b < max(int(x), 1):
        b <<= 1
    return b


def grid_key(op, n_keys: int, table_n: int, backend: str | None = None) -> str:
    backend = backend or _backend()
    return (f"{backend}/{op_tag(op)}/keys{size_bucket(n_keys)}"
            f"/n{size_bucket(table_n)}")


# ---------------------------------------------------------------------------
# The persisted cache
# ---------------------------------------------------------------------------

def cache_path() -> Path | None:
    """The active cache file: ``$REPRO_TUNE_CACHE`` (empty = disabled) or
    the checked-in ``benchmarks/results/TUNE_engine.json``."""
    env = os.environ.get(CACHE_ENV)
    if env is not None:
        return Path(env) if env else None
    return DEFAULT_CACHE_PATH


class TuneCache:
    """Grid key → :class:`TunedConfig`, JSON-persisted deterministically
    (sorted keys, stable formatting: same entries ⇒ byte-identical file)."""

    def __init__(self, entries: dict[str, TunedConfig] | None = None,
                 path: Path | None = None):
        self.entries: dict[str, TunedConfig] = dict(entries or {})
        self.path = path

    @classmethod
    def load(cls, path: Path | str | None = None) -> "TuneCache":
        p = Path(path) if path is not None else cache_path()
        if p is None or not p.exists():
            return cls({}, p)
        raw = json.loads(p.read_text())
        entries = {k: TunedConfig(**v)
                   for k, v in raw.get("entries", {}).items()}
        return cls(entries, p)

    def save(self, path: Path | str | None = None) -> Path:
        p = Path(path) if path is not None else (self.path or DEFAULT_CACHE_PATH)
        payload = {"version": CACHE_VERSION,
                   "entries": {k: asdict(self.entries[k])
                               for k in sorted(self.entries)}}
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        self.path = p
        return p

    def get(self, key: str) -> TunedConfig | None:
        return self.entries.get(key)

    def put(self, key: str, cfg: TunedConfig) -> None:
        self.entries[key] = cfg

    def __len__(self) -> int:
        return len(self.entries)


_ACTIVE: TuneCache | None = None


def active_cache() -> TuneCache:
    """The process-wide cache the engine consults, loaded lazily once."""
    global _ACTIVE
    if _ACTIVE is None:
        _ACTIVE = TuneCache.load()
    return _ACTIVE


def set_active_cache(cache: TuneCache | None) -> None:
    """Install (or, with ``None``, drop — forcing a lazy reload) the
    process-wide cache; tests and the tuner use this."""
    global _ACTIVE
    _ACTIVE = cache


# ---------------------------------------------------------------------------
# Dispatch-time resolution (pure dict lookups — never retraces)
# ---------------------------------------------------------------------------

def lookup_tuned(op, n_keys: int, table_n: int,
                 backend: str | None = None) -> TunedConfig | None:
    cfg = active_cache().get(grid_key(op, n_keys, table_n, backend))
    reg = _obs_registry()
    if reg.active:
        reg.counter("engine.autotune.hit" if cfg is not None
                    else "engine.autotune.miss").inc()
    return cfg


def resolve_block_rows(op, n_keys: int, table_n: int,
                       backend: str | None = None) -> int:
    cfg = lookup_tuned(op, n_keys, table_n, backend)
    if cfg is not None:
        return cfg.block_rows
    from .engine import DEFAULT_BLOCK_ROWS
    return DEFAULT_BLOCK_ROWS


def resolve_plane(op, n_keys: int, table_n: int,
                  backend: str | None = None) -> str:
    """Plane for ``plane="auto"`` callers: the tuned winner, else Pallas on
    TPU (the compiled kernel) and jnp elsewhere (interpret-mode Pallas is
    a correctness path, not a serving plane)."""
    cfg = lookup_tuned(op, n_keys, table_n, backend)
    if cfg is not None:
        return cfg.plane
    return "pallas" if (backend or _backend()) == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _time_best(fn, repeats: int) -> float:
    import jax
    jax.block_until_ready(fn())  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_lookup(image, n_keys: int, *, k: int = 1, seed: int = 0,
                    candidates=BLOCK_ROWS_GRID, planes=PLANES,
                    repeats: int = 3, cache: TuneCache | None = None,
                    backend: str | None = None) -> tuple[str, TunedConfig]:
    """Tune one grid cell: measure ``engine_lookup`` over every (plane,
    block_rows) candidate at this (image, batch) shape, assert every
    candidate bit-identical to the default configuration, record the
    fastest in ``cache`` (default: the active cache) and return
    ``(grid key, winner)``."""
    from .engine import DEFAULT_BLOCK_ROWS, EngineOp, engine_lookup

    op = EngineOp(algo=image.algo, k=k,
                  table="packed" if getattr(image, "packed", False)
                  else "dense")
    keys = np.random.default_rng(seed).integers(0, 2**32, size=n_keys,
                                                dtype=np.uint32)
    ref = np.asarray(engine_lookup(keys, image, k=k, plane="pallas",
                                   block_rows=DEFAULT_BLOCK_ROWS))
    measured: list[tuple[float, str, int]] = []
    if "jnp" in planes:
        t = _time_best(lambda: engine_lookup(keys, image, k=k, plane="jnp"),
                       repeats)
        out = np.asarray(engine_lookup(keys, image, k=k, plane="jnp"))
        if not np.array_equal(out, ref):
            raise AssertionError("jnp plane diverged from the default "
                                 f"configuration for {op_tag(op)}")
        measured.append((t, "jnp", DEFAULT_BLOCK_ROWS))
    if "pallas" in planes:
        for br in candidates:
            t = _time_best(lambda: engine_lookup(keys, image, k=k,
                                                 plane="pallas",
                                                 block_rows=br), repeats)
            out = np.asarray(engine_lookup(keys, image, k=k, plane="pallas",
                                           block_rows=br))
            if not np.array_equal(out, ref):
                raise AssertionError(
                    f"block_rows={br} diverged from the default "
                    f"configuration for {op_tag(op)}")
            measured.append((t, "pallas", br))
    if not measured:
        raise ValueError("no candidate planes to tune over")
    best_t, best_plane, best_br = min(measured)
    cfg = TunedConfig(block_rows=int(best_br), plane=best_plane,
                      us_per_key=round(best_t / n_keys * 1e6, 4))
    key = grid_key(op, n_keys, int(image.n), backend)
    (cache if cache is not None else active_cache()).put(key, cfg)
    return key, cfg
