"""Shared device-plane hash primitives (jnp, Pallas-safe).

One implementation of the TPU-native 32-bit arithmetic (DESIGN.md §3.1),
consumed by BOTH the pure-jnp oracles (``core/jax_lookup.py``) and the
Pallas kernels (``kernels/*_lookup.py``) — every op here lowers cleanly
inside a Pallas kernel body and under plain jit.

Bit-identical to the numpy/scalar host plane in ``core/hashing.py`` and
``core/jump.py``: murmur3 fmix32 mixing, 24-bit uniform variates, exact
f32 divides.  Constants are imported from ``core/hashing`` so there is a
single definition in the repo.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import _C1_32, _C2_32, GOLDEN32

_U = jnp.uint32

#: per-step salt of the jump32 variate stream (matches ``core/jump._step_u24``)
STEP_SALT = 0x2545F491


def fmix32(h):
    """Murmur3 32-bit finalizer over a uint32 array (or traced scalar)."""
    h = jnp.asarray(h).astype(_U)
    h ^= h >> _U(16)
    h = h * _U(_C1_32)
    h ^= h >> _U(13)
    h = h * _U(_C2_32)
    h ^= h >> _U(16)
    return h


def hash2(keys, seed):
    """(key, seed) hash — paper Alg. 4's ``hash(k, b)``; seed may be a traced
    scalar (e.g. the Dx probe index) or an array (e.g. bucket ids)."""
    s = fmix32(jnp.asarray(seed).astype(_U) * _U(GOLDEN32) + _U(1))
    return fmix32(jnp.asarray(keys).astype(_U) ^ s)


def step_u24(keys, step):
    """Per-(key, step) uniform 24-bit variate — exactly representable in f32."""
    s = jnp.asarray(step).astype(_U)
    h = fmix32(jnp.asarray(keys).astype(_U) ^ (s * _U(GOLDEN32) + _U(STEP_SALT)))
    return h >> _U(8)


def jump32(keys, n):
    """Vectorized TPU-native JumpHash: keys uint32 [...], n a dynamic scalar.

    State machine identical to the 64-bit original: ``b ← j; j ← ⌊(b+1)/r⌋``
    with ``r`` uniform in (0, 1], iterated while ``j < n``; lane-synchronous
    (a block settles in max-over-lanes steps, E ≈ ln n).
    """
    keys = jnp.asarray(keys).astype(_U)
    nf = jnp.asarray(n).astype(jnp.float32)
    b0 = jnp.zeros(keys.shape, jnp.int32)
    j0 = jnp.zeros(keys.shape, jnp.float32)

    def cond(state):
        _, j, _ = state
        return jnp.any(j < nf)

    def body(state):
        b, j, i = state
        active = j < nf
        b = jnp.where(active, j.astype(jnp.int32), b)
        u = step_u24(keys, i)
        r = (u.astype(jnp.float32) + jnp.float32(1.0)) * jnp.float32(2.0 ** -24)
        jn = jnp.minimum(jnp.floor((b.astype(jnp.float32) + jnp.float32(1.0)) / r), nf)
        j = jnp.where(active, jn, j)
        return b, j, i + jnp.int32(1)

    b, _, _ = jax.lax.while_loop(cond, body, (b0, j0, jnp.int32(0)))
    return b


def power32(keys, n):
    """Vectorized TPU-native PowerHash: keys uint32 [...], n a dynamic scalar.

    The level-descent scheme of :mod:`repro.core.power`, lane-synchronous:
    a scalar shift loop finds the top level ``L = ⌊log2(n−1)⌋`` (integer
    exact — no float log), the top level rejection-resamples until every
    lane draws ``v < n`` (geometric, ≥ ½ success per try, capped at
    ``POWER_TRY_CAP`` with descend as the deterministic fallback), then
    lanes still below ``2^L`` descend one full level per iteration.
    Bit-identical to ``core.power.power32`` (``variant="32"``).
    """
    from repro.core.power import POWER_SALT, POWER_TRY_CAP

    keys = jnp.asarray(keys).astype(_U)
    n = jnp.asarray(n).astype(jnp.int32)

    L = jax.lax.while_loop(lambda L: ((n - 1) >> (L + 1)) > 0,
                           lambda L: L + 1, jnp.int32(0))
    hi_mask = (_U(1) << (L + 1).astype(_U)) - _U(1)
    base = _U(POWER_SALT) + (L.astype(_U) << _U(6))
    v0 = hash2(keys, base) & hi_mask
    t0 = jnp.ones(keys.shape, jnp.int32)

    def rcond(state):
        v, t = state
        return jnp.any((v.astype(jnp.int32) >= n) & (t < POWER_TRY_CAP))

    def rbody(state):
        v, t = state
        redo = (v.astype(jnp.int32) >= n) & (t < POWER_TRY_CAP)
        cand = hash2(keys, base + t.astype(_U)) & hi_mask
        return jnp.where(redo, cand, v), jnp.where(redo, t + 1, t)

    v, _ = jax.lax.while_loop(rcond, rbody, (v0, t0))
    vi = v.astype(jnp.int32)
    out = jnp.where((vi < n) & (vi >= (jnp.int32(1) << L)), vi, jnp.int32(-1))

    def dcond(state):
        j, out = state
        return (j >= 0) & jnp.any(out < 0)

    def dbody(state):
        j, out = state
        mask_j = (_U(1) << (j + 1).astype(_U)) - _U(1)
        cand = (hash2(keys, _U(POWER_SALT) + (j.astype(_U) << _U(6)))
                & mask_j).astype(jnp.int32)
        take = (out < 0) & (cand >= (jnp.int32(1) << j))
        return j - 1, jnp.where(take, cand, out)

    _, out = jax.lax.while_loop(dcond, dbody, (L - 1, out))
    return jnp.where(out < 0, 0, out)


def gather1d(table, idx):
    """Row gather of a flat VMEM table by a 2-D (or any-D) index block."""
    return jnp.take(table, idx.reshape(-1), axis=0).reshape(idx.shape)


def table_shape2d(pad: int) -> tuple[int, int]:
    """VMEM layout of a flat length-``pad`` table: (rows, 128) lanes when
    128-aligned (every DeviceImage array is), else a thin (pad, 1) column."""
    return (-(-pad // 128), 128) if pad % 128 == 0 else (pad, 1)
