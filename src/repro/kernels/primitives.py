"""Shared device-plane hash primitives (jnp, Pallas-safe).

One implementation of the TPU-native 32-bit arithmetic (DESIGN.md §3.1),
consumed by BOTH the pure-jnp oracles (``core/jax_lookup.py``) and the
Pallas kernels (``kernels/*_lookup.py``) — every op here lowers cleanly
inside a Pallas kernel body and under plain jit.

Bit-identical to the numpy/scalar host plane in ``core/hashing.py`` and
``core/jump.py``: murmur3 fmix32 mixing, 24-bit uniform variates, exact
f32 divides.  Constants are imported from ``core/hashing`` so there is a
single definition in the repo.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import _C1_32, _C2_32, GOLDEN32

_U = jnp.uint32

#: per-step salt of the jump32 variate stream (matches ``core/jump._step_u24``)
STEP_SALT = 0x2545F491


def fmix32(h):
    """Murmur3 32-bit finalizer over a uint32 array (or traced scalar)."""
    h = jnp.asarray(h).astype(_U)
    h ^= h >> _U(16)
    h = h * _U(_C1_32)
    h ^= h >> _U(13)
    h = h * _U(_C2_32)
    h ^= h >> _U(16)
    return h


def hash2(keys, seed):
    """(key, seed) hash — paper Alg. 4's ``hash(k, b)``; seed may be a traced
    scalar (e.g. the Dx probe index) or an array (e.g. bucket ids)."""
    s = fmix32(jnp.asarray(seed).astype(_U) * _U(GOLDEN32) + _U(1))
    return fmix32(jnp.asarray(keys).astype(_U) ^ s)


def step_u24(keys, step):
    """Per-(key, step) uniform 24-bit variate — exactly representable in f32."""
    s = jnp.asarray(step).astype(_U)
    h = fmix32(jnp.asarray(keys).astype(_U) ^ (s * _U(GOLDEN32) + _U(STEP_SALT)))
    return h >> _U(8)


def jump32(keys, n):
    """Vectorized TPU-native JumpHash: keys uint32 [...], n a dynamic scalar.

    State machine identical to the 64-bit original: ``b ← j; j ← ⌊(b+1)/r⌋``
    with ``r`` uniform in (0, 1], iterated while ``j < n``; lane-synchronous
    (a block settles in max-over-lanes steps, E ≈ ln n).
    """
    keys = jnp.asarray(keys).astype(_U)
    nf = jnp.asarray(n).astype(jnp.float32)
    b0 = jnp.zeros(keys.shape, jnp.int32)
    j0 = jnp.zeros(keys.shape, jnp.float32)

    def cond(state):
        _, j, _ = state
        return jnp.any(j < nf)

    def body(state):
        b, j, i = state
        active = j < nf
        b = jnp.where(active, j.astype(jnp.int32), b)
        u = step_u24(keys, i)
        r = (u.astype(jnp.float32) + jnp.float32(1.0)) * jnp.float32(2.0 ** -24)
        jn = jnp.minimum(jnp.floor((b.astype(jnp.float32) + jnp.float32(1.0)) / r), nf)
        j = jnp.where(active, jn, j)
        return b, j, i + jnp.int32(1)

    b, _, _ = jax.lax.while_loop(cond, body, (b0, j0, jnp.int32(0)))
    return b


def gather1d(table, idx):
    """Row gather of a flat VMEM table by a 2-D (or any-D) index block."""
    return jnp.take(table, idx.reshape(-1), axis=0).reshape(idx.shape)


def table_shape2d(pad: int) -> tuple[int, int]:
    """VMEM layout of a flat length-``pad`` table: (rows, 128) lanes when
    128-aligned (every DeviceImage array is), else a thin (pad, 1) column."""
    return (-(-pad // 128), 128) if pad % 128 == 0 else (pad, 1)
