# Device data plane: Pallas TPU lookup kernels for the full algorithm
# family (memento/anchor/dx/jump_lookup.py), the shared 32-bit hash
# primitives (primitives.py), the jitted dispatch (ops.device_lookup),
# and the oracles kernel tests compare against (ref.py).  See DESIGN.md §3.
