# Device data plane: ONE unified lookup engine (engine.py, DESIGN.md §6)
# — a single tiled Pallas dispatch (and matching jitted jnp program) whose
# static EngineOp configuration covers plain lookup, k-replication,
# bounded-load (incl. the fused k-replica-under-cap op), chain-walk
# assignment rounds, and one/two-epoch diffs for every registry algorithm.
# ops.device_lookup is the public image-generic entry; primitives.py holds
# the shared 32-bit hash arithmetic; ref.py the oracles kernel tests
# compare against; delta_apply.py the epoch-delta scatter (§3.5).
# engine.py is the only import surface: the PR-4 per-algorithm re-export
# shims served their one release and are gone.
