# Device data plane: Pallas TPU lookup kernels for the full algorithm
# family (memento/anchor/dx/jump_lookup.py), the shared 32-bit hash
# primitives (primitives.py), the jitted dispatch (ops.device_lookup),
# and the oracles kernel tests compare against (ref.py).  See DESIGN.md §3.
# Control-plane kernels: delta_apply.py (epoch-delta scatter, §3.5) and
# migrate.py (fused two-epoch diff, §3.5).  Replica-aware serving:
# replica_lookup.py (salted k-replication + bounded-load chain walk, §4).
