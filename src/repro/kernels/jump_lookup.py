"""Pallas TPU kernel: batched JumpHash lookup.

The stateless corner of the device plane (image layout: DESIGN.md §3.3;
kernel structure: §3.4): no table at all, just the shared
TPU-native ``jump32`` state machine (``kernels/primitives.py``) over a
``(BLOCK_ROWS, 128)`` key block, with ``n`` as a dynamic prefetched scalar.
Also the first hop of every Memento lookup — kept as its own kernel so Jump
is benchmarkable on the device plane like the other three algorithms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .memento_lookup import DEFAULT_BLOCK_ROWS, _pad_rows
from .primitives import jump32

_U = jnp.uint32


def _jump_kernel(n_ref, keys_ref, out_ref):
    out_ref[...] = jump32(keys_ref[...].astype(_U), n_ref[0])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def jump_lookup(keys, n, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = True):
    """Batched JumpHash lookup: keys uint32 [K] → bucket ids int32 in [0, n)."""
    keys2d, k = _pad_rows(keys.astype(_U))
    rows = keys2d.shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)

    out = pl.pallas_call(
        _jump_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((block_rows, 128), lambda i, n_s: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, 128), lambda i, n_s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(keys2d.shape, jnp.int32),
        interpret=interpret,
    )(jnp.asarray([n], jnp.int32), keys2d)
    return out.reshape(-1)[:k]
