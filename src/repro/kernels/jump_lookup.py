"""JumpHash lookup — re-export shim over :mod:`repro.kernels.engine`.

The stateless corner of the device plane is the ``jump`` configuration of
the unified lookup engine (DESIGN.md §6); the state machine itself is
``kernels/primitives.jump32``.  Kept for one release; new code should
target :mod:`repro.kernels.engine`.
"""
from __future__ import annotations

from .engine import DEFAULT_BLOCK_ROWS, jump_lookup  # noqa: F401
