"""AnchorHash lookup — re-export shim over :mod:`repro.kernels.engine`.

The A/K-array kernel body now lives as the ``anchor`` configuration of the
unified lookup engine (DESIGN.md §6).  Kept for one release; new code
should target :mod:`repro.kernels.engine`.
"""
from __future__ import annotations

from .engine import DEFAULT_BLOCK_ROWS, anchor_body, anchor_lookup  # noqa: F401
