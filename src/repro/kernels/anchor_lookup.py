"""Pallas TPU kernel: batched AnchorHash lookup.

Same block-parallel shape as the Memento kernel (image layout: DESIGN.md
§3.3; kernel structure: §3.4): the grid
runs over ``(BLOCK_ROWS, 128)`` uint32 key blocks; the A-array image (removal
"timestamps") and the K-array (wrap successors) sit in VMEM for every
program; the capacity ``a`` travels as a dynamic prefetched scalar so device
buffers keep a stable shape across resizes.

The lane-synchronous loops mirror the host lookup exactly:

  * outer: while the lane's bucket is removed (``A[b] > 0``), re-hash into
    its wrap set ``hash(key, b) % A[b]``,
  * inner: follow ``K`` successors while the candidate was removed
    at-or-after ``b`` (``A[h] ≥ A[b]``) — a gather chain, no pointer chase.

Expected sweeps ≈ ln(a/w) (AnchorHash Thm. 4).  Bit-identical to
``core/jax_lookup.anchor_lookup`` and to the ``variant="32"`` host plane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .memento_lookup import DEFAULT_BLOCK_ROWS, _pad_rows
from .primitives import fmix32, gather1d, hash2

_U = jnp.uint32


def anchor_body(keys, A, K, a):
    """Kernel-side Anchor lookup body over flat VMEM A/K (shared with the
    fused migration-diff kernel in ``kernels/migrate.py``)."""
    b = (fmix32(keys) % a.astype(_U)).astype(jnp.int32)

    def outer_cond(b):
        return jnp.any(gather1d(A, b) > 0)

    def outer_body(b):
        Ab = gather1d(A, b)
        active = Ab > 0
        denom = jnp.where(active, Ab, 1).astype(_U)
        h = (hash2(keys, b) % denom).astype(jnp.int32)

        def inner_cond(h):
            return jnp.any(active & (gather1d(A, h) >= Ab))

        def inner_body(h):
            follow = active & (gather1d(A, h) >= Ab)  # removed at-or-after b
            return jnp.where(follow, gather1d(K, h), h)

        h = jax.lax.while_loop(inner_cond, inner_body, h)
        return jnp.where(active, h, b)

    return jax.lax.while_loop(outer_cond, outer_body, b)


def _anchor_kernel(a_ref, keys_ref, A_ref, K_ref, out_ref):
    keys = keys_ref[...].astype(_U)
    A = A_ref[...].reshape(-1)  # (a_pad,) int32: 0 = working, else |W| at removal
    K = K_ref[...].reshape(-1)  # (a_pad,) int32: wrap successor
    out_ref[...] = anchor_body(keys, A, K, a_ref[0])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def anchor_lookup(keys, A, K, a, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True):
    """Batched AnchorHash lookup: keys uint32 [K] → working bucket ids int32."""
    keys2d, k = _pad_rows(keys.astype(_U))
    rows = keys2d.shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    pad = A.shape[0]
    shape2d = (-(-pad // 128), 128) if pad % 128 == 0 else (pad, 1)
    A2d, K2d = A.reshape(shape2d), K.reshape(shape2d)

    out = pl.pallas_call(
        _anchor_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_rows, 128), lambda i, a_s: (i, 0)),
                pl.BlockSpec(shape2d, lambda i, a_s: (0, 0)),
                pl.BlockSpec(shape2d, lambda i, a_s: (0, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, 128), lambda i, a_s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(keys2d.shape, jnp.int32),
        interpret=interpret,
    )(jnp.asarray([a], jnp.int32), keys2d, A2d, K2d)
    return out.reshape(-1)[:k]
