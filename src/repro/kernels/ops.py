"""Jitted public wrappers around the Pallas lookup kernels.

:func:`device_lookup` is the algorithm-generic entry point: it takes any
:class:`~repro.core.protocol.DeviceImage` (Memento, Anchor, Dx, Jump) and
dispatches to the matching kernel, so routers / placements / benchmarks are
algorithm-pluggable end to end.

Execution planes:

  * ``plane='pallas'`` — the Pallas kernels (default).  On non-TPU backends
    they run in interpret mode (the validation path); on TPU they compile
    via Mosaic.
  * ``plane='jnp'``    — the pure-jnp oracles (no Pallas; any backend).

Memento additionally picks its table layout via ``table``:

  * ``'dense'``   — Θ(n) int32 VMEM image (default; n ≤ ~3M fits VMEM),
  * ``'compact'`` — Θ(r) open-addressing VMEM image (beyond-paper, for
    huge b-arrays with few removals).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import jax_lookup as _jnp
from . import anchor_lookup as _anchor
from . import dx_lookup as _dx
from . import jump_lookup as _jump
from . import memento_lookup as _k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def device_lookup(keys, image, *, plane: str = "pallas", table: str = "dense",
                  interpret: bool | None = None, block_rows: int | None = None):
    """Batched lookup over any DeviceImage: keys [K] → working bucket ids [K]."""
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    if plane == "jnp":
        return _jnp.lookup_image(keys, image)
    if plane != "pallas":
        raise ValueError(f"unknown plane {plane!r}")
    if interpret is None:
        interpret = _default_interpret()
    kw = {"interpret": interpret}
    if block_rows is not None:
        kw["block_rows"] = block_rows

    algo = image.algo
    if algo == "memento":
        repl = jnp.asarray(image.arrays["repl"], jnp.int32)
        if table == "dense":
            return _k.dense_lookup(keys, repl, image.n, **kw)
        if table == "compact":
            slot_b, slot_c = _k.build_compact_table(repl)
            return _k.compact_lookup(keys, slot_b, slot_c, image.n, **kw)
        raise ValueError(f"unknown table kind {table!r}")
    if algo == "anchor":
        return _anchor.anchor_lookup(keys, jnp.asarray(image.arrays["A"], jnp.int32),
                                     jnp.asarray(image.arrays["K"], jnp.int32),
                                     image.n, **kw)
    if algo == "dx":
        return _dx.dx_lookup(keys, jnp.asarray(image.arrays["words"], jnp.uint32),
                             image.n, image.scalars["max_probes"],
                             image.scalars["fallback"], **kw)
    if algo == "jump":
        return _jump.jump_lookup(keys, image.n, **kw)
    raise ValueError(f"unknown device image algo {algo!r}")


def memento_lookup(keys, repl, n, *, table: str = "dense", interpret: bool | None = None):
    """Batched Alg. 4 lookup: keys uint32 [K] → working bucket ids int32 [K]."""
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    repl = jnp.asarray(repl, dtype=jnp.int32)
    if interpret is None:
        interpret = _default_interpret()
    if table == "jnp":
        return _jnp.memento_lookup(keys, repl, n)
    if table == "dense":
        return _k.dense_lookup(keys, repl, n, interpret=interpret)
    if table == "compact":
        slot_b, slot_c = _k.build_compact_table(repl)
        return _k.compact_lookup(keys, slot_b, slot_c, n, interpret=interpret)
    raise ValueError(f"unknown table kind {table!r}")


def lookup_from_tables(keys, tables, **kw):
    """Route against a host :class:`repro.core.MementoTables`."""
    return memento_lookup(keys, tables.repl, tables.n, **kw)
