"""Jitted public wrappers around the Pallas lookup kernels.

``memento_lookup`` picks the execution path:

  * ``table='dense'``   — Θ(n) int32 VMEM image (default; n ≤ ~3M fits VMEM),
  * ``table='compact'`` — Θ(r) open-addressing VMEM image (beyond-paper,
    for huge b-arrays with few removals),
  * ``table='jnp'``     — pure-jnp fallback (no Pallas; any backend).

On non-TPU backends the kernels run in interpret mode (the brief's validation
path); on TPU they compile via Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.jax_lookup import memento_lookup as _jnp_lookup
from . import memento_lookup as _k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def memento_lookup(keys, repl, n, *, table: str = "dense", interpret: bool | None = None):
    """Batched Alg. 4 lookup: keys uint32 [K] → working bucket ids int32 [K]."""
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    repl = jnp.asarray(repl, dtype=jnp.int32)
    if interpret is None:
        interpret = _default_interpret()
    if table == "jnp":
        return _jnp_lookup(keys, repl, n)
    if table == "dense":
        return _k.dense_lookup(keys, repl, n, interpret=interpret)
    if table == "compact":
        slot_b, slot_c = _k.build_compact_table(repl)
        return _k.compact_lookup(keys, slot_b, slot_c, n, interpret=interpret)
    raise ValueError(f"unknown table kind {table!r}")


def lookup_from_tables(keys, tables, **kw):
    """Route against a host :class:`repro.core.MementoTables`."""
    return memento_lookup(keys, tables.repl, tables.n, **kw)
