"""Jitted public wrappers around the unified lookup engine.

:func:`device_lookup` is the algorithm-generic entry point: it takes any
:class:`~repro.core.protocol.DeviceImage` (Memento, Anchor, Dx, Jump) and
runs the matching :class:`~repro.kernels.engine.EngineOp` configuration,
so routers / placements / benchmarks are algorithm-pluggable end to end.
Every configuration — plain lookup, k-replica, bounded, epoch diff —
compiles to exactly one Pallas launch (DESIGN.md §6).

Execution planes:

  * ``plane='pallas'`` — the engine's Pallas launch (default).  On non-TPU
    backends it runs in interpret mode (the validation path); on TPU it
    compiles via Mosaic.
  * ``plane='jnp'``    — the engine's pure-jnp program (no Pallas; any
    backend; also the per-shard body of the mesh-sharded
    :class:`~repro.serve.plane.ShardedLookupPlane`).
  * ``plane='auto'``   — the autotuner's winner for this (op, batch,
    table-size) cell (``kernels/autotune.py``), falling back to Pallas on
    TPU and jnp elsewhere when no tuning entry exists.

Table layouts (``table``):

  * ``'dense'``   — Θ(n) int32 VMEM image (default; n ≤ ~3M fits VMEM),
  * ``'compact'`` — Θ(r) open-addressing VMEM image (Memento only;
    beyond-paper, for huge b-arrays with few removals),
  * ``'packed'``  — auto-selected for packed DeviceImages (bitmap + slots
    for Memento, narrowed dtypes for Anchor; ``repro.core.packing``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import jax_lookup as _jnp
from . import engine as _engine


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def device_lookup(keys, image, *, plane: str = "pallas", table: str = "dense",
                  k: int = 1, load=None, cap: int | None = None,
                  interpret: bool | None = None, block_rows: int | None = None):
    """Batched lookup over any DeviceImage: keys [K] → working bucket ids
    [K] (or [K, k] replica sets for ``k > 1``; with ``load``/``cap`` every
    returned bucket is additionally below the load cap — the fused
    bounded-replica configuration, still one launch)."""
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    packed = getattr(image, "packed", False)
    if plane == "auto":
        from . import autotune
        op = _engine.EngineOp(algo=image.algo, k=k,
                              bounded=load is not None,
                              table="packed" if packed else table)
        plane = autotune.resolve_plane(op, int(keys.shape[0]), int(image.n))
    if plane == "jnp" and k == 1 and load is None and not packed:
        return _jnp.lookup_image(keys, image)
    if plane not in ("jnp", "pallas"):
        raise ValueError(f"unknown plane {plane!r}")
    if table not in ("dense", "packed") and image.algo != "memento":
        raise ValueError(f"unknown table kind {table!r} for {image.algo!r}")
    return _engine.engine_lookup(keys, image, k=k, load=load, cap=cap,
                                 plane=plane, table=table,
                                 interpret=interpret,
                                 block_rows=block_rows)


def memento_lookup(keys, repl, n, *, table: str = "dense", interpret: bool | None = None):
    """Batched Alg. 4 lookup: keys uint32 [K] → working bucket ids int32 [K]."""
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    repl = jnp.asarray(repl, dtype=jnp.int32)
    if interpret is None:
        interpret = _default_interpret()
    if table == "jnp":
        return _jnp.memento_lookup(keys, repl, n)
    if table == "dense":
        return _engine.dense_lookup(keys, repl, n, interpret=interpret)
    if table == "compact":
        slot_b, slot_c = _engine.build_compact_table(repl)
        return _engine.compact_lookup(keys, slot_b, slot_c, n,
                                      interpret=interpret)
    raise ValueError(f"unknown table kind {table!r}")


def lookup_from_tables(keys, tables, **kw):
    """Route against a host :class:`repro.core.MementoTables`."""
    return memento_lookup(keys, tables.repl, tables.n, **kw)
