"""Device-plane k-replication + bounded-load chain walk (DESIGN.md §4).

Two device entry points, both protocol-generic over any
:class:`~repro.core.protocol.DeviceImage` (Memento, Anchor, Dx, Jump):

* :func:`replica_lookup` — k *distinct* working buckets per key
  (DESIGN.md §4.1): replica 0 is the plain lookup; replica j comes from
  re-looking-up the salted key ``hash2(key, salt)`` for salt = 1, 2, …,
  skipping candidates already chosen.  The salt counter is per-lane and
  shared across slots, so the device walk is bit-identical to the host
  ``ReplicatedLookup.lookup_k`` on ``variant="32"`` states.  One jitted
  jnp program (any backend) or ONE Pallas launch per key batch: the salt
  loop runs in-kernel as a lane-synchronous ``while_loop`` per replica
  slot, with the image tables VMEM-resident and k static (k outputs).

* :func:`chain_walk` / :func:`bounded_assign_device` — the bounded-load
  data plane (DESIGN.md §4.2): given per-bucket load words and the cap
  ``ceil(c·keys/working)``, walk each key's deterministic rehash chain
  (``chain ← hash2(chain, probe)``) to the first bucket below the cap.
  The walk order is exactly the host's (`core/bounded.py`), so host and
  device assignments agree bit-for-bit; the round-based acceptance in
  :func:`bounded_assign_device` resolves intra-batch races in key-index
  order — identical to the numpy reference ``bounded_assign_ref``.

The single-epoch lookup bodies are the exact ones the lookup and
migration-diff kernels run (``dense_body`` / ``anchor_body`` / ``dx_body``
/ ``jump32`` via ``kernels/migrate._body``), so replicas, bounded
assignment, and plain lookups can never disagree about placement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bounded import accept_in_index_order, walk_probe_bound
from repro.core.jax_lookup import lookup_dispatch
from repro.core.protocol import IMAGE_LAYOUT, REPLICA_SALT_CAP, image_scalar_vec
from .memento_lookup import DEFAULT_BLOCK_ROWS, _pad_rows
from .migrate import _body
from .primitives import gather1d, hash2, table_shape2d as _shape2d

_U = jnp.uint32


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Shared lane-synchronous bodies (consumed by both planes)
# ---------------------------------------------------------------------------

def replica_body(keys, k, single_lookup):
    """k distinct buckets per lane via the salted-re-lookup walk.

    ``single_lookup(keys_u32) -> int32 buckets`` is the plane's one-epoch
    lookup (jnp dispatch or a kernel body).  Returns a list of k int32
    arrays (replica slots).  Mirrors ``ReplicatedLookup.lookup_k`` exactly:
    per-lane salt counters advance on every try (including the successful
    one) and carry over to the next slot.  Lanes that exhaust
    ``REPLICA_SALT_CAP`` (probability ≤ ((k−1)/w)^CAP — see protocol.py)
    keep the primary bucket; the host raises instead, so keep k ≤ working.
    """
    keys = jnp.asarray(keys).astype(_U)
    first = single_lookup(keys)
    outs = [first]
    salt = jnp.ones(keys.shape, jnp.int32)
    for _ in range(1, k):
        prev = tuple(outs)

        def cond(state):
            salt, _slot, done = state
            return jnp.any(~done & (salt <= REPLICA_SALT_CAP))

        def body(state, prev=prev):
            salt, slot, done = state
            active = ~done & (salt <= REPLICA_SALT_CAP)
            cand = single_lookup(hash2(keys, salt))
            dup = cand == prev[0]
            for o in prev[1:]:
                dup = dup | (cand == o)
            ok = active & ~dup
            slot = jnp.where(ok, cand, slot)
            salt = jnp.where(active, salt + 1, salt)
            return salt, slot, done | ok

        salt, slot, _ = jax.lax.while_loop(
            cond, body, (salt, first, jnp.zeros(keys.shape, jnp.bool_)))
        outs.append(slot)
    return outs


def chain_walk_body(chain, probe, pending, load, cap, single_lookup):
    """Walk each pending lane's rehash chain to the first bucket with
    ``load[b] < cap``; non-pending lanes are left untouched.

    State per lane: the current chained key, the probe counter, the
    candidate bucket.  One step is exactly the host's
    ``probe += 1; chain = hash2(chain, probe); b = lookup(chain)``.
    Returns ``(b, chain, probe)``.

    Termination guard: lanes stop after ``64·len(load) + 64`` probes (same
    bound as the host reference, derived from the load array so both planes
    agree) — a lane that exhausts it is still above the cap, which the
    batch driver turns into the host's "no bucket below capacity" error
    instead of spinning forever on an infeasible cap.
    """
    chain = jnp.asarray(chain).astype(_U)
    probe = jnp.asarray(probe).astype(jnp.int32)
    max_probe = walk_probe_bound(load.shape[0])
    b = single_lookup(chain)

    def cond(state):
        _chain, probe, b, active = state
        return jnp.any(active & (gather1d(load, b) >= cap)
                       & (probe < max_probe))

    def body(state):
        chain, probe, b, active = state
        step = active & (gather1d(load, b) >= cap) & (probe < max_probe)
        probe = jnp.where(step, probe + 1, probe)
        chain = jnp.where(step, hash2(chain, probe), chain)
        b = jnp.where(step, single_lookup(chain), b)
        return chain, probe, b, active

    chain, probe, b, _ = jax.lax.while_loop(
        cond, body, (chain, probe, b, jnp.asarray(pending)))
    return b, chain, probe


# ---------------------------------------------------------------------------
# jnp plane: one jitted program per (algo, k, shapes)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("algo", "k"))
def _replicas_jnp(keys, arrays, scalars, *, algo, k):
    outs = replica_body(keys, k,
                        lambda kk: lookup_dispatch(algo, kk, arrays, scalars))
    return jnp.stack(outs)


@functools.partial(jax.jit, static_argnames=("algo",))
def _chain_walk_jnp(chain, probe, pending, load, cap, arrays, scalars, *, algo):
    return chain_walk_body(
        chain, probe, pending, load, cap,
        lambda kk: lookup_dispatch(algo, kk, arrays, scalars))


# ---------------------------------------------------------------------------
# Pallas plane: one launch, image tables in VMEM, salt loop in-kernel
# ---------------------------------------------------------------------------

def _replica_kernel_factory(algo: str, num_tables: int, num_scalars: int,
                            k: int):
    def kernel(s_ref, keys_ref, *refs):
        tabs = [r[...].reshape(-1) for r in refs[:num_tables]]
        out_refs = refs[num_tables:]
        keys = keys_ref[...].astype(_U)
        s = [s_ref[i] for i in range(num_scalars)]
        outs = replica_body(keys, k, lambda kk: _body(algo, kk, tabs, s))
        for ref, o in zip(out_refs, outs):
            ref[...] = o

    return kernel


@functools.partial(jax.jit, static_argnames=("algo", "k", "num_tables",
                                             "block_rows", "interpret"))
def _replicas_pallas(scalars, keys2d, *tables2d, algo, k, num_tables,
                     block_rows, interpret):
    rows = keys2d.shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    key_spec = pl.BlockSpec((block_rows, 128), lambda i, s: (i, 0))
    tab_specs = [pl.BlockSpec(t.shape, lambda i, s: (0, 0)) for t in tables2d]

    return pl.pallas_call(
        _replica_kernel_factory(algo, num_tables, scalars.shape[0], k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[key_spec] + tab_specs,
            out_specs=[key_spec] * k,
        ),
        out_shape=[jax.ShapeDtypeStruct(keys2d.shape, jnp.int32)] * k,
        interpret=interpret,
    )(scalars, keys2d, *tables2d)


def _walk_kernel_factory(algo: str, num_tables: int, num_scalars: int):
    # scalar vector = algo scalars + cap appended last
    def kernel(s_ref, chain_ref, probe_ref, pending_ref, *refs):
        tabs = [r[...].reshape(-1) for r in refs[:num_tables]]
        load = refs[num_tables][...].reshape(-1)
        out_b, out_chain, out_probe = refs[num_tables + 1:]
        s = [s_ref[i] for i in range(num_scalars)]
        cap = s_ref[num_scalars]
        b, chain, probe = chain_walk_body(
            chain_ref[...].astype(_U), probe_ref[...],
            pending_ref[...] != 0, load, cap,
            lambda kk: _body(algo, kk, tabs, s))
        out_b[...] = b
        out_chain[...] = chain.astype(jnp.int32)
        out_probe[...] = probe

    return kernel


@functools.partial(jax.jit, static_argnames=("algo", "num_tables",
                                             "block_rows", "interpret"))
def _chain_walk_pallas(scalars, chain2d, probe2d, pending2d, *tables2d,
                       algo, num_tables, block_rows, interpret):
    rows = chain2d.shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    blk = pl.BlockSpec((block_rows, 128), lambda i, s: (i, 0))
    tab_specs = [pl.BlockSpec(t.shape, lambda i, s: (0, 0)) for t in tables2d]

    return pl.pallas_call(
        _walk_kernel_factory(algo, num_tables, scalars.shape[0] - 1),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[blk, blk, blk] + tab_specs,
            out_specs=[blk, blk, blk],
        ),
        out_shape=[jax.ShapeDtypeStruct(chain2d.shape, jnp.int32)] * 3,
        interpret=interpret,
    )(scalars, chain2d, probe2d, pending2d, *tables2d)


# ---------------------------------------------------------------------------
# Public wrappers
# ---------------------------------------------------------------------------

def _image_operands(image):
    arrays = {k: jnp.asarray(v) for k, v in image.arrays.items()}
    scalars = tuple(jnp.asarray(s, jnp.int32) for s in image_scalar_vec(image))
    return arrays, scalars


def _image_tables2d(image):
    tables = []
    for name in IMAGE_LAYOUT[image.algo][1]:
        arr = jnp.asarray(image.arrays[name])
        tables.append(arr.reshape(_shape2d(arr.shape[0])))
    return tables


def replica_lookup(keys, image, k: int, *, plane: str = "jnp",
                   interpret: bool | None = None,
                   block_rows: int = DEFAULT_BLOCK_ROWS):
    """k-replica sets for a key batch: keys [K] → int32 [K, k].

    Column 0 equals the plain lookup; columns are pairwise distinct
    (working buckets) provided k ≤ working.  Bit-identical to the host
    ``lookup_k`` on ``variant="32"`` states, on both planes.
    """
    if k < 1:
        raise ValueError("k must be ≥ 1")
    keys = jnp.asarray(keys, dtype=_U)
    if plane == "jnp":
        arrays, scalars = _image_operands(image)
        return jnp.transpose(_replicas_jnp(keys, arrays, scalars,
                                           algo=image.algo, k=k))
    if plane != "pallas":
        raise ValueError(f"unknown plane {plane!r}")
    if interpret is None:
        interpret = _default_interpret()
    scalars = jnp.asarray(image_scalar_vec(image), jnp.int32)
    keys2d, nk = _pad_rows(keys)
    outs = _replicas_pallas(scalars, keys2d, *_image_tables2d(image),
                            algo=image.algo, k=k,
                            num_tables=len(IMAGE_LAYOUT[image.algo][1]),
                            block_rows=block_rows, interpret=interpret)
    return jnp.stack([o.reshape(-1)[:nk] for o in outs]).T


def chain_walk(chain, probe, pending, image, load, cap, *,
               plane: str = "jnp", interpret: bool | None = None,
               block_rows: int = DEFAULT_BLOCK_ROWS):
    """One bounded-load walk step for a batch: advance every pending lane to
    the first bucket of its rehash chain with ``load[b] < cap``.

    Returns numpy ``(b, chain, probe)``; non-pending lanes come back
    unchanged.  ``load`` is a bucket-indexed int32 array (the image's load
    word array, or any array covering the bucket id space).
    """
    chain = jnp.asarray(chain, dtype=_U)
    probe = jnp.asarray(probe, dtype=jnp.int32)
    pending = jnp.asarray(pending, dtype=jnp.bool_)
    load = jnp.asarray(load, dtype=jnp.int32)
    if plane == "jnp":
        arrays, scalars = _image_operands(image)
        b, ch, pr = _chain_walk_jnp(chain, probe, pending, load,
                                    jnp.asarray(cap, jnp.int32),
                                    arrays, scalars, algo=image.algo)
        return (np.asarray(b), np.asarray(ch).astype(np.uint32),
                np.asarray(pr))
    if plane != "pallas":
        raise ValueError(f"unknown plane {plane!r}")
    if interpret is None:
        interpret = _default_interpret()
    scalars = jnp.asarray(image_scalar_vec(image) + [int(cap)], jnp.int32)
    nk = chain.shape[0]
    chain2d, _ = _pad_rows(chain)
    probe2d, _ = _pad_rows(probe)
    pending2d, _ = _pad_rows(pending.astype(jnp.int32))
    load2d = load.reshape(_shape2d(load.shape[0]))
    b, ch, pr = _chain_walk_pallas(
        scalars, chain2d, probe2d, pending2d, *_image_tables2d(image), load2d,
        algo=image.algo, num_tables=len(IMAGE_LAYOUT[image.algo][1]),
        block_rows=block_rows, interpret=interpret)
    take = lambda x: np.asarray(x.reshape(-1)[:nk])  # noqa: E731
    return take(b), take(ch).astype(np.uint32), take(pr)


def bounded_assign_device(keys, image, load, cap: int, *, plane: str = "jnp",
                          interpret: bool | None = None):
    """Assign a key batch under the load cap on the device plane.

    Per round: (1) the chain-walk kernel advances every pending key to the
    first non-full bucket of its deterministic rehash chain; (2) intra-batch
    races are resolved in key-index order — the first ``cap − load[b]``
    pending proposers of each bucket are accepted, the rest stay pending
    (their bucket is now full, so the next round's walk advances them).
    Identical, round for round, to the numpy reference
    ``repro.core.bounded.bounded_assign_ref`` — the walk runs on device,
    the O(m log m) acceptance argsort on host.

    Returns ``(assignments int32 [m], new_load int32)``.
    """
    keys = np.asarray(keys, dtype=np.uint32)
    m = len(keys)
    chain = keys.copy()
    probe = np.zeros(m, np.int32)
    out = np.full(m, -1, np.int32)
    pending = np.ones(m, bool)
    load = np.asarray(load, dtype=np.int32).copy()
    while pending.any():
        b, chain, probe = chain_walk(chain, probe, pending, image, load, cap,
                                     plane=plane, interpret=interpret)
        if (load[b[pending]] >= cap).any():  # probe bound exhausted
            raise RuntimeError("no bucket below capacity (infeasible cap: "
                               f"cap={cap} cannot hold the pending keys)")
        accept_idx = accept_in_index_order(b, pending, load, cap)
        out[accept_idx] = b[accept_idx]
        np.add.at(load, b[accept_idx], 1)
        pending[accept_idx] = False
    return out, load
