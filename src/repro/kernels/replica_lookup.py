"""k-replication + bounded-load walk — re-export shim over
:mod:`repro.kernels.engine`.

The salted-re-lookup replica walk and the bounded-load chain walk
(DESIGN.md §4) are now the ``k>1`` / ``walk`` configurations of the
unified lookup engine (DESIGN.md §6) — including the fused
k-replica-under-cap op (``engine_lookup(..., k, load=, cap=)``) that
previously needed multiple launches.  Kept for one release; new code
should target :mod:`repro.kernels.engine`.
"""
from __future__ import annotations

import jax.numpy as jnp

from .engine import (  # noqa: F401
    DEFAULT_BLOCK_ROWS,
    bounded_assign as bounded_assign_device,
    chain_walk_body,
    engine_chain_walk as chain_walk,
    engine_lookup,
    replica_body,
)


def replica_lookup(keys, image, k: int, *, plane: str = "jnp",
                   interpret: bool | None = None,
                   block_rows: int = DEFAULT_BLOCK_ROWS):
    """k-replica sets for a key batch: keys [K] → int32 [K, k].

    Column 0 equals the plain lookup; columns are pairwise distinct
    (working buckets) provided k ≤ working.  Bit-identical to the host
    ``lookup_k`` on ``variant="32"`` states, on both planes.
    """
    out = engine_lookup(keys, image, k=k, plane=plane, interpret=interpret,
                        block_rows=block_rows)
    return jnp.reshape(out, (-1, 1)) if k == 1 else out
