"""Fused migration diff: lookup a key batch under two epochs in one pass.

The device-side minimal-disruption / monotonicity instrument (DESIGN.md
§3.5): given the epoch-N image and the epoch-N+1 image of the same
algorithm (the two halves of a :class:`~repro.core.image_store.
DeviceImageStore` double buffer), compute for a batch of keys

    ``b_old[k]``  — bucket under epoch N,
    ``b_new[k]``  — bucket under epoch N+1,
    ``moved[k]``  — ``b_old != b_new``,

without ever materializing per-key host loops.  The migration planners
(``data/pipeline.ShardPlacement`` → ``runtime/elastic.ElasticCluster``)
consume the mask to relocate exactly the moved resources, and the churn
benchmark uses it to verify minimal disruption at device speed.

Two planes, same semantics:

  * ``plane='jnp'``    — both epoch lookups inside ONE jitted function, so
    XLA schedules them as a single fused program (also allows diffing
    images of *different* algorithms, e.g. an algo migration);
  * ``plane='pallas'`` — one kernel launch per key block with BOTH epoch
    tables resident in VMEM; the lookup bodies are the exact ones the
    single-epoch kernels run (``dense_body`` / ``anchor_body`` /
    ``dx_body`` / ``jump32``), so the diff is bit-identical to two
    independent lookups.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.jax_lookup import lookup_dispatch
from repro.core.protocol import IMAGE_LAYOUT, image_scalar_vec
from .anchor_lookup import anchor_body
from .dx_lookup import dx_body
from .memento_lookup import DEFAULT_BLOCK_ROWS, _pad_rows, dense_body
from .primitives import jump32, table_shape2d as _shape2d

_U = jnp.uint32


@dataclass
class MigrationDiff:
    """Per-key placement under two epochs plus the moved mask."""

    old: np.ndarray    # int32 [K] — bucket under the old epoch
    new: np.ndarray    # int32 [K] — bucket under the new epoch
    moved: np.ndarray  # bool  [K]

    @property
    def num_moved(self) -> int:
        return int(np.asarray(self.moved).sum())


def _body(algo, keys, tables, s):
    if algo == "memento":
        return dense_body(keys, tables[0], s[0])
    if algo == "anchor":
        return anchor_body(keys, tables[0], tables[1], s[0])
    if algo == "dx":
        return dx_body(keys, tables[0], s[0], s[1], s[2])
    if algo == "jump":
        return jump32(keys, s[0])
    raise ValueError(f"unknown algo {algo!r}")


# ---------------------------------------------------------------------------
# jnp plane: one jitted program over both images
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("algo_old", "algo_new"))
def _diff_jnp(keys, old_arrays, old_scalars, new_arrays, new_scalars, *,
              algo_old, algo_new):
    b_old = lookup_dispatch(algo_old, keys, old_arrays, old_scalars)
    b_new = lookup_dispatch(algo_new, keys, new_arrays, new_scalars)
    return b_old, b_new, b_old != b_new


# ---------------------------------------------------------------------------
# Pallas plane: both epoch tables resident, one launch
# ---------------------------------------------------------------------------

def _migrate_kernel_factory(algo: str, num_tables: int, num_scalars: int):
    def kernel(s_ref, keys_ref, *refs):
        old_tabs = [r[...].reshape(-1) for r in refs[:num_tables]]
        new_tabs = [r[...].reshape(-1) for r in refs[num_tables:2 * num_tables]]
        out_old, out_new, out_moved = refs[2 * num_tables:]
        keys = keys_ref[...].astype(_U)
        s_old = [s_ref[i] for i in range(num_scalars)]
        s_new = [s_ref[num_scalars + i] for i in range(num_scalars)]
        b_old = _body(algo, keys, old_tabs, s_old)
        b_new = _body(algo, keys, new_tabs, s_new)
        out_old[...] = b_old
        out_new[...] = b_new
        out_moved[...] = (b_old != b_new).astype(jnp.int32)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("algo", "num_tables", "block_rows",
                                    "interpret"))
def _diff_pallas(scalars, keys2d, *tables2d, algo, num_tables,
                 block_rows, interpret):
    rows = keys2d.shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    key_spec = pl.BlockSpec((block_rows, 128), lambda i, s: (i, 0))
    tab_specs = [pl.BlockSpec(t.shape, lambda i, s: (0, 0)) for t in tables2d]
    num_scalars = scalars.shape[0] // 2

    outs = pl.pallas_call(
        _migrate_kernel_factory(algo, num_tables, num_scalars),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[key_spec] + tab_specs,
            out_specs=[key_spec, key_spec, key_spec],
        ),
        out_shape=[jax.ShapeDtypeStruct(keys2d.shape, jnp.int32)] * 3,
        interpret=interpret,
    )(scalars, keys2d, *tables2d)
    return outs


def migration_diff(keys, old_image, new_image, *, plane: str = "jnp",
                   interpret: bool | None = None,
                   block_rows: int = DEFAULT_BLOCK_ROWS) -> MigrationDiff:
    """Diff a key batch between two device images (old epoch vs new epoch)."""
    keys = jnp.asarray(keys, dtype=_U)
    if plane == "jnp":
        tr = lambda img: (  # noqa: E731
            {k: jnp.asarray(v) for k, v in img.arrays.items()},
            tuple(jnp.asarray(s, jnp.int32) for s in image_scalar_vec(img)))
        oa, os_ = tr(old_image)
        na, ns = tr(new_image)
        b_old, b_new, moved = _diff_jnp(keys, oa, os_, na, ns,
                                        algo_old=old_image.algo,
                                        algo_new=new_image.algo)
        return MigrationDiff(np.asarray(b_old), np.asarray(b_new),
                             np.asarray(moved))
    if plane != "pallas":
        raise ValueError(f"unknown plane {plane!r}")
    if old_image.algo != new_image.algo:
        raise ValueError("pallas migration diff requires one algorithm "
                         f"({old_image.algo!r} != {new_image.algo!r})")
    algo = old_image.algo
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    table_names = IMAGE_LAYOUT[algo][1]
    scalars = jnp.asarray(image_scalar_vec(old_image) + image_scalar_vec(new_image),
                          jnp.int32)
    tables = []
    for img in (old_image, new_image):
        for name in table_names:
            arr = jnp.asarray(img.arrays[name])
            tables.append(arr.reshape(_shape2d(arr.shape[0])))
    keys2d, k = _pad_rows(keys)
    b_old, b_new, moved = _diff_pallas(
        scalars, keys2d, *tables, algo=algo, num_tables=len(table_names),
        block_rows=block_rows, interpret=interpret)
    return MigrationDiff(np.asarray(b_old.reshape(-1)[:k]),
                         np.asarray(b_new.reshape(-1)[:k]),
                         np.asarray(moved.reshape(-1)[:k]).astype(bool))
