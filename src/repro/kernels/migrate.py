"""Fused migration diff — re-export shim over :mod:`repro.kernels.engine`.

The two-epoch diff (DESIGN.md §3.5) is now the ``diff=True``
configuration of the unified lookup engine (DESIGN.md §6), which also
generalizes it to whole replica sets (``k>1``).  Kept for one release;
new code should target :func:`repro.kernels.engine.engine_diff`.
"""
from __future__ import annotations

from .engine import (  # noqa: F401
    DEFAULT_BLOCK_ROWS,
    EngineDiff as MigrationDiff,
    engine_diff,
)


def migration_diff(keys, old_image, new_image, *, plane: str = "jnp",
                   interpret: bool | None = None,
                   block_rows: int = DEFAULT_BLOCK_ROWS) -> MigrationDiff:
    """Diff a key batch between two device images (old epoch vs new epoch)."""
    return engine_diff(keys, old_image, new_image, plane=plane,
                       interpret=interpret, block_rows=block_rows)
