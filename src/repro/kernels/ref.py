"""Pure-jnp oracle for the Pallas lookup kernels.

The reference implementation lives in :mod:`repro.core.jax_lookup` (it is
also the production CPU fallback); re-exported here so kernel tests read
naturally as ``kernel(...) == ref(...)``.  A numpy scalar oracle via the
host `MementoHash` is provided for end-to-end cross-plane checks.
"""
from __future__ import annotations

import numpy as np

from repro.core.jax_lookup import jump32 as jump32_ref  # noqa: F401
from repro.core.jax_lookup import memento_lookup as memento_lookup_ref  # noqa: F401


def memento_lookup_host(keys: np.ndarray, memento) -> np.ndarray:
    """Scalar host-plane oracle (paper Alg. 4 via the Θ(r) dict)."""
    return np.asarray([memento.lookup(int(k)) for k in np.asarray(keys)], dtype=np.int32)
