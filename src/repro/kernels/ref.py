"""Pure-jnp + scalar-host oracles for the Pallas lookup kernels.

The jnp reference implementations live in :mod:`repro.core.jax_lookup`
(they are also the production CPU fallback); re-exported here so kernel
tests read naturally as ``kernel(...) == ref(...)``.  The scalar host
oracle works for ANY ConsistentHash implementation (Memento, Anchor, Dx,
Jump) — end-to-end cross-plane checks run host vs jnp vs Pallas.
"""
from __future__ import annotations

import numpy as np

from repro.core.jax_lookup import anchor_lookup as anchor_lookup_ref  # noqa: F401
from repro.core.jax_lookup import dx_lookup as dx_lookup_ref  # noqa: F401
from repro.core.jax_lookup import jump32 as jump32_ref  # noqa: F401
from repro.core.jax_lookup import lookup_image as lookup_image_ref  # noqa: F401
from repro.core.jax_lookup import memento_lookup as memento_lookup_ref  # noqa: F401


def lookup_host(keys: np.ndarray, h) -> np.ndarray:
    """Scalar host-plane oracle: per-key python ``lookup`` of any algorithm."""
    return np.asarray([h.lookup(int(k)) for k in np.asarray(keys)], dtype=np.int32)


def memento_lookup_host(keys: np.ndarray, memento) -> np.ndarray:
    """Scalar host-plane oracle (paper Alg. 4 via the Θ(r) dict)."""
    return lookup_host(keys, memento)
