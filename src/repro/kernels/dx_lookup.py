"""Pallas TPU kernel: batched DxHash lookup.

Block-parallel pseudo-random probing (image layout: DESIGN.md §3.3;
kernel structure: §3.4): the grid runs over
``(BLOCK_ROWS, 128)`` uint32 key blocks; the packed active bitmap (bucket
``b`` ↔ bit ``b & 31`` of word ``b >> 5``, Θ(a) *bits* of VMEM) is resident
per program.  Three dynamic scalars are prefetched: the capacity ``a``, the
probe bound (64·⌈a/w⌉, the host's cap), and the precomputed first-working
``fallback`` bucket that catches the vanishing-probability bound overrun.

The probe loop is lane-synchronous: step ``i`` tests candidate
``hash(key, i) % a`` for every unsettled lane at once (word gather + bit
test); a block runs until all 128·BLOCK_ROWS lanes hit a working bucket —
max-over-lanes of geometric draws with success rate w/a.  Bit-identical to
``core/jax_lookup.dx_lookup`` and to the ``variant="32"`` host plane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .memento_lookup import DEFAULT_BLOCK_ROWS, _pad_rows
from .primitives import gather1d, hash2

_U = jnp.uint32


def dx_body(keys, words, a, max_probes, fallback):
    """Kernel-side Dx lookup body over the flat VMEM bitmap (shared with the
    fused migration-diff kernel in ``kernels/migrate.py``)."""
    b0 = jnp.zeros(keys.shape, jnp.int32)
    found0 = jnp.zeros(keys.shape, jnp.bool_)

    def cond(state):
        i, _, found = state
        return (i < max_probes) & jnp.any(~found)

    def body(state):
        i, b, found = state
        cand = (hash2(keys, i) % a.astype(_U)).astype(jnp.int32)
        w = gather1d(words, cand >> 5)
        bit = (w >> (cand & 31).astype(_U)) & _U(1)
        hit = ~found & (bit == _U(1))
        return i + jnp.int32(1), jnp.where(hit, cand, b), found | hit

    _, b, found = jax.lax.while_loop(cond, body, (jnp.int32(0), b0, found0))
    return jnp.where(found, b, fallback)


def _dx_kernel(s_ref, keys_ref, words_ref, out_ref):
    keys = keys_ref[...].astype(_U)
    words = words_ref[...].reshape(-1)  # (a_pad/32,) uint32 bitmap
    out_ref[...] = dx_body(keys, words, s_ref[0], s_ref[1], s_ref[2])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def dx_lookup(keys, words, a, max_probes, fallback, *,
              block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """Batched DxHash lookup: keys uint32 [K] → working bucket ids int32."""
    keys2d, k = _pad_rows(keys.astype(_U))
    rows = keys2d.shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    nwords = words.shape[0]
    shape2d = (-(-nwords // 128), 128) if nwords % 128 == 0 else (nwords, 1)
    w2d = words.reshape(shape2d)

    out = pl.pallas_call(
        _dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_rows, 128), lambda i, s: (i, 0)),
                pl.BlockSpec(shape2d, lambda i, s: (0, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, 128), lambda i, s: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(keys2d.shape, jnp.int32),
        interpret=interpret,
    )(jnp.asarray([a, max_probes, fallback], jnp.int32), keys2d, w2d)
    return out.reshape(-1)[:k]
