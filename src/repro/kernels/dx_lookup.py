"""DxHash lookup — re-export shim over :mod:`repro.kernels.engine`.

The packed-bitmap probing body now lives as the ``dx`` configuration of
the unified lookup engine (DESIGN.md §6).  Kept for one release; new code
should target :mod:`repro.kernels.engine`.
"""
from __future__ import annotations

from .engine import DEFAULT_BLOCK_ROWS, dx_body, dx_lookup  # noqa: F401
