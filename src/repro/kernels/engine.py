"""The lookup engine — ONE tiled Pallas dispatch for the whole data plane.

Every device-side lookup-shaped operation in this repo is a configuration
of a single kernel family (DESIGN.md §6): the grid tiles the key batch
into ``(BLOCK_ROWS, 128)`` uint32 blocks streamed through VMEM while the
algorithm's image tables stay resident, and the **op mode** and
**algorithm** are selected statically, so each configuration compiles to
exactly ONE ``pallas_call`` launch (and, on the jnp plane, one jitted XLA
program).  The configuration space is :class:`EngineOp`:

  =========== =====================================================
  op            outputs (per key)
  =========== =====================================================
  lookup        1 bucket                       (k=1, the classic op)
  lookup_k      k distinct buckets             (k>1, salted walk)
  + bounded     the salted walk also skips buckets at/above a load
                cap — the fused "k replicas under bounded load"
                that previously needed multiple launches
  + diff        everything above under TWO epoch images at once,
                plus the moved mask — k=1 is the migration diff,
                k>1 the fused replica-set diff
  walk          one bounded-load chain-walk step (b, chain, probe)
                — the round primitive of :func:`bounded_assign`
  =========== =====================================================

Algorithms: ``memento`` (dense Θ(n) table or the beyond-paper compact
Θ(r) open-addressing table), ``anchor`` (A/K arrays), ``dx`` (packed
bitmap), ``jump`` (stateless).  The per-algorithm lookup bodies live HERE
and only here; this module is the one import surface for device lookups
(the per-algorithm re-export shims of the engine's first release are
retired).

Planes: ``plane='pallas'`` (Mosaic on TPU, interpret elsewhere) and
``plane='jnp'`` (pure-jnp, any backend; also the per-shard body the
mesh-sharded :class:`~repro.serve.plane.ShardedLookupPlane` runs under
``shard_map``).  Both are bit-identical to the host control plane on
``variant="32"`` states — the bodies are the exact ones the pre-engine
kernels ran, block padding included.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bounded import accept_in_index_order, walk_probe_bound
from repro.core.hashing import GOLDEN32
from repro.core.jax_lookup import lookup_dispatch
from repro.core.packing import PACKED_LAYOUT, build_slots
from repro.core.protocol import (ALGORITHMS, IMAGE_LAYOUT, REPLICA_SALT_CAP,
                                 image_scalar_vec)
from repro.obs.metrics import default_registry as _obs_registry
from .primitives import fmix32, gather1d, hash2, jump32, power32, table_shape2d

_U = jnp.uint32

DEFAULT_BLOCK_ROWS = 8  # (8, 128) keys per program = 1024 lookups


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve_block_rows(op, n_keys: int, table_n: int,
                        block_rows: int | None) -> int:
    """Tile-height dispatch rule: an explicit ``block_rows=`` always wins;
    otherwise consult the autotuner's persisted cache (a pure dict lookup
    on the bucketed grid key — cache hits can never retrace), falling back
    to :data:`DEFAULT_BLOCK_ROWS`."""
    if block_rows is not None:
        return block_rows
    from . import autotune  # lazy: autotune ↔ engine would cycle at import
    return autotune.resolve_block_rows(op, n_keys, table_n)


def _obs_dispatch(reg, op: EngineOp, n_keys: int, t0_ns: int) -> None:
    """Fold one engine dispatch into the live telemetry registry
    (DESIGN.md §11): dispatches served, keys, batch-size distribution, and
    a per-:class:`EngineOp` latency histogram keyed by the autotuner's op
    tag.  Counters are integers of replayed control flow, so a replay's
    counter snapshot is bit-identical; only the latency buckets float."""
    from .autotune import op_tag
    reg.counter("engine.dispatches").inc()
    reg.counter("engine.keys").inc(n_keys)
    reg.histogram("engine.batch_keys").observe(n_keys)
    reg.histogram("engine.dispatch.us", op=op_tag(op)).observe(
        (time.perf_counter_ns() - t0_ns) / 1e3)


# ---------------------------------------------------------------------------
# Static op configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EngineOp:
    """Static engine configuration — one value of this dataclass, one
    compiled program (jnp) / one Pallas launch (pallas).

    * ``algo``    — a name in :data:`repro.core.protocol.ALGORITHMS`,
    * ``mode``    — "lookup" (k replica slots, optionally bounded and/or
      diffed across two epochs) or "walk" (one bounded chain-walk step),
    * ``k``       — replica slots per key (1 = plain lookup),
    * ``bounded`` — lookup mode: the salted walk also rejects buckets at or
      above the prefetched load cap (fused k-replica × bounded-load),
    * ``diff``    — lookup mode: run under two epoch images in the same
      launch and emit the moved mask (k>1 diffs whole replica sets),
    * ``table``   — "dense" (full-width layout), "packed" (the compact
      :mod:`repro.core.packing` layout of a ``packed=True`` image; any
      algorithm, any mode), or — memento only — "compact" (the legacy
      per-call Θ(r) open addressing; lookup mode).
    """

    algo: str
    mode: str = "lookup"
    k: int = 1
    bounded: bool = False
    diff: bool = False
    table: str = "dense"

    def __post_init__(self):
        if self.algo not in ALGORITHMS:
            raise ValueError(f"unknown algo {self.algo!r}")
        if self.mode not in ("lookup", "walk"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.k < 1:
            raise ValueError("k must be ≥ 1")
        if self.mode == "walk" and (self.k != 1 or self.diff or self.bounded):
            raise ValueError("walk mode is k=1, no diff, cap-implicit")
        if self.table not in ("dense", "compact", "packed"):
            raise ValueError(f"unknown table kind {self.table!r}")
        if self.table == "compact" and self.algo != "memento":
            raise ValueError("compact tables are Memento-only")
        if self.table == "compact" and (self.diff or self.mode == "walk"):
            raise ValueError("compact tables serve lookup mode only")

    # -- derived operand layout ---------------------------------------------
    @property
    def table_names(self) -> tuple[str, ...]:
        if self.table == "compact":
            return ("slot_b", "slot_c")
        if self.table == "packed":
            return PACKED_LAYOUT[self.algo][1]
        return IMAGE_LAYOUT[self.algo][1]

    @property
    def num_tables(self) -> int:
        return len(self.table_names)

    @property
    def num_scalars(self) -> int:
        return len(IMAGE_LAYOUT[self.algo][0])

    @property
    def has_load(self) -> bool:
        return self.bounded or self.mode == "walk"

    @property
    def num_outputs(self) -> int:
        if self.mode == "walk":
            return 3                      # b, chain, probe
        return 2 * self.k + 1 if self.diff else self.k


# ---------------------------------------------------------------------------
# The per-algorithm lookup bodies (the ONLY copies in the repo)
# ---------------------------------------------------------------------------

def memento_body(keys, read, n):
    """Paper Alg. 4, lane-synchronous, over an abstract table reader.

    ``read(idx) -> int32`` returns ``repl[idx]`` (−1 = working).  The dense
    plane reads by VMEM gather, the compact plane by open-addressing probe
    — one body, two table layouts (DESIGN.md §3.2).
    """

    b = jump32(keys, n)

    def outer_cond(b):
        return jnp.any(read(b) >= 0)

    def outer_body(b):
        c = read(b)
        active = c >= 0
        wb = jnp.where(active, c, 1)  # |W_b| after b was removed (Prop. V.3)
        d = (hash2(keys, b) % wb.astype(_U)).astype(jnp.int32)

        def inner_cond(d):
            u = read(d)
            return jnp.any(active & (u >= 0) & (u >= wb))

        def inner_body(d):
            u = read(d)
            follow = active & (u >= 0) & (u >= wb)  # follow only while u ≥ w_b
            return jnp.where(follow, u, d)

        d = jax.lax.while_loop(inner_cond, inner_body, d)
        return jnp.where(active, d, b)

    return jax.lax.while_loop(outer_cond, outer_body, b)


def dense_body(keys, repl, n):
    """Memento dense-table body: flat VMEM repl image + dynamic n."""
    return memento_body(keys, lambda idx: gather1d(repl, idx), n)


def compact_reader(slot_b, slot_c):
    """``read(idx)`` over the Θ(r) open-addressing image: linear probing
    from ``fmix32(idx·GOLDEN32 + 5) & mask`` until hit (→ c) or empty
    (→ −1, the bucket is working)."""
    nslots = slot_b.shape[0]  # power of two
    mask = _U(nslots - 1)

    def read(idx):
        h0 = (fmix32(idx.astype(_U) * _U(GOLDEN32) + _U(5)) & mask).astype(jnp.int32)

        def cond(state):
            pos, done, _ = state
            return jnp.any(~done)

        def body(state):
            pos, done, val = state
            sb = gather1d(slot_b, pos)
            hit = sb == idx
            empty = sb < 0
            val = jnp.where(~done & hit, gather1d(slot_c, pos), val)
            done = done | hit | empty
            pos = jnp.where(done, pos, (pos + 1) % nslots)
            return pos, done, val

        val0 = jnp.full(idx.shape, -1, jnp.int32)
        done0 = jnp.zeros(idx.shape, jnp.bool_)
        _, _, val = jax.lax.while_loop(cond, body, (h0, done0, val0))
        return val

    return read


def packed_reader(state, slot_b, slot_c):
    """``read(idx)`` over the packed Memento image (DESIGN.md §8.2): the
    uint32 ``state`` bitmap short-circuits working buckets (bit = 1 → −1,
    no probe at all — the overwhelmingly common case), removed buckets
    probe the open-addressing slots with the ``compact_reader`` sequence
    but stop only on EMPTY (−1): TOMBSTONE (−2) slots left by epoch-delta
    restores keep the chain alive.  Slot words may be dtype-narrowed;
    values widen to int32 at the gather."""
    nslots = slot_b.shape[0]  # power of two
    mask = _U(nslots - 1)

    def read(idx):
        w = gather1d(state, idx >> 5).astype(_U)
        working = ((w >> (idx & 31).astype(_U)) & _U(1)) == _U(1)
        h0 = (fmix32(idx.astype(_U) * _U(GOLDEN32) + _U(5)) & mask).astype(jnp.int32)

        def cond(state_):
            _, done, _ = state_
            return jnp.any(~done)

        def body(state_):
            pos, done, val = state_
            sb = gather1d(slot_b, pos).astype(jnp.int32)
            hit = sb == idx
            empty = sb == -1  # tombstones (−2) keep probing
            val = jnp.where(~done & hit,
                            gather1d(slot_c, pos).astype(jnp.int32), val)
            done = done | hit | empty
            pos = jnp.where(done, pos, (pos + 1) % nslots)
            return pos, done, val

        val0 = jnp.full(idx.shape, -1, jnp.int32)
        _, _, val = jax.lax.while_loop(cond, body, (h0, working, val0))
        return val

    return read


def anchor_body(keys, A, K, a):
    """AnchorHash body: A (removal stamps) / K (wrap successors) in VMEM."""
    b = (fmix32(keys) % a.astype(_U)).astype(jnp.int32)

    def outer_cond(b):
        return jnp.any(gather1d(A, b) > 0)

    def outer_body(b):
        Ab = gather1d(A, b)
        active = Ab > 0
        denom = jnp.where(active, Ab, 1).astype(_U)
        h = (hash2(keys, b) % denom).astype(jnp.int32)

        def inner_cond(h):
            return jnp.any(active & (gather1d(A, h) >= Ab))

        def inner_body(h):
            follow = active & (gather1d(A, h) >= Ab)  # removed at-or-after b
            return jnp.where(follow, gather1d(K, h), h)

        h = jax.lax.while_loop(inner_cond, inner_body, h)
        return jnp.where(active, h, b)

    return jax.lax.while_loop(outer_cond, outer_body, b)


def dx_body(keys, words, a, max_probes, fallback):
    """DxHash body: pseudo-random probing of the packed active bitmap."""
    b0 = jnp.zeros(keys.shape, jnp.int32)
    found0 = jnp.zeros(keys.shape, jnp.bool_)

    def cond(state):
        i, _, found = state
        return (i < max_probes) & jnp.any(~found)

    def body(state):
        i, b, found = state
        cand = (hash2(keys, i) % a.astype(_U)).astype(jnp.int32)
        w = gather1d(words, cand >> 5)
        bit = (w >> (cand & 31).astype(_U)) & _U(1)
        hit = ~found & (bit == _U(1))
        return i + jnp.int32(1), jnp.where(hit, cand, b), found | hit

    _, b, found = jax.lax.while_loop(cond, body, (jnp.int32(0), b0, found0))
    return jnp.where(found, b, fallback)


def algo_body(op: EngineOp, keys, tables, scalars):
    """One-epoch lookup body dispatch — shared by every op mode so plain
    lookups, replicas, bounded assignment, and epoch diffs can never
    disagree about placement."""
    if op.algo == "memento":
        if op.table == "compact":
            return memento_body(keys, compact_reader(tables[0], tables[1]),
                                scalars[0])
        if op.table == "packed":
            return memento_body(
                keys, packed_reader(tables[0], tables[1], tables[2]),
                scalars[0])
        return dense_body(keys, tables[0], scalars[0])
    if op.algo == "anchor":
        # packed tables may be dtype-narrowed; widen at the boundary (a
        # no-op trace-wise for the dense int32 layout)
        return anchor_body(keys, tables[0].astype(jnp.int32),
                           tables[1].astype(jnp.int32), scalars[0])
    if op.algo == "dx":
        return dx_body(keys, tables[0], scalars[0], scalars[1], scalars[2])
    if op.algo == "jump":
        return jump32(keys, scalars[0])
    if op.algo == "power":
        return power32(keys, scalars[0])
    raise ValueError(f"unknown algo {op.algo!r}")


# ---------------------------------------------------------------------------
# Mode bodies (lane-synchronous, plane-agnostic)
# ---------------------------------------------------------------------------

def replica_body(keys, k, single_lookup, load=None, cap=None):
    """k distinct buckets per lane via the salted-re-lookup walk
    (DESIGN.md §4.1); with ``load``/``cap`` the walk ALSO rejects buckets
    at or above the cap — the fused bounded-replica op (§6).

    The candidate at salt 0 is the plain lookup, salt s ≥ 1 re-looks-up
    ``hash2(key, s)``; the per-lane salt counter advances on every try and
    carries across slots, so the walk is bit-identical to the host
    ``ReplicatedLookup.lookup_k_filtered`` (with the load-cap reject rule
    when bounded).  Unbounded slot 0 always accepts at salt 0, which is
    exactly the legacy ``replica_body``.  Lanes that exhaust
    ``REPLICA_SALT_CAP`` keep the plain-lookup bucket (probability
    ≤ ((k−1)/w)^CAP — see protocol.py; the host raises instead).
    Returns a list of k int32 arrays.
    """
    keys = jnp.asarray(keys).astype(_U)
    first = single_lookup(keys)
    if load is None:
        # unbounded slot 0 is the plain lookup, accepted outside the loop
        # (no wasted salted pass); k=1 is exactly the one-body legacy program
        if k == 1:
            return [first]
        outs: list = [first]
        salt = jnp.ones(keys.shape, jnp.int32)
    else:
        outs = []  # bounded: slot 0 walks too (cap check on the primary)
        salt = jnp.zeros(keys.shape, jnp.int32)
    for _ in range(k - len(outs)):
        prev = tuple(outs)

        def cond(state):
            salt, _slot, done = state
            return jnp.any(~done & (salt <= REPLICA_SALT_CAP))

        def body(state, prev=prev):
            salt, slot, done = state
            active = ~done & (salt <= REPLICA_SALT_CAP)
            cand = single_lookup(hash2(keys, salt))
            if load is not None:  # only bounded lanes can sit at salt 0
                cand = jnp.where(salt == 0, first, cand)
            bad = jnp.zeros(keys.shape, jnp.bool_)
            for o in prev:
                bad = bad | (cand == o)
            if load is not None:
                bad = bad | (gather1d(load, cand) >= cap)
            ok = active & ~bad
            slot = jnp.where(ok, cand, slot)
            salt = jnp.where(active, salt + 1, salt)
            return salt, slot, done | ok

        salt, slot, _ = jax.lax.while_loop(
            cond, body, (salt, first, jnp.zeros(keys.shape, jnp.bool_)))
        outs.append(slot)
    return outs


def chain_walk_body(chain, probe, pending, load, cap, single_lookup):
    """Walk each pending lane's deterministic rehash chain
    (``chain ← hash2(chain, probe)``) to the first bucket with
    ``load[b] < cap``; non-pending lanes are left untouched (DESIGN.md
    §4.2).  One step is exactly the host's ``probe += 1; chain =
    hash2(chain, probe); b = lookup(chain)``; lanes stop after the shared
    ``walk_probe_bound`` so an infeasible cap surfaces as an error in the
    batch driver instead of spinning.  Returns ``(b, chain, probe)``.
    """
    chain = jnp.asarray(chain).astype(_U)
    probe = jnp.asarray(probe).astype(jnp.int32)
    max_probe = walk_probe_bound(load.shape[0])
    b = single_lookup(chain)

    def cond(state):
        _chain, probe, b, active = state
        return jnp.any(active & (gather1d(load, b) >= cap)
                       & (probe < max_probe))

    def body(state):
        chain, probe, b, active = state
        step = active & (gather1d(load, b) >= cap) & (probe < max_probe)
        probe = jnp.where(step, probe + 1, probe)
        chain = jnp.where(step, hash2(chain, probe), chain)
        b = jnp.where(step, single_lookup(chain), b)
        return chain, probe, b, active

    chain, probe, b, _ = jax.lax.while_loop(
        cond, body, (chain, probe, b, jnp.asarray(pending)))
    return b, chain, probe


def _mode_outputs(op: EngineOp, blocks, tables, scalars, load, cap):
    """Run the configured op over one key block; returns the output list.

    ``blocks`` is (keys,) in lookup mode, (chain, probe, pending) in walk
    mode; ``tables``/``scalars`` hold one epoch's operands, or two epochs
    concatenated when ``op.diff``.
    """
    nt, ns = op.num_tables, op.num_scalars
    if op.mode == "walk":
        chain, probe, pending = blocks
        b, chain, probe = chain_walk_body(
            chain, probe, pending != 0, load, cap,
            lambda kk: algo_body(op, kk, tables, scalars))
        return [b, chain.astype(jnp.int32), probe]
    keys = blocks[0]

    def epoch_outs(tabs, scals):
        return replica_body(keys, op.k,
                            lambda kk: algo_body(op, kk, tabs, scals),
                            load=load if op.bounded else None, cap=cap)

    outs = epoch_outs(tables[:nt], scalars[:ns])
    if op.diff:
        new = epoch_outs(tables[nt:2 * nt], scalars[ns:2 * ns])
        moved = jnp.zeros(keys.shape, jnp.bool_)
        for o, n_ in zip(outs, new):
            moved = moved | (o != n_)
        outs = outs + new + [moved.astype(jnp.int32)]
    return outs


# ---------------------------------------------------------------------------
# Pallas plane: one launch per configuration
# ---------------------------------------------------------------------------

def _pad_rows(x, cols=128):
    k = x.shape[0]
    rows = max(1, -(-k // cols))
    padded = jnp.zeros((rows * cols,), x.dtype).at[:k].set(x)
    return padded.reshape(rows, cols), k


def _engine_kernel_factory(op: EngineOp):
    nb = 1 if op.mode == "lookup" else 3   # key-shaped input blocks
    nt = op.num_tables * (2 if op.diff else 1)

    def kernel(s_ref, *refs):
        blocks = [r[...].astype(_U) if i == 0 and op.mode == "lookup"
                  else r[...] for i, r in enumerate(refs[:nb])]
        pos = nb
        tables = [r[...].reshape(-1) for r in refs[pos:pos + nt]]
        pos += nt
        load = refs[pos][...].reshape(-1) if op.has_load else None
        pos += int(op.has_load)
        out_refs = refs[pos:]
        ns_total = op.num_scalars * (2 if op.diff else 1)
        scalars = [s_ref[i] for i in range(ns_total)]
        cap = s_ref[ns_total] if op.has_load else None
        if op.mode == "walk":
            blocks[0] = blocks[0].astype(_U)
        outs = _mode_outputs(op, blocks, tables, scalars, load, cap)
        for ref, o in zip(out_refs, outs):
            ref[...] = o

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("op", "block_rows", "interpret"))
def _engine_pallas(scalars, blocks2d, tables2d, *, op: EngineOp,
                   block_rows: int, interpret: bool):
    rows = blocks2d[0].shape[0]
    block_rows = min(block_rows, rows)
    grid = (-(-rows // block_rows),)
    blk = pl.BlockSpec((block_rows, 128), lambda i, s: (i, 0))
    tab_specs = [pl.BlockSpec(t.shape, lambda i, s: (0, 0)) for t in tables2d]

    return pl.pallas_call(
        _engine_kernel_factory(op),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[blk] * len(blocks2d) + tab_specs,
            out_specs=[blk] * op.num_outputs,
        ),
        out_shape=[jax.ShapeDtypeStruct(blocks2d[0].shape, jnp.int32)]
        * op.num_outputs,
        interpret=interpret,
    )(scalars, *blocks2d, *tables2d)


# ---------------------------------------------------------------------------
# jnp plane: one jitted program per configuration (traced operands, so one
# compile serves every epoch of a given shape)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("op",))
def _engine_jnp(blocks, arrays, scalars, load, cap, *, op: EngineOp):
    def dispatch(tabs, scals):
        if op.table == "packed":
            # the packed layout has no jax_lookup oracle — its one body
            # lives in algo_body, shared with the Pallas plane
            return lambda kk: algo_body(op, kk, list(tabs), list(scals))
        arrs = dict(zip(names, tabs))
        return lambda kk: lookup_dispatch(op.algo, kk, arrs, scals)

    nt = op.num_tables
    tables = list(arrays)
    names = op.table_names  # rebuild named dicts for lookup_dispatch per epoch
    if op.mode == "walk":
        chain, probe, pending = blocks
        b, chain, probe = chain_walk_body(
            chain, probe, pending, load, cap,
            dispatch(tables[:nt], scalars[:op.num_scalars]))
        return b, chain, probe
    keys = blocks[0]

    def epoch_outs(tabs, scals):
        return replica_body(keys, op.k, dispatch(tabs, scals),
                            load=load if op.bounded else None, cap=cap)

    outs = epoch_outs(tables[:nt], scalars[:op.num_scalars])
    if op.diff:
        new = epoch_outs(tables[nt:2 * nt],
                         scalars[op.num_scalars:2 * op.num_scalars])
        moved = jnp.zeros(keys.shape, jnp.bool_)
        for o, n_ in zip(outs, new):
            moved = moved | (o != n_)
        return tuple(outs), tuple(new), moved
    return tuple(outs)


# ---------------------------------------------------------------------------
# Operand marshalling
# ---------------------------------------------------------------------------

def _op_table(image, table: str = "dense") -> str:
    """The table kind an image serves: a ``packed=True`` image always runs
    the packed configuration (callers never have to spell it)."""
    if getattr(image, "packed", False):
        if table not in ("dense", "packed"):
            raise ValueError(f"packed image cannot serve table={table!r}")
        return "packed"
    return table


def _image_tables(op: EngineOp, image):
    if op.table == "compact":
        slot_b, slot_c = build_compact_table(
            jnp.asarray(image.arrays["repl"], jnp.int32))
        return [slot_b, slot_c]
    if (op.table == "packed") != bool(getattr(image, "packed", False)):
        raise ValueError(f"table={op.table!r} op cannot read a "
                         f"{'packed' if image.packed else 'dense'} image")
    return [jnp.asarray(image.arrays[name]) for name in op.table_names]


def _tables2d(tables):
    return [t.reshape(table_shape2d(t.shape[0])) for t in tables]


def _scalar_vec(op: EngineOp, images, cap):
    vec: list[int] = []
    for img in images:
        vec += image_scalar_vec(img)
    if op.has_load:
        vec.append(int(cap))
    return jnp.asarray(vec, jnp.int32)


def _jnp_operands(images):
    arrays, scalars = [], []
    for img in images:
        layout = PACKED_LAYOUT if getattr(img, "packed", False) else IMAGE_LAYOUT
        names = layout[img.algo][1]
        arrays += [jnp.asarray(img.arrays[n]) for n in names]
        scalars += [jnp.asarray(s, jnp.int32) for s in image_scalar_vec(img)]
    return tuple(arrays), tuple(scalars)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def engine_lookup(keys, image, *, k: int = 1, load=None, cap: int | None = None,
                  plane: str = "pallas", table: str = "dense",
                  interpret: bool | None = None,
                  block_rows: int | None = None):
    """The one batched lookup: keys [K] → int32 [K] (k=1) or [K, k].

    ``k>1`` returns salted k-replica sets (column 0 = the plain lookup);
    passing ``load``/``cap`` fuses the bounded-load rejection into the same
    single launch (every returned bucket has ``load < cap``, slot 0
    included).  Bit-identical to the host plane on ``variant="32"`` states.
    """
    bounded = load is not None
    if bounded and cap is None:
        raise ValueError("bounded lookup needs a cap")
    table = _op_table(image, table)
    op = EngineOp(algo=image.algo, k=k, bounded=bounded, table=table)
    keys = jnp.asarray(keys, dtype=_U)
    _reg = _obs_registry()
    _t0 = time.perf_counter_ns() if _reg.active else 0
    if plane == "jnp":
        if table == "compact":
            raise ValueError("jnp plane serves the dense layout")
        arrays, scalars = _jnp_operands([image])
        outs = _engine_jnp((keys,), arrays, scalars,
                           None if load is None else jnp.asarray(load, jnp.int32),
                           None if cap is None else jnp.asarray(cap, jnp.int32),
                           op=op)
        out = outs[0] if k == 1 else jnp.stack(outs).T
    elif plane != "pallas":
        raise ValueError(f"unknown plane {plane!r}")
    else:
        if interpret is None:
            interpret = _default_interpret()
        tables = _image_tables(op, image)
        if bounded:
            tables.append(jnp.asarray(load, jnp.int32))
        keys2d, nk = _pad_rows(keys)
        outs = _engine_pallas(_scalar_vec(op, [image], cap), (keys2d,),
                              tuple(_tables2d(tables)), op=op,
                              block_rows=_resolve_block_rows(
                                  op, nk, int(image.n), block_rows),
                              interpret=interpret)
        flat = [o.reshape(-1)[:nk] for o in outs]
        out = flat[0] if k == 1 else jnp.stack(flat).T
    if _reg.active:
        _reg.counter("engine.lookups").inc()
        _obs_dispatch(_reg, op, int(keys.shape[0]), _t0)
    if bounded:
        # Slots are only accepted when distinct AND below the cap, so an
        # over-cap bucket OR a duplicate row means that lane exhausted the
        # salt budget (fewer than k distinct buckets below the cap) —
        # surface it like the host oracle instead of silently violating
        # either invariant.  The host sync this costs is deliberate: the
        # event is vanishingly rare on feasible caps (≤ ((k−1)/w)^CAP) but
        # a silent miss loses redundancy, and bounded callers consume the
        # result on host anyway.
        out_np = np.asarray(out)
        exhausted = bool((np.asarray(load)[out_np] >= cap).any())
        if not exhausted:
            for i in range(1, k):  # k(k−1)/2 vector compares, no sort
                for j in range(i):
                    if bool((out_np[:, i] == out_np[:, j]).any()):
                        exhausted = True
                        break
                if exhausted:
                    break
        if exhausted:
            raise RuntimeError(
                "replica salt budget exhausted (infeasible cap: fewer than "
                f"k={k} distinct working buckets below cap={cap})")
    return out


def replica_lookup(keys, image, k: int, *, plane: str = "jnp", **kw):
    """k-replica sets with a STABLE 2-D shape: keys [K] → int32 [K, k] even
    for k=1 (where :func:`engine_lookup` returns the flat classic op) —
    the convenience replica-set consumers and tests share instead of each
    hand-rolling the k=1 reshape."""
    out = engine_lookup(keys, image, k=k, plane=plane, **kw)
    return jnp.reshape(out, (-1, 1)) if k == 1 else out


@dataclass
class EngineDiff:
    """Per-key placement under two epochs plus the moved mask.

    ``old``/``new`` are int32 ``[K]`` for k=1 (the classic migration diff)
    or ``[K, k]`` replica sets for k>1; ``moved[key]`` is True when ANY
    slot differs between the epochs.
    """

    old: np.ndarray
    new: np.ndarray
    moved: np.ndarray

    @property
    def num_moved(self) -> int:
        return int(np.asarray(self.moved).sum())


def engine_diff(keys, old_image, new_image, *, k: int = 1,
                plane: str = "jnp", interpret: bool | None = None,
                block_rows: int | None = None) -> EngineDiff:
    """Fused epoch diff: lookup a key batch under two images in ONE program
    (jnp) / ONE launch (pallas, both epoch tables in VMEM).  ``k>1`` diffs
    whole replica sets — the movement planners' view of replica churn."""
    reg = _obs_registry()
    if not reg.active:
        return _engine_diff(keys, old_image, new_image, k=k, plane=plane,
                            interpret=interpret, block_rows=block_rows)
    t0 = time.perf_counter_ns()
    out = _engine_diff(keys, old_image, new_image, k=k, plane=plane,
                       interpret=interpret, block_rows=block_rows)
    reg.counter("engine.diffs").inc()
    reg.counter("engine.moved_keys").inc(out.num_moved)
    _obs_dispatch(reg, EngineOp(algo=new_image.algo, k=k, diff=True,
                                table=_op_table(new_image)),
                  int(np.shape(keys)[0]), t0)
    return out


def _engine_diff(keys, old_image, new_image, *, k: int = 1,
                 plane: str = "jnp", interpret: bool | None = None,
                 block_rows: int | None = None) -> EngineDiff:
    keys = jnp.asarray(keys, dtype=_U)
    if plane == "jnp":
        if old_image.algo != new_image.algo:
            # cross-algorithm migration: two dispatches, still one program
            op_old = EngineOp(algo=old_image.algo, k=k,
                              table=_op_table(old_image))
            op_new = EngineOp(algo=new_image.algo, k=k,
                              table=_op_table(new_image))
            ao, so = _jnp_operands([old_image])
            an, sn = _jnp_operands([new_image])
            old = _engine_jnp((keys,), ao, so, None, None, op=op_old)
            new = _engine_jnp((keys,), an, sn, None, None, op=op_new)
            old_np = _stack_np(old, k)
            new_np = _stack_np(new, k)
            moved = (old_np != new_np) if k == 1 else \
                (old_np != new_np).any(axis=1)
            return EngineDiff(old_np, new_np, np.asarray(moved))
        if bool(getattr(old_image, "packed", False)) != \
                bool(getattr(new_image, "packed", False)):
            raise ValueError("epoch diff needs both images in one layout")
        op = EngineOp(algo=old_image.algo, k=k, diff=True,
                      table=_op_table(old_image))
        arrays, scalars = _jnp_operands([old_image, new_image])
        old, new, moved = _engine_jnp((keys,), arrays, scalars, None, None,
                                      op=op)
        return EngineDiff(_stack_np(old, k), _stack_np(new, k),
                          np.asarray(moved))
    if plane != "pallas":
        raise ValueError(f"unknown plane {plane!r}")
    if old_image.algo != new_image.algo:
        raise ValueError("pallas epoch diff requires one algorithm "
                         f"({old_image.algo!r} != {new_image.algo!r})")
    if bool(getattr(old_image, "packed", False)) != \
            bool(getattr(new_image, "packed", False)):
        raise ValueError("epoch diff needs both images in one layout")
    op = EngineOp(algo=old_image.algo, k=k, diff=True,
                  table=_op_table(old_image))
    if interpret is None:
        interpret = _default_interpret()
    tables = _image_tables(op, old_image) + _image_tables(op, new_image)
    keys2d, nk = _pad_rows(keys)
    outs = _engine_pallas(_scalar_vec(op, [old_image, new_image], None),
                          (keys2d,), tuple(_tables2d(tables)), op=op,
                          block_rows=_resolve_block_rows(
                              op, nk, int(new_image.n), block_rows),
                          interpret=interpret)
    flat = [np.asarray(o.reshape(-1)[:nk]) for o in outs]
    old = flat[0] if k == 1 else np.stack(flat[:k]).T
    new = flat[k] if k == 1 else np.stack(flat[k:2 * k]).T
    return EngineDiff(old, new, flat[2 * k].astype(bool))


def _stack_np(outs, k):
    return (np.asarray(outs[0]) if k == 1 else
            np.stack([np.asarray(o) for o in outs]).T)


def engine_chain_walk(chain, probe, pending, image, load, cap, *,
                      plane: str = "jnp", interpret: bool | None = None,
                      block_rows: int | None = None):
    """One bounded-load chain-walk step (the round primitive of
    :func:`bounded_assign`): advance every pending lane to the first bucket
    of its rehash chain with ``load[b] < cap``.  Returns numpy
    ``(b, chain, probe)``; non-pending lanes come back unchanged."""
    op = EngineOp(algo=image.algo, mode="walk", table=_op_table(image))
    _reg = _obs_registry()
    _t0 = time.perf_counter_ns() if _reg.active else 0
    chain = jnp.asarray(chain, dtype=_U)
    probe = jnp.asarray(probe, dtype=jnp.int32)
    pending = jnp.asarray(pending, dtype=jnp.bool_)
    load = jnp.asarray(load, dtype=jnp.int32)
    if plane == "jnp":
        arrays, scalars = _jnp_operands([image])
        b, ch, pr = _engine_jnp((chain, probe, pending), arrays, scalars,
                                load, jnp.asarray(cap, jnp.int32), op=op)
        if _reg.active:
            _reg.counter("engine.walk_steps").inc()
            _obs_dispatch(_reg, op, int(chain.shape[0]), _t0)
        return (np.asarray(b), np.asarray(ch).astype(np.uint32),
                np.asarray(pr))
    if plane != "pallas":
        raise ValueError(f"unknown plane {plane!r}")
    if interpret is None:
        interpret = _default_interpret()
    nk = chain.shape[0]
    chain2d, _ = _pad_rows(chain)
    probe2d, _ = _pad_rows(probe)
    pending2d, _ = _pad_rows(pending.astype(jnp.int32))
    tables = _image_tables(op, image) + [load]
    b, ch, pr = _engine_pallas(
        _scalar_vec(op, [image], cap), (chain2d, probe2d, pending2d),
        tuple(_tables2d(tables)), op=op,
        block_rows=_resolve_block_rows(op, nk, int(image.n), block_rows),
        interpret=interpret)
    if _reg.active:
        _reg.counter("engine.walk_steps").inc()
        _obs_dispatch(_reg, op, nk, _t0)
    take = lambda x: np.asarray(x.reshape(-1)[:nk])  # noqa: E731
    return take(b), take(ch).astype(np.uint32), take(pr)


def bounded_assign(keys, image, load, cap: int, *, plane: str = "jnp",
                   interpret: bool | None = None):
    """Assign a key batch under the load cap on the device plane.

    Per round: (1) the walk configuration advances every pending key to the
    first non-full bucket of its deterministic rehash chain (one launch);
    (2) intra-batch races are resolved in key-index order
    (:func:`repro.core.bounded.accept_in_index_order`) — identical, round
    for round, to the numpy reference ``bounded_assign_ref``.  Returns
    ``(assignments int32 [m], new_load int32)``.
    """
    keys = np.asarray(keys, dtype=np.uint32)
    m = len(keys)
    chain = keys.copy()
    probe = np.zeros(m, np.int32)
    out = np.full(m, -1, np.int32)
    pending = np.ones(m, bool)
    load = np.asarray(load, dtype=np.int32).copy()
    rounds = 0
    while pending.any():
        b, chain, probe = engine_chain_walk(chain, probe, pending, image,
                                            load, cap, plane=plane,
                                            interpret=interpret)
        if (load[b[pending]] >= cap).any():  # probe bound exhausted
            raise RuntimeError("no bucket below capacity (infeasible cap: "
                               f"cap={cap} cannot hold the pending keys)")
        accept_idx = accept_in_index_order(b, pending, load, cap)
        out[accept_idx] = b[accept_idx]
        np.add.at(load, b[accept_idx], 1)
        pending[accept_idx] = False
        rounds += 1
    reg = _obs_registry()
    if reg.active:
        reg.counter("engine.bounded_assigns").inc()
        reg.counter("engine.bounded_rounds").inc(rounds)
    return out, load


def bounded_load_len(image) -> int:
    """Length of a load-word array covering ``image``'s bucket-id space —
    THE sizing rule for bounded ops (walk gathers + the fused bounded
    lookup index ``load`` by bucket id).  Anchor/Memento loads align with
    their bucket-indexed tables; Dx packs bits and Jump has no table, so
    their loads are sized to the (128-padded) id space directly."""
    from repro.core.protocol import round_up

    if image.algo == "anchor":
        return int(image.arrays["A"].shape[0])
    if image.algo == "memento":
        if getattr(image, "packed", False):  # bitmap covers 32 ids per word
            return 32 * int(image.arrays["state"].shape[0])
        return int(image.arrays["repl"].shape[0])
    return round_up(image.n)


def bounded_replica_sets(h, keys, k: int, load, cap: int) -> np.ndarray:
    """Numpy oracle for the fused bounded-replica op: the host salted walk
    (``lookup_k_filtered``) with the load-cap reject rule applied to EVERY
    slot (slot 0 included), so all k replicas land below the cap.  Ground
    truth for ``engine_lookup(..., k, load=, cap=)`` on both planes."""
    load = np.asarray(load)

    def reject(cand, chosen):
        return cand in chosen or load[cand] >= cap

    keys = np.asarray(keys)
    out = np.empty((len(keys), k), dtype=np.int32)
    for i, key in enumerate(keys):
        out[i] = h.lookup_k_filtered(int(key), k, reject, check_first=True)
    return out


# ---------------------------------------------------------------------------
# Raw-array entry points (the legacy per-algorithm kernel signatures, kept
# so the shim modules stay pure re-exports)
# ---------------------------------------------------------------------------

def _raw_lookup(op: EngineOp, tables, scalars, keys, block_rows, interpret):
    keys2d, nk = _pad_rows(jnp.asarray(keys).astype(_U))
    outs = _engine_pallas(jnp.asarray(scalars, jnp.int32), (keys2d,),
                          tuple(_tables2d([jnp.asarray(t) for t in tables])),
                          op=op, block_rows=block_rows, interpret=interpret)
    return outs[0].reshape(-1)[:nk]


def dense_lookup(keys, repl, n, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool = True):
    """Batched Memento lookup with the dense Θ(n)-int32 table in VMEM."""
    return _raw_lookup(EngineOp("memento"), [repl], [n], keys,
                       block_rows, interpret)


def compact_lookup(keys, slot_b, slot_c, n, *,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = True):
    """Batched Memento lookup with the Θ(r) open-addressing table in VMEM."""
    return _raw_lookup(EngineOp("memento", table="compact"),
                       [slot_b, slot_c], [n], keys, block_rows, interpret)


def anchor_lookup(keys, A, K, a, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True):
    """Batched AnchorHash lookup: keys uint32 [K] → working bucket ids."""
    return _raw_lookup(EngineOp("anchor"), [A, K], [a], keys,
                       block_rows, interpret)


def dx_lookup(keys, words, a, max_probes, fallback, *,
              block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """Batched DxHash lookup: keys uint32 [K] → working bucket ids."""
    return _raw_lookup(EngineOp("dx"), [words], [a, max_probes, fallback],
                       keys, block_rows, interpret)


def jump_lookup(keys, n, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = True):
    """Batched JumpHash lookup: keys uint32 [K] → bucket ids in [0, n)."""
    return _raw_lookup(EngineOp("jump"), [], [n], keys, block_rows, interpret)


def power_lookup(keys, n, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool = True):
    """Batched PowerHash lookup: keys uint32 [K] → bucket ids in [0, n)."""
    return _raw_lookup(EngineOp("power"), [], [n], keys, block_rows, interpret)


# ---------------------------------------------------------------------------
# Host-side compact-table builder (memento, beyond-paper Θ(r) image)
# ---------------------------------------------------------------------------

def build_compact_table(repl) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Host-side: dense repl image → open-addressing (slot_b, slot_c) arrays.

    Slots = next power of two ≥ max(2r, 128) → load factor ≤ 0.5, so the
    expected probe chain is ~1.5 and the VMEM working set is Θ(r).  The
    insertion algorithm (and the packed-image variant with headroom and
    dtype narrowing) lives in :func:`repro.core.packing.build_slots`.
    """
    slot_b, slot_c = build_slots(np.asarray(repl))
    return jnp.asarray(slot_b), jnp.asarray(slot_c)
