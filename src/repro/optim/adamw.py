"""AdamW (decoupled weight decay) on parameter pytrees, from scratch.

Optimizer state mirrors the parameter tree (same shapes ⇒ same
PartitionSpecs ⇒ ZeRO-style sharded optimizer state for free).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw_update(grads, opt, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = opt["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat = jax.tree.map(upd, grads, opt["m"], opt["v"], params)
    new_params = jax.tree.map(lambda t3: t3[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
