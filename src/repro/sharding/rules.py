"""Logical-axis → mesh-axis sharding rules (MaxText-style, divisibility-safe).

Every parameter/activation dimension carries a *logical* name; rules map the
name to mesh axes.  ``logical_to_spec`` drops any assignment that does not
divide the dimension (jax requires divisible input shardings), so a single
rule table serves every architecture — e.g. `heads` lands on `model` only
after TP padding made it divisible, `vocab` always divides by construction.

Default placement (single-pod mesh ``(data=16, model=16)``; multi-pod adds a
leading ``pod`` axis used as an extra data dimension):

  batch      → (pod, data)        activations' leading dim
  fsdp       → data               parameter ZeRO-3 sharding dim
  heads      → model              TP over (padded) query heads
  kv_heads   → model (if divides) else replicated
  d_ff       → model              TP over MLP hidden
  vocab      → model              TP over the (padded) vocabulary
  experts    → model              expert parallelism
  seq_kv     → model              KV-cache sequence dim (decode memory / SP)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """Version-portable shard_map.

    Newer jax exposes ``jax.shard_map`` (partial-manual via ``axis_names`` =
    the manual axes); older releases only have the experimental API, where
    partial-manual is the complement (``auto`` = the non-manual axes).
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    # check_rep is a static replication checker with no numeric effect; the
    # old one lacks rules for several collectives, so disable it.
    kw = {"check_rep": False}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def pvary(x, axes):
    """``jax.lax.pcast(x, axes, to="varying")`` where available.

    On older jax (no varying-axis typing) the cast is a no-op numerically,
    so identity is the correct fallback.
    """
    pcast = getattr(jax.lax, "pcast", None)
    return x if pcast is None else pcast(x, axes, to="varying")


@dataclass(frozen=True)
class AxisRules:
    rules: dict[str, tuple[str, ...]] = field(default_factory=dict)
    mesh_axis_sizes: dict[str, int] = field(default_factory=dict)
    mesh: object = None  # the jax Mesh (needed for shard_map sub-regions)

    def with_overrides(self, **kw) -> "AxisRules":
        r = dict(self.rules)
        for k, v in kw.items():
            r[k] = tuple(v) if v else ()
        return AxisRules(r, self.mesh_axis_sizes, self.mesh)


def default_rules(mesh) -> AxisRules:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    return AxisRules(
        mesh=mesh,
        rules={
            "batch": batch_axes,
            # ZeRO-3 over every data-parallel axis (incl. pod): gradient
            # reductions lower to reduce-scatters into the shard instead of
            # full-tensor all-reduces, params all-gather bf16 on use.
            "fsdp": batch_axes,
            "heads": ("model",),
            "kv_heads": ("model",),
            "d_ff": ("model",),
            "vocab": ("model",),
            "embed_d": ("model",),
            "experts": ("model",),
            "seq": (),
            "seq_kv": ("model",),
            "d_model": (),
            "head_dim": (),
            "ssm_inner": ("model",),
            "ssm_state": (),
            "rnn_width": ("model",),
            "stack": (),          # scan-over-layers leading dim
        },
        mesh_axis_sizes=sizes,
    )


DEFAULT_RULES = default_rules  # alias: call with a mesh


def logical_to_spec(logical: tuple[str | None, ...], rules: AxisRules,
                    dims: tuple[int, ...] | None = None) -> P:
    """Map logical dim names to a PartitionSpec, dropping non-divisible axes."""
    out = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        axes = tuple(a for a in rules.rules.get(name, ()) if a not in used)
        if dims is not None and axes:
            size = 1
            for a in axes:
                size *= rules.mesh_axis_sizes.get(a, 1)
            if size == 0 or dims[i] % size != 0:
                axes = ()
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def shard_constraint(x, logical: tuple[str | None, ...], rules: AxisRules):
    """with_sharding_constraint by logical names (no-op outside a mesh ctx)."""
    try:
        spec = logical_to_spec(logical, rules, tuple(x.shape))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def spec_tree_for_params(logical_tree, rules: AxisRules, shape_tree):
    """Map a pytree of logical-name tuples + shapes to PartitionSpecs."""
    return jax.tree.map(
        lambda logical, shaped: logical_to_spec(tuple(logical), rules, tuple(shaped.shape)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def named_sharding_tree(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
