from .rules import (
    AxisRules,
    DEFAULT_RULES,
    logical_to_spec,
    shard_constraint,
    spec_tree_for_params,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "logical_to_spec",
    "shard_constraint",
    "spec_tree_for_params",
]
