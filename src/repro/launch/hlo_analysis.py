"""Trip-weighted analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts the module *statically*: a collective or
dot inside a ``lax.scan``/``while`` body is counted once even though it runs
trip-count times — useless for scan-over-layers models.  This module parses
the optimized HLO into its computation graph and weights every instruction by
the product of enclosing while-loop trip counts (recovered as the largest
integer constant in the loop-condition computation — the induction bound of
``i < N``; validated against models with known period counts).

Per instruction we account:

* **collectives** (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute): operand bytes (per-device data injected into the
  interconnect — shapes in the partitioned module are per-device) plus a
  ring-model estimate:
      all-gather:         (g−1) · operand
      reduce-scatter:     (g−1)/g · operand
      all-reduce:         2·(g−1)/g · operand
      all-to-all:         (g−1)/g · operand
      collective-permute: operand
  with `metadata op_name` kept for attribution.

* **dot FLOPs**: 2 · prod(result dims) · prod(lhs contracting dims) — inside
  fusions too (kOutput fusions execute their dots).

* **memory traffic**: operand + result bytes of top-level instructions
  (fusion internals excluded — a fusion's traffic is its boundary), skipping
  no-cost ops (parameter/constant/tuple/get-tuple-element/bitcast).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')

_NO_COST = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "domain", "partition-id", "replica-id", "iota"}
_COLL_KINDS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "reduce-scatter-start", "collective-permute-start"}


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_args(args_rest: str) -> tuple[str, str]:
    """Split 'a, b), attr=..., metadata=...' into (operands, rest)."""
    depth = 0
    for i, ch in enumerate(args_rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                return args_rest[:i], args_rest[i + 1:]
            depth -= 1
    return args_rest, ""


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: list[str]
    rest: str
    line: str


@dataclass
class Collective:
    kind: str
    operand_bytes: float
    result_bytes: int
    group_size: int
    weight: float = 1.0
    op_name: str = ""

    @property
    def ring_bytes(self) -> float:
        g = max(self.group_size, 1)
        if self.kind == "all-gather":
            per = (g - 1) * self.operand_bytes
        elif self.kind == "all-reduce":
            per = 2.0 * (g - 1) / g * self.operand_bytes
        elif self.kind in ("reduce-scatter", "all-to-all"):
            per = (g - 1) / g * self.operand_bytes
        else:
            per = float(self.operand_bytes)
        return per * self.weight

    @property
    def weighted_operand_bytes(self) -> float:
        return self.operand_bytes * self.weight


@dataclass
class _Computation:
    name: str
    is_entry: bool = False
    instrs: list[Instr] = field(default_factory=list)
    defs: dict[str, str] = field(default_factory=dict)  # name → result type


def _parse(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in text.splitlines():
        h = _COMP_HEAD_RE.match(line)
        if h:
            cur = _Computation(h.group(1), is_entry=line.startswith("ENTRY"))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_type, opcode, args_rest = m.groups()
        operands_str, rest = _split_args(args_rest)
        operands = [o.strip() for o in operands_str.split(",") if o.strip()]
        ins = Instr(name, opcode, result_type, operands, rest, line)
        cur.instrs.append(ins)
        cur.defs[name] = result_type
    return comps


class HloAnalysis:
    def __init__(self, text: str):
        self.comps = _parse(text)
        self._global_defs: dict[str, str] = {}
        for c in self.comps.values():
            for k, v in c.defs.items():
                self._global_defs.setdefault(k, v)
        self._fused: set[str] = set()
        self._trips: dict[str, int] = {}
        self._entry = None
        for c in self.comps.values():
            if c.is_entry:
                self._entry = c
            for ins in c.instrs:
                if ins.opcode == "fusion":
                    m = _CALLS_RE.search(ins.rest)
                    if m:
                        self._fused.add(m.group(1))
                for m in _TO_APPLY_RE.finditer(ins.rest):
                    self._fused.add(m.group(1))  # reducers: no independent cost
        if self._entry is None and self.comps:
            self._entry = list(self.comps.values())[-1]

        self.collectives: list[Collective] = []
        self.flops = 0.0
        self.traffic_bytes = 0.0
        self._visit_counts: dict[str, float] = {}
        if self._entry is not None:
            self._visit(self._entry, 1.0, frozenset(), top_level=True)

    # -- helpers -----------------------------------------------------------
    def _operand_type(self, comp: _Computation, op: str) -> str:
        if "[" in op:
            return op
        name = op.split(" ")[-1].lstrip("%")
        return comp.defs.get(name) or self._global_defs.get(name, "")

    def _trip_count(self, cond_name: str) -> int:
        if cond_name in self._trips:
            return self._trips[cond_name]
        comp = self.comps.get(cond_name)
        n = 1
        if comp is not None:
            consts = [int(c) for ins in comp.instrs
                      for c in _CONST_RE.findall(ins.line)]
            n = max(consts) if consts else 1
        self._trips[cond_name] = n
        return n

    def _dot_flops(self, comp: _Computation, ins: Instr) -> float:
        res_dims = _shape_dims(ins.result_type)
        out_elems = 1
        for _, dims in res_dims[:1]:
            for d in dims:
                out_elems *= d
        k = 1
        m = _LHS_CONTRACT_RE.search(ins.rest)
        if m and ins.operands:
            lhs_type = self._operand_type(comp, ins.operands[0])
            lhs_dims = _shape_dims(lhs_type)
            if lhs_dims:
                dims = lhs_dims[0][1]
                for idx_s in m.group(1).split(","):
                    if idx_s:
                        idx = int(idx_s)
                        if idx < len(dims):
                            k *= dims[idx]
        return 2.0 * out_elems * k

    def _sliced_bytes(self, type_str: str, body_trip: int) -> int:
        """Bytes moved for one loop iteration: scan-stacked buffers (leading
        dim == the enclosing body's trip count) are aliased in place — only
        one slice moves per iteration, not the whole stack."""
        total = 0
        for dtype, dims in _shape_dims(type_str):
            n = 1
            for d in dims:
                n *= d
            if body_trip > 1 and dims and dims[0] == body_trip:
                n //= body_trip
            total += n * _DTYPE_BYTES[dtype]
        return total

    # -- traversal -----------------------------------------------------------
    def _visit(self, comp: _Computation, weight: float, stack: frozenset,
               top_level: bool, body_trip: int = 0):
        if comp.name in stack:
            return
        self._visit_counts[comp.name] = self._visit_counts.get(comp.name, 0.0) + weight
        for ins in comp.instrs:
            opc = ins.opcode
            if opc in _COLL_KINDS:
                kind = opc.replace("-start", "")
                ob = sum(_shape_bytes(self._operand_type(comp, o))
                         for o in ins.operands)
                rb = _shape_bytes(ins.result_type)
                if ob == 0:
                    ob = rb
                gs = 0
                g = _GROUPS_BRACE_RE.search(ins.line)
                if g:
                    gs = len([x for x in g.group(1).split(",") if x.strip()])
                else:
                    g2 = _GROUPS_IOTA_RE.search(ins.line)
                    if g2:
                        gs = int(g2.group(2))
                op_name = ""
                mo = _OPNAME_RE.search(ins.line)
                if mo:
                    op_name = mo.group(1)
                self.collectives.append(
                    Collective(kind, ob, rb, gs, weight, op_name))
                self.traffic_bytes += weight * (ob + rb)
                continue
            if opc == "dot":
                self.flops += weight * self._dot_flops(comp, ins)
            if opc == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    callee = self.comps.get(m.group(1))
                    if callee is not None:
                        # fusions: dots inside execute per call; traffic is
                        # the fusion boundary (counted below).
                        self._visit_flops_only(callee, weight, stack)
            if opc == "while":
                m = _WHILE_ATTR_RE.search(ins.rest)
                if m:
                    trips = self._trip_count(m.group(1))
                    body = self.comps.get(m.group(2))
                    if body is not None:
                        self._visit(body, weight * trips,
                                    stack | {comp.name}, top_level=True,
                                    body_trip=trips)
            if opc in ("call", "async-start"):
                m = _TO_APPLY_RE.search(ins.rest) or _CALLS_RE.search(ins.rest)
                if m:
                    callee = self.comps.get(m.group(1))
                    if callee is not None:
                        self._visit(callee, weight, stack | {comp.name},
                                    top_level=True)
            if opc == "conditional":
                for m in re.finditer(r"%([\w.\-]+)", ins.rest):
                    callee = self.comps.get(m.group(1))
                    if callee is not None:
                        self._visit(callee, weight, stack | {comp.name},
                                    top_level=True)
            if top_level and opc not in _NO_COST:
                rb = self._sliced_bytes(ins.result_type, body_trip)
                if opc in ("dynamic-slice", "gather"):
                    # only the sliced/gathered bytes move, not the operand
                    self.traffic_bytes += weight * 2 * rb
                elif opc == "dynamic-update-slice":
                    upd = (self._sliced_bytes(self._operand_type(comp, ins.operands[1]), body_trip)
                           if len(ins.operands) > 1 else rb)
                    self.traffic_bytes += weight * 2 * upd
                elif opc == "scatter":
                    upd = (self._sliced_bytes(self._operand_type(comp, ins.operands[2]), body_trip)
                           if len(ins.operands) > 2 else rb)
                    self.traffic_bytes += weight * 2 * upd
                else:
                    ob = sum(self._sliced_bytes(self._operand_type(comp, o), body_trip)
                             for o in ins.operands)
                    self.traffic_bytes += weight * (ob + rb)

    def _visit_flops_only(self, comp: _Computation, weight: float, stack: frozenset):
        if comp.name in stack:
            return
        for ins in comp.instrs:
            if ins.opcode == "dot":
                self.flops += weight * self._dot_flops(comp, ins)
            if ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    callee = self.comps.get(m.group(1))
                    if callee is not None:
                        self._visit_flops_only(callee, weight, stack | {comp.name})


def analyze(hlo_text: str) -> "HloAnalysis":
    return HloAnalysis(hlo_text)


def analyze_jit(fn, *args, static: dict | None = None, **kwargs) -> "HloAnalysis":
    """Lower + compile a jitted callable on example arguments and analyze
    the optimized (post-fusion) HLO — the cost model behind the engine's
    bytes/key accounting and the CI byte-budget gate.

    ``fn`` must be a ``jax.jit`` product (anything with ``.lower``);
    ``static`` merges extra keyword arguments (e.g. the engine's static
    ``op=``) into the lowering call.
    """
    kw = dict(kwargs)
    if static:
        kw.update(static)
    compiled = fn.lower(*args, **kw).compile()
    return HloAnalysis(compiled.as_text())


def parse_collectives(hlo_text: str) -> list[Collective]:
    return HloAnalysis(hlo_text).collectives


def collective_summary(hlo_text: str, analysis: HloAnalysis | None = None) -> dict:
    a = analysis or HloAnalysis(hlo_text)
    colls = a.collectives
    by_kind: dict[str, dict] = {}
    for c in colls:
        d = by_kind.setdefault(c.kind, {"count": 0.0, "operand_bytes": 0.0,
                                        "ring_bytes": 0.0})
        d["count"] += c.weight
        d["operand_bytes"] += c.weighted_operand_bytes
        d["ring_bytes"] += c.ring_bytes
    by_op: dict[str, float] = {}
    for c in colls:
        key = "/".join(c.op_name.split("/")[-3:])[-100:] if c.op_name else "?"
        by_op[key] = by_op.get(key, 0.0) + c.ring_bytes
    top_ops = dict(sorted(by_op.items(), key=lambda kv: -kv[1])[:12])
    return {
        "total_operand_bytes": sum(c.weighted_operand_bytes for c in colls),
        "total_ring_bytes": sum(c.ring_bytes for c in colls),
        "count": sum(c.weight for c in colls),
        "static_count": len(colls),
        "by_kind": by_kind,
        "top_ring_bytes_by_op": top_ops,
    }
