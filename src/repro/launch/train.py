"""Training launcher.

On the CPU container this runs reduced configs end-to-end (data pipeline →
train loop → checkpoints); on real hardware the same entry point drives the
production mesh (the dry-run proves every (arch × shape) lowers for it).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 20 --batch 8 --seq-len 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer
from repro.configs import get_config, smoke_config
from repro.data import DataPipeline, ShardPlacement
from repro.models import LM
from repro.optim import cosine_schedule
from repro.train import TrainStepConfig, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--moe-impl", default="global", choices=["global", "local"])
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg, attn_chunk=min(args.seq_len, 512), moe_impl=args.moe_impl)
    state = init_state(model, jax.random.PRNGKey(0))
    n_params = model.param_count()
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params")

    step_fn = jax.jit(make_train_step(
        model, TrainStepConfig(lr=args.lr, microbatches=args.microbatches)))
    placement = ShardPlacement(num_shards=64, num_hosts=4)
    pipe = DataPipeline(placement, host=0, batch=args.batch,
                        seq_len=args.seq_len, vocab_size=cfg.vocab_size)
    ck = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step {step}: loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if ck and step and step % args.ckpt_every == 0:
            ck.save(state, step)
    if ck:
        ck.wait()
    tok_s = args.steps * args.batch * args.seq_len / (time.time() - t0)
    print(f"[train] done: {tok_s:.0f} tok/s on {jax.default_backend()}")
    return 0


if __name__ == "__main__":
    main()
