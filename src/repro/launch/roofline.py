"""Roofline terms from a dry-run record (TPU v5e targets).

    t_compute    = HLO_FLOPs_per_dev / 197e12        (bf16 MXU peak)
    t_memory     = HLO_bytes_per_dev / 819e9         (HBM bandwidth)
    t_collective = collective_bytes_per_dev / 50e9   (per-link ICI)

`MODEL_FLOPS` = 6·N_active·D for training (N = active params, D = tokens) or
2·N_active·D for serving; the ratio against total HLO FLOPs exposes
remat/padding/dispatch waste (brief §Roofline).
"""
from __future__ import annotations

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link


def model_flops(cfg, shp) -> float:
    """Global useful FLOPs for the step (6ND train / 2ND serve)."""
    n_active = cfg.active_param_count()
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n_active * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n_active * tokens
    tokens = shp.global_batch * 1  # decode: one new token per sequence
    return 2.0 * n_active * tokens


def roofline_record(cfg, shp, record: dict) -> dict:
    chips = record["chips"]
    flops_dev = record["cost_analysis"]["flops_per_device"]
    bytes_dev = record["cost_analysis"]["bytes_accessed_per_device"]
    coll_naive = record["collectives"]["total_operand_bytes"]
    coll_ring = record["collectives"]["total_ring_bytes"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll_naive = coll_naive / ICI_BW
    t_coll_ring = coll_ring / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll_ring}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shp)
    hlo_total = flops_dev * chips
    useful_ratio = mf / hlo_total if hlo_total else 0.0
    # step time ≈ max(terms) (perfect overlap); roofline fraction = share of
    # the step spent doing useful model math at peak.
    t_step = max(terms.values()) if terms else 0.0
    t_useful = mf / (chips * PEAK_FLOPS)
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective_naive": t_coll_naive,
        "t_collective_ring": t_coll_ring,
        "bottleneck": bottleneck,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_total,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": (t_useful / t_step) if t_step else 0.0,
    }
