"""Roofline terms from a dry-run record, against a per-backend hardware table.

    t_compute    = HLO_FLOPs_per_dev / peak_flops      (bf16 MXU / FMA peak)
    t_memory     = HLO_bytes_per_dev / mem_bw          (HBM / DRAM bandwidth)
    t_collective = collective_bytes_per_dev / link_bw  (per-link ICI / NVLink)

`MODEL_FLOPS` = 6·N_active·D for training (N = active params, D = tokens) or
2·N_active·D for serving; the ratio against total HLO FLOPs exposes
remat/padding/dispatch waste (brief §Roofline).

The constants live in :data:`HARDWARE`, keyed by a spec name; the process
default comes from :func:`detect_hardware` (the jax backend + device kind)
and can be forced with ``REPRO_ROOFLINE_HW=<spec name>`` — numbers computed
against the wrong machine's roofline are silently wrong, so every consumer
reports the spec name it used alongside its utilizations.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

HARDWARE_ENV = "REPRO_ROOFLINE_HW"


@dataclass(frozen=True)
class HardwareSpec:
    """Peak rates of one accelerator (per chip / per link)."""

    name: str
    peak_flops: float    # FLOP/s per chip (bf16 where the chip has an MXU)
    mem_bw: float        # bytes/s per chip (HBM / DRAM)
    link_bw: float       # bytes/s per inter-chip link (ICI / NVLink / PCIe)


#: spec name → peaks.  TPU numbers are per-chip bf16 + HBM + per-link ICI;
#: GPU numbers are per-GPU bf16 tensor-core + HBM + per-direction NVLink;
#: ``cpu-host`` is a deliberately round server-class placeholder (FMA peak,
#: DDR bandwidth, PCIe link) so off-TPU runs label utilizations against an
#: honest denominator instead of a v5e they are not running on.
HARDWARE: dict[str, HardwareSpec] = {
    "tpu-v5e":  HardwareSpec("tpu-v5e",  197e12, 819e9, 50e9),
    "tpu-v4":   HardwareSpec("tpu-v4",   275e12, 1228e9, 50e9),
    "tpu-v5p":  HardwareSpec("tpu-v5p",  459e12, 2765e9, 100e9),
    "gpu-a100": HardwareSpec("gpu-a100", 312e12, 2039e9, 300e9),
    "gpu-h100": HardwareSpec("gpu-h100", 989e12, 3350e9, 450e9),
    "cpu-host": HardwareSpec("cpu-host", 1e12,   100e9,  32e9),
}

# legacy module constants (v5e): kept for the dry-run launch path, which
# models v5e pods regardless of where the dry run itself executes.
PEAK_FLOPS = HARDWARE["tpu-v5e"].peak_flops
HBM_BW = HARDWARE["tpu-v5e"].mem_bw
ICI_BW = HARDWARE["tpu-v5e"].link_bw


def detect_hardware() -> str:
    """Map the live jax backend to a :data:`HARDWARE` spec name.

    ``REPRO_ROOFLINE_HW`` overrides detection (it must name a known spec);
    unknown device kinds fall back to the family default (v5e for TPU,
    a100 for GPU) — the spec *name* travels with every record, so a
    fallback is visible, never silent.
    """
    forced = os.environ.get(HARDWARE_ENV)
    if forced:
        if forced not in HARDWARE:
            raise ValueError(f"{HARDWARE_ENV}={forced!r} is not one of "
                             f"{sorted(HARDWARE)}")
        return forced
    import jax

    backend = jax.default_backend()
    if backend == "cpu":
        return "cpu-host"
    kind = jax.devices()[0].device_kind.lower()
    if backend == "tpu":
        for name in ("tpu-v5p", "tpu-v5e", "tpu-v4"):
            if name.split("-")[1] in kind:
                return name
        return "tpu-v5e"
    if backend == "gpu":
        return "gpu-h100" if "h100" in kind else "gpu-a100"
    return "cpu-host"


def hardware_spec(name: str | None = None) -> HardwareSpec:
    """The spec to compute rooflines against: ``name``, the env override,
    or the detected backend's."""
    return HARDWARE[name or detect_hardware()]


def model_flops(cfg, shp) -> float:
    """Global useful FLOPs for the step (6ND train / 2ND serve)."""
    n_active = cfg.active_param_count()
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n_active * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n_active * tokens
    tokens = shp.global_batch * 1  # decode: one new token per sequence
    return 2.0 * n_active * tokens


def lookup_roofline(traffic_bytes: float, flops: float, n_keys: int,
                    measured_s: float | None = None,
                    hw: HardwareSpec | str | None = None) -> dict:
    """Roofline accounting for one engine lookup program.

    ``traffic_bytes``/``flops`` come from the HLO cost analysis
    (:func:`repro.launch.hlo_analysis.analyze_jit`); ``measured_s`` is an
    optional wall-clock for the same batch, turning the bound into a
    utilization.  Returns bytes/key, the memory- and compute-bound floor
    times, the bottleneck, and — when measured — the fraction of the
    bound actually achieved (1.0 = running at the roofline).
    """
    if not isinstance(hw, HardwareSpec):
        hw = hardware_spec(hw)
    t_memory = traffic_bytes / hw.mem_bw
    t_compute = flops / hw.peak_flops
    t_bound = max(t_memory, t_compute)
    out = {
        "hardware": hw.name,
        "bytes_per_key": traffic_bytes / n_keys if n_keys else 0.0,
        "flops_per_key": flops / n_keys if n_keys else 0.0,
        "t_memory_s": t_memory,
        "t_compute_s": t_compute,
        "bottleneck": "memory" if t_memory >= t_compute else "compute",
    }
    if measured_s is not None:
        out["measured_s"] = measured_s
        out["roofline_utilization"] = (t_bound / measured_s
                                       if measured_s > 0 else 0.0)
    return out


def roofline_record(cfg, shp, record: dict) -> dict:
    chips = record["chips"]
    flops_dev = record["cost_analysis"]["flops_per_device"]
    bytes_dev = record["cost_analysis"]["bytes_accessed_per_device"]
    coll_naive = record["collectives"]["total_operand_bytes"]
    coll_ring = record["collectives"]["total_ring_bytes"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll_naive = coll_naive / ICI_BW
    t_coll_ring = coll_ring / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll_ring}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shp)
    hlo_total = flops_dev * chips
    useful_ratio = mf / hlo_total if hlo_total else 0.0
    # step time ≈ max(terms) (perfect overlap); roofline fraction = share of
    # the step spent doing useful model math at peak.
    t_step = max(terms.values()) if terms else 0.0
    t_useful = mf / (chips * PEAK_FLOPS)
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective_naive": t_coll_naive,
        "t_collective_ring": t_coll_ring,
        "bottleneck": bottleneck,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_total,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": (t_useful / t_step) if t_step else 0.0,
    }
