import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we jit the real step function (train_step / prefill / decode)
with production shardings against ShapeDtypeStruct inputs (no allocation),
``.lower().compile()`` it for the 256-chip single-pod mesh and the 512-chip
2-pod mesh, and record:

  * ``memory_analysis()``  — per-device bytes (proves the cell fits 16 GB HBM),
  * ``cost_analysis()``    — per-device FLOPs / bytes-accessed,
  * collective schedule    — parsed from the partitioned HLO, while-loop
                             trip-count weighted (launch/hlo_analysis.py),
  * the three roofline terms (launch/roofline.py).

Results are cached as JSON under benchmarks/results/dryrun/ so the sweep is
resumable; ``--force`` recomputes.

Examples:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import LONG_CONTEXT_ARCHS, ARCHS, SHAPES, get_config
from repro.launch.hlo_analysis import collective_summary
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_record
from repro.models import LM
from repro.serve.step import (decode_cache_specs, decode_shapes, decode_specs,
                              make_decode_step, make_prefill_step,
                              prefill_shapes, prefill_specs)
from repro.sharding.rules import default_rules
from repro.train.step import (TrainStepConfig, batch_shapes, batch_specs,
                              make_train_step, state_shapes, state_specs)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# train_4k microbatch counts: keep live activations + remat boundaries < HBM
MICROBATCHES = {"llava-next-34b": 16, "qwen2.5-14b": 8, "gemma3-12b": 8,
                "phi3.5-moe-42b-a6.6b": 8, "recurrentgemma-9b": 8}
DEFAULT_MICROBATCHES = 4


def _ns(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def parse_overrides(items):
    out = {}
    for it in items or []:
        k, _, v = it.partition("=")
        out[k] = tuple(a for a in v.split("+") if a) if v else ()
    return out


def lower_cell(arch: str, shape_name: str, mesh_kind: str, *,
               attn_chunk=512, microbatches=None, remat="full",
               overrides=None, moe_impl="global", cache_dtype="bfloat16",
               verbose=True):
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = default_rules(mesh)
    if overrides:
        rules = rules.with_overrides(**overrides)

    model = LM(cfg, attn_chunk=attn_chunk, remat=remat, rules=rules,
               moe_impl=moe_impl, cache_dtype=cache_dtype)
    t0 = time.time()

    if shp.kind == "train":
        nmb = microbatches or MICROBATCHES.get(arch, DEFAULT_MICROBATCHES)
        batch_ways = 1
        for a in rules.rules.get("batch", ()):
            batch_ways *= rules.mesh_axis_sizes.get(a, 1)
        # keep the per-microbatch batch divisible by the batch sharding —
        # otherwise activations silently replicate (measured 4.5× worse)
        nmb = max(1, min(nmb, shp.global_batch // max(batch_ways, 1)))
        step = make_train_step(model, TrainStepConfig(microbatches=nmb), rules=rules)
        in_shapes = (state_shapes(model), batch_shapes(cfg, shp.global_batch, shp.seq_len))
        in_specs = (state_specs(model, rules),
                    batch_specs(cfg, rules, shp.global_batch, shp.seq_len))
        out_specs = (in_specs[0], None)
        jitted = jax.jit(step,
                         in_shardings=_ns(in_specs, mesh),
                         out_shardings=(_ns(out_specs[0], mesh), None),
                         donate_argnums=(0,))
    elif shp.kind == "decode":
        step = make_decode_step(model)
        in_shapes = decode_shapes(model, shp.global_batch, shp.seq_len)
        pspec, _, tokspec, posspec = decode_specs(model, rules, shp.global_batch)
        cspec = decode_cache_specs(model, shp.global_batch, shp.seq_len, rules)
        in_specs = (pspec, cspec, tokspec, posspec)
        jitted = jax.jit(step,
                         in_shardings=_ns(in_specs, mesh),
                         out_shardings=(_ns(cspec, mesh), None),
                         donate_argnums=(1,))
    elif shp.kind == "prefill":
        step = make_prefill_step(model)
        in_shapes = prefill_shapes(model, shp.global_batch, shp.seq_len)
        in_specs = prefill_specs(model, rules, shp.global_batch, shp.seq_len)
        jitted = jax.jit(step, in_shardings=_ns(in_specs, mesh))
    else:
        raise ValueError(shp.kind)

    with mesh:
        lowered = jitted.lower(*in_shapes)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze
    hla = analyze(hlo)
    colls = collective_summary(hlo, hla)

    mem = {}
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "peak_memory_in_bytes",
                  "alias_size_in_bytes", "generated_code_size_in_bytes"):
            mem[f] = int(getattr(ma, f, 0) or 0)

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": int(mesh.devices.size),
        "compile_seconds": round(compile_s, 1),
        "memory_analysis": mem,
        "cost_analysis": {
            # trip-weighted (scan bodies × trip count) — see hlo_analysis.py
            "flops_per_device": float(hla.flops),
            "bytes_accessed_per_device": float(hla.traffic_bytes),
            # raw XLA statics for cross-checking (undercount scanned models)
            "xla_static_flops": float(ca.get("flops", 0.0)),
            "xla_static_bytes": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": colls,
        "settings": {"attn_chunk": attn_chunk, "remat": remat,
                     "moe_impl": moe_impl, "cache_dtype": cache_dtype,
                     "microbatches": nmb if shp.kind == "train" else None,
                     "overrides": {k: list(v) for k, v in (overrides or {}).items()}},
    }
    record["roofline"] = roofline_record(cfg, shp, record)
    if verbose:
        r = record["roofline"]
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: compile {compile_s:.0f}s  "
              f"peak {mem.get('peak_memory_in_bytes', 0)/2**30:.2f} GiB/dev  "
              f"t_comp {r['t_compute']:.2e}s t_mem {r['t_memory']:.2e}s "
              f"t_coll {r['t_collective_ring']:.2e}s → {r['bottleneck']}", flush=True)
    return record


def cells(mesh_kinds):
    for arch in sorted(ARCHS):
        for shape_name in SHAPES:
            if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            for mk in mesh_kinds:
                yield arch, shape_name, mk


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default="full", choices=["full", "names", "none"])
    ap.add_argument("--moe-impl", default="global", choices=["global", "local"])
    ap.add_argument("--cache-dtype", default="bfloat16", choices=["bfloat16", "int8"])
    ap.add_argument("--override", action="append",
                    help="sharding rule override, e.g. --override seq_kv=model")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        todo = list(cells(mesh_kinds))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape, mk) for mk in mesh_kinds]

    failures = []
    for arch, shape_name, mk in todo:
        path = out_dir / f"{arch}__{shape_name}__{mk}__{args.variant}.json"
        if path.exists() and not args.force:
            print(f"[dryrun] cached: {path.name}", flush=True)
            continue
        try:
            rec = lower_cell(arch, shape_name, mk,
                             attn_chunk=args.attn_chunk,
                             microbatches=args.microbatches,
                             remat=args.remat,
                             moe_impl=args.moe_impl,
                             cache_dtype=args.cache_dtype,
                             overrides=parse_overrides(args.override))
            path.write_text(json.dumps(rec, indent=1))
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            failures.append((arch, shape_name, mk, repr(e)))
            print(f"[dryrun] FAILED {arch} × {shape_name} × {mk}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} failures:", flush=True)
        for f in failures:
            print("   ", f, flush=True)
        raise SystemExit(1)
    print("[dryrun] all cells OK", flush=True)


if __name__ == "__main__":
    main()
