"""Serving launcher: replica fleet + Memento session router.

Thin CLI over the end-to-end driver in ``examples/serve_cluster.py`` —
spins up R replicas of a (smoke) model, routes batched requests with the
Memento session router, optionally kills a replica mid-run, and reports
throughput + cache-affinity/minimal-disruption accounting.

    PYTHONPATH=src python -m repro.launch.serve --replicas 4 --sessions 24 \
        --rounds 6 --fail-at 3 [--cache-dtype int8]
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[3] / "examples"))


def main(argv=None):
    from serve_cluster import main as drive
    return drive(argv)


if __name__ == "__main__":
    main()
