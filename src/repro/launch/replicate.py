"""Cross-process delta replication: one membership owner, N converging
followers (DESIGN.md §9.3, §9.5–§9.7).

MementoHash's control plane is a bounded per-event delta log
(:class:`~repro.core.protocol.DeltaEmitter`).  This module ships that log
across process boundaries: the **leader** process owns the host
``ConsistentHash`` state and publishes each epoch advance as a flat int32
**frame**; **followers** hold no host state at all — just a
:class:`FollowerImageStore` replaying frames into an on-device
:class:`~repro.core.protocol.DeviceImage` with the same out-of-place
scatter code (:func:`repro.kernels.delta_apply.apply_updates`) the leader's
own :class:`~repro.core.DeviceImageStore` runs.  Because both sides apply
identical words in identical epoch order, followers converge to
**bit-identical** images (every word a lookup can gather —
:func:`~repro.core.protocol.image_fingerprint`) and equal epochs.

Frames come in four kinds (DESIGN.md §9.6–§9.7):

  * ``DELTA``           — O(changed-words): scatter (index, value) pairs
    per named array + the new dynamic scalars, epoch-chained onto the
    follower's current epoch;
  * ``DELTA_BATCH``     — the same wire layout covering a RANGE of epochs
    ``(base, epoch]``: the publisher composes N pending epochs
    last-write-wins into one frame, so a 100-event storm burst ships as
    one frame instead of 100;
  * ``SNAPSHOT``        — the full padded dense arrays, sent when the
    delta log no longer covers the published epoch or when growth outruns
    the published capacity (the publisher tracks the capacity it last
    announced, so the leader — not each follower — decides when a
    snapshot is due and every follower takes the same path);
  * ``SNAPSHOT_PACKED`` — the §8.2 compact layout (Memento bitmap + slot
    table, dtype-narrowed Anchor) shipped directly: Θ(n/8 + r) wire bytes
    instead of Θ(4n), and the follower installs it without a dense decode.

Every frame carries a CRC32 integrity word in its header; corrupted or
truncated frames are rejected before any word reaches ``apply_updates``.

Fan-out is topology-pluggable: the flat leader→all broadcast costs the
leader O(F) sends per publish; :class:`TreeTopology` relays verbatim
frames through interior followers (d-ary heap order), dropping the leader
to O(arity) while every node still applies the identical byte stream —
the relay invariant (DESIGN.md §9.5).  A lagging or newly-joined follower
does not stall the stream: :meth:`DeltaPublisher.catchup_frames` serves a
targeted pull — a composed ``DELTA_BATCH`` from the published-frame log
when it still covers the follower's epoch, else a snapshot at the
capacities the stream already announced — landing it exactly on the
published cursor (leader-decides preserved).

Transport is pluggable: :class:`LoopbackChannel` replicates in-process
(the sim driver's follower mode and the unit tests);
:class:`DistributedBroadcast` rides two
``multihost_utils.broadcast_one_to_all`` collectives per round over the
``jax.distributed`` mesh that :func:`repro.launch.mesh.init_distributed`
joins (gloo on CPU, ICI on TPU), and :class:`TreeBroadcast` runs one such
round per interior tree node so real processes relay instead of the
leader paying every send.  Frames are plain ``np.int32`` vectors either
way, so a transport is just "move this vector".
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

from repro.core.image_store import delta_fits
from repro.core.packing import PACKED_LAYOUT, pack_image
from repro.core.protocol import (ALGORITHM_REGISTRY, ALGORITHMS,
                                 IMAGE_LAYOUT, DeviceImage, ImageDelta,
                                 image_fingerprint, round_up)
from repro.obs.metrics import default_registry as _default_obs
from repro.obs.metrics import ensure_real

#: frame type tags
KIND_DELTA = 1
KIND_SNAPSHOT = 2
KIND_DELTA_BATCH = 3
KIND_SNAPSHOT_PACKED = 4

_DELTA_KINDS = (KIND_DELTA, KIND_DELTA_BATCH)
_SNAPSHOT_KINDS = (KIND_SNAPSHOT, KIND_SNAPSHOT_PACKED)

_MAGIC = 0x4D454D30  # "MEM0", truncated to int32 range
# wire algo ids ARE registry order — the registry is append-only, so ids
# stay stable across releases (memento=0, anchor=1, dx=2, jump=3, power=4)
_ALGO_IDS = {name: i for i, name in enumerate(ALGORITHMS)}
_ALGO_NAMES = {v: k for k, v in _ALGO_IDS.items()}

#: wire dtype enum for snapshot blocks (packed layouts narrow below int32)
_DTYPES = {0: np.dtype(np.int32), 1: np.dtype(np.uint32),
           2: np.dtype(np.int16), 3: np.dtype(np.int8)}
_DTYPE_IDS = {v: k for k, v in _DTYPES.items()}

#: header flag bits
_FLAG_PACKED = 1


def _array_names(algo: str, packed: bool = False) -> list[str]:
    """Canonical array-name table for the wire: layout tables + the
    bounded-load overlay word array (name_id = position).  Packed frames
    index the packed layout's table names instead of the dense ones."""
    layout = PACKED_LAYOUT if packed else IMAGE_LAYOUT
    return list(layout[algo][1]) + ["load"]


def _scalar_names(algo: str) -> tuple[str, ...]:
    return IMAGE_LAYOUT[algo][0]


# -- wire format --------------------------------------------------------------
# frame = [MAGIC, kind, algo_id, base_epoch, epoch, n, n_extra_scalars,
#          n_blocks, flags, crc, extra_scalars..., blocks...]    (all int32)
# DELTA/DELTA_BATCH block: [name_id, count,  idx[count], vals[count]]
# SNAPSHOT block: [name_id, length, dtype, nwords,  words[nwords]]
#   dtype: 0=i32 1=u32 2=i16 3=i8 (narrow arrays are byte-padded to 4-byte
#   multiples and shipped as int32 words)
# flags: bit 0 = packed layout (name_ids index PACKED_LAYOUT tables).
# crc: CRC32 of the whole frame with the crc word zeroed — the integrity
#   gate decode_frame checks before any word can reach apply_updates.
_HDR = 10
_CRC_SLOT = 9


def stamp_crc(frame: np.ndarray) -> np.ndarray:
    """Stamp the header CRC32 word in place (and return the frame).

    Public so tests that deliberately tamper with header fields can
    re-stamp and reach the check they target instead of tripping the CRC.
    """
    frame[_CRC_SLOT] = 0
    crc = zlib.crc32(frame.tobytes()) & 0xFFFFFFFF
    frame[_CRC_SLOT] = np.array([crc], np.uint32).view(np.int32)[0]
    return frame


def _check_crc(buf: np.ndarray) -> None:
    stored = int(np.array([buf[_CRC_SLOT]], np.int32).view(np.uint32)[0])
    clean = buf.copy()
    clean[_CRC_SLOT] = 0
    if (zlib.crc32(clean.tobytes()) & 0xFFFFFFFF) != stored:
        raise ValueError("frame CRC mismatch (corrupt or truncated frame)")


def _wire_words(arr: np.ndarray) -> tuple[np.ndarray, int, int]:
    """(int32 words, dtype id, element length) for a snapshot block."""
    arr = np.ascontiguousarray(arr)
    dt = _DTYPE_IDS.get(arr.dtype)
    if dt is None:
        raise ValueError(f"array dtype {arr.dtype} has no wire encoding")
    raw = arr.tobytes()
    if len(raw) % 4:
        raw += b"\0" * (4 - len(raw) % 4)
    return np.frombuffer(raw, np.int32), dt, arr.shape[0]


def encode_delta(delta: ImageDelta, *, packed: bool = False) -> np.ndarray:
    """Delta → one flat int32 frame (O(changed-words)).

    A single-epoch delta ships as ``DELTA``; a multi-epoch composition
    (``delta.events > 1``) as ``DELTA_BATCH`` — same block layout, the
    epoch-range header is what tells a follower it may land several epochs
    in one apply.  ``packed=True`` stamps the packed-layout flag: the
    update names index the §8.2 packed tables.
    """
    scal = [int(delta.scalars[s]) for s in _scalar_names(delta.algo)[1:]]
    names = _array_names(delta.algo, packed)
    body: list[np.ndarray] = []
    blocks = 0
    for name, (idx, vals) in sorted(delta.updates.items()):
        if not len(idx):
            continue
        blocks += 1
        head = np.asarray([names.index(name), len(idx)], np.int32)
        body += [head, np.asarray(idx, np.int32),
                 np.asarray(vals).astype(np.int64).astype(np.int32)]
    kind = KIND_DELTA_BATCH if delta.events > 1 else KIND_DELTA
    flags = _FLAG_PACKED if packed else 0
    hdr = np.asarray([_MAGIC, kind, _ALGO_IDS[delta.algo],
                      delta.base_epoch, delta.epoch, delta.n,
                      len(scal), blocks, flags, 0] + scal, np.int32)
    return stamp_crc(np.concatenate([hdr] + body) if body else hdr)


def encode_snapshot(image: DeviceImage) -> np.ndarray:
    """Full (padded) image → one flat int32 frame.

    Dense images ship as ``SNAPSHOT``; packed (§8.2) images ship their
    bitmap + slot tables directly as ``SNAPSHOT_PACKED`` — Θ(n/8 + r)
    wire bytes instead of Θ(4n), installed by a compact follower with no
    dense decode.  Narrow dtypes ride the block dtype tag.
    """
    scal = [int(image.scalars[s]) for s in _scalar_names(image.algo)[1:]]
    names = _array_names(image.algo, image.packed)
    body: list[np.ndarray] = []
    blocks = 0
    for name in sorted(image.arrays):
        words, dt, length = _wire_words(np.asarray(image.arrays[name]))
        blocks += 1
        body += [np.asarray([names.index(name), length, dt, len(words)],
                            np.int32), words]
    kind = KIND_SNAPSHOT_PACKED if image.packed else KIND_SNAPSHOT
    flags = _FLAG_PACKED if image.packed else 0
    hdr = np.asarray([_MAGIC, kind, _ALGO_IDS[image.algo],
                      0, image.epoch, image.n,
                      len(scal), blocks, flags, 0] + scal, np.int32)
    return stamp_crc(np.concatenate([hdr] + body))


@dataclass
class Frame:
    """A decoded (CRC-verified) replication frame."""

    kind: int
    algo: str
    base_epoch: int
    epoch: int
    n: int
    scalars: dict[str, int]
    # DELTA/DELTA_BATCH: name → (idx, vals); SNAPSHOT*: name → np array
    updates: dict
    arrays: dict
    packed: bool = False


def decode_frame(buf: np.ndarray) -> Frame:
    buf = np.asarray(buf, np.int32)
    if len(buf) < _HDR or buf[0] != _MAGIC:
        raise ValueError("not a replication frame")
    _check_crc(buf)
    kind, algo_id = int(buf[1]), int(buf[2])
    if kind not in _DELTA_KINDS + _SNAPSHOT_KINDS:
        raise ValueError(f"unknown frame kind {kind}")
    if algo_id not in _ALGO_NAMES:
        raise ValueError(f"unknown wire algo id {algo_id} "
                         f"(this build knows 0..{len(_ALGO_NAMES) - 1})")
    algo = _ALGO_NAMES[algo_id]
    base_epoch, epoch, n = int(buf[3]), int(buf[4]), int(buf[5])
    n_scal, n_blocks = int(buf[6]), int(buf[7])
    packed = bool(int(buf[8]) & _FLAG_PACKED)
    scal_names = _scalar_names(algo)[1:]
    scalars = {scal_names[i]: int(buf[_HDR + i]) for i in range(n_scal)}
    names = _array_names(algo, packed)
    pos = _HDR + n_scal
    updates: dict = {}
    arrays: dict = {}
    for _ in range(n_blocks):
        if kind in _DELTA_KINDS:
            name, count = names[int(buf[pos])], int(buf[pos + 1])
            pos += 2
            idx = np.array(buf[pos: pos + count], np.int32)
            vals = np.array(buf[pos + count: pos + 2 * count], np.int32)
            pos += 2 * count
            updates[name] = (idx, vals)
        else:
            name, length, dt, nwords = (names[int(buf[pos])],
                                        int(buf[pos + 1]), int(buf[pos + 2]),
                                        int(buf[pos + 3]))
            pos += 4
            dtype = _DTYPES[dt]
            raw = np.ascontiguousarray(buf[pos: pos + nwords]).tobytes()
            arrays[name] = np.frombuffer(
                raw[: length * dtype.itemsize], dtype).copy()
            pos += nwords
    if pos != len(buf):
        raise ValueError(f"trailing bytes in frame ({pos} != {len(buf)})")
    return Frame(kind=kind, algo=algo, base_epoch=base_epoch, epoch=epoch,
                 n=n, scalars=scalars, updates=updates, arrays=arrays,
                 packed=packed)


def _peek_kind(buf) -> int:
    return int(np.asarray(buf, np.int32)[1])


def _peek_base(buf) -> int:
    return int(np.asarray(buf, np.int32)[3])


# -- leader side --------------------------------------------------------------
class DeltaPublisher:
    """Leader-side cursor over the host state's bounded delta log.

    ``frames()`` returns the frames that advance followers from the last
    published epoch to the host's current one.  ``batch_epochs`` shapes
    the stream: 0 (default) composes ALL pending epochs into one
    ``DELTA_BATCH`` per call, 1 ships one ``DELTA`` per epoch (the dense
    per-epoch baseline the wire benchmark measures against), N chunks the
    pending range into batches of ≤ N epochs.  ``packed=True`` keeps a
    host-side numpy mirror of the §8.2 packed arrays and translates every
    dense delta into packed-layout scatters
    (:func:`repro.core.packing.packed_delta_updates`), so snapshots ship
    as ``SNAPSHOT_PACKED`` and deltas edit the follower's packed tables
    directly.  A SNAPSHOT frame goes out on first publish, on log
    overflow, when growth outruns the capacity the last snapshot announced
    (:func:`repro.core.image_store.delta_fits` — the same predicate the
    leader's own store runs), or when the packed mirror can no longer
    absorb a delta in place.  The publisher (not each follower) makes the
    snapshot-vs-delta decision, so every subscriber replays the identical
    frame sequence — the invariant behind bit-identical convergence.

    Published delta frames are remembered in a bounded log of decoded
    payloads; :meth:`catchup_frames` composes that log into ONE targeted
    ``DELTA_BATCH`` for a lagging follower (or falls back to a snapshot at
    the announced capacities), landing it exactly on the published cursor.
    """

    _CATCHUP_LOG_CAP = 512

    def __init__(self, ch, *, headroom: int = 2, batch_epochs: int = 0,
                 packed: bool = False, registry=None):
        self._ch = ch
        self._registry = registry  # None → follow the process default
        self.headroom = max(1, headroom)
        self.batch_epochs = max(0, int(batch_epochs))
        self.packed = bool(packed)
        self._epoch: int | None = None  # nothing published yet
        self._caps: dict[str, int] = {}  # capacities the last snapshot shipped
        self._snap_cap: int | None = None  # dense capacity last announced
        self._mirror: dict[str, np.ndarray] | None = None
        # published-but-not-snapshotted delta payloads, oldest first:
        # (base, epoch, wire updates, n, scalars) — catch-up composition.
        self._log: list[tuple] = []

    def _obs(self):
        """The live telemetry registry (injected, else process default)."""
        return self._registry or _default_obs()

    @property
    def published_epoch(self) -> int | None:  # obs-exempt: pure accessor
        return self._epoch

    @property
    def _algo(self) -> str:
        return getattr(self._ch, "image_algo", self._ch.name)

    def _snapshot_frame(self) -> np.ndarray:
        """Build, announce, and encode a stream snapshot (resets the
        capacity announcement, the packed mirror, and the catch-up log)."""
        algo = self._algo
        if not ALGORITHM_REGISTRY[algo].fixed_capacity:  # growable: same
            cap = round_up(max(self.headroom * self._ch.size, 128))  # headroom
        else:                                            # rule as the store
            cap = None
        img = self._ch.device_image(capacity=cap)
        if self.packed:
            # slot headroom 2 → ≤ 0.25 load factor, same as the leader
            # store's compact mode, so stream deltas insert in place.
            img = pack_image(img, slot_headroom=2)
            self._mirror = {k: np.array(v) for k, v in img.arrays.items()}
        self._caps = {k: int(np.asarray(v).shape[0])
                      for k, v in img.arrays.items()}
        self._snap_cap = cap
        self._epoch = img.epoch
        self._log.clear()
        return encode_snapshot(img)

    def _range_delta(self, base: int, until: int) -> ImageDelta | None:
        if hasattr(self._ch, "device_delta_range"):
            return self._ch.device_delta_range(base, until)
        if until == getattr(self._ch, "epoch", None):  # non-range emitter
            return self._ch.device_delta(base)
        return None

    def frames(self) -> list[np.ndarray]:
        """Frames advancing subscribers to the current host epoch
        (empty when already published)."""
        reg = self._obs()
        with reg.span("repl.encode"):
            out = self._encode_frames()
        if reg.active and out:
            for buf in out:
                kind = ("snapshot" if _peek_kind(buf) in _SNAPSHOT_KINDS
                        else "delta")
                reg.counter("repl.frames_encoded", kind=kind).inc()
        return out

    def _encode_frames(self) -> list[np.ndarray]:
        cur = getattr(self._ch, "epoch", None)
        if self._epoch is None:
            return [self._snapshot_frame()]
        if cur is None or cur == self._epoch:
            return []
        out: list[np.ndarray] = []
        base = self._epoch
        step = self.batch_epochs or (cur - base)
        while base < cur:
            until = min(base + step, cur)
            delta = self._range_delta(base, until)
            if delta is None or not delta_fits(self._caps, delta,
                                               compact=self.packed):
                return [self._snapshot_frame()]  # leader-decides fallback
            if self.packed:
                from repro.core.packing import packed_delta_updates

                updates = packed_delta_updates(self._mirror, delta)
                if updates is None:  # slots/bitmap/dtype outgrown: repack
                    return [self._snapshot_frame()]
                wire = ImageDelta(algo=delta.algo, base_epoch=base,
                                  epoch=until, n=delta.n, updates=updates,
                                  scalars=dict(delta.scalars))
            else:
                wire = delta
            out.append(encode_delta(wire, packed=self.packed))
            self._log.append((base, until, wire.updates, wire.n,
                              dict(wire.scalars)))
            if len(self._log) > self._CATCHUP_LOG_CAP:
                del self._log[: len(self._log) // 2]
            self._epoch = until
            base = until
        return out

    # -- targeted catch-up (the pull path, DESIGN.md §9.7) ---------------------
    def catchup_frames(self, follower_epoch: int) -> list[np.ndarray]:
        """Frames landing a follower at ``follower_epoch`` exactly on the
        published cursor: a composed ``DELTA_BATCH`` when the published
        frame log still chains from that epoch (O(changed-words)), else a
        snapshot at the ANNOUNCED capacities — never a fresh announcement,
        so the stream's in-flight deltas keep fitting on every subscriber.
        """
        if self._epoch is None:
            raise ValueError("nothing published yet (no cursor to target)")
        cur = getattr(self._ch, "epoch", None)
        if cur is not None and cur != self._epoch:
            raise ValueError("pending epochs unpublished: publish the "
                             "stream (frames()) before serving catch-up")
        if follower_epoch == self._epoch:
            return []
        if follower_epoch > self._epoch:
            raise ValueError(f"follower epoch {follower_epoch} is ahead of "
                             f"the published cursor {self._epoch}")
        self._obs().counter("repl.catchup_serves").inc()
        start = next((i for i, ent in enumerate(self._log)
                      if ent[0] == follower_epoch), None)
        if start is not None:
            from repro.kernels.delta_apply import compose_updates

            tail = self._log[start:]
            updates = compose_updates(u for _b, _e, u, _n, _s in tail)
            _b, until, _u, n, scalars = tail[-1]
            wire = ImageDelta(algo=self._algo, base_epoch=follower_epoch,
                              epoch=until, n=n, updates=updates,
                              scalars=dict(scalars))
            return [encode_delta(wire, packed=self.packed)]
        return [self._catchup_snapshot()]

    def _catchup_snapshot(self) -> np.ndarray:
        """Targeted snapshot at the published cursor and announced
        capacities.  Packed mode ships the MIRROR arrays verbatim — the
        slot table's probe layout is history-dependent (tombstones), so a
        fresh repack would diverge from what stream followers hold and
        later slot-position writes would land wrong; the mirror IS the
        byte-exact state every up-to-date follower has."""
        algo = self._algo
        if self.packed and self._mirror is not None:
            ref = self._ch.device_delta(self._epoch)  # empty: n + scalars
            img = DeviceImage(
                algo=algo, n=ref.n,
                arrays={k: v.copy() for k, v in self._mirror.items()},
                scalars=dict(ref.scalars), epoch=self._epoch, packed=True)
            return encode_snapshot(img)
        cap = (None if ALGORITHM_REGISTRY[algo].fixed_capacity
               else self._snap_cap)
        return encode_snapshot(self._ch.device_image(capacity=cap))


# -- follower side ------------------------------------------------------------
class FollowerImageStore:
    """Device image replica driven purely by replication frames.

    Holds no host ``ConsistentHash`` state: SNAPSHOT frames install a fresh
    device image (``SNAPSHOT_PACKED`` installs the §8.2 compact layout with
    no dense decode), DELTA/DELTA_BATCH frames scatter onto the current one
    through the same :func:`~repro.kernels.delta_apply.apply_updates` the
    leader store uses — out of place, with an atomic flip, so in-flight
    lookups stay epoch-consistent here too.

    :meth:`apply_frames` is the drain entry point: it reorders a drained
    batch (snapshot-first, then deltas by base epoch), skips frames made
    stale by a newer snapshot or an earlier catch-up (idempotent
    redelivery), verifies the survivors chain gap-free, and lands them as
    ONE composed scatter — a single device dispatch per drain, however many
    epochs arrived.  ``fingerprint()`` is canonical: packed replicas hash
    their dense equivalent, so a compact follower and a dense leader
    compare equal iff their lookups are bit-identical (the convergence
    gate).

    ``compact`` asserts the expected wire layout (``True`` = packed frames
    only, ``False`` = dense only, ``None`` = accept whatever the leader
    decides).
    """

    def __init__(self, *, plane: str = "jnp", interpret: bool | None = None,
                 compact: bool | None = None, registry=None):
        if plane not in ("jnp", "pallas"):
            raise ValueError(f"unknown plane {plane!r}")
        self.plane = plane
        self.compact = compact
        self._registry = registry  # None → follow the process default
        if interpret is None:
            import jax
            interpret = jax.default_backend() != "tpu"
        self._interpret = interpret
        self._front: DeviceImage | None = None
        self.frames_applied = 0
        self.snapshots = 0
        self.deltas = 0
        self.batches = 0        # multi-epoch DELTA_BATCH frames applied
        self.stale_skipped = 0  # idempotently dropped (epoch ≤ current)

    def _obs(self):
        """The live telemetry registry (injected, else process default)."""
        return self._registry or _default_obs()

    @property
    def epoch(self) -> int:  # obs-exempt: pure accessor
        return -1 if self._front is None else self._front.epoch

    def image(self) -> DeviceImage:  # obs-exempt: pure accessor
        if self._front is None:
            raise ValueError("no snapshot received yet")
        return self._front

    def fingerprint(self) -> str:  # obs-exempt: host-side hash, no wire
        """Canonical convergence fingerprint: packed replicas hash their
        dense-equivalent image so dense and compact followers of the same
        leader epoch fingerprint equal."""
        img = self.image()
        if img.packed:
            from repro.core.packing import unpack_image

            img = DeviceImage(
                algo=img.algo, n=img.n,
                arrays={k: np.asarray(v) for k, v in img.arrays.items()},
                scalars=dict(img.scalars), epoch=img.epoch, packed=True)
            img = unpack_image(img)
        return image_fingerprint(img)

    # -- frame application -----------------------------------------------------
    def apply_frame(self, buf: np.ndarray) -> None:
        # obs-exempt: delegates to apply_frames (instrumented)
        self.apply_frames([buf])

    def apply_frames(self, bufs: list[np.ndarray]) -> int:
        """Apply one drained batch of frames; returns how many landed.

        Within the batch: the newest snapshot installs first, deltas are
        reordered by base epoch (transports may interleave streams), frames
        at or below the resulting epoch are skipped as stale, and the
        surviving chain is composed last-write-wins into a single scatter.
        A chain with a REAL gap (a base epoch no frame in the batch
        reaches) still raises — reordering repairs shuffles, not losses.
        """
        reg = self._obs()
        before = (self.snapshots, self.deltas, self.stale_skipped)
        with reg.span("repl.drain", n_frames=len(bufs)):
            applied = self._drain(bufs)
        if reg.active:
            reg.counter("repl.frames_applied").inc(applied)
            reg.counter("repl.snapshots_installed").inc(
                self.snapshots - before[0])
            reg.counter("repl.deltas_applied").inc(self.deltas - before[1])
            reg.counter("repl.stale_skipped").inc(
                self.stale_skipped - before[2])
            reg.gauge("repl.follower_epoch").set(self.epoch)
        return applied

    def _drain(self, bufs: list[np.ndarray]) -> int:
        frames = [decode_frame(b) for b in bufs]
        if not frames:
            return 0
        applied = 0
        snaps = [f for f in frames if f.kind in _SNAPSHOT_KINDS]
        if snaps:
            best = max(snaps, key=lambda f: f.epoch)
            if best.epoch > self.epoch:
                self._install_snapshot(best)
                applied += 1
            self.stale_skipped += len(snaps) - (1 if applied else 0)
        live: list[Frame] = []
        for f in sorted((f for f in frames if f.kind in _DELTA_KINDS),
                        key=lambda f: (f.base_epoch, f.epoch)):
            if f.epoch <= self.epoch:
                self.stale_skipped += 1
                continue
            live.append(f)
        if live:
            applied += self._apply_chain(live)
        self.frames_applied += applied
        return applied

    def _apply_chain(self, live: list[Frame]) -> int:
        if self._front is None:
            raise ValueError("DELTA frame before any SNAPSHOT")
        cur = self._front.epoch
        chain: list[Frame] = []
        for f in live:
            if f.algo != self._front.algo:
                raise ValueError(f"frame algo {f.algo!r} != "
                                 f"{self._front.algo!r}")
            if f.packed != self._front.packed:
                raise ValueError(
                    f"frame layout packed={f.packed} != follower "
                    f"layout packed={self._front.packed}")
            if f.epoch <= cur:  # covered by an earlier frame in this drain
                self.stale_skipped += 1
                continue
            if f.base_epoch > cur:
                raise ValueError(f"frame base epoch {f.base_epoch} != "
                                 f"follower epoch {cur}")
            # base_epoch ≤ cur < epoch: overlap is fine — frames carry
            # ABSOLUTE values, so replaying an already-covered prefix
            # rewrites those words with the frame's (newer) finals.
            chain.append(f)
            cur = f.epoch
        if not chain:
            return 0
        from repro.kernels.delta_apply import apply_updates, compose_updates

        live = chain
        updates = (live[0].updates if len(live) == 1
                   else compose_updates(f.updates for f in live))
        last = live[-1]
        arrays = apply_updates(self._front.arrays, updates,
                               plane=self.plane, interpret=self._interpret)
        self._front = DeviceImage(algo=last.algo, n=last.n, arrays=arrays,
                                  scalars=last.scalars, epoch=last.epoch,
                                  packed=self._front.packed)
        self.deltas += len(live)
        self.batches += sum(f.kind == KIND_DELTA_BATCH for f in live)
        return len(live)

    def _install_snapshot(self, f: Frame) -> None:
        import jax.numpy as jnp

        packed = f.kind == KIND_SNAPSHOT_PACKED
        if self.compact is True and not packed:
            raise ValueError("compact follower received a dense SNAPSHOT")
        if self.compact is False and packed:
            raise ValueError("dense follower received a SNAPSHOT_PACKED")
        self._front = DeviceImage(
            algo=f.algo, n=f.n,
            arrays={k: jnp.asarray(v) for k, v in f.arrays.items()},
            scalars=f.scalars, epoch=f.epoch, packed=packed)
        self.snapshots += 1

    def lookup(self, keys, *, k: int = 1, **kw) -> np.ndarray:
        """Bulk lookup against the replicated image (unified engine —
        packed replicas dispatch the compact reader, no dense decode)."""
        from repro.kernels.engine import engine_lookup

        reg = self._obs()
        out = np.asarray(engine_lookup(keys, self.image(), k=k,
                                       plane=self.plane, **kw))
        if reg.active:
            reg.counter("repl.follower_lookup_keys").inc(int(out.shape[0]))
        return out


# -- topology -----------------------------------------------------------------
class TreeTopology:
    """d-ary relay tree over node ids (heap indexing): node 0 is the
    leader, follower j is node j+1, ``children(i) = a·i+1 … a·i+a``.

    Node-id order IS breadth-first order, which gives the relay invariant
    its schedule: delivering in ascending node id guarantees every
    interior follower has already applied (and can relay verbatim) the
    frames its children are about to receive.  The leader pays O(arity)
    sends per publish instead of the flat broadcast's O(F)."""

    def __init__(self, num_followers: int, *, arity: int = 2):
        if arity < 1:
            raise ValueError("tree arity must be ≥ 1")
        self.arity = int(arity)
        self.nodes = int(num_followers) + 1  # node 0 = leader

    def children(self, node: int) -> list[int]:
        lo = self.arity * node + 1
        return list(range(lo, min(lo + self.arity, self.nodes)))

    def parent(self, node: int) -> int:
        return (node - 1) // self.arity if node > 0 else -1

    def interior(self) -> list[int]:
        """Nodes with children, in BFS (ascending-id) order — the relay
        schedule, and the per-round sources of :class:`TreeBroadcast`."""
        return [i for i in range(self.nodes) if self.children(i)]

    @property
    def depth(self) -> int:
        """Relay hops from the leader to the deepest follower."""
        d, node = 0, self.nodes - 1
        while node > 0:
            node = self.parent(node)
            d += 1
        return d


# -- transports ---------------------------------------------------------------
class LoopbackChannel:
    """In-process frame queue: the sim driver's follower mode and the unit
    tests replicate leader → followers without a second process."""

    def __init__(self):
        self._q: list[np.ndarray] = []

    def publish(self, frames: list[np.ndarray]) -> None:
        self._q.extend(np.array(f, np.int32) for f in frames)

    def drain(self) -> list[np.ndarray]:
        out, self._q = self._q, []
        return out


def _pack_payload(frames: list[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Frames → (fixed-shape header, length-prefixed concatenated payload)
    — collectives need identical shapes on every process before the
    payload size is known, hence the two-hop scheme."""
    frames = [np.asarray(f, np.int32) for f in frames]
    if frames:
        payload = np.concatenate(
            [np.concatenate([np.asarray([len(f)], np.int32), f])
             for f in frames])
    else:
        payload = np.zeros((0,), np.int32)
    return np.asarray([len(frames), len(payload)], np.int32), payload


def _split_payload(payload: np.ndarray, n_frames: int) -> list[np.ndarray]:
    out, pos = [], 0
    for _ in range(n_frames):
        ln = int(payload[pos])
        out.append(np.array(payload[pos + 1: pos + 1 + ln]))
        pos += 1 + ln
    return out


def _broadcast_round(frames: list[np.ndarray] | None,
                     is_source: bool) -> list[np.ndarray]:
    """One two-hop ``broadcast_one_to_all`` round (header, then payload).
    Collective: EVERY process in the mesh must call this."""
    from jax.experimental import multihost_utils

    hdr, payload = _pack_payload(frames or [])
    hdr = np.asarray(multihost_utils.broadcast_one_to_all(
        hdr, is_source=is_source))
    n_frames, total = int(hdr[0]), int(hdr[1])
    if n_frames == 0:
        return []
    if not is_source:
        payload = np.zeros((total,), np.int32)
    payload = np.asarray(multihost_utils.broadcast_one_to_all(
        payload, is_source=is_source))
    return _split_payload(payload, n_frames)


class DistributedBroadcast:
    """Leader → all-processes frame transport over the ``jax.distributed``
    mesh (:func:`repro.launch.mesh.init_distributed` first; gloo on CPU).

    ``exchange`` is a *collective*: every process calls it each round.  The
    leader passes its frames; followers pass nothing and receive the
    leader's.  Two ``broadcast_one_to_all`` hops per round — a fixed-shape
    header (frame count + total words) then the exactly-sized concatenated
    payload with per-frame length prefixes.
    """

    def __init__(self, *, leader: int = 0):
        self.leader = leader

    def exchange(self, frames: list[np.ndarray] | None = None) -> list[np.ndarray]:
        import jax

        return _broadcast_round(frames,
                                jax.process_index() == self.leader)


class TreeBroadcast:
    """Tree-relay frame transport over the ``jax.distributed`` mesh:
    process id = tree node id (process 0 leads).

    ``exchange`` runs one two-hop broadcast round per INTERIOR tree node,
    in BFS order, with that node's process as the source: the leader seeds
    its children, then each interior follower re-broadcasts the verbatim
    frames it just received to its own children.  Rounds are collectives —
    every process participates in all of them — but only a round's
    children *keep* its frames, so the byte stream each follower applies
    is identical to the flat transport's (the relay invariant over a real
    mesh).  Rounds per publish = interior-node count ≈ F/arity instead of
    the leader serializing F sends."""

    def __init__(self, *, arity: int = 2, leader: int = 0):
        if leader != 0:
            raise ValueError("tree transport pins the leader to process 0")
        self.arity = max(1, int(arity))

    def exchange(self, frames: list[np.ndarray] | None = None) -> list[np.ndarray]:
        import jax

        nproc = int(jax.process_count())
        pid = int(jax.process_index())
        tree = TreeTopology(nproc - 1, arity=self.arity)
        mine = ([np.asarray(f, np.int32) for f in (frames or [])]
                if pid == 0 else [])
        received: list[np.ndarray] = []
        for src in tree.interior():
            got = _broadcast_round(mine if pid == src else [], pid == src)
            if tree.parent(pid) == src:
                received = got
                mine = got  # relay verbatim in this node's own round
        return received


# -- the in-process group -----------------------------------------------------
@dataclass
class WireStats:
    """Cumulative wire accounting for one :class:`ReplicationGroup` — the
    numbers the storm benchmark reads (frames/bytes distinguish what the
    LEADER sent from what crossed any link including relays)."""

    publishes: int = 0
    frames: int = 0          # distinct frames the publisher encoded
    leader_sends: int = 0    # frame transmissions the leader performed
    total_sends: int = 0     # every transmission, relays included
    leader_bytes: int = 0
    total_bytes: int = 0
    catchup_frames: int = 0  # targeted pull-path frames served
    catchup_bytes: int = 0


class ReplicationGroup:
    """Leader + in-process followers in one handle (the sim driver's
    ``followers=`` mode): every ``publish()`` ships the pending epochs to
    each online follower and returns the per-follower convergence lag
    (epochs a follower was behind *before* this round's frames applied).

    ``topology="tree"`` relays frames through interior followers
    (:class:`TreeTopology`) instead of the leader sending to every
    follower; ``batch_epochs``/``packed`` configure the publisher's frame
    stream.  ``set_online(i, False)`` simulates a partitioned follower —
    it misses publishes and, once back, is repaired by the targeted
    catch-up pull (automatically when the next delivery detects the gap,
    or explicitly via :meth:`catch_up`).  ``stats`` accumulates the wire
    accounting; ``last_publish`` snapshots the most recent round for the
    sim driver's per-event metrics."""

    def __init__(self, ch, num_followers: int = 1, *, plane: str = "jnp",
                 headroom: int = 2, topology: str = "flat", arity: int = 2,
                 batch_epochs: int = 0, packed: bool = False, registry=None):
        if topology not in ("flat", "tree"):
            raise ValueError(f"unknown topology {topology!r}")
        # lag/repair gauges are part of the group's public API, so they
        # must record even with telemetry globally off: the injected (or
        # process-default) registry when it is live, else a private one.
        self.telemetry = ensure_real(registry or _default_obs())
        self.publisher = DeltaPublisher(ch, headroom=headroom,
                                        batch_epochs=batch_epochs,
                                        packed=packed,
                                        registry=self.telemetry)
        self.followers = [FollowerImageStore(plane=plane,
                                             compact=packed or None,
                                             registry=self.telemetry)
                          for _ in range(num_followers)]
        self.tree = (TreeTopology(num_followers, arity=arity)
                     if topology == "tree" else None)
        self.topology = topology
        self._online = [True] * num_followers
        self._plane = plane
        self._ch = ch
        self.stats = WireStats()
        self.last_publish = {"frames": 0, "bytes": 0, "leader_sends": 0,
                             "catchup_frames": 0}

    @property
    def depth(self) -> int:  # obs-exempt: pure accessor
        """Fan-out depth: relay hops from leader to the farthest follower."""
        if self.tree is not None:
            return self.tree.depth
        return 1 if self.followers else 0

    def set_online(self, i: int, online: bool = True) -> None:
        """Partition (or heal) follower ``i``: offline followers receive no
        frames — and, in a tree, relay none to their subtree."""
        # obs-exempt: topology toggle, no frames move here
        self._online[i] = bool(online)

    # -- publishing ------------------------------------------------------------
    def publish(self) -> list[int]:
        reg = self.telemetry
        before = (self.stats.frames, self.stats.total_bytes,
                  self.stats.leader_sends, self.stats.catchup_frames)
        with reg.span("repl.publish", topology=self.topology):
            frames = self.publisher.frames()
            target = getattr(self._ch, "epoch", 0)
            lags = [max(0, target - max(f.epoch, 0))
                    for f in self.followers]
            if frames:
                self.stats.publishes += 1
                self.stats.frames += len(frames)
                with reg.span("repl.relay", n_frames=len(frames)):
                    if self.tree is None:
                        self._deliver_flat(frames)
                    else:
                        self._deliver_tree(frames)
        self.last_publish = {
            "frames": self.stats.frames - before[0],
            "bytes": self.stats.total_bytes - before[1],
            "leader_sends": self.stats.leader_sends - before[2],
            "catchup_frames": self.stats.catchup_frames - before[3],
        }
        if frames:
            reg.counter("repl.publishes").inc()
        reg.counter("repl.wire_frames").inc(self.last_publish["frames"])
        reg.counter("repl.wire_bytes").inc(self.last_publish["bytes"])
        reg.counter("repl.leader_sends").inc(
            self.last_publish["leader_sends"])
        for i, lag in enumerate(lags):
            reg.gauge("repl.follower_lag", follower=i).set(lag)
        reg.gauge("repl.follower_lag_max").set(max(lags, default=0))
        reg.sink.emit("publish", **self.last_publish,
                      epoch=self.publisher.published_epoch,
                      lag_max=max(lags, default=0))
        return lags

    @staticmethod
    def _nbytes(frames: list[np.ndarray]) -> int:
        return sum(4 * len(f) for f in frames)

    def _deliver_flat(self, frames: list[np.ndarray]) -> None:
        nbytes = self._nbytes(frames)
        for i in range(len(self.followers)):
            if not self._online[i]:
                continue
            self.stats.leader_sends += len(frames)
            self.stats.total_sends += len(frames)
            self.stats.leader_bytes += nbytes
            self.stats.total_bytes += nbytes
            self._apply(i, frames)

    def _deliver_tree(self, frames: list[np.ndarray]) -> None:
        nbytes = self._nbytes(frames)
        inbox: dict[int, list[np.ndarray]] = {}
        for c in self.tree.children(0):  # the only sends the leader pays
            inbox[c] = frames
            self.stats.leader_sends += len(frames)
            self.stats.total_sends += len(frames)
            self.stats.leader_bytes += nbytes
            self.stats.total_bytes += nbytes
        for node in range(1, self.tree.nodes):  # BFS: parents before kids
            got = inbox.pop(node, None)
            if got is None:
                continue
            i = node - 1
            if not self._online[i]:
                continue  # partitioned: subtree misses this round too
            self._apply(i, got)
            for c in self.tree.children(node):  # relay verbatim
                inbox[c] = got
                self.stats.total_sends += len(got)
                self.stats.total_bytes += nbytes

    def _apply(self, i: int, frames: list[np.ndarray]) -> None:
        """Deliver one round to follower ``i``; a follower that the round
        cannot chain onto (it missed earlier publishes) is first repaired
        through the targeted catch-up pull — after which the round's own
        frames skip as stale, keeping delivery idempotent."""
        fol = self.followers[i]
        batch = list(frames)
        has_snap = any(_peek_kind(b) in _SNAPSHOT_KINDS for b in batch)
        bases = [_peek_base(b) for b in batch
                 if _peek_kind(b) in _DELTA_KINDS]
        if not has_snap and bases and min(bases) > fol.epoch:
            batch = self._pull_catchup(fol.epoch) + batch
        with self.telemetry.span("repl.apply", follower=i):
            fol.apply_frames(batch)

    def _pull_catchup(self, epoch: int) -> list[np.ndarray]:
        cf = self.publisher.catchup_frames(epoch)
        nbytes = self._nbytes(cf)
        self.stats.catchup_frames += len(cf)
        self.stats.catchup_bytes += nbytes
        self.stats.leader_sends += len(cf)
        self.stats.total_sends += len(cf)
        self.stats.leader_bytes += nbytes
        self.stats.total_bytes += nbytes
        self.telemetry.counter("repl.catchup_repairs").inc()
        self.telemetry.counter("repl.catchup_frames").inc(len(cf))
        self.telemetry.counter("repl.catchup_bytes").inc(nbytes)
        return cf

    # -- the pull path ---------------------------------------------------------
    def catch_up(self, i: int) -> int:
        """Explicitly repair follower ``i`` to the published cursor via the
        targeted pull; returns the number of catch-up frames served."""
        # obs-exempt: delegates to publish/_pull_catchup (instrumented)
        self.publish()  # the stream ships to everyone first (leader-decides)
        fol = self.followers[i]
        if fol.epoch == self.publisher.published_epoch:
            return 0
        cf = self._pull_catchup(fol.epoch)
        fol.apply_frames(cf)
        return len(cf)

    def attach_follower(self) -> FollowerImageStore:
        """Join a NEW follower mid-stream: it pulls a targeted catch-up at
        its own (empty) base instead of stalling until the next publish."""
        self.publish()
        fol = FollowerImageStore(plane=self._plane,
                                 compact=self.publisher.packed or None,
                                 registry=self.telemetry)
        cf = self._pull_catchup(fol.epoch)
        fol.apply_frames(cf)
        self.followers.append(fol)
        self._online.append(True)
        self.telemetry.counter("repl.followers_attached").inc()
        return fol

    def converged(self, leader_image: DeviceImage) -> bool:
        # obs-exempt: host-side fingerprint comparison, no wire
        want = image_fingerprint(leader_image)
        return all(f.epoch == leader_image.epoch and f.fingerprint() == want
                   for f in self.followers)
