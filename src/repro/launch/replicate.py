"""Cross-process delta replication: one membership owner, N converging
followers (DESIGN.md §9.3).

MementoHash's control plane is a bounded per-event delta log
(:class:`~repro.core.protocol.DeltaEmitter`).  This module ships that log
across process boundaries: the **leader** process owns the host
``ConsistentHash`` state and publishes each epoch advance as a flat int32
**frame**; **followers** hold no host state at all — just a
:class:`FollowerImageStore` replaying frames into an on-device
:class:`~repro.core.protocol.DeviceImage` with the same out-of-place
scatter code (:func:`repro.kernels.delta_apply.apply_updates`) the leader's
own :class:`~repro.core.DeviceImageStore` runs.  Because both sides apply
identical words in identical epoch order, followers converge to
**bit-identical** images (every word a lookup can gather —
:func:`~repro.core.protocol.image_fingerprint`) and equal epochs.

Frames come in two kinds, mirroring the store's two sync paths:

  * ``DELTA``    — O(changed-words): scatter (index, value) pairs per named
    array + the new dynamic scalars, epoch-chained onto the follower's
    current epoch;
  * ``SNAPSHOT`` — the full padded arrays, sent when the delta log no
    longer covers the published epoch or when growth outruns the published
    capacity (the publisher tracks the capacity it last announced, so the
    leader — not each follower — decides when a snapshot is due and every
    follower takes the same path).

Transport is pluggable: :class:`LoopbackChannel` replicates in-process
(the sim driver's follower mode and the unit tests);
:class:`DistributedBroadcast` rides two
``multihost_utils.broadcast_one_to_all`` collectives per round over the
``jax.distributed`` mesh that :func:`repro.launch.mesh.init_distributed`
joins (gloo on CPU, ICI on TPU).  Frames are plain ``np.int32`` vectors
either way, so a transport is just "move this vector".
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.protocol import (ALGORITHM_REGISTRY, ALGORITHMS,
                                 IMAGE_LAYOUT, DeviceImage, ImageDelta,
                                 image_fingerprint, required_lengths,
                                 round_up)

#: frame type tags
KIND_DELTA = 1
KIND_SNAPSHOT = 2

_MAGIC = 0x4D454D30  # "MEM0", truncated to int32 range
# wire algo ids ARE registry order — the registry is append-only, so ids
# stay stable across releases (memento=0, anchor=1, dx=2, jump=3, power=4)
_ALGO_IDS = {name: i for i, name in enumerate(ALGORITHMS)}
_ALGO_NAMES = {v: k for k, v in _ALGO_IDS.items()}


def _array_names(algo: str) -> list[str]:
    """Canonical array-name table for the wire: layout tables + the
    bounded-load overlay word array (name_id = position)."""
    return list(IMAGE_LAYOUT[algo][1]) + ["load"]


def _scalar_names(algo: str) -> tuple[str, ...]:
    return IMAGE_LAYOUT[algo][0]


# -- wire format --------------------------------------------------------------
# frame = [MAGIC, kind, algo_id, base_epoch, epoch, n, n_extra_scalars,
#          n_arrays, extra_scalars..., blocks...]          (all int32)
# DELTA block:    [name_id, count,          idx[count], vals[count]]
# SNAPSHOT block: [name_id, length, dtype,  words[length]]   dtype: 0=i32 1=u32
_HDR = 8


def encode_delta(delta: ImageDelta) -> np.ndarray:
    """Delta → one flat int32 frame (O(changed-words))."""
    scal = [int(delta.scalars[s]) for s in _scalar_names(delta.algo)[1:]]
    names = _array_names(delta.algo)
    body: list[np.ndarray] = []
    blocks = 0
    for name, (idx, vals) in sorted(delta.updates.items()):
        if not len(idx):
            continue
        blocks += 1
        head = np.asarray([names.index(name), len(idx)], np.int32)
        body += [head, np.asarray(idx, np.int32),
                 np.asarray(vals).astype(np.int64).astype(np.int32)]
    hdr = np.asarray([_MAGIC, KIND_DELTA, _ALGO_IDS[delta.algo],
                      delta.base_epoch, delta.epoch, delta.n,
                      len(scal), blocks] + scal, np.int32)
    return np.concatenate([hdr] + body) if body else hdr


def encode_snapshot(image: DeviceImage) -> np.ndarray:
    """Full (padded) image → one flat int32 frame.  Dense layouts only:
    packed images keep their compaction process-local."""
    if image.packed:
        raise ValueError("packed images do not replicate; ship dense frames")
    scal = [int(image.scalars[s]) for s in _scalar_names(image.algo)[1:]]
    names = _array_names(image.algo)
    body: list[np.ndarray] = []
    for name in sorted(image.arrays):
        arr = np.ascontiguousarray(np.asarray(image.arrays[name]))
        dtype = 1 if arr.dtype == np.uint32 else 0
        head = np.asarray([names.index(name), arr.shape[0], dtype], np.int32)
        body += [head, arr.view(np.int32)]
    hdr = np.asarray([_MAGIC, KIND_SNAPSHOT, _ALGO_IDS[image.algo],
                      0, image.epoch, image.n,
                      len(scal), len(body) // 2] + scal, np.int32)
    return np.concatenate([hdr] + body)


@dataclass
class Frame:
    """A decoded replication frame."""

    kind: int
    algo: str
    base_epoch: int
    epoch: int
    n: int
    scalars: dict[str, int]
    # DELTA: name → (idx, vals); SNAPSHOT: name → (np array, dtype)
    updates: dict
    arrays: dict


def decode_frame(buf: np.ndarray) -> Frame:
    buf = np.asarray(buf, np.int32)
    if len(buf) < _HDR or buf[0] != _MAGIC:
        raise ValueError("not a replication frame")
    kind, algo_id = int(buf[1]), int(buf[2])
    if algo_id not in _ALGO_NAMES:
        raise ValueError(f"unknown wire algo id {algo_id} "
                         f"(this build knows 0..{len(_ALGO_NAMES) - 1})")
    algo = _ALGO_NAMES[algo_id]
    base_epoch, epoch, n = int(buf[3]), int(buf[4]), int(buf[5])
    n_scal, n_blocks = int(buf[6]), int(buf[7])
    scal_names = _scalar_names(algo)[1:]
    scalars = {scal_names[i]: int(buf[_HDR + i]) for i in range(n_scal)}
    names = _array_names(algo)
    pos = _HDR + n_scal
    updates: dict = {}
    arrays: dict = {}
    for _ in range(n_blocks):
        if kind == KIND_DELTA:
            name, count = names[int(buf[pos])], int(buf[pos + 1])
            pos += 2
            idx = np.array(buf[pos: pos + count], np.int32)
            vals = np.array(buf[pos + count: pos + 2 * count], np.int32)
            pos += 2 * count
            updates[name] = (idx, vals)
        else:
            name, length, dt = (names[int(buf[pos])], int(buf[pos + 1]),
                                int(buf[pos + 2]))
            pos += 3
            arr = np.array(buf[pos: pos + length], np.int32)
            pos += length
            arrays[name] = (arr.view(np.uint32) if dt else arr)
    if pos != len(buf):
        raise ValueError(f"trailing bytes in frame ({pos} != {len(buf)})")
    return Frame(kind=kind, algo=algo, base_epoch=base_epoch, epoch=epoch,
                 n=n, scalars=scalars, updates=updates, arrays=arrays)


# -- leader side --------------------------------------------------------------
class DeltaPublisher:
    """Leader-side cursor over the host state's bounded delta log.

    ``frames()`` returns the frames that advance followers from the last
    published epoch to the host's current one — usually one O(changed-words)
    DELTA frame; a SNAPSHOT frame on first publish, on log overflow, or
    when growth outruns the capacity the last snapshot announced.  The
    publisher (not each follower) makes the snapshot-vs-delta decision, so
    every subscriber replays the identical frame sequence — the invariant
    behind bit-identical convergence.
    """

    def __init__(self, ch, *, headroom: int = 2):
        self._ch = ch
        self.headroom = max(1, headroom)
        self._epoch: int | None = None  # nothing published yet
        self._caps: dict[str, int] = {}  # capacities the last snapshot shipped

    @property
    def published_epoch(self) -> int | None:
        return self._epoch

    def _snapshot_frame(self) -> np.ndarray:
        algo = getattr(self._ch, "image_algo", self._ch.name)
        if not ALGORITHM_REGISTRY[algo].fixed_capacity:  # growable: same
            cap = round_up(max(self.headroom * self._ch.size, 128))  # headroom
        else:                                            # rule as the store
            cap = None
        img = self._ch.device_image(capacity=cap)
        self._caps = {k: int(v.shape[0]) for k, v in img.arrays.items()}
        self._epoch = img.epoch
        return encode_snapshot(img)

    def _fits(self, delta: ImageDelta) -> bool:
        needed = dict(required_lengths(delta.algo, delta.n))
        if "load" in self._caps:
            needed["load"] = delta.n
        return all(self._caps.get(k, 0) >= v for k, v in needed.items())

    def frames(self) -> list[np.ndarray]:
        """Frames advancing subscribers to the current host epoch
        (empty when already published)."""
        cur = getattr(self._ch, "epoch", None)
        if self._epoch is None:
            return [self._snapshot_frame()]
        if cur is None or cur == self._epoch:
            return []
        delta = self._ch.device_delta(self._epoch)
        if delta is None or not self._fits(delta):
            return [self._snapshot_frame()]
        self._epoch = delta.epoch
        return [encode_delta(delta)]


# -- follower side ------------------------------------------------------------
class FollowerImageStore:
    """Device image replica driven purely by replication frames.

    Holds no host ``ConsistentHash`` state: SNAPSHOT frames install a fresh
    device image, DELTA frames scatter onto the current one through the
    same :func:`~repro.kernels.delta_apply.apply_updates` the leader store
    uses — out of place, with an atomic flip, so in-flight lookups stay
    epoch-consistent here too.  ``fingerprint()`` must equal the leader's
    once the follower has replayed every frame (the convergence gate).
    """

    def __init__(self, *, plane: str = "jnp", interpret: bool | None = None):
        if plane not in ("jnp", "pallas"):
            raise ValueError(f"unknown plane {plane!r}")
        self.plane = plane
        if interpret is None:
            import jax
            interpret = jax.default_backend() != "tpu"
        self._interpret = interpret
        self._front: DeviceImage | None = None
        self.frames_applied = 0
        self.snapshots = 0
        self.deltas = 0

    @property
    def epoch(self) -> int:
        return -1 if self._front is None else self._front.epoch

    def image(self) -> DeviceImage:
        if self._front is None:
            raise ValueError("no snapshot received yet")
        return self._front

    def fingerprint(self) -> str:
        return image_fingerprint(self.image())

    def apply_frame(self, buf: np.ndarray) -> None:
        import jax.numpy as jnp

        f = decode_frame(buf)
        if f.kind == KIND_SNAPSHOT:
            self._front = DeviceImage(
                algo=f.algo, n=f.n,
                arrays={k: jnp.asarray(v) for k, v in f.arrays.items()},
                scalars=f.scalars, epoch=f.epoch)
            self.snapshots += 1
        else:
            if self._front is None:
                raise ValueError("DELTA frame before any SNAPSHOT")
            if f.algo != self._front.algo:
                raise ValueError(f"frame algo {f.algo!r} != "
                                 f"{self._front.algo!r}")
            if f.base_epoch != self._front.epoch:
                raise ValueError(f"frame base epoch {f.base_epoch} != "
                                 f"follower epoch {self._front.epoch}")
            from repro.kernels.delta_apply import apply_updates

            arrays = apply_updates(self._front.arrays, f.updates,
                                   plane=self.plane,
                                   interpret=self._interpret)
            self._front = DeviceImage(algo=f.algo, n=f.n, arrays=arrays,
                                      scalars=f.scalars, epoch=f.epoch)
            self.deltas += 1
        self.frames_applied += 1

    def lookup(self, keys, *, k: int = 1, **kw) -> np.ndarray:
        """Bulk lookup against the replicated image (unified engine)."""
        from repro.kernels.engine import engine_lookup

        return np.asarray(engine_lookup(keys, self.image(), k=k,
                                        plane=self.plane, **kw))


# -- transports ---------------------------------------------------------------
class LoopbackChannel:
    """In-process frame queue: the sim driver's follower mode and the unit
    tests replicate leader → followers without a second process."""

    def __init__(self):
        self._q: list[np.ndarray] = []

    def publish(self, frames: list[np.ndarray]) -> None:
        self._q.extend(np.array(f, np.int32) for f in frames)

    def drain(self) -> list[np.ndarray]:
        out, self._q = self._q, []
        return out


class DistributedBroadcast:
    """Leader → all-processes frame transport over the ``jax.distributed``
    mesh (:func:`repro.launch.mesh.init_distributed` first; gloo on CPU).

    ``exchange`` is a *collective*: every process calls it each round.  The
    leader passes its frames; followers pass nothing and receive the
    leader's.  Two ``broadcast_one_to_all`` hops per round — a fixed-shape
    header (frame count + total words) then the exactly-sized concatenated
    payload with per-frame length prefixes — because collectives need
    identical shapes on every process before the payload size is known.
    """

    def __init__(self, *, leader: int = 0):
        self.leader = leader

    def exchange(self, frames: list[np.ndarray] | None = None) -> list[np.ndarray]:
        import jax
        from jax.experimental import multihost_utils

        is_leader = jax.process_index() == self.leader
        frames = [np.asarray(f, np.int32) for f in (frames or [])]
        if frames:
            payload = np.concatenate(
                [np.concatenate([np.asarray([len(f)], np.int32), f])
                 for f in frames])
        else:
            payload = np.zeros((0,), np.int32)
        hdr = np.asarray([len(frames), len(payload)], np.int32)
        hdr = np.asarray(multihost_utils.broadcast_one_to_all(
            hdr, is_source=is_leader))
        n_frames, total = int(hdr[0]), int(hdr[1])
        if n_frames == 0:
            return []
        if not is_leader:
            payload = np.zeros((total,), np.int32)
        payload = np.asarray(multihost_utils.broadcast_one_to_all(
            payload, is_source=is_leader))
        out, pos = [], 0
        for _ in range(n_frames):
            ln = int(payload[pos])
            out.append(np.array(payload[pos + 1: pos + 1 + ln]))
            pos += 1 + ln
        return out


class ReplicationGroup:
    """Leader + in-process followers in one handle (the sim driver's
    ``followers=`` mode): every ``publish()`` ships the pending epochs to
    each follower and returns the per-follower convergence lag (epochs a
    follower was behind *before* this round's frames were applied)."""

    def __init__(self, ch, num_followers: int = 1, *, plane: str = "jnp",
                 headroom: int = 2):
        self.publisher = DeltaPublisher(ch, headroom=headroom)
        self.followers = [FollowerImageStore(plane=plane)
                          for _ in range(num_followers)]
        self._ch = ch

    def publish(self) -> list[int]:
        frames = self.publisher.frames()
        target = getattr(self._ch, "epoch", 0)
        lags = [max(0, target - max(f.epoch, 0)) for f in self.followers]
        for frame in frames:
            for f in self.followers:
                f.apply_frame(frame)
        return lags

    def converged(self, leader_image: DeviceImage) -> bool:
        want = image_fingerprint(leader_image)
        return all(f.epoch == leader_image.epoch and f.fingerprint() == want
                   for f in self.followers)
