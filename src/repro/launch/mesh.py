"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (data=16, model=16) = 256 chips (v5e pod);
multi-pod adds a leading ``pod`` axis: (2, 16, 16) = 512 chips.  The dry-run
launcher sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before
any jax import so these meshes build on the CPU container.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax.sharding.AxisType only exists on newer jax; older releases default
    # every axis to Auto anyway, so omit the kwarg when it's unavailable.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale distribution tests (requires enough devices)."""
    return _mesh(shape, axes)


def make_lookup_mesh(num_devices: int | None = None, axis: str = "data"):
    """1-D serving mesh for the sharded lookup plane (DESIGN.md §6): key
    batches shard over ``axis`` across every available device (or the
    first ``num_devices``), images replicate.  On the CPU container the
    device count comes from ``--xla_force_host_platform_device_count``."""
    n = num_devices or len(jax.devices())
    return _mesh((n,), (axis,))
