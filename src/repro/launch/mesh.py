"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (data=16, model=16) = 256 chips (v5e pod);
multi-pod adds a leading ``pod`` axis: (2, 16, 16) = 512 chips.  The dry-run
launcher sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before
any jax import so these meshes build on the CPU container.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax.sharding.AxisType only exists on newer jax; older releases default
    # every axis to Auto anyway, so omit the kwarg when it's unavailable.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale distribution tests (requires enough devices)."""
    return _mesh(shape, axes)


def init_distributed(coordinator_address: str, num_processes: int,
                     process_id: int) -> None:
    """Join the multi-process mesh for cross-process delta replication
    (DESIGN.md §9.3): process 0 owns membership, followers receive the
    broadcast delta frames of :mod:`repro.launch.replicate`.

    On the CPU backend, cross-process collectives need the gloo
    implementation — the default CPU client rejects multi-process
    computations — so it is selected *before* ``jax.distributed``
    initializes the backend (a no-op on TPU, where ICI collectives are
    native).
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass  # older/newer jax without the option: TPU paths don't need it
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)


def make_lookup_mesh(num_devices: int | None = None, axis: str = "data"):
    """1-D serving mesh for the sharded lookup plane (DESIGN.md §6): key
    batches shard over ``axis`` across every available device (or the
    first ``num_devices``), images replicate.  On the CPU container the
    device count comes from ``--xla_force_host_platform_device_count``."""
    n = num_devices or len(jax.devices())
    return _mesh((n,), (axis,))
