from .step import TrainStepConfig, batch_specs, init_state, make_train_step, state_specs

__all__ = [
    "TrainStepConfig",
    "batch_specs",
    "init_state",
    "make_train_step",
    "state_specs",
]
