"""Pipeline parallelism over the ``pod`` axis (GPipe-style ring schedule).

The stacked layer periods are split across pods (stage s owns periods
[s·P/S, (s+1)·P/S)); microbatches stream through stages with
``jax.lax.ppermute`` handing activations to the next pod while ``data`` /
``model`` axes stay under GSPMD inside each stage (``shard_map`` with auto
axes).  The steady-state bubble is the classic (S−1)/(M+S−1).

This is the forward/serving pipeline (prefill scoring, eval, reward-model
passes).  Training backward uses the ZeRO-3 + TP path (`train/step.py`),
where the pod axis acts as extra data parallelism — on the assigned 2-pod
mesh that is the better-utilization choice; a 1F1B training schedule slots
into the same stage/ppermute skeleton.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.lm import block_apply
from repro.sharding.rules import pvary, shard_map


def make_pipelined_forward(model, rules, num_microbatches: int):
    """Returns fwd(params, embeds) → hidden states (B, S, D), pipelined over
    the pod axis.  `embeds` enter at stage 0; results exit at the last stage
    and are ppermuted back to stage 0 order.  Requires
    model.n_periods % n_stages == 0 and batch % (num_microbatches·data) == 0.
    """
    mesh = rules.mesh
    sizes = rules.mesh_axis_sizes
    n_stages = sizes.get("pod", 1)
    assert n_stages > 1, "pipeline needs a pod axis"
    assert model.n_periods % n_stages == 0, (model.n_periods, n_stages)
    per_stage = model.n_periods // n_stages
    cfg = model.cfg
    M = num_microbatches

    def stage_fn(blocks, h, positions):
        def apply_period(h, blk):
            for i, kind in enumerate(model.period_kinds):
                h, _ = block_apply(cfg, kind, blk[str(i)], h, positions,
                                   chunk=model.attn_chunk, rules=rules,
                                   moe_impl=model.moe_impl)
            return h, None
        h, _ = jax.lax.scan(apply_period, h, blocks)
        return h

    def body(blocks, embeds):  # inside shard_map over ("pod",); auto elsewhere
        # blocks: this pod's (per_stage, ...) slice.  embeds: (M, mb, S, D)
        stage = jax.lax.axis_index("pod")
        mb, S, D = embeds.shape[1:]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (mb, S))
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            inflight, outputs = carry
            # stage 0 injects microbatch t (if any); others use handed-off h
            inject = jnp.where(t < M, t, M - 1)
            h_in = jnp.where(stage == 0, embeds[inject], inflight)
            h_out = stage_fn(blocks, h_in.astype(embeds.dtype), positions)
            # completed microbatch index at the last stage
            done_idx = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (done_idx >= 0) & (done_idx < M)
            upd = jax.lax.dynamic_update_index_in_dim(
                outputs, h_out, jnp.clip(done_idx, 0, M - 1), axis=0)
            outputs = jnp.where(write, upd, outputs)
            handed = jax.lax.ppermute(h_out, "pod", perm)
            return (handed, outputs), None

        inflight0 = pvary(jnp.zeros_like(embeds[0]), ("pod",))
        outputs0 = pvary(jnp.zeros_like(embeds), ("pod",))
        (_, outputs), _ = jax.lax.scan(
            tick, (inflight0, outputs0),
            jnp.arange(M + n_stages - 1, dtype=jnp.int32))
        # non-last stages never write → psum both broadcasts the last
        # stage's results and proves pod-invariance for out_specs=P().
        # (f32 round-trip: XLA:CPU's ChangeOpDataType pass crashes cloning
        # bf16 all-reduces under partial-manual shard_map.)
        return jax.lax.psum(outputs.astype(jnp.float32), "pod").astype(outputs.dtype)

    def fwd(params, embeds):
        B, S, D = embeds.shape
        assert B % M == 0, (B, M)
        embs = embeds.reshape(M, B // M, S, D)
        # partial-manual shard_map: only the pod axis is manual; data/model
        # sharding rides on the arrays themselves under GSPMD.
        out = shard_map(
            body, mesh=mesh,
            in_specs=(P("pod"), P()),
            out_specs=P(),
            axis_names=frozenset({"pod"}),
        )(params["blocks"], embs)
        return out.reshape(B, S, D)

    return fwd
