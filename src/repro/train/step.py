"""Train-step builder: grad accumulation, clipping, AdamW, sharding specs.

``make_train_step(model, rules, ...)`` returns a pure function
``train_step(state, batch) → (state, metrics)`` plus the PartitionSpec trees
needed to jit it on a mesh.  Microbatching runs as a ``lax.scan`` over the
leading batch split so only one microbatch's activations are live (with the
model's scan-over-layers remat this bounds live activations to
O(periods · microbatch · S · D)).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.sharding.rules import logical_to_spec


@dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    grad_clip: float = 1.0
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1


def init_state(model, key):
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params)}


def state_shapes(model):
    pshapes = model.param_shapes()
    return {"params": pshapes,
            "opt": {"m": pshapes, "v": pshapes,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def state_specs(model, rules):
    pspecs = model.param_specs(rules)
    return {"params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": P()}}


def batch_specs(cfg, rules, B: int, S: int, *, with_embeds: bool | None = None):
    if with_embeds is None:
        with_embeds = bool(cfg.frontend)
    tok = logical_to_spec(("batch", None), rules, (B, S))
    out = {"labels": tok}
    if with_embeds:
        out["embeds"] = logical_to_spec(("batch", None, None), rules,
                                        (B, S, cfg.d_model))
    else:
        out["tokens"] = tok
    return out


def batch_shapes(cfg, B: int, S: int):
    if cfg.frontend:
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def make_train_step(model, tcfg: TrainStepConfig = TrainStepConfig(),
                    rules=None):
    nmb = tcfg.microbatches
    pspecs = model.param_specs(rules) if rules is not None else None

    def _constrain_like_params(tree):
        if pspecs is None:
            return tree
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), tree, pspecs)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state, batch):
        params = state["params"]

        if nmb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape(nmb, x.shape[0] // nmb, *x.shape[1:]), batch)

            def mb_step(carry, mb):
                acc, loss_acc = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                # sharded like params ⇒ per-microbatch reductions lower to
                # reduce-scatter into the ZeRO shard (no full-dW all-reduce)
                grads = _constrain_like_params(grads)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), None

            zeros = _constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss), _ = jax.lax.scan(mb_step, (zeros, jnp.float32(0.0)), split)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss = loss / nmb
            metrics = {}

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        new_params, new_opt = adamw_update(
            grads, state["opt"], params, lr=tcfg.lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay)
        out_metrics = {"loss": loss, "grad_norm": gnorm,
                       "step": new_opt["step"].astype(jnp.float32)}
        if isinstance(metrics, dict):
            out_metrics.update({k: v for k, v in metrics.items()
                                if k in ("ce", "load_balance", "router_z")})
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step
