"""Serving steps: prefill and one-token decode, with sharding/shape trees.

``serve_step`` for `decode_*` shapes is one new token against a KV cache of
``seq_len`` (per the brief); for `prefill_*` shapes it is the full-sequence
cache-building pass.  Cache specs shard batch over (pod, data) and kv-heads
or cache-sequence over `model` (whichever divides — see sharding/rules.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import logical_to_spec


def make_decode_step(model):
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)
    return decode_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"))
    return prefill_step


def decode_shapes(model, B: int, S: int):
    """(params, cache, tokens, pos) ShapeDtypeStructs for one-token decode."""
    return (model.param_shapes(),
            model.cache_shapes(B, S),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))


def decode_specs(model, rules, B: int):
    tok = logical_to_spec(("batch", None), rules, (B, 1))
    return (model.param_specs(rules), None, tok, P())


def decode_cache_specs(model, B, S, rules):
    return model.cache_specs(B, S, rules)


def prefill_shapes(model, B: int, S: int):
    cfg = model.cfg
    if cfg.frontend:
        batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return (model.param_shapes(), batch)


def prefill_specs(model, rules, B: int, S: int):
    cfg = model.cfg
    if cfg.frontend:
        batch = {"embeds": logical_to_spec(("batch", None, None), rules,
                                           (B, S, cfg.d_model))}
    else:
        batch = {"tokens": logical_to_spec(("batch", None), rules, (B, S))}
    return (model.param_specs(rules), batch)
