from .plane import ShardedLookupPlane
from .step import decode_shapes, decode_specs, make_decode_step, make_prefill_step, prefill_shapes, prefill_specs

__all__ = [
    "ShardedLookupPlane",
    "decode_shapes",
    "decode_specs",
    "make_decode_step",
    "make_prefill_step",
    "prefill_shapes",
    "prefill_specs",
]
