"""Session → replica router: MementoHash with KV-cache affinity.

The serving-side face of the paper: requests carry a session id (prefix /
KV-cache identity); the router consistent-hashes sessions onto model
replicas so

  * a session always lands on the replica holding its KV cache (affinity),
  * replica failure remaps ONLY that replica's sessions (minimal disruption)
    — the rest keep their warm caches,
  * replicas added back (restored) steal only the sessions that belonged to
    them (monotonicity), and the replica fleet can grow without bound.

Bulk routing (e.g. batch admission of thousands of queued requests) runs on
the device data plane (`repro.kernels.ops.memento_lookup`, Pallas on TPU).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import MementoHash, MementoTables
from repro.core.hashing import key_to_u32


@dataclass
class RouterStats:
    routed: int = 0
    moved_on_failure: int = 0
    affinity_hits: int = 0


class SessionRouter:
    def __init__(self, num_replicas: int, *, use_device_plane: bool = False):
        self.memento = MementoHash(num_replicas, variant="32")
        self.tables = MementoTables(self.memento)
        self.use_device_plane = use_device_plane
        self.stats = RouterStats()
        self._last: dict[int, int] = {}  # session → last replica (metrics)

    # -- single-request path --------------------------------------------------
    def route(self, session_id) -> int:
        key = key_to_u32(session_id)
        r = self.memento.lookup(key)
        self.stats.routed += 1
        if self._last.get(key) == r:
            self.stats.affinity_hits += 1
        self._last[key] = r
        return r

    # -- bulk path (device plane) ----------------------------------------------
    def route_batch(self, session_ids: np.ndarray) -> np.ndarray:
        from repro.core.hashing import np_key_to_u32
        keys = np_key_to_u32(np.asarray(session_ids))
        if self.use_device_plane:
            from repro.kernels import ops
            return np.asarray(ops.memento_lookup(keys, self.tables.repl,
                                                 self.tables.n))
        from repro.core.jax_lookup import memento_lookup
        import jax.numpy as jnp
        return np.asarray(memento_lookup(jnp.asarray(keys),
                                         jnp.asarray(self.tables.repl),
                                         self.tables.n))

    # -- membership ----------------------------------------------------------
    def fail_replica(self, replica: int) -> dict:
        before = dict(self._last)
        self.memento.remove(replica)
        self.tables.on_remove(replica)
        moved = {s for s, r in before.items() if r == replica}
        self.stats.moved_on_failure += len(moved)
        return {"replica": replica, "sessions_moved": len(moved)}

    def restore_replica(self) -> int:
        b = self.memento.add()
        self.tables.on_add(b)
        return b

    @property
    def replicas(self) -> set[int]:
        return self.memento.working_set()


@dataclass
class Request:
    session_id: int
    tokens: list[int] = field(default_factory=list)


class BatchScheduler:
    """Groups admitted requests per replica into decode batches."""

    def __init__(self, router: SessionRouter, max_batch: int):
        self.router = router
        self.max_batch = max_batch

    def assign(self, requests: list[Request]) -> dict[int, list[Request]]:
        ids = np.asarray([r.session_id for r in requests], dtype=np.uint64)
        replicas = (self.router.route_batch(ids) if len(ids) else
                    np.zeros((0,), np.int32))
        out: dict[int, list[Request]] = {}
        for req, rep in zip(requests, replicas):
            out.setdefault(int(rep), []).append(req)
        for rep, lst in out.items():
            out[rep] = lst[: self.max_batch]  # back-pressure beyond max_batch
        return out
