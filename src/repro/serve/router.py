"""Session → replica router: consistent hashing with KV-cache affinity.

The serving-side face of the paper: requests carry a session id (prefix /
KV-cache identity); the router consistent-hashes sessions onto model
replicas so

  * a session always lands on the replica holding its KV cache (affinity),
  * replica failure remaps ONLY that replica's sessions (minimal disruption)
    — the rest keep their warm caches,
  * replicas added back (restored) steal only the sessions that belonged to
    them (monotonicity), and (with Memento/Jump) the fleet can grow without
    bound.

The router is algorithm-pluggable: any :class:`~repro.core.ConsistentHash`
(Memento — the default —, Anchor, Dx, Jump) drives placement through the
same protocol.  Bulk routing (e.g. batch admission of thousands of queued
requests) runs on the device data plane through a
:class:`~repro.core.DeviceImageStore`: ``fail_replica``/``restore_replica``
push O(changed-words) epoch deltas to the device instead of nulling and
rebuilding the O(n) image (DESIGN.md §3.5), and lookups keep serving the
old epoch until the flip.  Batch lookups are single launches of the
unified engine (DESIGN.md §6); :meth:`SessionRouter.route_stream` fans
streams of batches across every device via the mesh-sharded
:class:`~repro.serve.plane.ShardedLookupPlane`.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.core import ConsistentHash, DeviceImageStore, make_hash
from repro.core.hashing import key_to_u32
from repro.obs.metrics import default_registry as _default_obs
from repro.obs.metrics import ensure_real


class RouterStats:
    """Live view over the router's ``router.*`` telemetry counters.

    The historical dataclass API is preserved — ``stats.routed`` reads,
    ``stats.routed += n`` writes — but the counters on a
    :class:`~repro.obs.metrics.MetricRegistry` are the store, so the same
    numbers flow to the exposition/snapshot exporters (DESIGN.md §11).
    With telemetry off the view rides a private registry
    (:func:`~repro.obs.metrics.ensure_real`), so the API never goes dark.
    Attribute writes are deltas on monotonic counters; rewinding (setting
    a smaller value) is a no-op.
    """

    FIELDS = ("routed", "moved_on_failure", "affinity_hits", "failovers")

    def __init__(self, registry=None):
        object.__setattr__(self, "_counters",
                           {f: ensure_real(registry).counter(f"router.{f}")
                            for f in self.FIELDS})

    def __getattr__(self, name):
        counters = object.__getattribute__(self, "_counters")
        if name in counters:
            return counters[name].value
        raise AttributeError(name)

    def __setattr__(self, name, value) -> None:
        counters = self._counters
        if name in counters:
            delta = int(value) - counters[name].value
            if delta > 0:
                counters[name].inc(delta)
            return
        object.__setattr__(self, name, value)

    def as_dict(self) -> dict:
        return {f: getattr(self, f) for f in self.FIELDS}

    def __repr__(self) -> str:
        inner = ", ".join(f"{f}={getattr(self, f)}" for f in self.FIELDS)
        return f"RouterStats({inner})"


class SessionRouter:
    """Session → replica router; with ``replicas_k > 1`` it is replica-aware
    (DESIGN.md §4.3): every session has a k-replica set (salted ``lookup_k``,
    so replica 0 is the classic placement) and a *marked-failed* replica
    fails over to replica r+1 **before** any membership delta lands — the
    instant a health checker calls :meth:`mark_failed`, routing avoids the
    node, while the epoch delta (``fail_replica``) catches up asynchronously.
    """

    def __init__(self, num_replicas: int, *, algo: str | ConsistentHash = "memento",
                 capacity: int | None = None, use_device_plane: bool = False,
                 max_sessions: int = 1_000_000, replicas_k: int = 1,
                 store: DeviceImageStore | None = None,
                 compact_images: bool = False,
                 block_rows: int | None = None,
                 sync_mode: str = "block", registry=None):
        if isinstance(algo, str):
            # variant="32": host lookups bit-identical to the device plane.
            self.ch = make_hash(algo, num_replicas, capacity=capacity, variant="32")
        else:
            self.ch = algo
        if replicas_k < 1:
            raise ValueError("replicas_k must be ≥ 1")
        if sync_mode not in ("block", "overlap"):
            raise ValueError(f"unknown sync_mode {sync_mode!r}")
        self.replicas_k = replicas_k
        # "overlap": membership deltas ride sync_async() — the flip lands at
        # the next batch boundary (bounded staleness) instead of stalling
        # the event path for the full delta-apply latency (DESIGN.md §9.2).
        self.sync_mode = sync_mode
        self.use_device_plane = use_device_plane
        # device-plane tuning knobs: compact (packed) device images and an
        # explicit Pallas tile height (None → the autotuner's winner)
        self.compact_images = compact_images
        self.block_rows = block_rows
        self._registry = registry  # None → follow the process default
        # stats land on the injected registry when it records, else on the
        # process default, else on the view's own private registry — the
        # public counter API works with telemetry globally off.
        self.stats = RouterStats(registry or _default_obs())
        self.max_sessions = max_sessions
        # session id → last replica (metrics), LRU-bounded: million-session
        # fleets must not grow host memory without limit.
        self._last: OrderedDict = OrderedDict()
        # an injected store (e.g. the scenario driver's) must wrap the SAME
        # host state, or deltas and lookups would split across two clusters
        if store is not None and store._ch is not self.ch:
            raise ValueError("injected store wraps a different host state")
        self._store: DeviceImageStore | None = store
        self._plane = None    # lazy ShardedLookupPlane (route_stream)
        self._plane_k = None  # lazy k-replica plane (failover streaming)
        # replicas marked failed but whose removal delta has not landed yet:
        # route()/route_batch() fail over around them immediately.
        self._failed: set[int] = set()
        # overlap mode: replica → host epoch whose device landing clears the
        # mark.  While the async removal is in flight, device lookups still
        # serve the pre-removal epoch, so the failover mask must outlive
        # fail_replica() until the flip actually happens.
        self._unmark_at: dict[int, int] = {}

    @property
    def memento(self) -> ConsistentHash:
        """Back-compat alias from the Memento-only router."""  # obs-exempt
        return self.ch

    def _obs(self):
        """The live telemetry registry (injected, else process default)."""
        return self._registry or _default_obs()

    # -- single-request path --------------------------------------------------
    def replica_set(self, session_id) -> list[int]:
        """The session's k distinct candidate replicas (replica 0 = the
        classic single-lookup placement).  k is clamped to the surviving
        fleet so deep failure cascades degrade instead of raising."""
        self._obs().counter("router.replica_set_calls").inc()
        k = min(self.replicas_k, self.ch.working)
        return self.ch.lookup_k(key_to_u32(session_id), k)

    def route(self, session_id) -> int:
        reg = self._obs()
        t0 = time.perf_counter_ns() if reg.active else 0
        self._poll_store()
        if self.replicas_k > 1 and self._failed:
            reps = self.replica_set(session_id)
            # fail over to replica r+1 while the primary is marked failed;
            # if every replica is marked, keep the primary (nothing better).
            r = next((c for c in reps if c not in self._failed), reps[0])
            if r != reps[0]:
                self.stats.failovers += 1
        else:
            r = self.ch.lookup(key_to_u32(session_id))
        self.stats.routed += 1
        if self._last.get(session_id) == r:
            self.stats.affinity_hits += 1
        self._last[session_id] = r
        self._last.move_to_end(session_id)  # no-op for fresh keys
        if len(self._last) > self.max_sessions:
            self._last.popitem(last=False)  # evict the coldest session
        if reg.active:
            reg.histogram("router.route.us").observe(
                (time.perf_counter_ns() - t0) / 1e3)
        return r

    # -- bulk path (device plane) ----------------------------------------------
    def image_store(self) -> DeviceImageStore:
        if self._store is None:
            plane = "pallas" if self.use_device_plane else "jnp"
            self._store = DeviceImageStore(self.ch, plane=plane,
                                           compact=self.compact_images,
                                           registry=self._registry)
        return self._store

    def device_image(self):  # obs-exempt: pure accessor
        return self.image_store().image()

    def _failover_pick(self, sets: np.ndarray) -> np.ndarray:
        """THE failover rule, shared by every batch path: per row of k
        candidate replicas, pick the first not marked failed (all marked →
        keep the primary).  Accepts 1-D input (k clamped to 1 by a
        collapsed fleet)."""
        sets = np.asarray(sets)
        if sets.ndim == 1:
            sets = sets.reshape(-1, 1)
        ok = ~np.isin(sets, sorted(self._failed))
        ok[:, 0] |= ~ok.any(axis=1)  # all failed → keep the primary
        col = ok.argmax(axis=1)
        self.stats.failovers += int((col > 0).sum())
        return sets[np.arange(len(sets)), col]

    def route_batch(self, session_ids: np.ndarray) -> np.ndarray:
        from repro.core.hashing import np_key_to_u32
        reg = self._obs()
        t0 = time.perf_counter_ns() if reg.active else 0
        self._poll_store()
        keys = np_key_to_u32(np.asarray(session_ids))
        plane = "pallas" if self.use_device_plane else "jnp"
        if self.replicas_k > 1 and self._failed:
            # k-replica sets in one device pass; same rule as route()
            out = self._failover_pick(self.replica_set_batch(session_ids))
        else:
            out = self.image_store().lookup(keys, plane=plane,
                                            block_rows=self.block_rows)
        if reg.active:
            reg.counter("router.batch_keys").inc(len(keys))
            reg.histogram("router.route_batch.us").observe(
                (time.perf_counter_ns() - t0) / 1e3)
        return out

    def replica_set_batch(self, session_ids: np.ndarray) -> np.ndarray:
        """k-replica sets for a session batch in one engine launch:
        int32 [len(ids), k], column 0 = the classic placement."""
        from repro.core.hashing import np_key_to_u32
        reg = self._obs()
        t0 = time.perf_counter_ns() if reg.active else 0
        keys = np_key_to_u32(np.asarray(session_ids))
        plane = "pallas" if self.use_device_plane else "jnp"
        k = min(self.replicas_k, self.ch.working)
        out = self.image_store().lookup(keys, plane=plane, k=k,
                                        block_rows=self.block_rows)
        if reg.active:
            reg.histogram("router.replica_set.us", k=k).observe(
                (time.perf_counter_ns() - t0) / 1e3)
        return out.reshape(-1, 1) if k == 1 else out

    # -- streaming path (mesh-sharded plane) ----------------------------------
    def sharded_plane(self, *, mesh=None, axes=None):
        """The router's :class:`~repro.serve.plane.ShardedLookupPlane` over
        its image store: million-session batches fan out across every
        device, with membership deltas reaching each device through the
        store's epoch sync (DESIGN.md §6)."""
        from repro.serve.plane import ShardedLookupPlane
        if self._plane is None or mesh is not None or axes is not None:
            plane = ShardedLookupPlane(self.image_store(), mesh=mesh,
                                       axes=axes, block_rows=self.block_rows,
                                       sync_mode=self.sync_mode,
                                       registry=self._registry)
            if mesh is None and axes is None:
                self._plane = plane
            return plane
        return self._plane

    def route_stream(self, session_id_batches, *, mesh=None):
        """Stream batches of session ids → np int32 replica batches through
        the mesh-sharded plane.  Membership events applied between batches
        (``fail_replica``/``restore_replica``) are picked up at the next
        batch boundary, and — like :meth:`route_batch` — replicas marked
        failed (:meth:`mark_failed`) are failed over BEFORE their removal
        delta lands.  A replica-unaware router (``replicas_k == 1``)
        streams through the plane's pipelined double-buffered path; a
        replica-aware one dispatches per batch so the failover mask is
        applied with the same rule as the scalar path."""
        from repro.core.hashing import np_key_to_u32
        reg = self._obs()
        plane = self.sharded_plane(mesh=mesh)
        if self.replicas_k == 1:
            def to_keys():
                for ids in session_id_batches:
                    self.stats.routed += len(ids)
                    reg.counter("router.stream_batches").inc()
                    yield np_key_to_u32(np.asarray(ids))

            yield from plane.route_stream(to_keys())
            return
        kplane = self._replica_plane(mesh)  # built once per stream, not per batch
        for ids in session_id_batches:
            ids = np.asarray(ids)
            self._poll_store()  # overlap: land a ready flip, retire marks
            self.stats.routed += len(ids)
            reg.counter("router.stream_batches").inc()
            keys = np_key_to_u32(ids)
            if not self._failed:
                yield plane.lookup(keys)
            else:
                yield self._failover_pick(kplane.lookup(keys))

    def _replica_plane(self, mesh=None):
        """Sharded k-replica plane for the failover stream path."""
        from repro.serve.plane import ShardedLookupPlane
        k = min(self.replicas_k, self.ch.working)
        if self._plane_k is None or self._plane_k.k != k or mesh is not None:
            plane = ShardedLookupPlane(self.image_store(), mesh=mesh, k=k,
                                       block_rows=self.block_rows,
                                       sync_mode=self.sync_mode,
                                       registry=self._registry)
            if mesh is None:
                self._plane_k = plane
            return plane
        return self._plane_k

    # -- membership ----------------------------------------------------------
    def _push_delta(self) -> None:
        """Mirror the membership event to the device as an epoch delta.

        ``sync_mode='block'`` flips synchronously; ``'overlap'`` dispatches
        the delta apply and defers the flip to the next poll point (a batch
        boundary, or the next membership event)."""
        if self._store is not None:
            if self.sync_mode == "overlap":
                self._store.sync_async()
            else:
                self._store.sync()

    def _poll_store(self) -> None:
        """Overlap-mode poll point: land a ready async epoch (never blocks)
        and retire failover marks whose removal epoch has reached the
        device."""
        if self.sync_mode == "overlap" and self._store is not None:
            self._store.poll()
        if self._unmark_at and self._store is not None:
            ep = self._store.epoch
            for r, until in list(self._unmark_at.items()):
                if ep >= until:
                    del self._unmark_at[r]
                    self._failed.discard(r)

    def mark_failed(self, replica: int) -> None:
        """Health-checker hook: route around ``replica`` NOW, before any
        membership delta is emitted or applied (DESIGN.md §4.3)."""
        self._failed.add(replica)
        self._obs().counter("router.failover_marks").inc()

    def fail_replica(self, replica: int) -> dict:
        reg = self._obs()
        before = dict(self._last)
        self.mark_failed(replica)  # failover active while the delta lands
        removed = False
        try:
            with reg.span("router.fail_replica", replica=replica):
                self.ch.remove(replica)
                removed = True
                self._push_delta()
            reg.counter("router.membership_events", op="fail").inc()
        finally:
            host_ep = getattr(self.ch, "epoch", None)
            if (removed and self.sync_mode == "overlap"
                    and self._store is not None and host_ep is not None
                    and self._store.epoch < host_ep):
                # async removal still in flight: the device plane serves
                # the pre-removal epoch, so keep failing over until the
                # flip lands (_poll_store retires the mark by epoch).
                self._unmark_at[replica] = host_ep
            else:
                # membership reflects the failure (or the removal was
                # invalid): either way the mark must not outlive this call
                self._failed.discard(replica)
        moved = {s for s, r in before.items() if r == replica}
        self.stats.moved_on_failure += len(moved)
        info = {"replica": replica, "sessions_moved": len(moved)}
        if self._store is not None:
            # overlap: the delta is dispatched but not flipped — report the
            # in-flight handle's target-epoch stats, not the stale last_sync
            pend = self._store.pending
            st = pend.stats if pend is not None else self._store.last_sync
            if st is not None:
                info["control_plane"] = {"mode": st.mode, "words": st.words,
                                         "epoch": st.epoch}
        return info

    def restore_replica(self) -> int:
        reg = self._obs()
        with reg.span("router.restore_replica"):
            b = self.ch.add()
            self._push_delta()
        reg.counter("router.membership_events", op="restore").inc()
        return b

    @property
    def replicas(self) -> set[int]:  # obs-exempt: pure accessor
        return self.ch.working_set()


@dataclass
class Request:
    session_id: int
    tokens: list[int] = field(default_factory=list)


class BatchScheduler:
    """Groups admitted requests per replica into decode batches.

    ``assign`` honours ``max_batch`` per replica and returns the overflow
    explicitly — requests beyond a replica's budget are NOT silently
    dropped; they come back in arrival order for the caller to re-queue
    (or are carried in ``self.pending`` and drained first on the next
    ``assign``).
    """

    def __init__(self, router: SessionRouter, max_batch: int):
        self.router = router
        self.max_batch = max_batch
        self.pending: list[Request] = []

    def assign(self, requests: list[Request]) -> tuple[dict[int, list[Request]], list[Request]]:
        """Route ``pending + requests``; returns ``(batches, overflow)``.

        ``batches`` maps replica → at most ``max_batch`` requests.
        ``overflow`` lists the requests that exceeded some replica's
        budget; the scheduler retains them in ``self.pending`` and drains
        them first on the next call, so callers must NOT resubmit them —
        the returned list is for back-pressure telemetry.
        """
        work = self.pending + list(requests)
        ids = np.asarray([r.session_id for r in work], dtype=np.uint64)
        replicas = (self.router.route_batch(ids) if len(ids) else
                    np.zeros((0,), np.int32))
        out: dict[int, list[Request]] = {}
        overflow: list[Request] = []
        for req, rep in zip(work, replicas):
            lst = out.setdefault(int(rep), [])
            if len(lst) < self.max_batch:
                lst.append(req)
            else:
                overflow.append(req)  # back-pressure, not truncation
        self.pending = overflow
        return out, list(overflow)  # copy: callers must not mutate the queue
