"""Session → replica router: consistent hashing with KV-cache affinity.

The serving-side face of the paper: requests carry a session id (prefix /
KV-cache identity); the router consistent-hashes sessions onto model
replicas so

  * a session always lands on the replica holding its KV cache (affinity),
  * replica failure remaps ONLY that replica's sessions (minimal disruption)
    — the rest keep their warm caches,
  * replicas added back (restored) steal only the sessions that belonged to
    them (monotonicity), and (with Memento/Jump) the fleet can grow without
    bound.

The router is algorithm-pluggable: any :class:`~repro.core.ConsistentHash`
(Memento — the default —, Anchor, Dx, Jump) drives placement through the
same protocol.  Bulk routing (e.g. batch admission of thousands of queued
requests) runs on the device data plane via the algorithm's
``device_image()`` (`repro.kernels.ops.device_lookup`, Pallas on TPU).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import ConsistentHash, make_hash
from repro.core.hashing import key_to_u32


@dataclass
class RouterStats:
    routed: int = 0
    moved_on_failure: int = 0
    affinity_hits: int = 0


class SessionRouter:
    def __init__(self, num_replicas: int, *, algo: str | ConsistentHash = "memento",
                 capacity: int | None = None, use_device_plane: bool = False):
        if isinstance(algo, str):
            # variant="32": host lookups bit-identical to the device plane.
            self.ch = make_hash(algo, num_replicas, capacity=capacity, variant="32")
        else:
            self.ch = algo
        self.use_device_plane = use_device_plane
        self.stats = RouterStats()
        self._last: dict = {}   # session id → last replica (metrics)
        self._image = None      # cached device image; rebuilt after churn

    @property
    def memento(self) -> ConsistentHash:
        """Back-compat alias from the Memento-only router."""
        return self.ch

    # -- single-request path --------------------------------------------------
    def route(self, session_id) -> int:
        r = self.ch.lookup(key_to_u32(session_id))
        self.stats.routed += 1
        if self._last.get(session_id) == r:
            self.stats.affinity_hits += 1
        self._last[session_id] = r
        return r

    # -- bulk path (device plane) ----------------------------------------------
    def device_image(self):
        if self._image is None:
            self._image = self.ch.device_image()
        return self._image

    def route_batch(self, session_ids: np.ndarray) -> np.ndarray:
        from repro.core.hashing import np_key_to_u32
        keys = np_key_to_u32(np.asarray(session_ids))
        from repro.kernels import ops
        plane = "pallas" if self.use_device_plane else "jnp"
        return np.asarray(ops.device_lookup(keys, self.device_image(), plane=plane))

    # -- membership ----------------------------------------------------------
    def fail_replica(self, replica: int) -> dict:
        before = dict(self._last)
        self.ch.remove(replica)
        self._image = None
        moved = {s for s, r in before.items() if r == replica}
        self.stats.moved_on_failure += len(moved)
        return {"replica": replica, "sessions_moved": len(moved)}

    def restore_replica(self) -> int:
        b = self.ch.add()
        self._image = None
        return b

    @property
    def replicas(self) -> set[int]:
        return self.ch.working_set()


@dataclass
class Request:
    session_id: int
    tokens: list[int] = field(default_factory=list)


class BatchScheduler:
    """Groups admitted requests per replica into decode batches."""

    def __init__(self, router: SessionRouter, max_batch: int):
        self.router = router
        self.max_batch = max_batch

    def assign(self, requests: list[Request]) -> dict[int, list[Request]]:
        ids = np.asarray([r.session_id for r in requests], dtype=np.uint64)
        replicas = (self.router.route_batch(ids) if len(ids) else
                    np.zeros((0,), np.int32))
        out: dict[int, list[Request]] = {}
        for req, rep in zip(requests, replicas):
            out.setdefault(int(rep), []).append(req)
        for rep, lst in out.items():
            out[rep] = lst[: self.max_batch]  # back-pressure beyond max_batch
        return out
