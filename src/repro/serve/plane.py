"""ShardedLookupPlane — mesh-sharded serving for million-key batches.

The multi-device face of the lookup engine (DESIGN.md §6): one
``shard_map`` over the axes of a :mod:`repro.launch.mesh` mesh fans a key
batch across every device — each shard runs the engine's per-shard body
(the jnp dispatch off-TPU, the one-launch Pallas configuration on TPU)
against a **per-device replicated** copy of the
:class:`~repro.core.protocol.DeviceImage`.  The image rides a
:class:`~repro.core.DeviceImageStore` wherever the caller has one, so
membership churn reaches every device as the store's O(changed-words)
epoch deltas and the plane just re-pins the flipped front image
(``_ensure``); plain images and raw ConsistentHash states work too.

Throughput mechanics:

  * keys are padded to ``devices × 128`` lanes and sharded over the mesh
    axes; the image arrays and dynamic scalars are device_put once per
    epoch with a replicated sharding (no per-call broadcast),
  * the staged key buffer is **donated** to the jitted sharded program, so
    steady-state streaming keeps exactly two key buffers and two result
    buffers alive (double buffering),
  * :meth:`route_stream` overlaps host-side result materialization of
    batch *i* with device compute of batch *i+1* (dispatch is async).

Correctness: a sharded lookup is bit-identical to the single-device
engine for ANY mesh shape — the per-shard body is elementwise over keys
(tests/test_engine.py, including the forced multi-device subprocess
check).
"""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.core.packing import image_table_names
from repro.core.protocol import image_scalar_vec
from repro.obs.metrics import default_registry as _default_obs


def _is_store(source) -> bool:
    return hasattr(source, "image") and hasattr(source, "sync")


class ShardedLookupPlane:
    """Fan engine lookups over a device mesh with per-device images.

    ``source`` is a :class:`~repro.core.DeviceImageStore` (preferred: its
    epoch deltas keep the replicated image fresh), a raw
    :class:`~repro.core.protocol.DeviceImage`, or any ConsistentHash host
    state (snapshot on epoch change).  ``mesh`` defaults to a 1-D
    ``("data",)`` mesh over every device
    (:func:`repro.launch.mesh.make_lookup_mesh`); any mesh works — keys
    shard over the product of ``axes`` (default: all mesh axes).
    """

    def __init__(self, source, *, mesh=None, axes: tuple[str, ...] | None = None,
                 k: int = 1, plane: str = "jnp", interpret: bool | None = None,
                 block_rows: int | None = None, sync_mode: str = "block",
                 registry=None):
        import jax

        if plane not in ("jnp", "pallas", "auto"):
            raise ValueError(f"unknown plane {plane!r}")
        if k < 1:
            raise ValueError("k must be ≥ 1")
        if sync_mode not in ("block", "overlap"):
            raise ValueError(f"unknown sync_mode {sync_mode!r}")
        self.sync_mode = sync_mode
        if mesh is None:
            from repro.launch.mesh import make_lookup_mesh
            mesh = make_lookup_mesh()
        self.mesh = mesh
        self.axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
        self.k = k
        self.plane = plane
        self._interpret = (jax.default_backend() != "tpu"
                           if interpret is None else interpret)
        self._block_rows = block_rows
        self._source = source
        self._registry = registry  # None → follow the process default
        self._image = None       # host-side image the device copy mirrors
        self._dev = None         # (arrays dict, scalars tuple) replicated
        self._rep_cache: dict = {}  # name → (source array, replicated copy)
        self._fns: dict = {}     # (algo, shape sig, padded) → jitted program

    def _obs(self):
        """The live telemetry registry (injected, else process default)."""
        return self._registry or _default_obs()

    # -- mesh geometry -------------------------------------------------------
    @property
    def num_shards(self) -> int:  # obs-exempt: mesh geometry
        n = 1
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        for a in self.axes:
            n *= sizes[a]
        return n

    @property
    def lanes(self) -> int:  # obs-exempt: mesh geometry
        """Key-count granularity: every shard gets 128-aligned rows."""
        return self.num_shards * 128

    # -- image replication ---------------------------------------------------
    def _poll_source(self) -> None:
        """``sync_mode='overlap'``: land the store's pending async epoch iff
        its device result is ready (non-blocking), so the flip + re-pin
        pipeline between ``route_stream`` batches instead of stalling one."""
        if self.sync_mode == "overlap" and _is_store(self._source):
            poll = getattr(self._source, "poll", None)
            if poll is not None:
                poll()

    def _current_image(self):
        if _is_store(self._source):
            return self._source.image()
        if hasattr(self._source, "device_image"):
            src = self._source
            if self._image is not None and \
                    getattr(src, "epoch", None) == self._image.epoch:
                return self._image
            return src.device_image()
        return self._source  # a plain DeviceImage

    def _ensure(self):
        """Re-pin the replicated per-device image iff the epoch flipped.

        Arrays the store's out-of-place delta apply did NOT touch are the
        same objects across epochs, so their replicated copies are reused
        — per-flip fan-out cost is O(changed arrays), and the compiled
        sharded programs survive flips (they are keyed by shape, and every
        operand is an argument, not a constant)."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        img = self._current_image()
        if self._dev is not None and img is self._image:
            return
        self._obs().counter("plane.repins").inc()
        rep = NamedSharding(self.mesh, P())
        names = image_table_names(img)
        arrays = {}
        for n in names:
            src = img.arrays[n]
            cached = self._rep_cache.get(n)
            if cached is None or cached[0] is not src:
                self._rep_cache[n] = (src, jax.device_put(jnp.asarray(src),
                                                          rep))
            arrays[n] = self._rep_cache[n][1]
        scalars = tuple(jax.device_put(jnp.asarray(s, jnp.int32), rep)
                        for s in image_scalar_vec(img))
        self._image = img
        self._dev = (arrays, scalars)

    # -- the sharded program -------------------------------------------------
    def _sharded_fn(self, padded: int):
        """One jitted shard_map program per (algo, table shapes, padded
        key count) — epoch flips at stable shapes reuse the compiled
        program (the store pads capacities exactly so this holds)."""
        arrays, _ = self._dev
        packed = getattr(self._image, "packed", False)
        key = (self._image.algo, packed,
               tuple(sorted((n, a.shape) for n, a in arrays.items())),
               padded)
        fn = self._fns.get(key)
        if fn is not None:
            return fn
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.core.jax_lookup import lookup_dispatch
        from repro.kernels import autotune
        from repro.kernels.engine import (EngineOp, _engine_pallas, _pad_rows,
                                          _tables2d, algo_body, replica_body)
        from repro.sharding.rules import shard_map

        op = EngineOp(algo=self._image.algo, k=self.k,
                      table="packed" if packed else "dense")
        names = op.table_names
        # tuned parameters resolve once, at program-build time, against the
        # per-shard batch this program will always see (padded is part of
        # the fn cache key, so the resolution is as static as the jit key).
        shard_keys = padded // self.num_shards
        table_n = int(self._image.n)
        plane = self.plane
        if plane == "auto":
            plane = autotune.resolve_plane(op, shard_keys, table_n)
        block_rows = (self._block_rows if self._block_rows is not None
                      else autotune.resolve_block_rows(op, shard_keys, table_n))
        shard_dim = self.axes if len(self.axes) > 1 else self.axes[0]
        key_spec = P(shard_dim)

        def per_shard(keys, arrays, scalars):
            # keys travel as an int32 buffer so the k=1 result (int32, same
            # shape) can alias the donated input; bitcast restores uint32.
            keys = jax.lax.bitcast_convert_type(keys, jnp.uint32)
            if plane == "jnp":
                if packed:
                    body = lambda kk: algo_body(op, kk,
                                                [arrays[n] for n in names],
                                                list(scalars))
                else:
                    body = lambda kk: lookup_dispatch(op.algo, kk, arrays,
                                                      scalars)
                outs = replica_body(keys, op.k, body)
            else:  # one Pallas launch per shard, tables in VMEM
                keys2d, nk = _pad_rows(keys)
                tabs = tuple(_tables2d([arrays[n] for n in names]))
                scal = (jnp.stack(scalars) if scalars
                        else jnp.zeros((0,), jnp.int32))
                raw = _engine_pallas(
                    scal, (keys2d,), tabs, op=op,
                    block_rows=block_rows,
                    interpret=self._interpret)
                outs = [o.reshape(-1)[:nk] for o in raw]
            return outs[0] if op.k == 1 else jnp.stack(outs)  # [K'] | [k, K']

        f = shard_map(per_shard, mesh=self.mesh,
                      in_specs=(key_spec, P(), P()),
                      out_specs=key_spec if op.k == 1 else P(None, shard_dim))
        # k=1: the int32 result aliases the donated int32 key buffer —
        # steady-state streaming keeps two buffers alive, not 2×batches.
        fn = jax.jit(f, donate_argnums=(0,) if op.k == 1 else ())
        self._fns[key] = fn
        return fn

    def _stage(self, keys) -> tuple:
        """Pad + device_put a key batch with the sharded layout."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        keys = np.asarray(keys, dtype=np.uint32)
        n = len(keys)
        padded = max(self.lanes, -(-n // self.lanes) * self.lanes)
        buf = np.zeros(padded, np.int32)  # donated: int32 so results alias
        buf[:n] = keys.view(np.int32)
        key_spec = P(self.axes if len(self.axes) > 1 else self.axes[0])
        dev = jax.device_put(jnp.asarray(buf),
                             NamedSharding(self.mesh, key_spec))
        return dev, n, padded

    # -- public data plane ---------------------------------------------------
    def lookup(self, keys) -> np.ndarray:
        """Sharded batched lookup: keys [K] → np int32 [K] (k=1) or [K, k]."""
        reg = self._obs()
        t0 = time.perf_counter_ns() if reg.active else 0
        self._poll_source()
        self._ensure()
        dev, n, padded = self._stage(keys)
        arrays, scalars = self._dev
        out = self._sharded_fn(padded)(dev, arrays, scalars)
        res = self._finish(out, n)
        if reg.active:
            self._record_batch(reg, n, padded, t0)
        return res

    def route_stream(self, batches):
        """Stream key batches through the plane with double buffering.

        Yields one np result per input batch, in order.  The donated key
        buffers and the one-batch pipeline keep host staging of batch
        *i+1* overlapped with device compute of batch *i*.
        """
        reg = self._obs()
        pending = None  # (device out, n)
        for batch in batches:
            t0 = time.perf_counter_ns() if reg.active else 0
            self._poll_source()  # overlap: commit a ready async epoch
            self._ensure()  # pick up any epoch flip between batches
            arrays, scalars = self._dev
            dev, n, padded = self._stage(batch)
            out = self._sharded_fn(padded)(dev, arrays, scalars)  # async
            if reg.active:  # dispatch latency — materialization overlaps
                self._record_batch(reg, n, padded, t0)
            if pending is not None:
                yield self._finish(*pending)
            pending = (out, n)
        if pending is not None:
            yield self._finish(*pending)

    def _record_batch(self, reg, n: int, padded: int, t0_ns: int) -> None:
        """Per-batch plane telemetry: batch/key counters, the per-shard
        batch-size distribution, and the host-side dispatch latency."""
        reg.counter("plane.batches").inc()
        reg.counter("plane.keys").inc(n)
        reg.histogram("plane.shard_keys").observe(padded // self.num_shards)
        reg.histogram("plane.dispatch.us").observe(
            (time.perf_counter_ns() - t0_ns) / 1e3)

    def _finish(self, out, n) -> np.ndarray:
        out = np.asarray(out)
        return out[:n] if self.k == 1 else out[:, :n].T
