"""CI gate: the engine's HLO byte budget must not regress.

For a fixed set of deterministic engine configurations (algo × op ×
table layout, seeded churned states), this script lowers + compiles the
engine's jnp program and extracts bytes/key and flops/key from the HLO
cost model (``launch/hlo_analysis.analyze_jit`` — the same accounting
``bench_engine`` reports).  The numbers are compared against the
checked-in baseline ``benchmarks/results/HLO_baseline.json``:

* bytes/key **growth** beyond ``--tolerance`` (default 10 %) fails the
  run — an engine change silently inflating per-key memory traffic is
  exactly the regression this catches;
* reductions and new configurations are reported and pass — run with
  ``--update`` to rewrite the baseline (sorted keys, stable formatting)
  and commit the diff.

Counts come from compiled HLO on the CI backend (CPU), so they are
deterministic per jax version; the pinned CI leg gates hard, the
``latest`` leg stays advisory.

Usage:
    PYTHONPATH=src python scripts/check_hlo_budget.py [--update] [--tolerance 0.1]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

BASELINE = Path(__file__).resolve().parents[1] / "benchmarks" / "results" / "HLO_baseline.json"

W = 1024          # initial buckets
CAPACITY = 4 * W  # image capacity (a/w = 4)
REMOVALS = W // 4
N_KEYS = 8192
SEED = 32


def _state(algo):
    from repro.core import ALGORITHM_REGISTRY, make_hash

    h = make_hash(algo, W, capacity=CAPACITY, variant="32")
    rng = np.random.default_rng(SEED)
    lifo = ALGORITHM_REGISTRY[algo].lifo_only
    removals = min(REMOVALS, W - 1) if lifo else REMOVALS
    for _ in range(removals):
        if lifo:
            h.remove(h.size - 1)
        else:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])
    return h


def _account(images, op, keys):
    import jax.numpy as jnp

    from repro.kernels.engine import _engine_jnp, _jnp_operands
    from repro.launch.hlo_analysis import analyze_jit

    arrays, scalars = _jnp_operands(images)
    a = analyze_jit(_engine_jnp, (jnp.asarray(keys),), arrays, scalars,
                    None, None, static={"op": op})
    return {"bytes_per_key": round(a.traffic_bytes / N_KEYS, 2),
            "flops_per_key": round(a.flops / N_KEYS, 2)}


def measure() -> dict:
    """One entry per gated engine configuration: ``algo.op.table``."""
    from repro.core import ALGORITHMS
    from repro.core.packing import pack_image
    from repro.kernels.engine import EngineOp, _op_table

    keys = np.random.default_rng(SEED).integers(0, 2**32, size=N_KEYS,
                                                dtype=np.uint32)
    out: dict = {}
    for algo in ALGORITHMS:
        h = _state(algo)
        dense = h.device_image()
        layouts = [("dense", dense), ("packed", pack_image(dense))]
        for tag, img in layouts:
            table = _op_table(img)
            out[f"{algo}.lookup.k1.{tag}"] = _account(
                [img], EngineOp(algo=algo, table=table), keys)
            out[f"{algo}.lookup.k2.{tag}"] = _account(
                [img], EngineOp(algo=algo, k=2, table=table), keys)
            out[f"{algo}.diff.k1.{tag}"] = _account(
                [img, img], EngineOp(algo=algo, diff=True, table=table), keys)
    return out


def compare(current: dict, baseline: dict, tolerance: float) -> list[str]:
    failures = []
    for key in sorted(current):
        cur = current[key]["bytes_per_key"]
        base = baseline.get(key, {}).get("bytes_per_key")
        if base is None:
            print(f"  NEW   {key}: {cur} bytes/key (no baseline — passes)")
            continue
        ratio = cur / base if base else float("inf")
        status = "OK"
        if ratio > 1 + tolerance:
            status = "FAIL"
            failures.append(f"{key}: {base} → {cur} bytes/key "
                            f"(+{(ratio - 1) * 100:.1f}% > "
                            f"{tolerance * 100:.0f}% budget)")
        elif ratio < 1 - tolerance:
            status = "BETTER"
        print(f"  {status:6s}{key}: {base} → {cur} bytes/key "
              f"({(ratio - 1) * 100:+.1f}%)")
    for key in sorted(set(baseline) - set(current)):
        print(f"  GONE  {key}: configuration no longer measured "
              f"(update the baseline)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this run")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed bytes/key growth fraction (default 0.10)")
    ap.add_argument("--baseline", default=str(BASELINE))
    args = ap.parse_args(argv)

    print(f"# HLO byte budget: engine configs at w={W}, {N_KEYS} keys, "
          f"seed {SEED}")
    current = measure()
    path = Path(args.baseline)
    if args.update or not path.exists():
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {"w": W, "capacity": CAPACITY, "removals": REMOVALS,
             "n_keys": N_KEYS, "seed": SEED,
             "entries": {k: current[k] for k in sorted(current)}},
            indent=2, sort_keys=True) + "\n")
        print(f"# wrote baseline {path} ({len(current)} entries)")
        return 0
    baseline = json.loads(path.read_text()).get("entries", {})
    failures = compare(current, baseline, args.tolerance)
    if failures:
        print(f"# HLO byte budget EXCEEDED ({len(failures)}):")
        for f in failures:
            print(f"#   {f}")
        return 1
    print(f"# HLO byte budget OK ({len(current)} configs within "
          f"{args.tolerance * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
