#!/usr/bin/env python3
"""Offline markdown link check for the repo's docs.

Every *relative* link target in the tracked markdown files must exist on
disk (anchors are stripped; http(s)/mailto links are not fetched — CI must
stay deterministic offline).  Exits non-zero listing the dangling links.

    python scripts/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"^(```|~~~).*?^\1", re.MULTILINE | re.DOTALL)
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def main() -> int:
    bad: list[str] = []
    mds = sorted(p for p in ROOT.rglob("*.md")
                 if ".git" not in p.parts and "results" not in p.parts)
    for md in mds:
        text = FENCE.sub("", md.read_text())  # links inside code are not links
        for m in LINK.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path = (md.parent / target.split("#", 1)[0])
            if not path.exists():
                bad.append(f"{md.relative_to(ROOT)} -> {target}")
    if bad:
        print("dangling markdown links:")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"checked {len(mds)} markdown files: all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
