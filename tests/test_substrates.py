"""Substrate tests: data placement, pipeline resume, ckpt, runtime, router,
optimizer, and a tiny end-to-end training-loss check."""
from __future__ import annotations

import numpy as np
import pytest

from repro.ckpt import AsyncCheckpointer, restore_checkpoint, save_checkpoint
from repro.data import DataPipeline, ShardPlacement, synthetic_shard_tokens
from repro.runtime import ElasticCluster, StragglerMonitor
from repro.serve.router import BatchScheduler, Request, SessionRouter


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_shard_placement_minimal_disruption():
    p = ShardPlacement(num_shards=512, num_hosts=16)
    baseline = p.assignment()
    sizes = [len(v) for v in baseline.values()]
    assert sum(sizes) == 512
    assert max(sizes) - min(sizes) < 6 * np.sqrt(512 / 16)  # balance

    plan = p.fail_host(5)
    assert plan["minimal"]
    assert set(plan["moved"]) == set(baseline[5])
    assert all(h != 5 for h in plan["moved"].values())

    plan2 = p.add_host()
    assert plan2["host"] == 5 and plan2["monotone"]
    assert p.assignment() == baseline  # exact restoration


def test_pipeline_determinism_and_resume():
    p = ShardPlacement(num_shards=64, num_hosts=4)
    pipe = DataPipeline(p, host=1, batch=4, seq_len=32, vocab_size=1000)
    b1 = pipe.next_batch()
    b2 = pipe.next_batch()
    st = pipe.state()
    b3 = pipe.next_batch()

    pipe2 = DataPipeline(p, host=1, batch=4, seq_len=32, vocab_size=1000)
    pipe2.load_state(st)
    b3r = pipe2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], b3r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < 1000


def test_synthetic_tokens_offset_continuity():
    a = synthetic_shard_tokens(7, 64, 500, offset=0)
    b = synthetic_shard_tokens(7, 32, 500, offset=32)
    np.testing.assert_array_equal(a[32:], b)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tiny_state():
    rng = np.random.default_rng(0)
    return {"params": {"w": rng.normal(size=(8, 8)).astype(np.float32),
                       "b": rng.normal(size=(8,)).astype(np.float32)},
            "opt": {"m": {"w": np.zeros((8, 8), np.float32),
                          "b": np.ones((8,), np.float32)},
                    "step": np.int32(7)}}


def test_ckpt_roundtrip(tmp_path):
    st = _tiny_state()
    save_checkpoint(st, 10, tmp_path, num_buckets=3)
    restored, manifest = restore_checkpoint(tmp_path)
    assert manifest["step"] == 10
    np.testing.assert_array_equal(restored["params"]["w"], st["params"]["w"])
    np.testing.assert_array_equal(restored["opt"]["m"]["b"], st["opt"]["m"]["b"])
    assert int(restored["opt"]["step"]) == 7


def test_ckpt_async_and_gc(tmp_path):
    ck = AsyncCheckpointer(tmp_path, num_buckets=2, keep=2)
    st = _tiny_state()
    for step in (1, 2, 3, 4):
        ck.save(st, step)
    ck.wait()
    from repro.ckpt.store import latest_step
    assert latest_step(tmp_path) == 4
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2  # gc kept last 2


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

def test_elastic_cluster_failure_and_rejoin():
    c = ElasticCluster(num_hosts=8, num_shards=128)
    base = c.placement.assignment()
    c.fail(3)
    c.fail(6)
    assert c.hosts == set(range(8)) - {3, 6}
    c.join()  # restores 6 (LIFO)
    c.join()  # restores 3
    assert c.hosts == set(range(8))
    assert c.placement.assignment() == base
    assert c.movement_total() < 4 * (128 // 8 + 10)


def test_straggler_monitor():
    mon = StragglerMonitor(k_sigma=3.0)
    rng = np.random.default_rng(0)
    for _ in range(20):
        mon.observe(1.0 + 0.01 * rng.normal())
    res = mon.filter_step({0: 1.0, 1: 1.01, 2: 9.0, 3: 0.99})
    assert res["skipped"] == {2}
    assert res["participants"] == {0, 1, 3}
    assert res["grad_scale"] == pytest.approx(4 / 3)


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

def test_router_affinity_and_failover():
    r = SessionRouter(num_replicas=8)
    sessions = list(range(1000, 1400))
    first = {s: r.route(s) for s in sessions}
    again = {s: r.route(s) for s in sessions}
    assert first == again  # perfect affinity while stable

    victim = first[sessions[0]]
    r.fail_replica(victim)
    after = {s: r.route(s) for s in sessions}
    for s in sessions:
        if first[s] != victim:
            assert after[s] == first[s], "warm session moved!"
        else:
            assert after[s] != victim
    b = r.restore_replica()
    assert b == victim
    assert {s: r.route(s) for s in sessions} == first


def test_router_batch_matches_scalar():
    r = SessionRouter(num_replicas=16)
    for _ in range(5):
        r.fail_replica(sorted(r.replicas)[2])
    ids = np.arange(5000, 5512, dtype=np.uint32)
    batch = r.route_batch(ids)
    from repro.core.hashing import key_to_u32
    scalar = np.asarray([r.memento.lookup(key_to_u32(int(s))) for s in ids])
    np.testing.assert_array_equal(batch, scalar)


def test_batch_scheduler_groups_by_replica():
    r = SessionRouter(num_replicas=4)
    sched = BatchScheduler(r, max_batch=64)
    reqs = [Request(session_id=i) for i in range(300)]
    groups, overflow = sched.assign(reqs)
    assert set(groups) <= r.replicas
    # nothing is dropped: every request is either admitted or in overflow
    admitted = sum(len(v) for v in groups.values())
    assert admitted + len(overflow) == 300
    assert all(len(v) <= 64 for v in groups.values())
    assert admitted > 150  # sane balance across 4 replicas
    assert sched.pending == overflow  # re-queued for the next round
    # next round drains the overflow first
    groups2, overflow2 = sched.assign([])
    assert sum(len(v) for v in groups2.values()) + len(overflow2) == len(overflow)


# ---------------------------------------------------------------------------
# optimizer + tiny end-to-end: loss decreases
# ---------------------------------------------------------------------------

def test_train_loss_decreases_tiny_lm():
    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.models import LM
    from repro.train import TrainStepConfig, init_state, make_train_step

    cfg = smoke_config("gemma-2b")
    model = LM(cfg, attn_chunk=8)
    state = init_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, TrainStepConfig(lr=1e-2, microbatches=1)))

    p = ShardPlacement(num_shards=8, num_hosts=2)
    pipe = DataPipeline(p, host=0, batch=4, seq_len=16, vocab_size=cfg.vocab_size)
    losses = []
    batch0 = pipe.next_batch()  # overfit one batch: loss must drop fast
    batch = {k: jnp.asarray(v) for k, v in batch0.items()}
    for _ in range(20):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
    assert np.isfinite(losses).all()


def test_microbatched_grad_matches_single():
    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.models import LM
    from repro.train import TrainStepConfig, init_state, make_train_step

    cfg = smoke_config("qwen2.5-14b")
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = LM(cfg, attn_chunk=8, remat="none")
    state1 = init_state(model, jax.random.PRNGKey(1))
    state2 = jax.tree.map(jnp.copy, state1)

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)}
    s1 = make_train_step(model, TrainStepConfig(microbatches=1))
    s4 = make_train_step(model, TrainStepConfig(microbatches=4))
    new1, m1 = jax.jit(s1)(state1, batch)
    new4, m4 = jax.jit(s4)(state2, batch)
    for a, b in zip(jax.tree.leaves(new1["params"]), jax.tree.leaves(new4["params"])):
        # f32 reduction-order noise through Adam's 1/(√v+ε): absolute tolerance
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-4)
