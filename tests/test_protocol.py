"""ConsistentHash protocol conformance — one shared suite, four algorithms.

Every implementation (Memento, Anchor, Dx, Jump) must satisfy the same
contract: structural protocol membership, lookups land on working buckets,
minimal disruption on remove, monotonicity on add, sane memory accounting,
and a ``device_image()`` whose jnp lookup matches the host plane
(``variant="32"`` states).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import ConsistentHash, DeviceImage, make_hash

ALGOS = ("memento", "anchor", "dx", "jump")
KEYS = [int(k) for k in np.random.default_rng(0).integers(0, 2**63, size=300)]


def _mk(algo, n0=40, variant="64"):
    return make_hash(algo, n0, capacity=4 * n0, variant=variant)


def _churn(h, removals, seed=0):
    """Random removals (LIFO for Jump, which supports nothing else)."""
    rng = np.random.default_rng(seed)
    for _ in range(removals):
        if h.name == "jump":
            h.remove(h.size - 1)
        else:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])


@pytest.mark.parametrize("algo", ALGOS)
def test_protocol_membership(algo):
    h = _mk(algo)
    assert isinstance(h, ConsistentHash)
    assert h.name == algo


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("variant", ["64", "32"])
def test_lookup_lands_on_working(algo, variant):
    h = _mk(algo, variant=variant)
    _churn(h, 15, seed=1)
    ws = h.working_set()
    assert len(ws) == h.working
    for k in KEYS:
        assert h.lookup(k) in ws


@pytest.mark.parametrize("algo", ALGOS)
def test_minimal_disruption_and_monotonicity(algo):
    h = _mk(algo)
    _churn(h, 8, seed=2)
    before = {k: h.lookup(k) for k in KEYS}
    victim = (h.size - 1 if algo == "jump"
              else sorted(h.working_set())[len(h.working_set()) // 2])
    h.remove(victim)
    for k in KEYS:
        if before[k] != victim:
            assert h.lookup(k) == before[k], "non-victim key moved"
        else:
            assert h.lookup(k) != victim
    b = h.add()
    assert b == victim  # all four restore the most recent removal
    assert {k: h.lookup(k) for k in KEYS} == before


@pytest.mark.parametrize("algo", ALGOS)
def test_memory_accounting(algo):
    h = _mk(algo)
    m0 = h.memory_bytes()
    assert isinstance(m0, int) and m0 > 0
    _churn(h, 10, seed=3)
    assert h.memory_bytes() >= m0 - 8  # Jump may shrink; others only grow


@pytest.mark.parametrize("algo", ALGOS)
def test_device_image_matches_host(algo):
    import jax.numpy as jnp
    from repro.core.jax_lookup import lookup_image

    h = _mk(algo, n0=64, variant="32")
    _churn(h, 25, seed=4)
    image = h.device_image()
    assert isinstance(image, DeviceImage)
    assert image.algo == algo
    for arr in image.arrays.values():
        assert arr.shape[0] % 128 == 0, "device arrays must be lane-padded"
        assert arr.dtype in (np.int32, np.uint32)
    keys = np.asarray(KEYS, dtype=np.uint64).astype(np.uint32)
    dev = np.asarray(lookup_image(jnp.asarray(keys), image))
    host = np.asarray([h.lookup(int(k)) for k in keys], dtype=np.int32)
    np.testing.assert_array_equal(dev, host)


@pytest.mark.parametrize("algo", ALGOS)
def test_image_is_snapshot(algo):
    """Membership changes must not leak into previously-built images."""
    import jax.numpy as jnp
    from repro.core.jax_lookup import lookup_image

    h = _mk(algo, n0=32, variant="32")
    image = h.device_image()
    keys = jnp.asarray(np.asarray(KEYS[:64], dtype=np.uint64).astype(np.uint32))
    before = np.asarray(lookup_image(keys, image))
    _churn(h, 5, seed=5)
    np.testing.assert_array_equal(np.asarray(lookup_image(keys, image)), before)


def test_make_hash_rejects_unknown():
    with pytest.raises(ValueError):
        make_hash("rendezvous", 8)
