"""Protocol wire-format pins.

The per-algorithm protocol conformance grid (membership, lookup landing,
disruption/monotonicity, memory accounting, device images) lives in
``tests/test_conformance.py`` now, derived from
:data:`repro.core.ALGORITHM_REGISTRY`.  What remains here are the pins
that must NOT derive from the registry: the wire format is positional,
so the registry order itself is an append-only contract.
"""
from __future__ import annotations

from conformance import ALGORITHMS


def test_wire_order_is_append_only():
    """Replication frame algo ids are positional (``launch/replicate``),
    so the registry order is wire format: entries may only be appended.
    A new algorithm extends this literal; reordering it is a protocol
    break."""
    assert ALGORITHMS == (
        "memento", "anchor", "dx", "jump", "power")  # registry-literal-ok


def test_replication_algo_ids_match_registry_order():
    from repro.launch.replicate import _ALGO_IDS

    assert _ALGO_IDS == {name: i for i, name in enumerate(ALGORITHMS)}
