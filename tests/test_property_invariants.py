"""Hypothesis property tests — random op sequences, all invariants at once.

Kept separate from ``test_core_algorithms.py`` so environments without
``hypothesis`` (an optional dev dependency, see requirements-dev.txt) skip
these instead of failing the whole collection.
"""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import AnchorHash, MementoHash  # noqa: E402

RNG = np.random.default_rng(0)
KEYS = [int(k) for k in RNG.integers(0, 2**63, size=400)]


@st.composite
def op_sequences(draw):
    n0 = draw(st.integers(min_value=2, max_value=40))
    ops = draw(st.lists(st.tuples(st.sampled_from(["remove", "add"]),
                                  st.integers(0, 10**9)), max_size=40))
    return n0, ops


@given(op_sequences())
@settings(max_examples=60, deadline=None)
def test_property_memento_invariants(seq):
    n0, ops = seq
    m = MementoHash(n0)
    keys = KEYS[:120]
    prev = {k: m.lookup(k) for k in keys}
    for op, salt in ops:
        if op == "remove" and m.working > 1:
            ws = sorted(m.working_set())
            victim = ws[salt % len(ws)]
            m.remove(victim)
            cur = {k: m.lookup(k) for k in keys}
            for k in keys:
                if prev[k] != victim:
                    assert cur[k] == prev[k]  # minimal disruption
                else:
                    assert cur[k] != victim
            prev = cur
        elif op == "add":
            b = m.add()
            cur = {k: m.lookup(k) for k in keys}
            for k in keys:
                assert cur[k] == prev[k] or cur[k] == b  # monotonicity
            prev = cur
        # global invariants
        assert m.working == m.n - len(m.R)
        ws = m.working_set()
        assert all(v in ws for v in prev.values())


@given(op_sequences())
@settings(max_examples=30, deadline=None)
def test_property_anchor_invariants(seq):
    n0, ops = seq
    h = AnchorHash(capacity=3 * n0 + 8, initial_node_count=n0)
    keys = KEYS[:60]
    for op, salt in ops:
        if op == "remove" and h.working > 1:
            ws = sorted(h.working_set())
            h.remove(ws[salt % len(ws)])
        elif op == "add" and h.R:
            h.add()
        ws = h.working_set()
        assert len(ws) == h.working
        for k in keys:
            assert h.lookup(k) in ws
