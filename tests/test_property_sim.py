"""Hypothesis property tests for the scenario engine (DESIGN.md §7).

Gated on ``hypothesis`` like the other property suites.  The core
property (ISSUE 5 acceptance): for RANDOM scenario scripts — arbitrary
interleavings of removal bursts, additions, and traffic over a random
fleet — the replayed guarantee checkers never fire: minimal disruption
and monotonicity hold exactly per event, balance stays within the ε
bound, and the replay is deterministic (same script → same fingerprint).
"""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conformance import ALGORITHMS as ALGOS  # noqa: E402
from repro.sim import Trace, TraceEvent, replay  # noqa: E402


def _random_script(draw) -> tuple[str, Trace]:
    algo = draw(st.sampled_from(ALGOS))
    w = draw(st.integers(min_value=8, max_value=48))
    seed = draw(st.integers(min_value=0, max_value=2**31))
    n_events = draw(st.integers(min_value=1, max_value=8))
    events: list[TraceEvent] = []
    for _ in range(n_events):
        op = draw(st.sampled_from(("remove", "add", "lookup", "remove")))
        if op == "remove":
            events.append(TraceEvent(
                "remove",
                count=draw(st.integers(min_value=1, max_value=6)),
                select=draw(st.sampled_from(("random", "lifo", "first"))),
                sync=draw(st.booleans())))
        elif op == "add":
            events.append(TraceEvent(
                "add", count=draw(st.integers(min_value=1, max_value=4))))
        else:
            events.append(TraceEvent(
                "lookup", n_keys=256,
                dist=draw(st.sampled_from(("uniform", "zipf")))))
    events.append(TraceEvent("lookup", n_keys=256))  # always end with traffic
    return algo, Trace("random_script", seed, w, events)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_random_scripts_never_fire_the_checkers(data):
    """Minimal disruption, monotonicity, and balance hold for every random
    lifecycle — the paper's guarantees as a property over the whole event
    space, replayed through the real device stack."""
    algo, trace = _random_script(data.draw)
    r = replay(trace, algo=algo, plane="jnp", probe_keys=768)
    assert r.ok, [str(v) for v in r.violations]


@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_random_scripts_replay_deterministically(data):
    algo, trace = _random_script(data.draw)
    a = replay(trace, algo=algo, plane="jnp", probe_keys=256, check=False)
    b = replay(trace, algo=algo, plane="jnp", probe_keys=256, check=False)
    assert a.fingerprint == b.fingerprint
    # and the resolved script replays to the same placements
    c = replay(Trace.from_json(a.resolved.to_json()), algo=algo,
               plane="jnp", probe_keys=256, check=False)
    assert c.fingerprint == a.fingerprint


@settings(max_examples=6, deadline=None)
@given(algo=st.sampled_from(ALGOS),
       seed=st.integers(min_value=0, max_value=2**31),
       k=st.integers(min_value=2, max_value=3))
def test_replica_stability_bound_under_random_churn(algo, seed, k):
    """k-replica sets only change for keys whose salted walk candidates
    touched a victim (DESIGN.md §4.1), replayed per removal event."""
    events = [TraceEvent("remove", count=c) for c in (2, 1, 3)]
    trace = Trace("replica_churn", seed, 32, events)
    r = replay(trace, algo=algo, plane="jnp", probe_keys=384, replica_k=k)
    assert r.ok, [str(v) for v in r.violations]
