"""CI smoke for the benchmark harness (quick sizes).

Deterministic shape/metadata checks ONLY — wall-clock claim orderings
(e.g. "Memento ≤ 2× Jump") are load-sensitive and flaked under parallel
CI, so they are printed by ``benchmarks.run`` for humans but not asserted
here.  The harness must also never rewrite the tracked golden artifact
(``benchmarks/results/paper/bench.csv``) unless ``--update-golden`` is
passed — ordinary runs land in a run-scoped directory.
"""
from __future__ import annotations

import csv
from pathlib import Path

GOLDEN = Path(__file__).resolve().parent.parent / "benchmarks" / "results" \
    / "paper" / "bench.csv"

EXPECTED_TABLES = {
    "stable_lookup", "stable_memory", "oneshot_worst_memory",
    "oneshot_best_memory", "incremental_worst_lookup",
    "sensitivity_stable_lookup", "sensitivity_stable_memory",
    "quality_balance", "quality_min_disruption", "quality_monotonicity",
    "resize",
}


def test_benchmarks_quick_shapes_and_run_scoped_output(tmp_path):
    from benchmarks.run import main

    golden_before = GOLDEN.read_bytes() if GOLDEN.exists() else None
    rc = main(["--quick", "--out-dir", str(tmp_path)])
    assert rc in (0, 1)  # 1 = a timing-ordering claim missed under load

    out = tmp_path / "bench.csv"
    assert out.exists(), "run did not write its run-scoped bench.csv"
    with open(out, newline="") as f:
        rows = list(csv.DictReader(f))
    assert {r["table"] for r in rows} >= EXPECTED_TABLES
    from conformance import ALGORITHMS
    algos = {r["algo"] for r in rows if r["table"] == "stable_lookup"}
    assert algos == set(ALGORITHMS)
    # every emitted value parses as a finite number
    vals = [float(r["value"]) for r in rows]
    assert all(v == v for v in vals)  # no NaNs
    assert all(float(r["value"]) > 0 for r in rows
               if r["metric"] == "us_per_lookup")
    assert all(float(r["value"]) >= 0 for r in rows if r["metric"] == "bytes")

    # the tracked golden artifact must be untouched by a normal run
    golden_after = GOLDEN.read_bytes() if GOLDEN.exists() else None
    assert golden_after == golden_before, \
        "benchmarks.run rewrote the tracked bench.csv without --update-golden"


def test_device_plane_bench_smoke():
    from benchmarks.bench_device_plane import bench_device_plane
    rows = []
    bench_device_plane(lambda *r: rows.append(r), sizes=((256, 40),), n_keys=1024)
    algos = {r[1] for r in rows}
    assert algos == {"host_scalar", "jnp_batched", "pallas_interpret"}
    assert all(r[4] > 0 for r in rows)


def test_engine_bench_smoke():
    """Engine benchmark emits its schema and its correctness gates hold
    (timings advisory; fused-vs-legacy equality is asserted inside)."""
    from benchmarks.bench_engine import bench_engine, check_engine_claims
    rows = []
    summary = bench_engine(lambda *r: rows.append(r), w=128,
                           key_counts=(2048,), k_values=(1, 2),
                           algos=("memento", "jump"), scenarios=("stable",))
    assert rows and all(isinstance(r[4], (int, float)) for r in rows)
    assert check_engine_claims(summary)
    mesh = summary["mesh"]
    assert mesh["devices"] >= 1
    for key, e in summary["results"].items():
        assert e["sharded_equal"], key


def test_scenarios_bench_smoke():
    """Scenario benchmark emits its schema and every deterministic gate
    holds (checkers silent, cross-plane fingerprints equal, knee in-band)
    on a trimmed scenario × algo grid."""
    from benchmarks.bench_scenarios import bench_scenarios, check_scenario_claims
    rows = []
    summary = bench_scenarios(lambda *r: rows.append(r), w=24, n_keys=384,
                              probe_keys=384, deg_w=128, deg_keys=256,
                              scenarios=("oneshot", "flapping"),
                              algos=("memento", "dx"))
    assert rows and all(isinstance(r[4], (int, float)) for r in rows)
    assert check_scenario_claims(summary)
    for key, s in summary["results"].items():
        assert s["violations"] == 0, key
    assert summary["results"]["oneshot_memento"]["planes_agree"]
