"""CI smoke for the benchmark harness (quick sizes) + paper-claims check."""
from __future__ import annotations


def test_benchmarks_quick_and_claims_pass(capsys):
    from benchmarks.run import main
    assert main(["--quick"]) == 0, "paper-claims check failed at quick sizes"


def test_device_plane_bench_smoke():
    from benchmarks.bench_device_plane import bench_device_plane
    rows = []
    bench_device_plane(lambda *r: rows.append(r), sizes=((256, 40),), n_keys=1024)
    algos = {r[1] for r in rows}
    assert algos == {"host_scalar", "jnp_batched", "pallas_interpret"}
    assert all(r[4] > 0 for r in rows)
