"""Host ⇄ device data-plane equivalence for batched Memento lookups."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import MementoHash, MementoTables, np_jump32, random_state
from repro.core import jax_lookup


@pytest.fixture(scope="module")
def keys():
    return np.random.default_rng(0).integers(0, 2**32, size=512, dtype=np.uint32)


def test_jnp_jump_matches_numpy(keys):
    import jax.numpy as jnp

    for n in (1, 3, 97, 4096, 100000):
        dev = np.asarray(jax_lookup.jump32(jnp.asarray(keys), n))
        host = np_jump32(keys, n)
        np.testing.assert_array_equal(dev, host)


@pytest.mark.parametrize("n0,removals", [(16, 0), (16, 7), (128, 50), (1000, 400)])
def test_jnp_memento_matches_host(keys, n0, removals):
    import jax.numpy as jnp

    m = random_state(np.random.default_rng(1), n0, removals, variant="32")
    tabs = MementoTables(m)
    out = np.asarray(jax_lookup.memento_lookup(jnp.asarray(keys), jnp.asarray(tabs.repl), m.n))
    ws = m.working_set()
    host = np.asarray([m.lookup(int(k)) for k in keys])
    np.testing.assert_array_equal(out, host)
    assert set(out.tolist()) <= ws


def test_jnp_memento_balance(keys):
    import jax.numpy as jnp

    m = random_state(np.random.default_rng(2), 32, 12, variant="32")
    tabs = MementoTables(m)
    big = np.random.default_rng(3).integers(0, 2**32, size=50000, dtype=np.uint32)
    out = np.asarray(jax_lookup.memento_lookup(jnp.asarray(big), jnp.asarray(tabs.repl), m.n))
    counts = np.bincount(out, minlength=m.n)
    ws = sorted(m.working_set())
    expected = len(big) / len(ws)
    assert counts[[b for b in range(m.n) if b not in ws]].sum() == 0
    for b in ws:
        assert abs(counts[b] - expected) < 6 * np.sqrt(expected)


def test_tables_incremental_updates():
    m = MementoHash(64, variant="32")
    tabs = MementoTables(m)
    rng = np.random.default_rng(4)
    for step in range(60):
        if rng.random() < 0.6 and m.working > 1:
            ws = sorted(m.working_set())
            b = ws[int(rng.integers(len(ws)))]
            m.remove(b)
            tabs.on_remove(b)
        else:
            b = m.add()
            tabs.on_add(b)
        tabs.check()
