"""Hypothesis property tests for the epoch-delta control plane.

Gated on ``hypothesis`` like ``test_property_invariants.py``.  The core
property (ISSUE 2 acceptance): across ≥1000 random remove/add events per
algorithm, delta-applied device images must stay bit-identical to fresh
``device_image()`` snapshots — on both the jnp and the Pallas-interpret
apply planes.  Syncs happen every few events, so the test also exercises
multi-event delta composition (last-write-wins merge).
"""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conformance import (ALGORITHM_REGISTRY, ALGORITHMS as ALGOS,  # noqa: E402
                         pick_victim)
from repro.core import DeviceImageStore, make_hash  # noqa: E402

# events per hypothesis example; with max_examples=5 every (algo, plane)
# cell sees ≥1000 random events
EVENTS = 250
SYNC_EVERY = {"jnp": 5, "pallas": 25}  # interpret-mode applies are pricier


def _churn_once(h, rng):
    if h.working > 1 and (rng.random() < 0.6
                          or (ALGORITHM_REGISTRY[h.name].fixed_capacity
                              and not h.R)):
        h.remove(pick_victim(h, rng))
    else:
        try:
            h.add()
        except ValueError:
            h.remove(pick_victim(h, rng))


def _assert_bit_identical(store, h):
    fresh = h.device_image()
    img = store.image()
    assert img.n == fresh.n and img.epoch == fresh.epoch == h.epoch
    assert img.scalars == fresh.scalars
    for name, arr in fresh.arrays.items():
        got = np.asarray(img.arrays[name])
        np.testing.assert_array_equal(got[: arr.shape[0]], arr)
        # headroom beyond the snapshot must hold the algorithm's fill value
        if name == "repl":
            assert np.all(got[arr.shape[0]:] == -1)


@pytest.mark.parametrize("plane", ["jnp", "pallas"])
@pytest.mark.parametrize("algo", ALGOS)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=5, deadline=None)
def test_property_delta_applied_images_bit_identical(algo, plane, seed):
    rng = np.random.default_rng(seed)
    n0 = int(rng.integers(8, 120))
    h = make_hash(algo, n0, capacity=4 * n0, variant="32")
    store = DeviceImageStore(h, plane=plane)
    sync_every = SYNC_EVERY[plane]
    for i in range(EVENTS):
        _churn_once(h, rng)
        if (i + 1) % sync_every == 0:
            store.sync()
            _assert_bit_identical(store, h)
    store.sync()
    _assert_bit_identical(store, h)
    # the run must exercise the delta path, not hide behind rebuilds
    assert store.totals.delta_applies >= store.totals.snapshot_rebuilds


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=10, deadline=None)
def test_property_epoch_flip_serves_old_epoch(seed):
    """Mid-apply atomicity: the retained epoch answers exactly as the host
    did at that epoch, for every algorithm, after arbitrary churn."""
    from repro.core.jax_lookup import lookup_image

    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**32, size=128, dtype=np.uint32)
    algo = ALGOS[int(rng.integers(len(ALGOS)))]
    h = make_hash(algo, 32, capacity=128, variant="32")
    store = DeviceImageStore(h)
    for _ in range(int(rng.integers(1, 30))):
        _churn_once(h, rng)
    store.sync()
    frozen = store.image()
    want = np.asarray([h.lookup(int(k)) for k in keys], np.int32)
    for _ in range(int(rng.integers(1, 20))):
        _churn_once(h, rng)
    store.sync()  # flips epochs; `frozen` must be untouched
    np.testing.assert_array_equal(np.asarray(lookup_image(keys, frozen)), want)
    now = np.asarray([h.lookup(int(k)) for k in keys], np.int32)
    np.testing.assert_array_equal(store.lookup(keys), now)
