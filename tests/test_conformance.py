"""The unified algorithm-conformance suite (ISSUE 8's headline satellite).

One parameterized grid — algorithm registry × {host, jnp, pallas} ×
{lookup, lookup_k, bounded, diff, delta-replay, packed} — replacing the
per-algorithm parametrize lists that used to be copy-pasted across
``test_protocol.py`` / ``test_device_planes.py`` / ``test_engine.py``.
Everything below derives from :data:`repro.core.ALGORITHM_REGISTRY`, so
adding algorithm #6 to that registry (one entry) enrolls it in every
test here with zero test edits; a grep-style source scan asserts nobody
reintroduces a hard-coded algorithm list elsewhere.
"""
from __future__ import annotations

import re
from pathlib import Path

import numpy as np
import pytest

from conformance import (ALGORITHM_REGISTRY, ALGORITHMS, DEVICE_PLANES,
                         churn, churn_mixed, lifo_only, make, state)
from repro.core import (ConsistentHash, DeviceImage, apply_delta,
                        image_fingerprint, make_hash)
from repro.core.protocol import replica_sets
from repro.kernels import engine, ref

KEYS = np.random.default_rng(77).integers(0, 2**32, size=600,
                                          dtype=np.uint32)
KEYS64 = [int(k) for k in
          np.random.default_rng(0).integers(0, 2**63, size=300)]


# ---------------------------------------------------------------------------
# Registry integrity: one entry is ALL an algorithm needs
# ---------------------------------------------------------------------------

def test_registry_names_are_keys_and_ordered():
    assert tuple(ALGORITHM_REGISTRY) == ALGORITHMS
    for name, info in ALGORITHM_REGISTRY.items():
        assert info.name == name


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_registry_entry_is_self_consistent(algo):
    """The factory, layouts, and flags of one registry entry agree with
    the instance they build — the contract algorithm #6 must meet."""
    info = ALGORITHM_REGISTRY[algo]
    h = make(algo)
    assert isinstance(h, ConsistentHash)
    assert h.name == algo
    image = h.device_image()
    assert image.algo == algo
    assert set(image.arrays) >= set(info.tables)
    req = info.required(h.size)
    assert set(req) <= set(info.tables)
    if info.lifo_only:
        with pytest.raises(ValueError):
            h.remove(0 if h.size > 1 else h.size)  # non-LIFO removal
    if not info.fixed_capacity:
        for _ in range(3 * h.size):
            h.add()  # growable: no capacity ceiling


def test_make_hash_rejects_unknown():
    with pytest.raises(ValueError):
        make_hash("rendezvous", 8)


def test_report_algos_literal_matches_registry():
    """benchmarks/report.py is stdlib-only (docs CI has no numpy/jax), so
    it carries a literal copy of the registry order — keep it synced."""
    from benchmarks.report import ALGOS
    assert tuple(ALGOS) == ALGORITHMS


def test_no_hardcoded_algorithm_lists():
    """Grep-style scan: no source line outside the registry may enumerate
    three or more algorithm names — derive from ALGORITHMS instead.
    Deliberate two-name scopings (e.g. a trimmed benchmark grid) pass;
    a line carrying the ``registry-literal-ok`` marker is whitelisted."""
    root = Path(__file__).resolve().parent.parent
    pat = re.compile("|".join(f"[\"']{n}[\"']" for n in ALGORITHMS))
    offenders = []
    for sub in ("src", "tests", "benchmarks", "scripts", "examples"):
        for path in sorted((root / sub).rglob("*.py")):
            for lineno, line in enumerate(
                    path.read_text().splitlines(), start=1):
                if "registry-literal-ok" in line:
                    continue
                names = {m.strip("\"'") for m in pat.findall(line)}
                if len(names) >= 3:
                    offenders.append(f"{path.relative_to(root)}:{lineno}: "
                                     f"{line.strip()}")
    assert not offenders, (
        "hard-coded algorithm lists (derive from repro.core.ALGORITHMS):\n"
        + "\n".join(offenders))


# ---------------------------------------------------------------------------
# Host-plane protocol conformance (was test_protocol.py's parametrize grid)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("variant", ["64", "32"])
def test_lookup_lands_on_working(algo, variant):
    h = make(algo, variant=variant)
    churn(h, 15, seed=1)
    ws = h.working_set()
    assert len(ws) == h.working
    for k in KEYS64:
        assert h.lookup(k) in ws


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_minimal_disruption_and_monotonicity(algo):
    h = make(algo, variant="64")
    churn(h, 8, seed=2)
    before = {k: h.lookup(k) for k in KEYS64}
    victim = (h.size - 1 if lifo_only(algo)
              else sorted(h.working_set())[len(h.working_set()) // 2])
    h.remove(victim)
    for k in KEYS64:
        if before[k] != victim:
            assert h.lookup(k) == before[k], "non-victim key moved"
        else:
            assert h.lookup(k) != victim
    b = h.add()
    assert b == victim  # every algorithm restores the most recent removal
    assert {k: h.lookup(k) for k in KEYS64} == before


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_memory_accounting(algo):
    h = make(algo, variant="64")
    m0 = h.memory_bytes()
    assert isinstance(m0, int) and m0 > 0
    churn(h, 10, seed=3)
    assert h.memory_bytes() >= m0 - 8  # LIFO shrink may shed; others grow


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_image_is_snapshot(algo):
    """Membership changes must not leak into previously-built images."""
    import jax.numpy as jnp
    from repro.core.jax_lookup import lookup_image

    h = make(algo, n0=32)
    image = h.device_image()
    keys = jnp.asarray(KEYS[:64])
    before = np.asarray(lookup_image(keys, image))
    churn(h, 5, seed=5)
    np.testing.assert_array_equal(np.asarray(lookup_image(keys, image)),
                                  before)


@pytest.mark.parametrize("algo", ALGORITHMS)
def test_image_arrays_are_lane_padded(algo):
    h = make(algo, n0=64)
    churn(h, 25, seed=4)
    image = h.device_image()
    assert isinstance(image, DeviceImage)
    for arr in image.arrays.values():
        assert arr.shape[0] % 128 == 0, "device arrays must be lane-padded"
        assert arr.dtype in (np.int32, np.uint32)


# ---------------------------------------------------------------------------
# Plane equivalence: host ⇄ jnp ⇄ pallas, all engine op modes
# ---------------------------------------------------------------------------

CASES = [(16, 6), (96, 40), (200, 130)]


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("n0,removals", CASES)
def test_three_planes_bit_identical(algo, n0, removals):
    h = state(algo, n0, removals, seed=n0 + removals)
    image = h.device_image()
    host = ref.lookup_host(KEYS, h)
    for plane in DEVICE_PLANES:
        out = np.asarray(engine.engine_lookup(KEYS, image, plane=plane))
        np.testing.assert_array_equal(out, host)
        assert set(out.tolist()) <= h.working_set()


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("plane", DEVICE_PLANES)
@pytest.mark.parametrize("k", [2, 3])
def test_lookup_k_matches_host(algo, plane, k):
    h = state(algo, 64, 20, seed=2)
    out = np.asarray(engine.engine_lookup(KEYS[:128], h.device_image(),
                                          k=k, plane=plane))
    np.testing.assert_array_equal(out, replica_sets(h, KEYS[:128], k))
    assert all(len(set(row)) == k for row in out.tolist())


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("plane", DEVICE_PLANES)
def test_bounded_replica_lookup_fused(algo, plane):
    """The fused k-replica-under-cap op: one launch, every slot below the
    cap, bit-identical to the host salted walk with the reject rule."""
    h = state(algo, 64, 16, seed=3)
    image = h.device_image()
    load = np.zeros(engine.bounded_load_len(image), np.int32)
    cap = 7
    ws = sorted(h.working_set())
    load[ws[: len(ws) // 3]] = cap  # a third of the fleet is full
    want = engine.bounded_replica_sets(h, KEYS[:96], 2, load, cap)
    got = np.asarray(engine.engine_lookup(KEYS[:96], image, k=2, load=load,
                                          cap=cap, plane=plane))
    np.testing.assert_array_equal(got, want)
    assert (load[got] < cap).all()
    plain = np.asarray(engine.engine_lookup(KEYS[:96], image, plane=plane))
    moved = got[:, 0] != plain
    assert (load[plain[moved]] >= cap).all()


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("plane", DEVICE_PLANES)
def test_epoch_diff_and_replica_set_diff(algo, plane):
    from repro.core import DeviceImageStore

    h = state(algo, 96, 30, seed=4)
    store = DeviceImageStore(h)
    churn_mixed(h, 5, seed=5, p_remove=0.7)
    store.sync()
    old, new = store.previous_image(), store.image()
    d = engine.engine_diff(KEYS, old, new, plane=plane)
    np.testing.assert_array_equal(
        d.old, np.asarray(engine.engine_lookup(KEYS, old, plane="jnp")))
    np.testing.assert_array_equal(
        d.new, np.asarray(engine.engine_lookup(KEYS, new, plane="jnp")))
    np.testing.assert_array_equal(d.moved, d.old != d.new)
    dk = engine.engine_diff(KEYS[:200], old, new, k=2, plane=plane)
    np.testing.assert_array_equal(
        dk.old, np.asarray(engine.engine_lookup(KEYS[:200], old, k=2,
                                                plane="jnp")))
    np.testing.assert_array_equal(
        dk.new, np.asarray(engine.engine_lookup(KEYS[:200], new, k=2,
                                                plane="jnp")))
    np.testing.assert_array_equal(dk.moved, (dk.old != dk.new).any(axis=1))


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("plane", DEVICE_PLANES)
def test_bounded_assign_matches_reference(algo, plane):
    from repro.core.bounded import bounded_assign_ref

    h = state(algo, 48, 12, seed=6)
    image = h.device_image()
    keys = KEYS[:300]
    cap = max(1, int(np.ceil(1.25 * len(keys) / h.working)))
    load0 = np.zeros(engine.bounded_load_len(image), np.int32)
    want, want_load = bounded_assign_ref(h, keys, load0, cap)
    got, got_load = engine.bounded_assign(keys, image, load0, cap,
                                          plane=plane)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got_load, want_load)
    assert got_load.max() <= cap


# ---------------------------------------------------------------------------
# Delta replay and the packed layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGORITHMS)
def test_delta_replay_bit_identical(algo):
    """Base image + composed delta == fresh snapshot, fingerprint-exact."""
    h = make(algo, n0=48)
    base = h.device_image()
    churn_mixed(h, 40, seed=7)
    delta = h.device_delta(base.epoch)
    if delta is None:
        pytest.skip(f"{algo} emits no deltas (snapshot-only)")
    replayed = apply_delta(base, delta)
    assert image_fingerprint(replayed) == image_fingerprint(
        h.device_image())


@pytest.mark.parametrize("algo", ALGORITHMS)
@pytest.mark.parametrize("plane", DEVICE_PLANES)
def test_packed_layout_or_skip(algo, plane):
    """The packed table encoding must not change any lookup; algorithms
    without a packed encoding share dense tables and pass through."""
    from repro.core.packing import pack_image, unpack_image

    h = state(algo, 96, 40, seed=8)
    dense = h.device_image()
    try:
        packed = pack_image(dense)
    except ValueError as e:  # pragma: no cover — algorithm #6 may opt out
        pytest.skip(f"{algo} has no packed layout: {e}")
    host = ref.lookup_host(KEYS, h)
    out = np.asarray(engine.engine_lookup(KEYS, packed, plane=plane,
                                          table="packed"))
    np.testing.assert_array_equal(out, host)
    rt = unpack_image(packed)
    assert rt.n == dense.n and rt.epoch == dense.epoch
