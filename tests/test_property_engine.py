"""Hypothesis property tests for the lookup engine + sharded plane.

Gated on ``hypothesis`` like the other ``test_property_*`` modules.

Two properties:

* **engine ≡ host on random churned states** — for random event streams,
  every engine op mode stays bit-identical to the host control plane
  (in-process, both planes).

* **sharded ≡ single-device for any mesh shape** — a forced multi-device
  subprocess (``--xla_force_host_platform_device_count``, the same trick
  the dry-run launcher uses) builds a mesh of the drawn shape over the
  drawn axes and checks :class:`~repro.serve.plane.ShardedLookupPlane`
  against the single-device engine.  Results are memoized per drawn case
  so hypothesis re-draws stay cheap.
"""
from __future__ import annotations

import functools
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conformance import ALGORITHMS as ALGOS, lifo_only, pick_victim  # noqa: E402
from repro.core import make_hash  # noqa: E402
from repro.kernels import engine, ref  # noqa: E402

NDEV = 4  # forced host-platform device count in the subprocess
MESH_SHAPES = ((1,), (2,), (4,), (2, 2), (1, 4), (2, 1))


def _churned(algo, seed):
    rng = np.random.default_rng(seed)
    h = make_hash(algo, 48, capacity=192, variant="32")
    for _ in range(int(rng.integers(5, 40))):
        if h.working > 2 and rng.random() < 0.65:
            h.remove(pick_victim(h, rng))
        else:
            h.add()
    return h


@settings(max_examples=10, deadline=None)
@given(algo=st.sampled_from(ALGOS), seed=st.integers(0, 2**16),
       plane=st.sampled_from(("jnp", "pallas")), k=st.integers(1, 3))
def test_engine_matches_host_on_random_churn(algo, seed, plane, k):
    h = _churned(algo, seed)
    keys = np.random.default_rng(seed ^ 0xA5).integers(
        0, 2**32, size=257, dtype=np.uint32)
    out = np.asarray(engine.engine_lookup(keys, h.device_image(), k=k,
                                          plane=plane))
    if k == 1:
        np.testing.assert_array_equal(out, ref.lookup_host(keys, h))
    else:
        from repro.core.protocol import replica_sets
        np.testing.assert_array_equal(out, replica_sets(h, keys, k))


_SUBPROCESS_CHECK = textwrap.dedent("""
    import numpy as np, jax
    assert len(jax.devices()) == {ndev}, jax.devices()
    from repro.core import DeviceImageStore, make_hash
    from repro.kernels.engine import engine_lookup
    from repro.launch.mesh import _mesh
    from repro.serve.plane import ShardedLookupPlane

    shape, algo, seed, lifo = {shape!r}, {algo!r}, {seed}, {lifo}
    rng = np.random.default_rng(seed)
    h = make_hash(algo, 64, capacity=256, variant="32")
    for _ in range(int(rng.integers(3, 25))):
        if lifo:
            h.remove(h.size - 1) if h.size > 2 else h.add()
        elif h.working > 2 and rng.random() < 0.7:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])
        else:
            h.add()
    store = DeviceImageStore(h)
    axes = ("data", "model")[: len(shape)]
    mesh = _mesh(shape, axes)
    plane = ShardedLookupPlane(store, mesh=mesh)
    keys = rng.integers(0, 2**32, size=20_011, dtype=np.uint32)
    want = np.asarray(engine_lookup(keys, store.image(), plane="jnp"))
    np.testing.assert_array_equal(plane.lookup(keys), want)
    outs = list(plane.route_stream([keys[:4096], keys[4096:8192]]))
    np.testing.assert_array_equal(outs[0], want[:4096])
    np.testing.assert_array_equal(outs[1], want[4096:8192])
    print("OK", shape, algo)
""")


@functools.lru_cache(maxsize=None)
def _run_mesh_case(shape: tuple, algo: str, seed: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={NDEV} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = _SUBPROCESS_CHECK.format(ndev=NDEV, shape=tuple(shape), algo=algo,
                                    seed=seed, lifo=lifo_only(algo))
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)


@settings(max_examples=6, deadline=None)
@given(shape=st.sampled_from(MESH_SHAPES), algo=st.sampled_from(ALGOS),
       seed=st.integers(0, 3))
def test_sharded_plane_equals_single_device_any_mesh(shape, algo, seed):
    res = _run_mesh_case(shape, algo, seed)
    assert res.returncode == 0, f"{res.stdout}\n{res.stderr}"
    assert "OK" in res.stdout
