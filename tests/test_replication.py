"""Cross-process delta replication (DESIGN.md §9.3): wire frames,
follower image stores, and bit-identical leader/follower convergence.

The in-process tests drive :class:`~repro.launch.replicate.ReplicationGroup`
through real churn; the capstone forces a REAL 2-process
``jax.distributed`` mesh (gloo CPU collectives) in subprocesses and
asserts the follower converges to the leader's epoch and fingerprint.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import DeviceImageStore, image_fingerprint, make_hash
from repro.launch.replicate import (KIND_DELTA, KIND_SNAPSHOT, DeltaPublisher,
                                    FollowerImageStore, LoopbackChannel,
                                    ReplicationGroup, decode_frame,
                                    encode_delta, encode_snapshot)

from conformance import ALGORITHMS as ALGOS, lifo_only

KEYS = np.random.default_rng(5).integers(0, 2**32, size=256, dtype=np.uint32)


def _mk(algo, n0=64):
    return make_hash(algo, n0, capacity=4 * n0, variant="32")


def _victim(h, rng):
    return (h.size - 1 if lifo_only(h.name)
            else h.lookup(int(rng.integers(1 << 30))))


def _churn_once(h, rng):
    if h.working > 1 and rng.random() < 0.55:
        h.remove(_victim(h, rng))
    else:
        try:
            h.add()
        except ValueError:
            h.remove(_victim(h, rng))


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_snapshot_frame_roundtrip(algo):
    h = _mk(algo)
    img = h.device_image()
    f = decode_frame(encode_snapshot(img))
    assert f.kind == KIND_SNAPSHOT and f.algo == algo
    assert f.epoch == img.epoch and f.n == img.n
    assert set(f.arrays) == set(img.arrays)
    for name, arr in img.arrays.items():
        got = f.arrays[name]
        assert got.dtype == np.asarray(arr).dtype
        np.testing.assert_array_equal(got, np.asarray(arr))
    assert all(f.scalars[k] == v for k, v in img.scalars.items()
               if k in f.scalars)


@pytest.mark.parametrize("algo", ALGOS)
def test_delta_frame_roundtrip(algo):
    h = _mk(algo)
    e0 = h.epoch
    if lifo_only(algo):
        h.remove(h.size - 1)
    else:
        h.remove(h.lookup(12345))
    d = h.device_delta(e0)
    f = decode_frame(encode_delta(d))
    assert f.kind == KIND_DELTA and f.algo == algo
    assert f.base_epoch == e0 and f.epoch == d.epoch and f.n == d.n
    for name, (idx, vals) in d.updates.items():
        if not len(idx):
            continue
        gi, gv = f.updates[name]
        np.testing.assert_array_equal(gi, np.asarray(idx, np.int32))
        np.testing.assert_array_equal(
            gv, np.asarray(vals).astype(np.int64).astype(np.int32))


def test_decode_rejects_garbage():
    with pytest.raises(ValueError):
        decode_frame(np.zeros(16, np.int32))
    h = _mk("memento")
    frame = encode_snapshot(h.device_image())
    with pytest.raises(ValueError):  # trailing words
        decode_frame(np.concatenate([frame, np.zeros(3, np.int32)]))
    beyond = np.array(frame)
    beyond[2] = len(ALGOS)  # first unassigned wire algo id
    with pytest.raises(ValueError, match="algo id"):  # future-algo frame
        decode_frame(beyond)


# ---------------------------------------------------------------------------
# follower convergence (in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_loopback_follower_converges_bit_identical(algo):
    rng = np.random.default_rng(9)
    h = _mk(algo)
    store = DeviceImageStore(h)
    group = ReplicationGroup(h, num_followers=2)
    group.publish()  # initial snapshot
    for step in range(40):
        _churn_once(h, rng)
        store.sync()
        lags = group.publish()
        assert all(lag >= 1 for lag in lags)  # was behind before frames
        assert group.converged(store.image())
    fol = group.followers[0]
    assert fol.epoch == store.epoch == h.epoch
    assert fol.fingerprint() == image_fingerprint(store.image())
    np.testing.assert_array_equal(fol.lookup(KEYS), store.lookup(KEYS))
    assert fol.deltas > 0  # steady state rode the O(changed-words) path


def test_growth_forces_snapshot_and_still_converges():
    h = _mk("memento", n0=64)
    store = DeviceImageStore(h)
    group = ReplicationGroup(h, num_followers=1)
    group.publish()
    for _ in range(200):  # outgrow the published capacity
        h.add()
    store.sync()
    group.publish()
    fol = group.followers[0]
    assert fol.snapshots >= 2  # init + capacity fallback
    assert group.converged(store.image())
    np.testing.assert_array_equal(fol.lookup(KEYS), store.lookup(KEYS))


def test_log_overflow_forces_snapshot_and_still_converges():
    h = _mk("anchor")
    h._DELTA_LOG_CAP = 8
    store = DeviceImageStore(h)
    group = ReplicationGroup(h, num_followers=1)
    group.publish()
    rng = np.random.default_rng(3)
    for _ in range(30):  # >> log cap between publishes
        _churn_once(h, rng)
    store.sync()
    group.publish()
    fol = group.followers[0]
    assert fol.snapshots >= 2  # delta fell out of the bounded log
    assert group.converged(store.image())


def test_follower_rejects_mischained_delta():
    h = _mk("dx")
    pub = DeltaPublisher(h)
    fol = FollowerImageStore()
    with pytest.raises(ValueError):  # DELTA before any SNAPSHOT
        e0 = h.epoch
        h.remove(h.lookup(7))
        fol.apply_frame(encode_delta(h.device_delta(e0)))
    for f in pub.frames():
        fol.apply_frame(f)
    e1 = h.epoch
    h.remove(h.lookup(99))
    h.remove(h.lookup(100))
    late = h.device_delta(h.epoch - 1)  # skips the first event
    with pytest.raises(ValueError):
        fol.apply_frame(encode_delta(late))
    fol.apply_frame(encode_delta(h.device_delta(e1)))  # correct chain lands
    assert fol.epoch == h.epoch


def test_loopback_channel_drains_in_order():
    ch = LoopbackChannel()
    ch.publish([np.ones(4, np.int32), np.full(2, 7, np.int32)])
    got = ch.drain()
    assert [g.tolist() for g in got] == [[1, 1, 1, 1], [7, 7]]
    assert ch.drain() == []


def test_driver_replays_storm_with_followers():
    from repro.sim import make_trace, replay

    trace = make_trace("churn_storm", seed=1, w=64, storms=2, burst=8,
                       n_keys=256)
    r = replay(trace, algo="memento", plane="jnp", sync_mode="overlap",
               followers=2)
    assert r.ok, [str(v) for v in r.violations]
    s = r.summary()
    assert s["followers"] == 2 and s["follower_lag_max"] >= 1


# ---------------------------------------------------------------------------
# the real thing: 2 OS processes over jax.distributed (gloo CPU mesh)
# ---------------------------------------------------------------------------

_WORKER = textwrap.dedent("""
    import os, sys
    import numpy as np
    from repro.launch.mesh import init_distributed
    pid = int(os.environ["REPL_PID"])
    init_distributed("127.0.0.1:" + os.environ["REPL_PORT"], 2, pid)
    from repro.core import DeviceImageStore, image_fingerprint, make_hash
    from repro.launch.replicate import DistributedBroadcast, DeltaPublisher, \\
        FollowerImageStore
    chan = DistributedBroadcast()
    rng = np.random.default_rng(0)
    steps = 20
    algo = os.environ["REPL_ALGO"]
    if pid == 0:
        from repro.core.protocol import ALGORITHM_REGISTRY
        lifo = ALGORITHM_REGISTRY[algo].lifo_only
        h = make_hash(algo, 64, variant="32")
        store = DeviceImageStore(h)
        pub = DeltaPublisher(h)
        chan.exchange(pub.frames())
        for _ in range(steps):
            if rng.random() < 0.4 and h.size > 8:
                h.remove(h.size - 1 if lifo
                         else h.lookup(int(rng.integers(1 << 30))))
            else:
                h.add()
            store.sync()
            chan.exchange(pub.frames())
        print("RESULT", store.epoch, image_fingerprint(store.image()),
              flush=True)
    else:
        fol = FollowerImageStore()
        for _ in range(steps + 1):
            for f in chan.exchange():
                fol.apply_frame(f)
        print("RESULT", fol.epoch, fol.fingerprint(), flush=True)
""")


@pytest.mark.parametrize("algo", ["memento", "power"])
def test_two_process_distributed_convergence(algo):
    """Leader and follower in SEPARATE processes on a real
    ``jax.distributed`` 2-process CPU mesh converge to the same epoch and
    bit-identical image fingerprint — for the paper's algorithm and for
    the stateless LIFO newcomer (whose frames carry the new wire id)."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    src = str(Path(__file__).resolve().parent.parent / "src")
    procs = []
    for pid in range(2):
        env = dict(os.environ, JAX_PLATFORMS="cpu", REPL_PID=str(pid),
                   REPL_PORT=str(port), REPL_ALGO=algo,
                   PYTHONPATH=src + os.pathsep + os.environ.get(
                       "PYTHONPATH", ""))
        procs.append(subprocess.Popen([sys.executable, "-c", _WORKER],
                                      env=env, stdout=subprocess.PIPE,
                                      stderr=subprocess.PIPE, text=True))
    results = []
    for p in procs:
        out, err = p.communicate(timeout=240)
        assert p.returncode == 0, f"worker failed:\n{out}\n{err}"
        line = [ln for ln in out.splitlines() if ln.startswith("RESULT")][-1]
        results.append(tuple(line.split()[1:]))
    assert results[0] == results[1], results  # same epoch, same fingerprint
