"""Cross-process delta replication (DESIGN.md §9.3): wire frames,
follower image stores, and bit-identical leader/follower convergence.

The in-process tests drive :class:`~repro.launch.replicate.ReplicationGroup`
through real churn; the capstone forces a REAL 2-process
``jax.distributed`` mesh (gloo CPU collectives) in subprocesses and
asserts the follower converges to the leader's epoch and fingerprint.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core import DeviceImageStore, image_fingerprint, make_hash
from repro.launch.replicate import (KIND_DELTA, KIND_DELTA_BATCH,
                                    KIND_SNAPSHOT, KIND_SNAPSHOT_PACKED,
                                    DeltaPublisher, FollowerImageStore,
                                    LoopbackChannel, ReplicationGroup,
                                    TreeTopology, decode_frame, encode_delta,
                                    encode_snapshot, stamp_crc)

from conformance import ALGORITHMS as ALGOS, lifo_only

KEYS = np.random.default_rng(5).integers(0, 2**32, size=256, dtype=np.uint32)


def _mk(algo, n0=64):
    return make_hash(algo, n0, capacity=4 * n0, variant="32")


def _victim(h, rng):
    return (h.size - 1 if lifo_only(h.name)
            else h.lookup(int(rng.integers(1 << 30))))


def _churn_once(h, rng):
    if h.working > 1 and rng.random() < 0.55:
        h.remove(_victim(h, rng))
    else:
        try:
            h.add()
        except ValueError:
            h.remove(_victim(h, rng))


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_snapshot_frame_roundtrip(algo):
    h = _mk(algo)
    img = h.device_image()
    f = decode_frame(encode_snapshot(img))
    assert f.kind == KIND_SNAPSHOT and f.algo == algo
    assert f.epoch == img.epoch and f.n == img.n
    assert set(f.arrays) == set(img.arrays)
    for name, arr in img.arrays.items():
        got = f.arrays[name]
        assert got.dtype == np.asarray(arr).dtype
        np.testing.assert_array_equal(got, np.asarray(arr))
    assert all(f.scalars[k] == v for k, v in img.scalars.items()
               if k in f.scalars)


@pytest.mark.parametrize("algo", ALGOS)
def test_delta_frame_roundtrip(algo):
    h = _mk(algo)
    e0 = h.epoch
    if lifo_only(algo):
        h.remove(h.size - 1)
    else:
        h.remove(h.lookup(12345))
    d = h.device_delta(e0)
    f = decode_frame(encode_delta(d))
    assert f.kind == KIND_DELTA and f.algo == algo
    assert f.base_epoch == e0 and f.epoch == d.epoch and f.n == d.n
    for name, (idx, vals) in d.updates.items():
        if not len(idx):
            continue
        gi, gv = f.updates[name]
        np.testing.assert_array_equal(gi, np.asarray(idx, np.int32))
        np.testing.assert_array_equal(
            gv, np.asarray(vals).astype(np.int64).astype(np.int32))


def test_decode_rejects_garbage():
    with pytest.raises(ValueError):
        decode_frame(np.zeros(16, np.int32))
    h = _mk("memento")
    frame = encode_snapshot(h.device_image())
    with pytest.raises(ValueError):  # trailing words
        decode_frame(stamp_crc(np.concatenate([frame, np.zeros(3, np.int32)])))
    beyond = np.array(frame)
    beyond[2] = len(ALGOS)  # first unassigned wire algo id
    stamp_crc(beyond)  # a well-formed frame FROM THE FUTURE, not a corrupt one
    with pytest.raises(ValueError, match="algo id"):  # future-algo frame
        decode_frame(beyond)


def test_crc_rejects_corruption_and_truncation():
    """Every frame carries a CRC32 integrity word: a flipped payload word,
    a tampered header, or a truncated buffer is rejected before any word
    could reach the follower's scatter."""
    h = _mk("memento")
    h.remove(h.lookup(42))
    for frame in (encode_snapshot(h.device_image()),
                  encode_delta(h.device_delta(h.epoch - 1))):
        decode_frame(frame)  # pristine frame passes
        flipped = np.array(frame)
        flipped[len(flipped) // 2] ^= 1  # one payload bit
        with pytest.raises(ValueError, match="CRC"):
            decode_frame(flipped)
        tampered = np.array(frame)
        tampered[4] += 1  # epoch header word
        with pytest.raises(ValueError, match="CRC"):
            decode_frame(tampered)
        with pytest.raises(ValueError):  # truncation (CRC or header length)
            decode_frame(np.array(frame)[:-2])


# ---------------------------------------------------------------------------
# follower convergence (in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_loopback_follower_converges_bit_identical(algo):
    rng = np.random.default_rng(9)
    h = _mk(algo)
    store = DeviceImageStore(h)
    group = ReplicationGroup(h, num_followers=2)
    group.publish()  # initial snapshot
    for step in range(40):
        _churn_once(h, rng)
        store.sync()
        lags = group.publish()
        assert all(lag >= 1 for lag in lags)  # was behind before frames
        assert group.converged(store.image())
    fol = group.followers[0]
    assert fol.epoch == store.epoch == h.epoch
    assert fol.fingerprint() == image_fingerprint(store.image())
    np.testing.assert_array_equal(fol.lookup(KEYS), store.lookup(KEYS))
    assert fol.deltas > 0  # steady state rode the O(changed-words) path


def test_growth_forces_snapshot_and_still_converges():
    h = _mk("memento", n0=64)
    store = DeviceImageStore(h)
    group = ReplicationGroup(h, num_followers=1)
    group.publish()
    for _ in range(200):  # outgrow the published capacity
        h.add()
    store.sync()
    group.publish()
    fol = group.followers[0]
    assert fol.snapshots >= 2  # init + capacity fallback
    assert group.converged(store.image())
    np.testing.assert_array_equal(fol.lookup(KEYS), store.lookup(KEYS))


def test_log_overflow_forces_snapshot_and_still_converges():
    h = _mk("anchor")
    h._DELTA_LOG_CAP = 8
    store = DeviceImageStore(h)
    group = ReplicationGroup(h, num_followers=1)
    group.publish()
    rng = np.random.default_rng(3)
    for _ in range(30):  # >> log cap between publishes
        _churn_once(h, rng)
    store.sync()
    group.publish()
    fol = group.followers[0]
    assert fol.snapshots >= 2  # delta fell out of the bounded log
    assert group.converged(store.image())


def test_follower_rejects_mischained_delta():
    h = _mk("dx")
    pub = DeltaPublisher(h)
    fol = FollowerImageStore()
    with pytest.raises(ValueError):  # DELTA before any SNAPSHOT
        e0 = h.epoch
        h.remove(h.lookup(7))
        fol.apply_frame(encode_delta(h.device_delta(e0)))
    for f in pub.frames():
        fol.apply_frame(f)
    e1 = h.epoch
    h.remove(h.lookup(99))
    h.remove(h.lookup(100))
    late = h.device_delta(h.epoch - 1)  # skips the first event
    with pytest.raises(ValueError):
        fol.apply_frame(encode_delta(late))
    fol.apply_frame(encode_delta(h.device_delta(e1)))  # correct chain lands
    assert fol.epoch == h.epoch


# ---------------------------------------------------------------------------
# cross-epoch batching, packed wire frames, drain reordering
# ---------------------------------------------------------------------------

def _twin_churn(hs, burst_seed, events=6):
    """Drive identical churn on twin leaders (same rng per leader)."""
    for h in hs:
        r = np.random.default_rng([97, burst_seed])
        for _ in range(events):
            _churn_once(h, r)


@pytest.mark.parametrize("algo", ALGOS)
def test_batched_deltas_bit_identical_to_per_epoch(algo):
    """batch_epochs=0 (one DELTA_BATCH per publish) and batch_epochs=1
    (one DELTA per epoch — the dense per-epoch baseline) land followers on
    bit-identical fingerprints, and the batch ships strictly fewer bytes
    (one header + deduped last-write-wins payload per burst)."""
    h1, h2 = _mk(algo), _mk(algo)
    g_batch = ReplicationGroup(h1, 1, batch_epochs=0)
    g_step = ReplicationGroup(h2, 1, batch_epochs=1)
    g_batch.publish()
    g_step.publish()
    for burst in range(6):
        _twin_churn((h1, h2), burst, events=8)
        g_batch.publish()
        g_step.publish()
        f1, f2 = g_batch.followers[0], g_step.followers[0]
        assert f1.epoch == f2.epoch == h1.epoch == h2.epoch
        want = image_fingerprint(h1.device_image())
        assert f1.fingerprint() == f2.fingerprint() == want
    assert g_batch.followers[0].batches > 0  # rode DELTA_BATCH frames
    assert g_batch.stats.frames < g_step.stats.frames
    assert g_batch.stats.total_bytes < g_step.stats.total_bytes


def test_batch_epochs_chunks_the_pending_range():
    h = _mk("memento")
    pub = DeltaPublisher(h, batch_epochs=3)
    pub.frames()  # initial snapshot
    for i in range(7):
        h.remove(h.lookup(1000 + i))
    frames = pub.frames()  # 7 pending epochs → chunks of ≤ 3: 3 + 3 + 1
    assert len(frames) == 3
    kinds = [decode_frame(f).kind for f in frames]
    assert kinds == [KIND_DELTA_BATCH, KIND_DELTA_BATCH, KIND_DELTA]
    fol = FollowerImageStore()
    with pytest.raises(ValueError, match="SNAPSHOT"):
        fol.apply_frames(frames)  # chunks alone cannot land a fresh replica
    # a targeted catch-up (snapshot at the published cursor) + the now-stale
    # chunks land it — redelivered frames skip idempotently
    fol.apply_frames(pub.catchup_frames(-1) + frames)
    assert fol.epoch == h.epoch
    assert fol.fingerprint() == image_fingerprint(h.device_image())


@pytest.mark.parametrize("algo", ALGOS)
def test_packed_follower_matches_dense_follower(algo):
    """A compact follower (SNAPSHOT_PACKED + packed-layout deltas) and a
    dense follower of twin leaders stay fingerprint-identical: the §8.2
    layout changes the wire and the resident bytes, never the lookups."""
    h1, h2 = _mk(algo), _mk(algo)
    gd = ReplicationGroup(h1, 1)
    gp = ReplicationGroup(h2, 1, packed=True)
    gd.publish()
    gp.publish()
    fd, fp = gd.followers[0], gp.followers[0]
    for burst in range(8):
        _twin_churn((h1, h2), 100 + burst)
        gd.publish()
        gp.publish()
        assert fp.epoch == fd.epoch
        assert fp.fingerprint() == fd.fingerprint()
    assert fp.image().packed and not fd.image().packed
    np.testing.assert_array_equal(fp.lookup(KEYS), fd.lookup(KEYS))
    assert fp.deltas > 0  # steady state rode packed-layout delta frames


@pytest.mark.parametrize("algo", ALGOS)
def test_packed_snapshot_frame_roundtrip(algo):
    from repro.core.packing import pack_image

    rng = np.random.default_rng(2)
    h = _mk(algo)
    for _ in range(10):
        _churn_once(h, rng)
    img = pack_image(h.device_image(), slot_headroom=2)
    frame = encode_snapshot(img)
    f = decode_frame(frame)
    assert f.kind == KIND_SNAPSHOT_PACKED and f.packed
    for name, arr in img.arrays.items():  # dtype narrowing survives the wire
        assert f.arrays[name].dtype == np.asarray(arr).dtype
        np.testing.assert_array_equal(f.arrays[name], np.asarray(arr))
    fol = FollowerImageStore(compact=True)
    fol.apply_frame(frame)
    assert fol.fingerprint() == image_fingerprint(h.device_image())
    with pytest.raises(ValueError, match="dense"):  # layout assertion works
        FollowerImageStore(compact=False).apply_frame(frame)


def test_packed_memento_snapshot_is_smaller_on_the_wire():
    h = _mk("memento", n0=2048)
    from repro.core.packing import pack_image

    rng = np.random.default_rng(4)
    for _ in range(64):
        h.remove(h.lookup(int(rng.integers(1 << 30))))
    dense = encode_snapshot(h.device_image())
    packed = encode_snapshot(pack_image(h.device_image(), slot_headroom=2))
    assert 4 * len(packed) < 4 * len(dense) / 4  # Θ(n/8 + r) vs Θ(4n)


def test_drain_reorder_repairs_shuffles_not_losses():
    rng = np.random.default_rng(14)
    h = _mk("memento")
    pub = DeltaPublisher(h, batch_epochs=1)
    fol = FollowerImageStore()
    fol.apply_frames(pub.frames())
    for _ in range(6):
        _churn_once(h, rng)
    frames = pub.frames()
    assert len(frames) == 6
    d0 = fol.deltas
    fol.apply_frames([frames[i] for i in (4, 0, 5, 2, 1, 3)])  # shuffled drain
    assert fol.epoch == h.epoch
    assert fol.fingerprint() == image_fingerprint(h.device_image())
    assert fol.deltas == d0 + 6  # all six landed, in ONE composed apply
    for _ in range(3):
        _churn_once(h, rng)
    frames = pub.frames()
    with pytest.raises(ValueError, match="base epoch"):  # a REAL gap
        fol.apply_frames(frames[1:])  # first frame lost, not shuffled
    fol.apply_frames(frames)  # the full drain still lands afterwards
    assert fol.epoch == h.epoch


def test_stale_frames_skip_idempotently():
    rng = np.random.default_rng(15)
    h = _mk("anchor")
    pub = DeltaPublisher(h, batch_epochs=1)
    fol = FollowerImageStore()
    fol.apply_frames(pub.frames())
    for _ in range(4):
        _churn_once(h, rng)
    frames = pub.frames()
    fol.apply_frames(frames)
    fp = fol.fingerprint()
    fol.apply_frames(frames)  # exact redelivery: every frame is stale
    assert fol.fingerprint() == fp and fol.stale_skipped >= len(frames)


# ---------------------------------------------------------------------------
# tree fan-out and targeted catch-up
# ---------------------------------------------------------------------------

def test_tree_topology_shape():
    t = TreeTopology(6, arity=2)  # nodes 0 (leader) .. 6
    assert t.children(0) == [1, 2] and t.children(1) == [3, 4]
    assert t.children(2) == [5, 6] and t.children(3) == []
    assert t.parent(0) == -1 and t.parent(5) == 2
    assert t.interior() == [0, 1, 2]
    assert t.depth == 2
    assert TreeTopology(6, arity=4).depth == 2
    assert TreeTopology(3, arity=4).depth == 1
    with pytest.raises(ValueError):
        TreeTopology(3, arity=0)


@pytest.mark.parametrize("arity", [2, 4])
@pytest.mark.parametrize("algo", ALGOS)
def test_tree_fanout_converges_every_algorithm(algo, arity):
    rng = np.random.default_rng(13)
    h = _mk(algo)
    store = DeviceImageStore(h)
    g = ReplicationGroup(h, 7, topology="tree", arity=arity)
    g.publish()
    for _ in range(25):
        _churn_once(h, rng)
        store.sync()
        g.publish()
        assert g.converged(store.image())
    # the leader paid O(arity) sends per frame; interior followers relayed
    assert g.stats.leader_sends == min(arity, 7) * g.stats.frames
    assert g.stats.total_sends == 7 * g.stats.frames  # one receive per node


def test_tree_leader_pays_arity_not_fanout():
    h1, h2 = _mk("memento"), _mk("memento")
    gf = ReplicationGroup(h1, 7, topology="flat")
    gt = ReplicationGroup(h2, 7, topology="tree", arity=2)
    gf.publish()
    gt.publish()
    for burst in range(5):
        _twin_churn((h1, h2), 200 + burst, events=4)
        gf.publish()
        gt.publish()
    assert gt.followers[-1].fingerprint() == gf.followers[-1].fingerprint()
    assert gf.stats.leader_sends == 7 * gf.stats.frames  # flat: O(F)
    assert gt.stats.leader_sends == 2 * gt.stats.frames  # tree: O(arity)
    # same bytes cross the wire — relays change WHO pays, not how much
    assert gt.stats.total_bytes == gf.stats.total_bytes
    assert gt.depth == 3 and gf.depth == 1


def test_lagging_follower_targeted_catchup_via_delta():
    rng = np.random.default_rng(5)
    h = _mk("memento")
    g = ReplicationGroup(h, 2)
    g.publish()
    g.set_online(1, False)
    for _ in range(2):
        for _ in range(4):
            _churn_once(h, rng)
        g.publish()  # follower 1 misses both rounds
    g.set_online(1, True)
    for _ in range(4):
        _churn_once(h, rng)
    g.publish()  # delivery detects the gap and prepends the targeted pull
    assert g.converged(h.device_image())
    assert g.stats.catchup_frames >= 1
    # repaired by a composed DELTA_BATCH from the published-frame log — the
    # only snapshot this follower ever saw is the initial one
    assert g.followers[1].snapshots == 1


def test_catch_up_and_attach_mid_stream():
    rng = np.random.default_rng(8)
    h = _mk("anchor")
    g = ReplicationGroup(h, 1)
    g.publish()
    for _ in range(5):
        _churn_once(h, rng)
    g.set_online(0, False)
    g.publish()  # ships to nobody; the cursor still advances
    g.set_online(0, True)
    assert g.followers[0].epoch < h.epoch
    assert g.catch_up(0) >= 1  # explicit pull repairs it
    assert g.followers[0].epoch == h.epoch
    for _ in range(3):
        _churn_once(h, rng)
    fol = g.attach_follower()  # a NEW follower joins mid-stream
    assert fol.epoch == h.epoch
    assert fol.fingerprint() == image_fingerprint(h.device_image())
    assert g.converged(h.device_image())


def test_tree_offline_interior_node_subtree_catches_up():
    rng = np.random.default_rng(17)
    h = _mk("memento")
    g = ReplicationGroup(h, 3, topology="tree", arity=2)
    # nodes: leader 0 → {1, 2}; node 1 → {3}.  follower i is node i+1.
    g.publish()
    g.set_online(0, False)  # follower 0 = interior node 1
    for _ in range(4):
        _churn_once(h, rng)
    g.publish()
    assert g.followers[1].epoch == h.epoch  # node 2: fed by the leader
    assert g.followers[0].epoch < h.epoch   # partitioned interior node
    assert g.followers[2].epoch < h.epoch   # its subtree missed the relay
    g.set_online(0, True)
    for _ in range(3):
        _churn_once(h, rng)
    g.publish()  # both gaps detected; targeted pulls repair them in-round
    assert g.stats.catchup_frames >= 2
    assert g.converged(h.device_image())


def test_driver_tree_storm_records_wire_metrics():
    from repro.sim import make_trace, replay

    trace = make_trace("churn_storm", seed=2, w=64, storms=2, burst=8,
                       n_keys=256)
    r = replay(trace, algo="memento", plane="jnp", sync_mode="overlap",
               followers=3, repl_config={"topology": "tree", "arity": 2,
                                         "batch_epochs": 0})
    assert r.ok, [str(v) for v in r.violations]
    s = r.summary()
    assert s["followers"] == 3 and s["fanout_depth"] == 2
    assert s["wire_frames_total"] > 0 and s["wire_bytes_total"] > 0
    # tree: 2 leader sends per frame vs 3 flat
    assert s["leader_sends_total"] == 2 * s["wire_frames_total"]


def test_loopback_channel_drains_in_order():
    ch = LoopbackChannel()
    ch.publish([np.ones(4, np.int32), np.full(2, 7, np.int32)])
    got = ch.drain()
    assert [g.tolist() for g in got] == [[1, 1, 1, 1], [7, 7]]
    assert ch.drain() == []


def test_driver_replays_storm_with_followers():
    from repro.sim import make_trace, replay

    trace = make_trace("churn_storm", seed=1, w=64, storms=2, burst=8,
                       n_keys=256)
    r = replay(trace, algo="memento", plane="jnp", sync_mode="overlap",
               followers=2)
    assert r.ok, [str(v) for v in r.violations]
    s = r.summary()
    assert s["followers"] == 2 and s["follower_lag_max"] >= 1


# ---------------------------------------------------------------------------
# the real thing: 2 OS processes over jax.distributed (gloo CPU mesh)
# ---------------------------------------------------------------------------

_WORKER = textwrap.dedent("""
    import os, sys
    import numpy as np
    from repro.launch.mesh import init_distributed
    pid = int(os.environ["REPL_PID"])
    init_distributed("127.0.0.1:" + os.environ["REPL_PORT"], 2, pid)
    from repro.core import DeviceImageStore, image_fingerprint, make_hash
    from repro.launch.replicate import DistributedBroadcast, DeltaPublisher, \\
        FollowerImageStore
    chan = DistributedBroadcast()
    rng = np.random.default_rng(0)
    steps = 20
    algo = os.environ["REPL_ALGO"]
    if pid == 0:
        from repro.core.protocol import ALGORITHM_REGISTRY
        lifo = ALGORITHM_REGISTRY[algo].lifo_only
        h = make_hash(algo, 64, variant="32")
        store = DeviceImageStore(h)
        pub = DeltaPublisher(h)
        chan.exchange(pub.frames())
        for _ in range(steps):
            if rng.random() < 0.4 and h.size > 8:
                h.remove(h.size - 1 if lifo
                         else h.lookup(int(rng.integers(1 << 30))))
            else:
                h.add()
            store.sync()
            chan.exchange(pub.frames())
        print("RESULT", store.epoch, image_fingerprint(store.image()),
              flush=True)
    else:
        fol = FollowerImageStore()
        for _ in range(steps + 1):
            for f in chan.exchange():
                fol.apply_frame(f)
        print("RESULT", fol.epoch, fol.fingerprint(), flush=True)
""")


@pytest.mark.parametrize("algo", ["memento", "power"])
def test_two_process_distributed_convergence(algo):
    """Leader and follower in SEPARATE processes on a real
    ``jax.distributed`` 2-process CPU mesh converge to the same epoch and
    bit-identical image fingerprint — for the paper's algorithm and for
    the stateless LIFO newcomer (whose frames carry the new wire id)."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    src = str(Path(__file__).resolve().parent.parent / "src")
    procs = []
    for pid in range(2):
        env = dict(os.environ, JAX_PLATFORMS="cpu", REPL_PID=str(pid),
                   REPL_PORT=str(port), REPL_ALGO=algo,
                   PYTHONPATH=src + os.pathsep + os.environ.get(
                       "PYTHONPATH", ""))
        procs.append(subprocess.Popen([sys.executable, "-c", _WORKER],
                                      env=env, stdout=subprocess.PIPE,
                                      stderr=subprocess.PIPE, text=True))
    results = []
    for p in procs:
        out, err = p.communicate(timeout=240)
        assert p.returncode == 0, f"worker failed:\n{out}\n{err}"
        line = [ln for ln in out.splitlines() if ln.startswith("RESULT")][-1]
        results.append(tuple(line.split()[1:]))
    assert results[0] == results[1], results  # same epoch, same fingerprint


# ---------------------------------------------------------------------------
# tree relay over a REAL 4-process jax.distributed mesh
# ---------------------------------------------------------------------------

_TREE_WORKER = textwrap.dedent("""
    import os
    import numpy as np
    from repro.launch.mesh import init_distributed
    pid = int(os.environ["REPL_PID"])
    nproc = int(os.environ["REPL_NPROC"])
    init_distributed("127.0.0.1:" + os.environ["REPL_PORT"], nproc, pid)
    from repro.core import DeviceImageStore, image_fingerprint, make_hash
    from repro.launch.replicate import DeltaPublisher, FollowerImageStore, \\
        TreeBroadcast
    chan = TreeBroadcast(arity=2)
    steps = 12
    if pid == 0:
        rng = np.random.default_rng(0)
        h = make_hash("memento", 64, variant="32")
        store = DeviceImageStore(h)
        pub = DeltaPublisher(h, batch_epochs=0)
        chan.exchange(pub.frames())
        for _ in range(steps):
            for _ in range(3):  # a small burst per round → DELTA_BATCH
                if rng.random() < 0.45 and h.working > 8:
                    h.remove(h.lookup(int(rng.integers(1 << 30))))
                else:
                    h.add()
            store.sync()
            chan.exchange(pub.frames())
        print("RESULT", store.epoch, image_fingerprint(store.image()),
              flush=True)
    else:
        fol = FollowerImageStore()
        for _ in range(steps + 1):
            fol.apply_frames(chan.exchange())
        print("RESULT", fol.epoch, fol.fingerprint(), flush=True)
""")


def test_four_process_tree_relay_convergence():
    """4 OS processes on a real ``jax.distributed`` CPU mesh, arity-2 tree:
    process 0 leads, process 1 relays the verbatim frames it applied to
    process 3, process 2 is a leaf — every follower must reach the
    leader's epoch and bit-identical fingerprint through the relay path."""
    nproc = 4
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    src = str(Path(__file__).resolve().parent.parent / "src")
    procs = []
    for pid in range(nproc):
        env = dict(os.environ, JAX_PLATFORMS="cpu", REPL_PID=str(pid),
                   REPL_PORT=str(port), REPL_NPROC=str(nproc),
                   PYTHONPATH=src + os.pathsep + os.environ.get(
                       "PYTHONPATH", ""))
        procs.append(subprocess.Popen([sys.executable, "-c", _TREE_WORKER],
                                      env=env, stdout=subprocess.PIPE,
                                      stderr=subprocess.PIPE, text=True))
    results = []
    for p in procs:
        out, err = p.communicate(timeout=240)
        assert p.returncode == 0, f"worker failed:\n{out}\n{err}"
        line = [ln for ln in out.splitlines() if ln.startswith("RESULT")][-1]
        results.append(tuple(line.split()[1:]))
    assert len(set(results)) == 1, results  # all four agree bit-identically
