"""Scenario engine (DESIGN.md §7): trace layer, replay driver, checkers.

Covers: generator determinism + JSON round-trip, driver determinism from
seed (including replaying the RESOLVED trace, which consumes no membership
randomness), checker correctness on hand-built traces and on synthetic
broken inputs, and every registry algorithm × host/jnp/Pallas planes
agreeing bit-for-bit under replay.
"""
from __future__ import annotations

import numpy as np
import pytest

from conformance import ALGORITHMS as ALGOS, PLANES
from repro.sim import (SCENARIOS, ScenarioDriver, Trace, TraceEvent,
                       degradation_knee, make_trace, replay)
from repro.sim.checkers import (check_balance, check_cap_invariant,
                                check_minimal_disruption,
                                check_replica_stability)

SMALL = dict(w=32, n_keys=512)


# ---------------------------------------------------------------------------
# trace layer
# ---------------------------------------------------------------------------

def test_every_scenario_generates_and_round_trips():
    for name in SCENARIOS:
        tr = make_trace(name, seed=9)
        assert tr.events, name
        again = Trace.from_json(tr.to_json())
        assert again.to_dict() == tr.to_dict(), name
        # same seed → identical script (generators are pure)
        assert make_trace(name, seed=9).to_dict() == tr.to_dict(), name


def test_make_trace_rejects_unknown_scenario():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_trace("thundering_herd")


def test_trace_event_validation():
    with pytest.raises(ValueError, match="unknown trace op"):
        TraceEvent("explode")
    with pytest.raises(ValueError, match="n_keys"):
        TraceEvent("lookup")
    with pytest.raises(ValueError, match="cap_c"):
        TraceEvent("assign", n_keys=8)
    with pytest.raises(ValueError, match="domain"):
        TraceEvent("remove", select="domain")
    with pytest.raises(ValueError, match="victim policy"):
        TraceEvent("remove", select="unlucky")
    with pytest.raises(ValueError, match="exactly one victim"):
        TraceEvent("remove", bucket=5, count=3)


def test_paper_scenarios_are_builtin():
    """The paper's three §VIII scenarios ship as named traces."""
    assert {"stable", "oneshot", "incremental"} <= set(SCENARIOS)
    one = make_trace("oneshot", w=40, frac=0.9)
    burst = [e for e in one.events if e.op == "remove"]
    assert len(burst) == 1 and burst[0].count == 36  # 90 % in ONE delta


# ---------------------------------------------------------------------------
# driver determinism
# ---------------------------------------------------------------------------

def test_driver_deterministic_from_seed():
    tr = make_trace("churn_storm", seed=21, **SMALL)
    r1 = replay(tr, algo="memento", plane="jnp", probe_keys=512)
    r2 = replay(tr, algo="memento", plane="jnp", probe_keys=512)
    assert r1.fingerprint == r2.fingerprint

    def logical(res):  # wall-clock fields legitimately differ across runs
        return {k: v for k, v in res.summary().items()
                if not k.endswith(("_us_mean", "_us_per_key"))}

    assert logical(r1) == logical(r2)
    assert [e.__dict__ for e in r1.resolved.events] == \
        [e.__dict__ for e in r2.resolved.events]


def test_resolved_trace_replays_bit_for_bit():
    """The resolved trace (explicit victims) consumes no membership
    randomness yet reproduces every placement — the replayable-churn-trace
    contract, across a JSON round trip."""
    tr = make_trace("flapping", seed=4, **SMALL)
    r1 = replay(tr, algo="anchor", plane="jnp", probe_keys=512)
    resolved = Trace.from_json(r1.resolved.to_json())
    assert any(e.bucket is not None for e in resolved.events)
    r2 = replay(resolved, algo="anchor", plane="jnp", probe_keys=512)
    assert r2.fingerprint == r1.fingerprint
    assert r2.summary()["moved_probe_total"] == \
        r1.summary()["moved_probe_total"]


def test_different_seeds_diverge():
    a = replay(make_trace("churn_storm", seed=1, **SMALL), probe_keys=512)
    b = replay(make_trace("churn_storm", seed=2, **SMALL), probe_keys=512)
    assert a.fingerprint != b.fingerprint


def test_driver_rejects_unknown_plane():
    with pytest.raises(ValueError, match="unknown plane"):
        ScenarioDriver(make_trace("stable"), plane="cuda")


# ---------------------------------------------------------------------------
# guarantees under replay: every algorithm, every plane
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("scenario", ["oneshot", "incremental", "flapping",
                                      "churn_storm", "staged_scaling"])
def test_guarantees_hold_under_replay(algo, scenario):
    tr = make_trace(scenario, seed=13, **SMALL)
    r = replay(tr, algo=algo, plane="jnp", probe_keys=768, replica_k=2)
    assert r.ok, [str(v) for v in r.violations]
    assert r.summary()["membership_events"] > 0


@pytest.mark.parametrize("algo", ALGOS)
def test_planes_agree_bit_for_bit(algo):
    """host / jnp / Pallas replay the same trace to identical fingerprints
    (every lookup, route, and epoch diff agrees exactly)."""
    tr = make_trace("churn_storm", seed=6, w=24, n_keys=256, storms=2,
                    burst=5)
    fps = {p: replay(tr, algo=algo, plane=p, probe_keys=256).fingerprint
           for p in PLANES}
    assert len(set(fps.values())) == 1, fps


def test_bounded_assign_planes_agree():
    ev = [TraceEvent("assign", n_keys=256, cap_c=1.25),
          TraceEvent("remove", count=5),
          TraceEvent("assign", n_keys=256, cap_c=1.25)]
    tr = Trace("bounded", 3, 24, ev)
    fps = set()
    for plane in PLANES:
        r = replay(tr, algo="memento", plane=plane, probe_keys=256)
        assert r.ok, [str(v) for v in r.violations]
        fps.add(r.fingerprint)
    assert len(fps) == 1


def test_domain_outage_removes_whole_domain():
    tr = make_trace("domain_outage", seed=2, w=32, num_domains=4,
                    outages=1, n_keys=256)
    r = replay(tr, algo="memento", plane="jnp", probe_keys=256)
    assert r.ok
    # every resolved removal of the outage burst belongs to domain 0
    victims = [e.bucket for e in r.resolved.events if e.op == "remove"]
    assert victims and all(b % 4 == 0 for b in victims)
    # jump can't target a domain: it loses a LIFO burst of the SAME size,
    # so the cross-algorithm lifecycle comparison stays like-for-like
    rj = replay(tr, algo="jump", plane="jnp", probe_keys=256)
    jv = [e.bucket for e in rj.resolved.events if e.op == "remove"]
    assert len(jv) == len(victims)


def test_session_affinity_uses_router_failover():
    tr = make_trace("session_affinity", seed=8, replicas=8, sessions=128,
                    rounds=5)
    d = ScenarioDriver(tr, algo="memento", plane="jnp", probe_keys=256,
                       replica_k=2)
    r = d.run()
    assert r.ok, [str(v) for v in r.violations]
    assert d.router.stats.failovers > 0      # routed around the mark
    assert d.router.stats.routed >= 5 * 128
    routes = [rec for rec in r.metrics.records if rec.op == "route"]
    # the uneventful round before the failure keeps every session on its
    # replica (warm caches); failure/restore rounds move only a slice
    assert routes[1].moved == 0
    assert any(rec.moved > 0 for rec in routes)
    assert max(rec.moved for rec in routes) < 128


def test_fixed_capacity_add_degrades_to_noop():
    """Anchor/Dx cannot grow past ``a``: a scale-up on a full-capacity
    fleet is a recorded no-op, not a crash, and the replay stays
    deterministic."""
    tr = Trace("grow", 0, 16, [TraceEvent("add", count=4),
                               TraceEvent("lookup", n_keys=256)],
               capacity_factor=1)  # a == w: nothing left to add
    r = replay(tr, algo="anchor", plane="jnp", probe_keys=256)
    assert r.ok
    add = next(rec for rec in r.metrics.records if rec.op == "add")
    assert add.buckets == []
    assert r.final_working == 16


# ---------------------------------------------------------------------------
# checker correctness on synthetic (hand-built) inputs
# ---------------------------------------------------------------------------

def test_minimal_disruption_checker_passes_lawful_diff():
    old = np.asarray([0, 1, 2, 3, 1])
    new = np.asarray([0, 4, 2, 3, 4])  # bucket 1 removed, its keys → 4
    assert check_minimal_disruption(0, old, new, {1}, set()) == []


def test_minimal_disruption_checker_catches_stranded_keys():
    old = np.asarray([1, 1, 2])
    new = np.asarray([1, 3, 2])  # one key stayed on removed bucket 1
    out = check_minimal_disruption(0, old, new, {1}, set())
    assert any("stayed on removed" in v.detail for v in out)
    assert any("landed ON removed" in v.detail for v in out)


def test_minimal_disruption_checker_catches_gratuitous_moves():
    old = np.asarray([0, 1, 2])
    new = np.asarray([0, 2, 1])  # keys shuffled with no membership cause
    out = check_minimal_disruption(0, old, new, set(), set())
    assert len(out) == 1 and "moved without" in out[0].detail


def test_monotonicity_checker_on_additions():
    old = np.asarray([0, 1, 2, 0])
    new = np.asarray([0, 5, 2, 0])  # joiner 5 stole exactly one key: lawful
    assert check_minimal_disruption(0, old, new, set(), {5}) == []
    bad = np.asarray([0, 5, 1, 0])  # key 2 moved to a NON-joiner
    out = check_minimal_disruption(0, old, bad, set(), {5})
    assert len(out) == 1 and "moved without" in out[0].detail


def test_balance_checker():
    rng = np.random.default_rng(0)
    working = list(range(16))
    uniform = rng.integers(0, 16, size=2048)
    assert check_balance(0, uniform, working) == []
    skewed = np.zeros(2048, np.int64)  # everything on bucket 0
    out = check_balance(0, skewed, working)
    assert len(out) == 1 and "peak bucket" in out[0].detail
    # too few keys for the σ bound to mean anything → skipped, not noisy
    assert check_balance(0, uniform[:32], working) == []


def test_replica_stability_checker():
    moved = np.asarray([True, False, True])
    hits = np.asarray([True, True, True])
    assert check_replica_stability(0, moved, hits) == []
    out = check_replica_stability(0, moved, np.asarray([True, False, False]))
    assert len(out) == 1 and "replica sets changed" in out[0].detail


def test_cap_invariant_checker():
    load = np.asarray([2, 2, 1])
    assert check_cap_invariant(0, np.asarray([0, 1]), load, cap=2) == []
    out = check_cap_invariant(0, np.asarray([0, -1]),
                              np.asarray([3, 0, 0]), cap=2)
    assert {v.detail.split()[0] for v in out} == {"1", "unassigned"}


def test_degradation_knee_locator():
    # ln-like convex profile: knee in the 0.6–0.8 band (the paper's ~70 %)
    fr = [0.1, 0.2, 0.35, 0.5, 0.65, 0.8, 0.9]
    prof = [(f, np.log(1 / (1 - f)) ** 2) for f in fr]
    knee = degradation_knee(prof)
    assert knee is not None and 0.5 <= knee <= 0.8
    assert degradation_knee([]) is None
    assert degradation_knee([(0.1, 1.0), (0.5, 1.0), (0.9, 1.0)]) is None


# ---------------------------------------------------------------------------
# metrics plumbing
# ---------------------------------------------------------------------------

def test_metrics_summary_accounts_control_plane():
    tr = make_trace("incremental", seed=1, w=32, n_keys=256)
    r = replay(tr, algo="memento", plane="jnp", probe_keys=512)
    s = r.summary()
    assert s["delta_applies"] + s["snapshot_rebuilds"] > 0
    assert s["moved_probe_total"] > 0
    assert s["lookup_us_per_key"] > 0
    assert s["violations"] == 0
    assert len(s["degradation"]) == s["delta_applies"] + s["snapshot_rebuilds"]
    assert r.final_epoch == r.trace.membership_events


def test_store_is_shared_with_router():
    """Router membership events ride the driver's store: one image, one
    epoch stream (no second device mirror)."""
    tr = make_trace("session_affinity", seed=0, replicas=6, sessions=64,
                    rounds=3)
    d = ScenarioDriver(tr, algo="memento", plane="jnp", probe_keys=128)
    d.run()
    assert d.router.image_store() is d.store
    assert d.store.epoch == d.h.epoch


def test_sharded_replay_matches_single_device():
    """sharded=True routes lookups (k=1 AND k>1) through the
    ShardedLookupPlane and reproduces the unsharded fingerprint."""
    ev = [TraceEvent("lookup", n_keys=256),
          TraceEvent("remove", count=4),
          TraceEvent("lookup", n_keys=256, k=2)]
    tr = Trace("sharded", 5, 24, ev)
    plain = replay(tr, algo="memento", plane="jnp", probe_keys=256)
    d = ScenarioDriver(tr, algo="memento", plane="jnp", probe_keys=256,
                       sharded=True)
    sharded = d.run()
    assert sharded.fingerprint == plain.fingerprint
    assert set(d._planes_sharded) == {1, 2}  # both fanouts went sharded


def test_zipf_skew_validated_at_trace_build():
    with pytest.raises(ValueError, match="skew"):
        TraceEvent("lookup", n_keys=8, dist="zipf", skew=1.0)
