"""Packed (compact) device images (DESIGN.md §8.2): bit-identical lookups
across host / jnp / Pallas for every registry algorithm, dtype narrowing
and exact unpack round-trips, epoch-delta application on packed tables
through the compact DeviceImageStore, and the snapshot fallbacks when the
packed buffers cannot absorb a delta."""
from __future__ import annotations

import numpy as np
import pytest

from conformance import ALGORITHMS as ALGOS, churn_mixed, state as _state
from repro.core import DeviceImageStore, make_hash
from repro.core.packing import (EMPTY, TOMBSTONE, build_slots,
                                image_table_bytes, narrow_dtype, pack_image,
                                packed_delta_updates, unpack_image)
from repro.kernels import engine, ref

PLANES = ["jnp", "pallas"]

KEYS = np.random.default_rng(99).integers(0, 2**32, size=700, dtype=np.uint32)


def _churn(h, events, seed):
    churn_mixed(h, events, seed=seed, p_remove=0.7)


# ---------------------------------------------------------------------------
# Packing primitives
# ---------------------------------------------------------------------------

def test_narrow_dtype_thresholds():
    assert narrow_dtype(100) == np.int8
    assert narrow_dtype(127) == np.int8
    assert narrow_dtype(128) == np.int16
    assert narrow_dtype(32767) == np.int16
    assert narrow_dtype(32768) == np.int32


def test_build_slots_roundtrip_and_sentinels():
    repl = np.full(512, -1, np.int32)
    removed = {3: 17, 100: 450, 511: 0}
    for b, c in removed.items():
        repl[b] = c
    slot_b, slot_c = build_slots(repl)
    assert slot_b.shape[0] >= 128 and (slot_b.shape[0] & (slot_b.shape[0] - 1)) == 0
    stored = {int(b): int(c) for b, c in zip(slot_b, slot_c) if b != EMPTY}
    assert stored == removed


def test_pack_unpack_roundtrip_all_algos():
    for algo in ALGOS:
        h = _state(algo, 96, 30, seed=1)
        img = h.device_image()
        back = unpack_image(pack_image(img))
        assert not back.packed
        for name, arr in img.arrays.items():
            a, b = np.asarray(arr), np.asarray(back.arrays[name])
            m = min(len(a), len(b))
            np.testing.assert_array_equal(a[:m], b[:m], err_msg=f"{algo}.{name}")
        assert back.n == img.n and back.epoch == img.epoch


def test_anchor_packing_narrows_dtype():
    h = _state("anchor", 96, 20, seed=2)
    p = pack_image(h.device_image())
    assert p.arrays["A"].dtype == np.int16
    assert p.arrays["K"].dtype == np.int16
    assert image_table_bytes(p) < image_table_bytes(h.device_image())


def test_memento_packed_layout_is_bitmap_plus_slots():
    h = _state("memento", 256, 40, seed=3)
    img = h.device_image()
    p = pack_image(img)
    assert p.packed and set(p.arrays) == {"state", "slot_b", "slot_c"}
    assert p.arrays["state"].dtype == np.uint32
    repl = np.asarray(img.arrays["repl"])
    state = np.asarray(p.arrays["state"])
    bits = (state[np.arange(len(repl)) >> 5]
            >> (np.arange(len(repl)) & 31)) & 1
    np.testing.assert_array_equal(bits == 1, repl < 0)


# ---------------------------------------------------------------------------
# Engine equality on packed images, all planes and op modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("plane", PLANES)
def test_packed_lookup_matches_host(algo, plane):
    h = _state(algo, 96, 30, seed=4)
    p = pack_image(h.device_image())
    out = np.asarray(engine.engine_lookup(KEYS, p, plane=plane))
    np.testing.assert_array_equal(out, ref.lookup_host(KEYS, h))


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("plane", PLANES)
def test_packed_replica_sets_match_dense(algo, plane):
    h = _state(algo, 64, 16, seed=5)
    dense, packed = h.device_image(), pack_image(h.device_image())
    want = np.asarray(engine.engine_lookup(KEYS, dense, k=3, plane="jnp"))
    got = np.asarray(engine.engine_lookup(KEYS, packed, k=3, plane=plane))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("plane", PLANES)
def test_packed_bounded_replica_matches_dense(plane):
    h = _state("memento", 96, 20, seed=6)
    dense, packed = h.device_image(), pack_image(h.device_image())
    cap = max(2, -(-len(KEYS) * 5 // (4 * h.working)))
    load = np.zeros(engine.bounded_load_len(dense), np.int32)
    full = sorted(h.working_set())[: h.working // 4]
    load[full] = cap
    want = np.asarray(engine.engine_lookup(KEYS, dense, k=2, load=load,
                                           cap=cap, plane="jnp"))
    plen = engine.bounded_load_len(packed)
    pload = np.zeros(plen, np.int32)
    pload[:len(load)] = load
    got = np.asarray(engine.engine_lookup(KEYS, packed, k=2, load=pload,
                                          cap=cap, plane=plane))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("plane", PLANES)
def test_packed_epoch_diff_matches_dense(plane):
    h = _state("memento", 96, 10, seed=7)
    old_d = h.device_image(capacity=512)
    old_p = pack_image(old_d)
    _churn(h, 15, seed=8)
    new_d = h.device_image(capacity=512)
    new_p = pack_image(new_d)
    want = engine.engine_diff(KEYS, old_d, new_d, plane="jnp")
    got = engine.engine_diff(KEYS, old_p, new_p, plane=plane)
    np.testing.assert_array_equal(got.old, want.old)
    np.testing.assert_array_equal(got.new, want.new)
    np.testing.assert_array_equal(got.moved, want.moved)


def test_packed_diff_rejects_mixed_layouts_same_algo():
    h = _state("memento", 64, 8, seed=9)
    dense = h.device_image()
    packed = pack_image(dense)
    with pytest.raises(ValueError, match="one layout"):
        engine.engine_diff(KEYS, dense, packed, plane="pallas")


# ---------------------------------------------------------------------------
# Compact DeviceImageStore: packed epoch deltas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_compact_store_churn_stays_bit_identical(algo):
    h = make_hash(algo, 64, capacity=256, variant="32")
    st = DeviceImageStore(h, compact=True)
    assert st.image().packed
    for round_ in range(5):
        _churn(h, 4, seed=20 + round_)
        st.sync()
        host = ref.lookup_host(KEYS, h)
        np.testing.assert_array_equal(st.lookup(KEYS), host)
        np.testing.assert_array_equal(st.lookup(KEYS, plane="pallas"), host)
    assert st.totals.delta_applies > 0  # churn rode the packed delta path


def test_compact_store_remove_then_restore_uses_tombstones():
    h = make_hash("memento", 128, capacity=512, variant="32")
    st = DeviceImageStore(h, compact=True)
    ws = sorted(h.working_set())
    for b in ws[:6]:
        h.remove(b)
    st.sync()
    for _ in range(6):  # add back: restores clear bitmap bits via tombstones
        h.add()
    st.sync()
    assert st.totals.delta_applies == 2
    assert st.totals.snapshot_rebuilds == 0
    np.testing.assert_array_equal(st.lookup(KEYS), ref.lookup_host(KEYS, h))
    mirror = st._mirror
    assert (mirror["slot_b"] == TOMBSTONE).sum() > 0  # restores left tombstones


def test_compact_store_slot_overflow_falls_back_to_snapshot():
    h = make_hash("memento", 512, capacity=512, variant="32")
    st = DeviceImageStore(h, compact=True)
    # remove far more buckets than the rebuilt slot table can absorb
    rng = np.random.default_rng(0)
    for _ in range(400):
        ws = sorted(h.working_set())
        h.remove(ws[int(rng.integers(len(ws)))])
    st.sync()
    assert st.totals.snapshot_rebuilds >= 1
    np.testing.assert_array_equal(st.lookup(KEYS), ref.lookup_host(KEYS, h))


def test_compact_store_migration_diff():
    h = make_hash("memento", 96, capacity=384, variant="32")
    st = DeviceImageStore(h, compact=True)
    _churn(h, 10, seed=30)
    st.sync()
    d = st.migration_diff(KEYS)
    host_new = ref.lookup_host(KEYS, h)
    np.testing.assert_array_equal(d.new, host_new)
    assert d.moved.any() or (d.old == d.new).all()


def test_packed_delta_updates_overflow_returns_none():
    h = _state("memento", 96, 5, seed=31)
    img = pack_image(h.device_image())
    mirror = {k: np.array(v) for k, v in img.arrays.items()}
    # a bucket index beyond the bitmap capacity cannot be scattered in place
    from repro.core.protocol import ImageDelta
    beyond = 32 * len(mirror["state"])
    delta = ImageDelta(algo="memento", base_epoch=img.epoch,
                       epoch=img.epoch + 1, n=beyond + 1,
                       updates={"repl": (np.array([beyond]),
                                         np.array([0]))})
    assert packed_delta_updates(mirror, delta) is None
