"""Replica-aware serving layer (DESIGN.md §4): k-replication, bounded load,
router failover.

The ISSUE 3 acceptance matrix:

  * ``lookup_k`` k-distinctness + slot-0 = plain lookup, every algorithm,
  * host / jnp / Pallas bit-equivalence of the replica sets across random
    churn states (``variant="32"``),
  * bounded-load cap ≤ ⌈c·keys/working⌉ invariant, device assignment
    bit-identical to the ``BoundedLoadMemento``-preserving host oracle,
  * load-word deltas riding the epoch store,
  * router replica-failover before the membership delta lands.
"""
from __future__ import annotations

import math

import numpy as np
import pytest

from conformance import ALGORITHMS as ALGOS, churn, lifo_only, make
from repro.core import (BoundedLoad, BoundedLoadMemento, DeviceImageStore,
                        make_hash, replica_sets)
from repro.core.bounded import bounded_assign_ref
from repro.kernels.engine import bounded_load_len as _load_len


def _state(algo, n0, removals, seed, variant="32"):
    h = make(algo, n0, variant=variant)
    churn(h, min(removals, n0 - 1) if lifo_only(algo) else removals,
          seed=seed)
    return h


KEYS = np.random.default_rng(3).integers(0, 2**32, size=513, dtype=np.uint32)


# ---------------------------------------------------------------------------
# lookup_k host semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("variant", ["64", "32"])
def test_lookup_k_distinct_working_and_primary(algo, variant):
    h = _state(algo, 64, 20, seed=1, variant=variant)
    for k in (1, 2, 3, 5):
        for key in KEYS[:50]:
            reps = h.lookup_k(int(key), k)
            assert len(reps) == k
            assert len(set(reps)) == k  # pairwise distinct
            assert reps[0] == h.lookup(int(key))  # slot 0 = classic placement
            assert set(reps) <= h.working_set()


def test_lookup_k_rejects_bad_k():
    h = _state("memento", 8, 4, seed=0)
    with pytest.raises(ValueError):
        h.lookup_k(1, 0)
    with pytest.raises(ValueError):
        h.lookup_k(1, h.working + 1)


def test_lookup_k_equals_working_enumerates_all():
    h = _state("memento", 6, 2, seed=2)
    reps = h.lookup_k(12345, h.working)
    assert set(reps) == h.working_set()


# ---------------------------------------------------------------------------
# host / jnp / Pallas bit-equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("n0,removals", [(16, 0), (16, 6), (200, 130)])
def test_replica_lookup_three_planes_bit_identical(algo, n0, removals):
    from repro.kernels.engine import replica_lookup

    h = _state(algo, n0, removals, seed=n0 + removals)
    image = h.device_image()
    k = min(3, h.working)
    want = replica_sets(h, KEYS, k)  # numpy oracle over the host plane
    got_jnp = np.asarray(replica_lookup(KEYS, image, k, plane="jnp"))
    got_pallas = np.asarray(replica_lookup(KEYS, image, k, plane="pallas"))
    np.testing.assert_array_equal(got_jnp, want)
    np.testing.assert_array_equal(got_pallas, want)


def test_replica_lookup_rejects_unknown_plane():
    from repro.kernels.engine import engine_lookup

    h = _state("memento", 16, 0, seed=0)
    with pytest.raises(ValueError):
        engine_lookup(KEYS[:4], h.device_image(), k=2, plane="cuda")


# ---------------------------------------------------------------------------
# bounded load
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_bounded_load_cap_invariant(algo):
    h = make_hash(algo, 32, capacity=128, variant="32")
    bl = BoundedLoad(h, c=1.25)
    n_keys = 1000
    bl.assign_batch(KEYS[:n_keys // 2].astype(np.uint64))
    for key in KEYS[n_keys // 2: n_keys // 2 + 100]:
        bl.assign(int(key))
    total = len(bl.assignment)
    cap = max(1, math.ceil(1.25 * total / bl.working))
    assert bl.load.max() <= cap  # the c-cap invariant
    assert bl.load.sum() == total
    assert bl.peak_to_mean() <= cap / (total / bl.working) + 1e-9


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("plane", ["jnp", "pallas"])
def test_bounded_assign_device_matches_host_oracle(algo, plane):
    h = _state(algo, 24, 8, seed=7)
    image = h.device_image()
    n_keys = 256
    cap = max(1, math.ceil(1.25 * n_keys / h.working))
    load0 = np.zeros(_load_len(image), np.int32)

    from repro.kernels.engine import bounded_assign as bounded_assign_device
    want, want_load = bounded_assign_ref(h, KEYS[:n_keys], load0, cap)
    got, got_load = bounded_assign_device(KEYS[:n_keys], image, load0, cap,
                                          plane=plane)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got_load[: len(want_load)], want_load)
    assert got_load.max() <= cap


def test_bounded_single_assign_is_batch_of_one():
    """The preserved BoundedLoadMemento semantics ARE the m=1 batch case."""
    a = BoundedLoadMemento(10, c=1.25)
    b = BoundedLoadMemento(10, c=1.25)
    keys = [int(k) for k in KEYS[:300]]
    for k in keys:
        a.assign(k)
    for k in keys:
        b.assign_batch(np.asarray([k], np.uint64))
    assert a.assignment == b.assignment
    np.testing.assert_array_equal(a.load[: b.load.shape[0]],
                                  b.load[: a.load.shape[0]])


def test_bounded_load_words_ride_epoch_deltas():
    """Assign/release/fail events reach the device as O(changed-words)
    deltas; the synced load array matches the host's."""
    bl = BoundedLoadMemento(16, c=1.5, variant="32")
    store = DeviceImageStore(bl)
    bl.assign_batch(KEYS[:200].astype(np.uint64))
    st = store.sync()
    assert st.mode == "delta"
    np.testing.assert_array_equal(
        np.asarray(store.image().arrays["load"])[: bl.load.shape[0]], bl.load)

    victim = sorted(bl.working_set())[0]
    moves = bl.remove(victim)  # membership + re-spill in one epoch
    st = store.sync()
    assert st.mode == "delta" and st.events == 1
    img = store.image()
    np.testing.assert_array_equal(
        np.asarray(img.arrays["load"])[: bl.load.shape[0]], bl.load)
    assert all(b in bl.working_set() for b in moves.values())
    # the image still serves plain lookups (load is extra payload)
    out = store.lookup(KEYS[:64])
    host = [bl.lookup(int(k)) for k in KEYS[:64]]
    np.testing.assert_array_equal(out, host)

    bl.release(int(KEYS[0]))
    assert store.sync().mode == "delta"
    np.testing.assert_array_equal(
        np.asarray(store.image().arrays["load"])[: bl.load.shape[0]], bl.load)


def test_bounded_remove_moves_only_victims():
    """The original BoundedLoadMemento contract still holds."""
    bl = BoundedLoadMemento(10, c=1.25)
    keys = [int(k) for k in
            np.random.default_rng(2).integers(0, 2**63, size=2000)]
    for k in keys:
        bl.assign(k)
    assert bl.peak_to_mean() <= 1.3
    before = dict(bl.assignment)
    victim = sorted(bl.m.working_set())[0]
    victims = {k for k, b in before.items() if b == victim}
    moves = bl.remove(victim)
    assert set(moves) == victims
    for k, b in bl.assignment.items():
        if k not in victims:
            assert b == before[k]


def test_bounded_rejects_bad_c():
    with pytest.raises(ValueError):
        BoundedLoadMemento(4, c=1.0)


@pytest.mark.parametrize("plane", ["host", "jnp"])
def test_bounded_infeasible_cap_raises_instead_of_spinning(plane):
    """cap·buckets < keys can never settle: both planes must raise the
    host walk's 'no bucket below capacity' error, not loop forever."""
    h = _state("memento", 4, 0, seed=0)
    image = h.device_image()
    keys, cap = KEYS[:16], 1  # 16 keys, 4 buckets × cap 1 = 4 slots
    load0 = np.zeros(_load_len(image), np.int32)
    with pytest.raises(RuntimeError, match="no bucket below capacity"):
        if plane == "host":
            bounded_assign_ref(h, keys, load0, cap)
        else:
            from repro.kernels.engine import bounded_assign as bounded_assign_device
            bounded_assign_device(keys, image, load0, cap, plane="jnp")


# ---------------------------------------------------------------------------
# router replica-failover
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["memento", "anchor"])
def test_router_failover_before_delta_lands(algo):
    from repro.serve.router import SessionRouter

    r = SessionRouter(12, algo=algo, capacity=48, replicas_k=3)
    sids = np.arange(400, dtype=np.uint64)
    base = r.route_batch(sids)
    sets = r.replica_set_batch(sids)
    assert (sets[:, 0] == base).all()
    assert all(len(set(row)) == 3 for row in sets.tolist())

    victim = int(np.bincount(base).argmax())
    r.mark_failed(victim)  # health check fired; NO membership delta yet
    assert victim in r.replicas  # membership (and the device image) untouched
    after = r.route_batch(sids)
    assert victim not in set(after.tolist())
    moved = after != base
    # ONLY the victim's sessions fail over, and they go to replica 1
    assert moved.sum() == (base == victim).sum()
    np.testing.assert_array_equal(after[moved], sets[moved, 1])
    # scalar path applies the same rule
    for s in np.nonzero(moved)[0][:10]:
        assert r.route(int(sids[s])) == after[s]
    assert r.stats.failovers > 0

    # the delta lands: the mark clears and membership catches up
    info = r.fail_replica(victim)
    assert victim not in r.replicas
    assert info["control_plane"]["mode"] in ("delta", "snapshot")
    final = r.route_batch(sids)
    assert victim not in set(final.tolist())


def test_router_all_marked_falls_back_to_primary():
    from repro.serve.router import SessionRouter

    r = SessionRouter(4, replicas_k=2)
    for rep in list(r.replicas):
        r.mark_failed(rep)
    sid = 7
    assert r.route(sid) == r.replica_set(sid)[0]


# ---------------------------------------------------------------------------
# elastic failure domains
# ---------------------------------------------------------------------------

def test_elastic_replica_sets_span_distinct_domains():
    from repro.runtime.elastic import ElasticCluster

    c = ElasticCluster(16, num_shards=64, replica_k=3, num_domains=4)
    placement = c.replica_placement()
    for shard, hosts in placement.items():
        assert len(hosts) == 3
        assert len({h % 4 for h in hosts}) == 3  # pairwise-distinct domains
        assert hosts[0] == c.placement.host_of(shard)

    c.fail(sorted(c.hosts)[0])
    for shard, hosts in c.replica_placement().items():
        assert len({h % 4 for h in hosts}) == 3
        assert set(hosts) <= c.hosts


def test_elastic_replica_k_exceeding_domains_raises():
    from repro.runtime.elastic import ElasticCluster

    c = ElasticCluster(8, num_shards=8, replica_k=3, num_domains=2)
    with pytest.raises(ValueError):
        c.replica_hosts(0)
