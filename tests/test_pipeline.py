"""Pipeline parallelism: executed equivalence on a real 2-device pod mesh.

Subprocess (needs XLA_FLAGS device-count before jax init): a 2-stage
pipeline over the pod axis must reproduce the plain forward pass exactly.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

import jax
import pytest

REPO = Path(__file__).resolve().parents[1]

# Partial-manual shard_map with in-region sharding constraints that mention
# the manual axis is only legal on newer jax (jax.shard_map + varying-axis
# types); the old experimental API rejects it outright.
requires_new_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="pipeline partial-manual shard_map requires jax.shard_map "
           "(newer jax); the baked-in jax only has the experimental API")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.models import LM
from repro.sharding.rules import default_rules
from repro.train.pipeline import make_pipelined_forward

from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 1, 1), ("pod", "data", "model"))
rules = default_rules(mesh).with_overrides(stack=("pod",))
cfg = dataclasses.replace(smoke_config("phi4-mini-3.8b"), dtype="float32",
                          num_layers=4)
model = LM(cfg, attn_chunk=8, remat="none", rules=rules)
params = model.init(jax.random.PRNGKey(0))
B, S = 8, 16
embeds = jnp.asarray(np.random.default_rng(0).normal(size=(B, S, cfg.d_model)),
                     jnp.float32)

# reference: plain forward up to final norm — recreate by running blocks only
from repro.models.lm import block_apply
positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
h = embeds
for p_idx in range(model.n_periods):
    blk = jax.tree.map(lambda x: x[p_idx], params["blocks"])
    for i, kind in enumerate(model.period_kinds):
        h, _ = block_apply(cfg, kind, blk[str(i)], h, positions, chunk=8)
ref = h

fwd = make_pipelined_forward(model, rules, num_microbatches=4)
pspecs = model.param_specs(rules)
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
with mesh:
    params_sharded = jax.device_put(params, ns(pspecs))
    out = jax.jit(fwd)(params_sharded, embeds)
err = float(jnp.abs(out - ref).max())
scale = float(jnp.abs(ref).max())
assert err < 1e-3 * max(scale, 1.0), (err, scale)
print("PIPELINE_OK", err)
"""


@requires_new_shard_map
def test_pipeline_matches_forward():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=str(REPO))
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "PIPELINE_OK" in out.stdout
