"""Autotuner (DESIGN.md §8.1): deterministic JSON cache, dispatch-time
resolution that never retraces, explicit-override precedence, and the
tuned-equals-default bit-identity the tuner itself enforces."""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import make_hash
from repro.kernels import autotune, engine
from repro.kernels.autotune import (TuneCache, TunedConfig, grid_key,
                                    size_bucket)

KEYS = np.random.default_rng(5).integers(0, 2**32, size=700, dtype=np.uint32)


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Point the process cache at a tmpdir and drop any loaded state."""
    path = tmp_path / "TUNE_engine.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    autotune.set_active_cache(None)
    yield path
    autotune.set_active_cache(None)


def _image(n=96, removals=20, seed=3):
    h = make_hash("memento", n, capacity=4 * n, variant="32")
    rng = np.random.default_rng(seed)
    for _ in range(removals):
        ws = sorted(h.working_set())
        h.remove(ws[int(rng.integers(len(ws)))])
    return h.device_image()


# ---------------------------------------------------------------------------
# Cache determinism + JSON round-trip
# ---------------------------------------------------------------------------

def test_size_bucket_powers_of_two():
    assert size_bucket(1) == 1
    assert size_bucket(1000) == 1024
    assert size_bucket(1024) == 1024
    assert size_bucket(1025) == 2048


def test_grid_key_shares_size_band():
    op = engine.EngineOp(algo="memento")
    a = grid_key(op, 1000, 500, backend="cpu")
    b = grid_key(op, 1024, 512, backend="cpu")
    c = grid_key(op, 2048, 512, backend="cpu")
    assert a == b != c
    assert a == "cpu/memento.lookup.k1.dense/keys1024/n512"


def test_cache_json_roundtrip_and_determinism(tmp_cache):
    cache = TuneCache()
    cache.put("cpu/memento.lookup.k1.dense/keys1024/n512",
              TunedConfig(block_rows=16, plane="jnp", us_per_key=0.12))
    cache.put("cpu/dx.lookup.k2.dense/keys2048/n512",
              TunedConfig(block_rows=4, plane="pallas", us_per_key=1.5))
    p = cache.save(tmp_cache)
    first = p.read_text()
    loaded = TuneCache.load(p)
    assert loaded.entries == cache.entries
    # same entries inserted in the other order ⇒ byte-identical file
    other = TuneCache()
    for k in reversed(list(cache.entries)):
        other.put(k, cache.entries[k])
    assert other.save(tmp_cache).read_text() == first
    payload = json.loads(first)
    assert payload["version"] == autotune.CACHE_VERSION
    assert list(payload["entries"]) == sorted(payload["entries"])


def test_env_empty_disables_cache(monkeypatch):
    monkeypatch.setenv(autotune.CACHE_ENV, "")
    autotune.set_active_cache(None)
    assert autotune.cache_path() is None
    assert len(autotune.active_cache()) == 0
    autotune.set_active_cache(None)


# ---------------------------------------------------------------------------
# Dispatch-time resolution
# ---------------------------------------------------------------------------

def test_resolution_fallback_and_tuned(tmp_cache):
    op = engine.EngineOp(algo="memento")
    assert autotune.resolve_block_rows(op, 700, 96) == engine.DEFAULT_BLOCK_ROWS
    cache = autotune.active_cache()
    cache.put(grid_key(op, 700, 96), TunedConfig(block_rows=32, plane="jnp"))
    assert autotune.resolve_block_rows(op, 700, 96) == 32
    assert autotune.resolve_plane(op, 700, 96) == "jnp"
    # off the tuned cell: defaults again (jnp on the CPU backend)
    assert autotune.resolve_block_rows(op, 70_000, 96) == engine.DEFAULT_BLOCK_ROWS
    assert autotune.resolve_plane(op, 70_000, 96) in ("jnp", "pallas")


def test_explicit_block_rows_overrides_tuned(tmp_cache):
    img = _image()
    op = engine.EngineOp(algo="memento")
    cache = autotune.active_cache()
    cache.put(grid_key(op, len(KEYS), int(img.n)), TunedConfig(block_rows=32))
    assert engine._resolve_block_rows(op, len(KEYS), int(img.n), 16) == 16
    assert engine._resolve_block_rows(op, len(KEYS), int(img.n), None) == 32


def test_cache_hit_never_retraces(tmp_cache, monkeypatch):
    img = _image()
    op = engine.EngineOp(algo="memento")
    cache = autotune.active_cache()
    cache.put(grid_key(op, len(KEYS), int(img.n)), TunedConfig(block_rows=4))

    calls = {"n": 0}
    real = engine._engine_kernel_factory

    def counting(op_):
        calls["n"] += 1
        return real(op_)

    monkeypatch.setattr(engine, "_engine_kernel_factory", counting)
    out1 = np.asarray(engine.engine_lookup(KEYS, img, plane="pallas"))
    traced = calls["n"]
    assert traced >= 1  # first call traces with the tuned tile
    out2 = np.asarray(engine.engine_lookup(KEYS, img, plane="pallas"))
    assert calls["n"] == traced  # cache hit: same static key, no retrace
    np.testing.assert_array_equal(out1, out2)


# ---------------------------------------------------------------------------
# The tuner itself
# ---------------------------------------------------------------------------

def test_autotune_lookup_records_bit_identical_winner(tmp_cache):
    img = _image()
    key, cfg = autotune.autotune_lookup(img, len(KEYS), seed=5, repeats=1,
                                        candidates=(4, 8))
    assert cfg.block_rows in (4, 8) or cfg.plane == "jnp"
    assert cfg.us_per_key > 0
    assert autotune.active_cache().get(key) == cfg
    # the tuned configuration serves bit-identically to the default
    default = np.asarray(engine.engine_lookup(
        KEYS, img, plane="pallas", block_rows=engine.DEFAULT_BLOCK_ROWS))
    tuned = np.asarray(engine.engine_lookup(KEYS, img, plane=cfg.plane,
                                            block_rows=cfg.block_rows))
    np.testing.assert_array_equal(tuned, default)


def test_autotune_lookup_packed_image(tmp_cache):
    from repro.core.packing import pack_image

    img = pack_image(_image())
    key, cfg = autotune.autotune_lookup(img, len(KEYS), seed=5, repeats=1,
                                        candidates=(8,))
    assert ".packed/" in key
    tuned = np.asarray(engine.engine_lookup(KEYS, img, plane=cfg.plane,
                                            block_rows=cfg.block_rows))
    dense = np.asarray(engine.engine_lookup(KEYS, _image(), plane="jnp"))
    np.testing.assert_array_equal(tuned, dense)
