"""Int8-quantized KV cache: bounded error vs f32, exact prefill logits."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import LM


@pytest.mark.parametrize("name", ["qwen2.5-14b", "gemma3-12b"])
def test_int8_cache_decode_close(name):
    cfg = dataclasses.replace(smoke_config(name), dtype="float32")
    m_ref = LM(cfg, attn_chunk=8, remat="none")
    m_i8 = LM(cfg, attn_chunk=8, remat="none", cache_dtype="int8")
    params = m_ref.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)),
                         jnp.int32)
    full, _ = m_ref.forward(params, tokens=tokens)

    # control: the same decode loop with an f32 cache must track the forward
    # pass to f32 op-reordering noise (~1e-3 ≪ the int8 drift below) —
    # isolates quantization noise from decode-path bugs.
    cache = m_ref.init_cache(B, max_len=S)
    for t in range(S):
        cache, lg_f32 = m_ref.decode_step(params, cache, tokens[:, t:t + 1],
                                          jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg_f32[:, 0]),
                                   np.asarray(full[:, t]), rtol=1e-3, atol=1e-3)

    cache = m_i8.init_cache(B, max_len=S)
    assert cache["blocks"]["0"]["k"].dtype == jnp.int8
    errs = []
    for t in range(S):
        cache, lg = m_i8.decode_step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    # int8 noise compounds with depth, and a random-init toy model's logits
    # sit in a band comparable to that noise (rankings there are meaningless
    # — no argmax/top-k assertion can be stable).  Assert bounded drift:
    # the int8 logits stay well-aligned with the f32 logits.
    a = np.asarray(lg[:, 0]).ravel()
    b = np.asarray(full[:, -1]).ravel()
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
    assert cos > 0.8, (cos, max(errs))


def test_int8_prefill_logits_exact():
    cfg = dataclasses.replace(smoke_config("phi4-mini-3.8b"), dtype="float32")
    m_ref = LM(cfg, attn_chunk=8, remat="none")
    m_i8 = LM(cfg, attn_chunk=8, remat="none", cache_dtype="int8")
    params = m_ref.init(jax.random.PRNGKey(1))
    tokens = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 16)),
                         jnp.int32)
    full, _ = m_ref.forward(params, tokens=tokens)
    _, lp = m_i8.prefill(params, tokens=tokens, max_len=20)
    # prefill attention runs on unquantized k/v; only the stored cache is int8
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-4, atol=1e-4)
