"""Per-architecture smoke tests: reduced config, forward/train/decode on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import LM

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.frontend:  # stub modality frontend: precomputed embeddings
        return {"embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "labels": labels}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "labels": labels}


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_and_loss(name):
    cfg = smoke_config(name)
    model = LM(cfg, attn_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, _ = model.forward(params, tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"))
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_matches_forward(name):
    """Token-by-token decode must reproduce the teacher-forced forward pass.

    Runs in f32 compute: this asserts *algorithmic* equivalence of the two
    paths; bf16 accumulation-order drift is covered by the forward test.
    """
    import dataclasses
    cfg = dataclasses.replace(smoke_config(name), dtype="float32")
    model = LM(cfg, attn_chunk=8, remat="none")
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    full_logits, _ = model.forward(params, tokens=tokens)

    cache = model.init_cache(B, max_len=S)
    dec = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        cache, logits = dec(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_then_decode_matches(name):
    import dataclasses
    cfg = dataclasses.replace(smoke_config(name), dtype="float32")
    model = LM(cfg, attn_chunk=8, remat="none")
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 16
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    # ground truth: decode from scratch
    cache = model.init_cache(B, max_len=S + 4)
    for t in range(S):
        cache, logits_ref = model.decode_step(params, cache, tokens[:, t:t + 1], jnp.int32(t))

    cache2, logits_pre = model.prefill(params, tokens=tokens, max_len=S + 4)
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]), np.asarray(logits_ref[:, 0]),
                               rtol=2e-2, atol=2e-2)
    # one more decoded token must agree between the two cache lineages
    nxt = tokens[:, :1]
    _, a = model.decode_step(params, cache, nxt, jnp.int32(S))
    _, b = model.decode_step(params, cache2, nxt, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-2)


def test_param_counts_match_analytic():
    for name in ALL_ARCHS:
        cfg = get_config(name)
        model = LM(cfg)
        got = model.param_count()
        want = cfg.param_count()
        assert abs(got - want) / want < 0.02, (name, got, want)


def test_full_configs_match_brief():
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (32, 4096, 32, 8)
    assert (c.num_experts, c.num_experts_per_tok) == (16, 2)
    assert 40e9 < c.param_count() < 45e9
    assert 6e9 < c.active_param_count() < 8e9
    c = get_config("olmoe-1b-7b")
    assert 6e9 < c.param_count() < 8e9
    assert 0.9e9 < c.active_param_count() < 1.6e9
    c = get_config("mamba2-780m")
    assert 0.6e9 < c.param_count() < 1.0e9
    c = get_config("gemma3-12b")
    assert c.pattern[:6] == ("local",) * 5 + ("attn",)
    c = get_config("recurrentgemma-9b")
    assert c.pattern[:3] == ("rglru", "rglru", "local")
    assert len(c.pattern) == 38 and c.full_periods == 12 and len(c.tail_layers) == 2
