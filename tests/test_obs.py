"""Telemetry plane (repro.obs, DESIGN.md §11): bucket math, registry
thread-safety under epoch-flip races, span nesting, export round-trips,
the no-op strictness of the NullRegistry, the scenario-replay
determinism gates, and the instrumentation-coverage scan that keeps
every serving-layer public method either instrumented or explicitly
``# obs-exempt``."""
from __future__ import annotations

import inspect
import json
import math
import threading

import numpy as np
import pytest

from repro.obs import (Histogram, MetricRegistry, NullRegistry,
                       TelemetrySink, bucket_index, bucket_upper,
                       default_registry, render_prometheus,
                       set_default_registry, snapshot_text)
from repro.obs.metrics import (BUCKETS_PER_OCTAVE, MAX_EXP, MIN_EXP,
                               ensure_real)

# ---------------------------------------------------------------------------
# histogram bucket math


def test_bucket_index_fixtures():
    """Known values land in the right log bucket; boundaries are exact."""
    # factor-2^(1/4) buckets: 1.0 sits exactly on a boundary (index -1 has
    # upper 2^0 = 1.0, so 1.0 belongs to the bucket whose UPPER is 1.0)
    assert bucket_upper(bucket_index(1.0)) >= 1.0
    for v in (1e-3, 0.5, 1.0, 3.7, 1024.0, 1e6):
        idx = bucket_index(v)
        assert bucket_upper(idx - 1) < v <= bucket_upper(idx) or \
            idx in (MIN_EXP, MAX_EXP)
    # exact powers of two on their boundary, never one bucket high
    for e in (0, 1, 4, 10):
        assert bucket_upper(bucket_index(2.0 ** e)) == 2.0 ** e


def test_bucket_index_clamps_degenerate_values():
    """0, negatives, and denormals clamp to the floor bucket; huge values
    to the ceiling — observe() can never throw on a weird latency."""
    assert bucket_index(0.0) == MIN_EXP
    assert bucket_index(-5.0) == MIN_EXP
    assert bucket_index(1e-30) == MIN_EXP
    assert bucket_index(1e80) == MAX_EXP


def test_quantile_relative_error_bound():
    """Factor-2^(1/4) buckets ⇒ any quantile is within 19 % above the
    true value (and max is exact)."""
    rng = np.random.default_rng(7)
    vals = rng.lognormal(mean=3.0, sigma=2.0, size=5000)
    h = Histogram("t")
    for v in vals:
        h.observe(float(v))
    for q in (0.5, 0.95, 0.99):
        true = float(np.quantile(vals, q, method="inverted_cdf"))
        got = h.quantile(q)
        assert true <= got <= true * 2 ** (1 / BUCKETS_PER_OCTAVE) * 1.0001, \
            (q, true, got)
    assert h.quantile(1.0) == pytest.approx(h.max)
    assert h.mean == pytest.approx(float(vals.sum()) / len(vals))


def test_histogram_merge_associative_and_commutative():
    rng = np.random.default_rng(11)
    parts = [rng.exponential(50, size=200) for _ in range(3)]

    def mk(*chunks):
        h = Histogram("m")
        for c in chunks:
            for v in c:
                h.observe(float(v))
        return h

    def merged(a, b):
        out = mk()
        out.merge(a)
        out.merge(b)
        return out

    a, b, c = (mk(p) for p in parts)
    ab_c = merged(merged(mk(parts[0]), mk(parts[1])), mk(parts[2]))
    a_bc = merged(mk(parts[0]), merged(mk(parts[1]), mk(parts[2])))
    ba = merged(mk(parts[1]), mk(parts[0]))
    whole = mk(*parts)
    for h in (ab_c, a_bc):
        assert h.buckets == whole.buckets
        assert h.count == whole.count
        assert h.sum == pytest.approx(whole.sum)
        assert (h.min, h.max) == (whole.min, whole.max)
    assert ba.buckets == merged(mk(parts[0]), mk(parts[1])).buckets


# ---------------------------------------------------------------------------
# registry semantics


def test_registry_labels_and_kind_mismatch():
    reg = MetricRegistry()
    c1 = reg.counter("x.hits", op="lookup")
    c2 = reg.counter("x.hits", op="lookup")
    c3 = reg.counter("x.hits", op="diff")
    assert c1 is c2 and c1 is not c3
    c1.inc(3)
    assert reg.counter("x.hits", op="lookup").value == 3
    with pytest.raises(TypeError):
        reg.histogram("x.hits", op="lookup")  # same key, different kind
    with pytest.raises(ValueError):
        c1.inc(-1)  # counters are monotonic


def test_registry_thread_safety_under_epoch_flip_race():
    """The test_image_store hammer pattern, pointed at telemetry: a
    thread hammers instrumented ``store.lookup`` while the main thread
    races epoch flips through ``sync_async``.  Every counter lands
    (exact totals), no exception escapes either thread."""
    from repro.core import DeviceImageStore, make_hash

    reg = MetricRegistry()
    h = make_hash("memento", 32, variant="32")
    store = DeviceImageStore(h, registry=reg)
    keys = np.arange(64, dtype=np.uint32)
    store.lookup(keys)  # warm the jit before the clocked race

    base_lookups = reg.counter("store.lookups").value
    stop = threading.Event()
    errors: list[Exception] = []
    done = [0]

    def hammer():
        try:
            while not stop.is_set():
                store.lookup(keys)
                done[0] += 1
        except Exception as e:  # surfaced in the main thread
            errors.append(e)

    t = threading.Thread(target=hammer)
    t.start()
    try:
        rng = np.random.default_rng(3)
        for _ in range(12):
            h.remove(int(rng.choice(sorted(h.working_set())[1:])))
            handle = store.sync_async()
            while not handle.poll():
                pass
    finally:
        stop.set()
        t.join()
    assert not errors, errors
    assert done[0] > 0
    assert reg.counter("store.lookups").value == base_lookups + done[0]
    assert reg.counter("store.lookup_keys").value == \
        (base_lookups + done[0]) * len(keys)
    assert reg.counter("store.syncs").value == 12


def test_counter_exact_under_contention():
    reg = MetricRegistry()
    c = reg.counter("contended")
    n, per = 8, 5000

    def worker():
        for _ in range(per):
            c.inc()

    ts = [threading.Thread(target=worker) for _ in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n * per


# ---------------------------------------------------------------------------
# null registry strictness


def test_null_registry_is_stateless_and_shared():
    null = NullRegistry()
    assert not null.active
    c = null.counter("anything", label="x")
    assert c is null.histogram("other")  # one shared no-op instrument
    c.inc(5)
    c.observe(3.0)
    assert c.value == 0
    assert null.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert null.sink.to_jsonl() == ""
    with null.span("noop", a=1):  # a usable (empty) context manager
        pass
    assert null.tracer.completed() == []


def test_default_registry_starts_null_and_restores():
    assert not default_registry().active
    reg = MetricRegistry()
    prev = set_default_registry(reg)
    try:
        assert default_registry() is reg
    finally:
        set_default_registry(prev)
    assert not default_registry().active


def test_ensure_real_gives_private_registry_when_telemetry_off():
    r = ensure_real(None)
    assert r.active  # public stats APIs keep working with telemetry off
    live = MetricRegistry()
    assert ensure_real(live) is live
    assert ensure_real(NullRegistry()) is not None
    assert ensure_real(NullRegistry()).active


# ---------------------------------------------------------------------------
# spans


def test_span_nesting_parent_child_and_order():
    reg = MetricRegistry()
    with reg.span("outer", mode="x") as outer:
        with reg.span("mid") as mid:
            with reg.span("inner"):
                pass
        with reg.span("mid2"):
            pass
    tr = reg.tracer
    names = [s.name for s in tr.completed()]
    # completion order is deterministic: children close before parents
    assert names == ["inner", "mid", "mid2", "outer"]
    spans = {s.name: s for s in tr.completed()}
    assert spans["outer"].parent == 0 and spans["outer"].depth == 1
    assert spans["mid"].parent == spans["outer"].id
    assert spans["inner"].parent == spans["mid"].id
    assert spans["inner"].depth == 3
    assert spans["outer"].attrs == {"mode": "x"}
    assert {s.name for s in tr.children_of(outer)} == {"mid", "mid2"}
    assert outer.dur_us >= mid.dur_us >= 0.0
    assert [d for d, _, _ in tr.tree()] == [3, 2, 2, 1]


def test_span_ring_is_bounded():
    from repro.obs.trace import Tracer

    tr = Tracer(max_spans=8)
    for i in range(20):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.completed()) == 8
    assert tr.dropped == 12
    assert tr.completed()[-1].name == "s19"


def test_span_emits_sink_events():
    reg = MetricRegistry()
    with reg.span("a", epoch=3):
        pass
    evs = reg.sink.events("span")
    assert len(evs) == 1
    assert evs[0]["name"] == "a" and evs[0]["epoch"] == 3
    assert evs[0]["dur_us"] >= 0.0


# ---------------------------------------------------------------------------
# export


def _fixture_registry() -> MetricRegistry:
    reg = MetricRegistry()
    reg.counter("eng.hits", op="lookup").inc(7)
    reg.counter("eng.hits", op="diff").inc(2)
    reg.gauge("lag", follower="0").set(4)
    h = reg.histogram("lat.us")
    for v in (1.0, 2.0, 2.0, 100.0):
        h.observe(v)
    return reg


def test_prometheus_exposition_shape():
    txt = render_prometheus(_fixture_registry())
    lines = txt.splitlines()
    assert '# TYPE repro_eng_hits counter' in lines
    assert 'repro_eng_hits{op="diff"} 2' in lines
    assert 'repro_eng_hits{op="lookup"} 7' in lines
    assert 'repro_lag{follower="0"} 4' in lines
    assert '# TYPE repro_lat_us histogram' in lines
    assert 'repro_lat_us_bucket{le="+Inf"} 4' in lines
    assert 'repro_lat_us_count 4' in lines
    assert 'repro_lat_us_sum 105.0' in lines
    # cumulative bucket counts never decrease
    cums = [int(l.rsplit(" ", 1)[1]) for l in lines
            if l.startswith("repro_lat_us_bucket")]
    assert cums == sorted(cums) and cums[-1] == 4
    # deterministic: same registry renders byte-identical
    assert txt == render_prometheus(_fixture_registry())


def test_snapshot_text_round_trip():
    reg = _fixture_registry()
    snap = json.loads(snapshot_text(reg))
    assert snap["counters"]['eng.hits{op="lookup"}'] == 7
    assert snap["gauges"]['lag{follower="0"}'] == 4
    hist = snap["histograms"]["lat.us"]
    assert hist["count"] == 4 and hist["sum"] == 105.0
    assert hist["max"] == 100.0
    assert snapshot_text(reg) == snapshot_text(reg)


def test_sink_jsonl_round_trip_and_bound():
    sink = TelemetrySink(max_events=4)
    for i in range(7):
        sink.emit("tick", i=i)
    assert sink.emitted == 7 and sink.dropped == 3
    evs = sink.events()
    assert [e["i"] for e in evs] == [3, 4, 5, 6]
    assert TelemetrySink.parse_jsonl(sink.to_jsonl()) == evs


# ---------------------------------------------------------------------------
# RouterStats view (the dict API rides registry counters now)


def test_router_stats_view_keeps_dict_api():
    from repro.serve.router import RouterStats

    reg = MetricRegistry()
    stats = RouterStats(reg)
    stats.routed += 5
    stats.failovers += 1
    assert stats.routed == 5
    assert reg.counter("router.routed").value == 5
    assert stats.as_dict() == {"routed": 5, "moved_on_failure": 0,
                               "affinity_hits": 0, "failovers": 1}
    stats.routed = 2  # backwards writes can't decrement a counter
    assert stats.routed == 5


# ---------------------------------------------------------------------------
# scenario-replay determinism gates (the ISSUE's acceptance bar)


def _storm():
    from repro.sim.traces import churn_storm_trace
    return churn_storm_trace(0, w=32, storms=1, burst=4, n_keys=128)


def test_replay_telemetry_deterministic_and_fingerprint_stable():
    from repro.sim.driver import replay

    resolved = replay(_storm(), algo="memento", plane="jnp").resolved
    r_off = replay(resolved, algo="memento", plane="jnp")
    r1 = replay(resolved, algo="memento", plane="jnp", telemetry=True)
    r2 = replay(resolved, algo="memento", plane="jnp", telemetry=True)
    assert not default_registry().active  # scoped install restored
    # telemetry may never change a placement
    assert r_off.fingerprint == r1.fingerprint == r2.fingerprint
    t1, t2 = r1.summary()["telemetry"], r2.summary()["telemetry"]
    assert t1["counters"] == t2["counters"]
    assert t1["gauges"] == t2["gauges"]
    assert {k: v["count"] for k, v in t1["histograms"].items()} == \
        {k: v["count"] for k, v in t2["histograms"].items()}
    assert any(v["count"] > 0 and k.startswith("engine.dispatch.us")
               for k, v in t1["histograms"].items())
    assert "telemetry" not in r_off.summary()
    # the summary numbers agree between telemetered and plain replays
    s_off, s_on = r_off.summary(), r1.summary()
    for k, v in s_off.items():
        if isinstance(v, (int, str)) and not k.endswith("us_mean"):
            assert s_on[k] == v, k


def test_replay_accepts_external_registry():
    from repro.sim.driver import replay

    reg = MetricRegistry()
    res = replay(_storm(), algo="memento", plane="jnp", telemetry=reg)
    assert res.metrics.obs is reg
    assert reg.counter("sim.events").value == len(res.metrics.records)
    assert reg.counter("store.syncs").value > 0


def test_time_fn_histogram_deltas():
    from benchmarks.timing import time_fn

    h = Histogram("bench.us")
    h.observe(1e6)  # pre-existing sample must not skew the mean
    mean_s = time_fn(lambda: None, repeats=4, warmup=0, histogram=h)
    assert h.count == 5
    assert 0.0 <= mean_s < 0.1


# ---------------------------------------------------------------------------
# instrumentation-coverage scan: every public method on the serving
# surfaces either records telemetry or carries an explicit allowlist
# marker (`# obs-exempt`) saying why it does no device/wire work.

SURFACES = [
    ("repro.core.image_store", ("DeviceImageStore", "SyncHandle")),
    ("repro.serve.router", ("SessionRouter",)),
    ("repro.serve.plane", ("ShardedLookupPlane",)),
    ("repro.launch.replicate", ("DeltaPublisher", "FollowerImageStore",
                                "ReplicationGroup")),
]

#: source fragments that prove a method (or its delegate) records
INSTRUMENTED = ("_obs(", "self.telemetry", "_record_batch(", "_account(",
                "registry", "ensure_real(", ".span(", ".counter(",
                ".histogram(", ".gauge(")


def _public_methods(cls):
    for name, member in vars(cls).items():
        if name.startswith("_"):
            continue
        fn = member.fget if isinstance(member, property) else member
        if callable(fn):
            yield name, fn


@pytest.mark.parametrize("modname,classes", SURFACES,
                         ids=[m for m, _ in SURFACES])
def test_serving_surfaces_fully_instrumented(modname, classes):
    import importlib

    mod = importlib.import_module(modname)
    missing = []
    for clsname in classes:
        for name, fn in _public_methods(getattr(mod, clsname)):
            try:
                src = inspect.getsource(fn)
            except (OSError, TypeError):
                continue
            if "obs-exempt" in src:
                continue
            if not any(tok in src for tok in INSTRUMENTED):
                missing.append(f"{clsname}.{name}")
    assert not missing, (
        f"uninstrumented public methods on {modname}: {missing} — record "
        "telemetry or mark the def with `# obs-exempt: <why>`")
