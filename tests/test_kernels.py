"""Pallas kernel sweeps vs the pure-jnp oracle (interpret mode on CPU)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import MementoTables, random_state
from repro.kernels import ops
from repro.kernels import ref


def _state(n0, removals, seed=0):
    m = random_state(np.random.default_rng(seed), n0, removals, variant="32")
    return m, MementoTables(m)


@pytest.mark.parametrize("n0,removals", [(16, 0), (16, 6), (200, 75), (1024, 500), (4096, 100)])
@pytest.mark.parametrize("nkeys", [1, 100, 1000])
def test_dense_kernel_matches_oracle(n0, removals, nkeys):
    import jax.numpy as jnp

    m, tabs = _state(n0, removals, seed=n0 + nkeys)
    keys = np.random.default_rng(1).integers(0, 2**32, size=nkeys, dtype=np.uint32)
    got = np.asarray(ops.memento_lookup(keys, tabs.repl, m.n, table="dense"))
    want = np.asarray(ref.memento_lookup_ref(jnp.asarray(keys), jnp.asarray(tabs.repl), m.n))
    np.testing.assert_array_equal(got, want)
    # and against the scalar host plane (end-to-end, three implementations)
    np.testing.assert_array_equal(got, ref.memento_lookup_host(keys, m))


@pytest.mark.parametrize("n0,removals", [(16, 6), (1024, 30), (100000, 200)])
def test_compact_kernel_matches_oracle(n0, removals):
    import jax.numpy as jnp

    m, tabs = _state(n0, removals, seed=7)
    keys = np.random.default_rng(2).integers(0, 2**32, size=777, dtype=np.uint32)
    got = np.asarray(ops.memento_lookup(keys, tabs.repl, m.n, table="compact"))
    want = np.asarray(ref.memento_lookup_ref(jnp.asarray(keys), jnp.asarray(tabs.repl), m.n))
    np.testing.assert_array_equal(got, want)


def test_compact_table_is_theta_r():
    from repro.kernels.engine import build_compact_table

    m, tabs = _state(100000, 50, seed=3)
    slot_b, slot_c = build_compact_table(tabs.repl)
    assert slot_b.shape[0] <= 256  # 2·r rounded to a power of two ≥ 128
    assert int((np.asarray(slot_b) >= 0).sum()) == len(m.R)


@pytest.mark.parametrize("dtype", [np.uint32, np.int64, np.uint64])
def test_kernel_key_dtypes(dtype):
    m, tabs = _state(64, 20, seed=4)
    keys = np.random.default_rng(3).integers(0, 2**31, size=130).astype(dtype)
    got = np.asarray(ops.memento_lookup(keys, tabs.repl, m.n))
    want = ref.memento_lookup_host(keys.astype(np.uint32), m)
    np.testing.assert_array_equal(got, want)


def test_kernel_block_rows_sweep():
    import jax.numpy as jnp
    from repro.kernels.engine import dense_lookup

    m, tabs = _state(512, 170, seed=5)
    keys = np.random.default_rng(4).integers(0, 2**32, size=2048, dtype=np.uint32)
    want = np.asarray(ref.memento_lookup_ref(jnp.asarray(keys), jnp.asarray(tabs.repl), m.n))
    for block_rows in (1, 2, 8, 16):
        got = np.asarray(dense_lookup(jnp.asarray(keys), jnp.asarray(tabs.repl), m.n,
                                      block_rows=block_rows))
        np.testing.assert_array_equal(got, want)
