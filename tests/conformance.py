"""Shared algorithm-conformance helpers (imported by the test modules).

THE one place tests get algorithm-generic state builders from: every
helper derives its behaviour from :data:`repro.core.ALGORITHM_REGISTRY`
(LIFO-only removal, fixed capacity, packed layout), so adding algorithm
#6 to the registry automatically enrolls it in the whole conformance
suite — no per-algorithm copies, no name special-cases.
"""
from __future__ import annotations

import numpy as np

from repro.core import ALGORITHM_REGISTRY, ALGORITHMS, make_hash

#: the three lookup planes every algorithm must agree on bit-for-bit
PLANES = ("host", "jnp", "pallas")

#: device planes (arguments to engine_lookup & friends)
DEVICE_PLANES = ("jnp", "pallas")


def make(algo: str, n0: int = 40, variant: str = "32",
         capacity_factor: int = 4):
    """A fresh instance via the registry factory (capacity = factor·n0
    for the fixed-capacity algorithms; ignored by the growable ones)."""
    return make_hash(algo, n0, capacity=capacity_factor * n0,
                     variant=variant)


def lifo_only(algo: str) -> bool:
    return ALGORITHM_REGISTRY[algo].lifo_only


def pick_victim(h, rng: np.random.Generator) -> int:
    """A legal removal victim: random working bucket, or the highest id
    for LIFO-only algorithms."""
    if lifo_only(h.name):
        return h.size - 1
    ws = sorted(h.working_set())
    return ws[int(rng.integers(len(ws)))]


def churn(h, removals: int, seed: int = 0) -> None:
    """``removals`` legal removals (never below one working bucket)."""
    rng = np.random.default_rng(seed)
    for _ in range(removals):
        if h.working <= 1:
            break
        h.remove(pick_victim(h, rng))


def churn_mixed(h, events: int, seed: int = 0,
                p_remove: float = 0.5) -> None:
    """``events`` random add/remove events (a shrinking-biased walk when
    ``p_remove`` > 0.5), always keeping at least one working bucket."""
    rng = np.random.default_rng(seed)
    for _ in range(events):
        if h.working > 1 and rng.random() < p_remove:
            h.remove(pick_victim(h, rng))
        else:
            h.add()


def state(algo: str, n0: int, removals: int, seed: int):
    """Churned ``variant="32"`` state — the standard fixture the plane-
    equivalence and engine-mode tests all build on."""
    h = make(algo, n0)
    churn(h, min(removals, n0 - 1) if lifo_only(algo) else removals,
          seed=seed)
    return h


__all__ = ["ALGORITHMS", "ALGORITHM_REGISTRY", "DEVICE_PLANES", "PLANES",
           "churn", "churn_mixed", "lifo_only", "make", "pick_victim",
           "state"]
