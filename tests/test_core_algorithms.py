"""Unit tests for the consistent-hashing control plane.

Hypothesis property tests (random op sequences) live in
``test_property_invariants.py`` so a missing ``hypothesis`` install skips
only those instead of aborting this module's collection.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import AnchorHash, DxHash, JumpHash, MementoHash
from repro.core.jump import jump32, jump64, np_jump32

RNG = np.random.default_rng(0)
KEYS = [int(k) for k in RNG.integers(0, 2**63, size=400)]


# ---------------------------------------------------------------------------
# JumpHash
# ---------------------------------------------------------------------------

def test_jump64_reference_values():
    # Spot-check the classic invariants of Lamping & Veach's function.
    for key in KEYS[:50]:
        assert jump64(key, 1) == 0
        b10 = jump64(key, 10)
        assert 0 <= b10 < 10
        # monotone growth: the bucket under n+1 either stays or becomes n.
        b11 = jump64(key, 11)
        assert b11 == b10 or b11 == 10


def test_jump_minimal_disruption_shrink():
    for fn in (jump64, jump32):
        for key in KEYS[:30]:
            b = fn(key, 100)
            # removing buckets from the tail never moves keys off live buckets
            for n in range(99, max(b, 1), -1):
                assert fn(key, n) == b or b >= n


def test_jump32_matches_vectorized():
    keys = np.asarray(KEYS[:100], dtype=np.uint64).astype(np.uint32)
    for n in (1, 2, 7, 100, 1234):
        vec = np_jump32(keys, n)
        for i in range(0, 100, 7):
            assert jump32(int(keys[i]), n) == int(vec[i])


def test_jump_balance():
    keys = RNG.integers(0, 2**63, size=20000)
    n = 16
    counts = np.bincount([jump64(int(k), n) for k in keys], minlength=n)
    expected = len(keys) / n
    assert np.all(np.abs(counts - expected) < 5 * np.sqrt(expected))


# ---------------------------------------------------------------------------
# MementoHash — paper examples and invariants
# ---------------------------------------------------------------------------

def test_memento_paper_example_section_vb():
    """Sec. V-B worked example: remove 9, 5, 1 from a 10-bucket cluster."""
    m = MementoHash(10)
    assert (m.n, m.l, m.R) == (10, 10, {})
    m.remove(9)
    assert (m.n, m.l, m.R) == (9, 9, {})
    m.remove(5)
    assert (m.n, m.l) == (9, 5) and m.R == {5: (8, 9)}
    m.remove(1)
    assert (m.n, m.l) == (9, 1) and m.R == {5: (8, 9), 1: (7, 5)}
    assert m.working == 7
    assert m.working_set() == {0, 2, 3, 4, 6, 7, 8}


def test_memento_paper_example_chained_removal():
    """Sec. V-C/V-D: removing a replacing bucket, then self-replacement."""
    m = MementoHash(10)
    for b in (9, 5, 1, 8):
        m.remove(b)
    # N4 = {0,2,3,4,6,7} per the paper
    assert m.working_set() == {0, 2, 3, 4, 6, 7}
    assert m.R[8] == (6, 1)
    m.remove(5) if 5 in m.working_set() else None
    # bucket 5 was already removed; removing e.g. nothing — instead verify
    # the self-replacement case from Fig. 12 on a fresh copy:
    m2 = MementoHash(10)
    for b in (9, 5, 1, 8):
        m2.remove(b)
    # next removal of bucket 6 (pos w-1=5 → replacement 5... exercise chains)
    m2.remove(6)
    assert m2.working_set() == {0, 2, 3, 4, 7}
    for key in KEYS[:200]:
        assert m2.lookup(key) in m2.working_set()


def test_memento_fig13_replacement_set():
    """Fig. 13: size 6, remove 0, 3, 5 → R = {0:(5,6), 3:(4,0), 5:(3,3)}."""
    m = MementoHash(6)
    m.remove(0)
    m.remove(3)
    m.remove(5)
    assert m.R == {0: (5, 6), 3: (4, 0), 5: (3, 3)}
    assert m.working_set() == {1, 2, 4}


def test_memento_add_restores_in_reverse_order():
    m = MementoHash(10)
    m.remove(9)
    m.remove(5)
    m.remove(1)
    assert m.add() == 1
    assert m.add() == 5
    assert m.R == {}
    assert m.add() == 9  # tail growth resumes at n
    assert m.n == 10
    assert m.add() == 10
    assert m.n == 11


def test_memento_lifo_equals_jump():
    m = MementoHash(64)
    j = JumpHash(64)
    for _ in range(30):
        m.remove(m.n - 1)
        j.remove(j.n - 1)
    for key in KEYS:
        assert m.lookup(key) == j.lookup(key)
    assert m.memory_bytes() == 8  # empty R: as cheap as Jump


@pytest.mark.parametrize("variant", ["64", "32"])
def test_memento_lookup_lands_on_working(variant):
    m = MementoHash(50, variant=variant)
    rng = np.random.default_rng(1)
    for _ in range(35):
        ws = sorted(m.working_set())
        m.remove(ws[int(rng.integers(len(ws)))])
    ws = m.working_set()
    for key in KEYS:
        assert m.lookup(key) in ws


def test_memento_minimal_disruption_random_removal():
    m = MementoHash(40)
    rng = np.random.default_rng(2)
    for _ in range(10):
        ws = sorted(m.working_set())
        m.remove(ws[int(rng.integers(len(ws)))])
    before = {k: m.lookup(k) for k in KEYS}
    victim = sorted(m.working_set())[7]
    m.remove(victim)
    after = {k: m.lookup(k) for k in KEYS}
    for k in KEYS:
        if before[k] != victim:
            assert after[k] == before[k], "non-victim key moved"
        else:
            assert after[k] != victim


def test_memento_monotonicity_on_add():
    m = MementoHash(40)
    rng = np.random.default_rng(3)
    for _ in range(12):
        ws = sorted(m.working_set())
        m.remove(ws[int(rng.integers(len(ws)))])
    before = {k: m.lookup(k) for k in KEYS}
    b_new = m.add()
    after = {k: m.lookup(k) for k in KEYS}
    for k in KEYS:
        assert after[k] == before[k] or after[k] == b_new, "key moved to an old bucket"


def test_memento_balance_after_removals():
    m = MementoHash(20)
    rng = np.random.default_rng(4)
    for _ in range(8):
        ws = sorted(m.working_set())
        m.remove(ws[int(rng.integers(len(ws)))])
    keys = RNG.integers(0, 2**63, size=30000)
    counts: dict[int, int] = {}
    for k in keys:
        b = m.lookup(int(k))
        counts[b] = counts.get(b, 0) + 1
    assert set(counts) <= m.working_set()
    expected = len(keys) / m.working
    for b in m.working_set():
        assert abs(counts.get(b, 0) - expected) < 6 * np.sqrt(expected), (
            f"bucket {b} unbalanced: {counts.get(b, 0)} vs {expected}"
        )


def test_memento_guards():
    m = MementoHash(3)
    with pytest.raises(ValueError):
        m.remove(5)
    m.remove(1)
    with pytest.raises(ValueError):
        m.remove(1)
    m.remove(2)
    with pytest.raises(ValueError):  # last working bucket
        m.remove(0)


# ---------------------------------------------------------------------------
# AnchorHash / DxHash baselines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [AnchorHash, DxHash])
def test_baseline_lands_on_working(cls):
    h = cls(capacity=100, initial_node_count=60)
    rng = np.random.default_rng(5)
    for _ in range(25):
        ws = sorted(h.working_set())
        h.remove(ws[int(rng.integers(len(ws)))])
    ws = h.working_set()
    assert len(ws) == 35
    for key in KEYS:
        assert h.lookup(key) in ws


@pytest.mark.parametrize("cls", [AnchorHash, DxHash])
def test_baseline_minimal_disruption(cls):
    h = cls(capacity=80, initial_node_count=50)
    rng = np.random.default_rng(6)
    for _ in range(10):
        ws = sorted(h.working_set())
        h.remove(ws[int(rng.integers(len(ws)))])
    before = {k: h.lookup(k) for k in KEYS}
    victim = sorted(h.working_set())[3]
    h.remove(victim)
    for k in KEYS:
        if before[k] != victim:
            assert h.lookup(k) == before[k]


@pytest.mark.parametrize("cls", [AnchorHash, DxHash])
def test_baseline_add_restores(cls):
    h = cls(capacity=64, initial_node_count=40)
    before = {k: h.lookup(k) for k in KEYS[:150]}
    removed = [30, 12, 25]
    for b in removed:
        h.remove(b)
    for _ in removed:
        h.add()
    assert h.working_set() == set(range(40))
    for k in KEYS[:150]:
        assert h.lookup(k) == before[k], "state not restored after add-backs"


def test_anchor_balance():
    h = AnchorHash(capacity=100, initial_node_count=10)
    keys = RNG.integers(0, 2**63, size=20000)
    counts = np.zeros(10)
    for k in keys:
        counts[h.lookup(int(k))] += 1
    expected = len(keys) / 10
    assert np.all(np.abs(counts - expected) < 6 * np.sqrt(expected))


def test_memory_ranking_matches_paper():
    """Paper Figs. 18/20: mem(jump) ≤ mem(memento) ≪ mem(dx) < mem(anchor)."""
    n = 10000
    j = JumpHash(n)
    m = MementoHash(n)
    a = AnchorHash(capacity=10 * n, initial_node_count=n)
    d = DxHash(capacity=10 * n, initial_node_count=n)
    rng = np.random.default_rng(7)
    for _ in range(n // 10):
        ws = sorted(m.working_set())
        b = ws[int(rng.integers(len(ws)))]
        m.remove(b)
        a.remove(b)
        d.remove(b)
    assert j.memory_bytes() <= m.memory_bytes()
    assert m.memory_bytes() < d.memory_bytes() < a.memory_bytes()
