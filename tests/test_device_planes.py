"""Device-plane edge cases that are NOT algorithm-generic.

The algorithm × plane bit-identity matrix (host ⇄ jnp ⇄ Pallas, every
registry entry) lives in ``tests/test_conformance.py``; this module keeps
the kernel-specific paths: block-shape independence for the fixed-capacity
kernels, Dx's probe-bound fallback, and plane-name validation.
"""
from __future__ import annotations

import numpy as np
import pytest

from conformance import state
from repro.kernels import ops, ref


@pytest.mark.parametrize("algo", ["anchor", "dx"])
def test_kernel_block_rows_sweep(algo):
    """Block-shape independence for the new kernels (Memento: test_kernels)."""
    h = state(algo, 256, 140, seed=9)
    image = h.device_image()
    keys = np.random.default_rng(8).integers(0, 2**32, size=1500, dtype=np.uint32)
    want = ref.lookup_host(keys, h)
    for block_rows in (1, 4, 16):
        got = np.asarray(ops.device_lookup(keys, image, block_rows=block_rows))
        np.testing.assert_array_equal(got, want)


def test_dx_fallback_path():
    """A probe-bound overrun must settle on the host's first-working bucket."""
    h = state("dx", 16, 0, seed=0)
    image = h.device_image()
    image.scalars = dict(image.scalars, max_probes=1)  # force overruns
    keys = np.random.default_rng(10).integers(0, 2**32, size=400, dtype=np.uint32)
    out = np.asarray(ops.device_lookup(keys, image))
    assert set(out.tolist()) <= h.working_set()
    assert (out == image.scalars["fallback"]).any()


def test_device_lookup_rejects_unknown_plane():
    h = state("memento", 16, 0, seed=0)
    with pytest.raises(ValueError):
        ops.device_lookup(np.zeros(4, np.uint32), h.device_image(), plane="cuda")
