"""Cross-plane equivalence, per algorithm: host ⇄ jnp oracle ⇄ Pallas kernel.

For every algorithm the three planes must be BIT-identical on random
``variant="32"`` states with random removals (LIFO for Jump):

  * host   — per-key python lookup (the paper-methodology control plane),
  * jnp    — ``core/jax_lookup`` lane-synchronous batched lookup,
  * Pallas — the VMEM kernels, interpret mode on CPU (Mosaic on TPU).

Memento's dense/compact sweeps stay in ``test_kernels.py``; this module is
the algorithm-generic matrix the unified data plane (ISSUE 1) promises.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import make_hash
from repro.kernels import ops, ref


def _state(algo, n0, removals, seed):
    h = make_hash(algo, n0, capacity=4 * n0, variant="32")
    rng = np.random.default_rng(seed)
    for _ in range(removals):
        if algo == "jump":
            h.remove(h.size - 1)
        else:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])
    return h


CASES = [(16, 0), (16, 6), (200, 130), (1024, 512)]


@pytest.mark.parametrize("algo", ["memento", "anchor", "dx", "jump"])
@pytest.mark.parametrize("n0,removals", CASES)
def test_three_planes_bit_identical(algo, n0, removals):
    import jax.numpy as jnp

    if algo == "jump":
        removals = min(removals, n0 - 1)  # LIFO shrink keeps n ≥ 1
    h = _state(algo, n0, removals, seed=n0 + removals)
    image = h.device_image()
    keys = np.random.default_rng(7).integers(0, 2**32, size=777, dtype=np.uint32)

    host = ref.lookup_host(keys, h)
    jnp_out = np.asarray(ref.lookup_image_ref(jnp.asarray(keys), image))
    pallas = np.asarray(ops.device_lookup(keys, image, plane="pallas"))

    np.testing.assert_array_equal(jnp_out, host)
    np.testing.assert_array_equal(pallas, host)
    assert set(pallas.tolist()) <= h.working_set()


@pytest.mark.parametrize("algo", ["anchor", "dx"])
def test_kernel_block_rows_sweep(algo):
    """Block-shape independence for the new kernels (Memento: test_kernels)."""
    h = _state(algo, 256, 140, seed=9)
    image = h.device_image()
    keys = np.random.default_rng(8).integers(0, 2**32, size=1500, dtype=np.uint32)
    want = ref.lookup_host(keys, h)
    for block_rows in (1, 4, 16):
        got = np.asarray(ops.device_lookup(keys, image, block_rows=block_rows))
        np.testing.assert_array_equal(got, want)


def test_dx_fallback_path():
    """A probe-bound overrun must settle on the host's first-working bucket."""
    h = _state("dx", 16, 0, seed=0)
    image = h.device_image()
    image.scalars = dict(image.scalars, max_probes=1)  # force overruns
    keys = np.random.default_rng(10).integers(0, 2**32, size=400, dtype=np.uint32)
    out = np.asarray(ops.device_lookup(keys, image))
    assert set(out.tolist()) <= h.working_set()
    assert (out == image.scalars["fallback"]).any()


def test_device_lookup_rejects_unknown_plane():
    h = _state("memento", 16, 0, seed=0)
    with pytest.raises(ValueError):
        ops.device_lookup(np.zeros(4, np.uint32), h.device_image(), plane="cuda")
