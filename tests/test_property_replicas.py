"""Hypothesis property tests: replica sets under random churn (DESIGN.md §4.1).

Gated on ``hypothesis`` like the other property files.  Two properties:

* **cross-plane**: across random churn trajectories, host ``lookup_k``
  and the jitted jnp replica walk stay bit-identical (the Pallas plane is
  pinned to the jnp plane in test_replicas.py; interpret-mode runs are too
  slow to fuzz here);

* **replica stability** (the §4.1 disruption bound, exactly): removing
  bucket b changes a key's replica set ONLY if b appeared among the key's
  salted-walk candidates (including dedup-rejected ones) — keys whose
  trace avoided b keep their set verbatim, and every new set is distinct,
  working, and primary-consistent.
"""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conformance import ALGORITHMS as ALGOS, lifo_only, pick_victim  # noqa: E402
from repro.core import make_hash, replica_sets  # noqa: E402

KEYS = np.random.default_rng(11).integers(0, 2**32, size=128, dtype=np.uint32)


def _churn(h, rng, events):
    for _ in range(events):
        if h.working > 2 and (rng.random() < 0.6
                              or getattr(h, "R", None) in ([], None)):
            h.remove(pick_victim(h, rng))
        else:
            try:
                h.add()
            except ValueError:
                pass


@settings(max_examples=8, deadline=None)
@given(algo=st.sampled_from(ALGOS),
       n0=st.integers(min_value=8, max_value=96),
       events=st.integers(min_value=0, max_value=40),
       seed=st.integers(min_value=0, max_value=2**31))
def test_host_jnp_replica_sets_bit_identical_under_churn(algo, n0, events,
                                                         seed):
    from repro.kernels.engine import replica_lookup

    h = make_hash(algo, n0, capacity=4 * n0, variant="32")
    _churn(h, np.random.default_rng(seed), events)
    k = min(3, h.working)
    want = replica_sets(h, KEYS, k)
    got = np.asarray(replica_lookup(KEYS, h.device_image(), k, plane="jnp"))
    np.testing.assert_array_equal(got, want)
    assert all(len(set(r)) == k for r in got.tolist())


@settings(max_examples=10, deadline=None)
@given(algo=st.sampled_from([a for a in ALGOS if not lifo_only(a)]),
       n0=st.integers(min_value=16, max_value=96),
       events=st.integers(min_value=0, max_value=30),
       seed=st.integers(min_value=0, max_value=2**31))
def test_replica_stability_under_removal(algo, n0, events, seed):
    h = make_hash(algo, n0, capacity=4 * n0, variant="32")
    rng = np.random.default_rng(seed)
    _churn(h, rng, events)
    k = min(3, h.working - 1)
    if k < 1:
        return
    before = {}
    for key in KEYS[:64]:
        before[int(key)] = h.lookup_k_trace(int(key), k)

    ws = sorted(h.working_set())
    victim = ws[int(rng.integers(len(ws)))]
    h.remove(victim)

    for key in KEYS[:64]:
        old_set, old_cands = before[int(key)]
        new_set = h.lookup_k(int(key), k)
        assert len(set(new_set)) == k
        assert set(new_set) <= h.working_set()
        assert new_set[0] == h.lookup(int(key))
        if victim not in old_cands:
            # the §4.1 disruption bound: an untouched walk is unchanged
            assert new_set == old_set
