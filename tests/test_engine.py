"""The unified lookup engine (DESIGN.md §6): every (algorithm × op-mode ×
plane) cell bit-identical to the pre-engine kernels and the numpy/host
oracles on random churned states, plus the mesh-sharded serving plane.

Op modes covered: plain lookup, k-replica lookup, fused bounded-replica
lookup (k replicas under a load cap, one launch), bounded chain-walk
assignment, one-epoch→epoch diff, and the fused replica-set diff.  The
sharded plane is checked on whatever mesh the process has (1 CPU device
here) and, in ``test_property_engine.py``, on forced multi-device
subprocesses for arbitrary mesh shapes.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import DeviceImageStore, make_hash
from repro.core.protocol import replica_sets
from repro.kernels import engine, ref

ALGOS = ["memento", "anchor", "dx", "jump"]
PLANES = ["jnp", "pallas"]


def _state(algo, n0, removals, seed):
    h = make_hash(algo, n0, capacity=4 * n0, variant="32")
    rng = np.random.default_rng(seed)
    removals = min(removals, n0 - 1) if algo == "jump" else removals
    for _ in range(removals):
        if algo == "jump":
            h.remove(h.size - 1)
        else:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])
    return h


def _churn(h, events, seed):
    rng = np.random.default_rng(seed)
    for _ in range(events):
        if h.name != "jump" and h.working > 2 and rng.random() < 0.7:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])
        elif h.name == "jump" and h.size > 2 and rng.random() < 0.7:
            h.remove(h.size - 1)
        else:
            h.add()


_load_len = engine.bounded_load_len  # the one sizing rule for load words


KEYS = np.random.default_rng(77).integers(0, 2**32, size=700, dtype=np.uint32)


# ---------------------------------------------------------------------------
# Lookup modes vs host oracles, all planes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("plane", PLANES)
def test_lookup_matches_host(algo, plane):
    h = _state(algo, 96, 40, seed=1)
    out = np.asarray(engine.engine_lookup(KEYS, h.device_image(), plane=plane))
    np.testing.assert_array_equal(out, ref.lookup_host(KEYS, h))


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("plane", PLANES)
@pytest.mark.parametrize("k", [2, 3])
def test_lookup_k_matches_host(algo, plane, k):
    h = _state(algo, 64, 20, seed=2)
    out = np.asarray(engine.engine_lookup(KEYS[:128], h.device_image(), k=k,
                                          plane=plane))
    np.testing.assert_array_equal(out, replica_sets(h, KEYS[:128], k))
    assert all(len(set(row)) == k for row in out.tolist())


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("plane", PLANES)
def test_bounded_replica_lookup_fused(algo, plane):
    """The fused k-replica-under-cap op: one launch, every slot below the
    cap, bit-identical to the host salted walk with the load reject rule."""
    h = _state(algo, 64, 16, seed=3)
    image = h.device_image()
    load = np.zeros(_load_len(image), np.int32)
    cap = 7
    ws = sorted(h.working_set())
    load[ws[: len(ws) // 3]] = cap  # a third of the fleet is full
    want = engine.bounded_replica_sets(h, KEYS[:96], 2, load, cap)
    got = np.asarray(engine.engine_lookup(KEYS[:96], image, k=2, load=load,
                                          cap=cap, plane=plane))
    np.testing.assert_array_equal(got, want)
    assert (load[got] < cap).all()
    # bounded slot 0 may legitimately differ from the unbounded primary
    plain = np.asarray(engine.engine_lookup(KEYS[:96], image, plane=plane))
    moved = got[:, 0] != plain
    assert (load[plain[moved]] >= cap).all()
    # an infeasible cap (< k buckets under cap) must raise, like the host
    # oracle — never silently return over-cap buckets
    full_load = np.full_like(load, cap)
    with pytest.raises(RuntimeError, match="salt budget"):
        engine.engine_lookup(KEYS[:16], image, k=2, load=full_load, cap=cap,
                             plane=plane)


@pytest.mark.parametrize("plane", PLANES)
def test_bounded_replica_duplicate_rows_raise(plane):
    """Fewer than k DISTINCT below-cap buckets (primary itself below cap)
    must raise too — not return duplicate replica sets."""
    h = make_hash("memento", 2, variant="32")
    image = h.device_image()
    load = np.zeros(_load_len(image), np.int32)
    load[1] = 5  # bucket 1 full: only bucket 0 remains below cap
    with pytest.raises(RuntimeError, match="salt budget"):
        engine.engine_lookup(KEYS[:32], image, k=2, load=load, cap=5,
                             plane=plane)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("plane", PLANES)
def test_epoch_diff_and_replica_set_diff(algo, plane):
    h = _state(algo, 96, 30, seed=4)
    store = DeviceImageStore(h)
    _churn(h, 5, seed=5)
    store.sync()
    old, new = store.previous_image(), store.image()
    d = engine.engine_diff(KEYS, old, new, plane=plane)
    np.testing.assert_array_equal(
        d.old, np.asarray(engine.engine_lookup(KEYS, old, plane="jnp")))
    np.testing.assert_array_equal(
        d.new, np.asarray(engine.engine_lookup(KEYS, new, plane="jnp")))
    np.testing.assert_array_equal(d.moved, d.old != d.new)
    # fused replica-set diff == per-epoch replica lookups
    dk = engine.engine_diff(KEYS[:200], old, new, k=2, plane=plane)
    np.testing.assert_array_equal(
        dk.old, np.asarray(engine.engine_lookup(KEYS[:200], old, k=2,
                                                plane="jnp")))
    np.testing.assert_array_equal(
        dk.new, np.asarray(engine.engine_lookup(KEYS[:200], new, k=2,
                                                plane="jnp")))
    np.testing.assert_array_equal(dk.moved, (dk.old != dk.new).any(axis=1))


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("plane", PLANES)
def test_bounded_assign_matches_reference(algo, plane):
    from repro.core.bounded import bounded_assign_ref

    h = _state(algo, 48, 12, seed=6)
    image = h.device_image()
    keys = KEYS[:300]
    cap = max(1, int(np.ceil(1.25 * len(keys) / h.working)))
    load0 = np.zeros(_load_len(image), np.int32)
    want, want_load = bounded_assign_ref(h, keys, load0, cap)
    got, got_load = engine.bounded_assign(keys, image, load0, cap,
                                          plane=plane)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got_load, want_load)
    assert got_load.max() <= cap


def test_memento_compact_all_modes():
    h = _state("memento", 200, 130, seed=7)
    image = h.device_image()
    host = ref.lookup_host(KEYS, h)
    out = np.asarray(engine.engine_lookup(KEYS, image, plane="pallas",
                                          table="compact"))
    np.testing.assert_array_equal(out, host)


def test_engine_op_validation():
    with pytest.raises(ValueError):
        engine.EngineOp("cuckoo")
    with pytest.raises(ValueError):
        engine.EngineOp("memento", k=0)
    with pytest.raises(ValueError):
        engine.EngineOp("anchor", table="compact")
    with pytest.raises(ValueError):
        engine.EngineOp("memento", mode="walk", k=2)
    h = _state("memento", 16, 0, seed=0)
    with pytest.raises(ValueError):
        engine.engine_lookup(KEYS[:4], h.device_image(), plane="cuda")
    with pytest.raises(ValueError):
        engine.engine_lookup(KEYS[:4], h.device_image(), load=np.zeros(16))


def test_shim_modules_are_gone():
    """The PR-4 re-export shims were retired after their one release: the
    engine is the only import surface for device lookups."""
    for mod in ("memento_lookup", "anchor_lookup", "dx_lookup",
                "jump_lookup", "replica_lookup", "migrate"):
        with pytest.raises(ImportError):
            __import__(f"repro.kernels.{mod}")


def test_cross_algo_diff_jnp():
    """Algorithm migrations diff across table layouts on the jnp plane."""
    hm = _state("memento", 64, 10, seed=10)
    ha = _state("anchor", 64, 10, seed=10)
    d = engine.engine_diff(KEYS[:128], hm.device_image(), ha.device_image(),
                           plane="jnp")
    np.testing.assert_array_equal(d.old, ref.lookup_host(KEYS[:128], hm))
    np.testing.assert_array_equal(d.new, ref.lookup_host(KEYS[:128], ha))
    with pytest.raises(ValueError):
        engine.engine_diff(KEYS[:8], hm.device_image(), ha.device_image(),
                           plane="pallas")


# ---------------------------------------------------------------------------
# Sharded serving plane (this process' devices; multi-device: property test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_sharded_plane_matches_single_device(algo):
    from repro.serve.plane import ShardedLookupPlane

    h = _state(algo, 96, 30, seed=11)
    store = DeviceImageStore(h)
    plane = ShardedLookupPlane(store)
    keys = np.random.default_rng(12).integers(0, 2**32, size=4321,
                                              dtype=np.uint32)
    np.testing.assert_array_equal(plane.lookup(keys),
                                  store.lookup(keys, plane="jnp"))
    p2 = ShardedLookupPlane(store, k=2)
    np.testing.assert_array_equal(p2.lookup(keys[:512]),
                                  store.lookup(keys[:512], k=2, plane="jnp"))


def test_sharded_plane_stream_tracks_epochs():
    from repro.serve.plane import ShardedLookupPlane

    h = _state("memento", 64, 10, seed=13)
    store = DeviceImageStore(h)
    plane = ShardedLookupPlane(store)
    keys = np.random.default_rng(14).integers(0, 2**32, size=1000,
                                              dtype=np.uint32)

    def batches():
        yield keys
        h.remove(sorted(h.working_set())[0])
        store.sync()  # flips between batches; plane must re-pin
        yield keys

    out0, out1 = list(plane.route_stream(batches()))
    np.testing.assert_array_equal(out1, ref.lookup_host(keys, h))
    assert (out0 != out1).any()


def test_router_route_stream_matches_route_batch():
    from repro.serve.router import SessionRouter

    r = SessionRouter(12)
    ids = [np.arange(i * 64, (i + 1) * 64, dtype=np.uint64) for i in range(3)]
    streamed = list(r.route_stream(iter(ids)))
    for batch, out in zip(ids, streamed):
        np.testing.assert_array_equal(out, r.route_batch(batch))


def test_router_route_stream_honours_mark_failed():
    """Streamed traffic must fail over around a health-marked replica with
    the same rule as route_batch — BEFORE the membership delta lands."""
    from repro.serve.router import SessionRouter

    r = SessionRouter(8, replicas_k=2)
    ids = np.arange(0, 256, dtype=np.uint64)
    primary = r.route_batch(ids)
    victim = int(np.bincount(primary).argmax())
    r.mark_failed(victim)
    want = r.route_batch(ids)
    assert victim not in set(want.tolist())
    (streamed,) = list(r.route_stream([ids]))
    np.testing.assert_array_equal(streamed, want)
    assert r.stats.failovers > 0
    r._failed.clear()
    (clean,) = list(r.route_stream([ids]))
    np.testing.assert_array_equal(clean, primary)


def test_router_route_stream_survives_fleet_collapse():
    """replicas_k > 1 with the fleet collapsed to one survivor: the
    k-clamped (1-D) replica sets must stream without error, matching
    route_batch."""
    from repro.serve.router import SessionRouter

    r = SessionRouter(3, replicas_k=2)
    ids = np.arange(0, 64, dtype=np.uint64)
    r.fail_replica(2)
    r.fail_replica(1)
    r.mark_failed(0)  # every candidate marked → keep the primary
    want = r.route_batch(ids)
    (streamed,) = list(r.route_stream([ids]))
    np.testing.assert_array_equal(streamed, want)


def test_elastic_replica_movement_plan():
    from repro.runtime.elastic import ElasticCluster

    c = ElasticCluster(16, num_shards=64, replica_k=2)
    before = {s: c.replica_hosts(s) for s in range(64)}
    c.fail(5)
    mv = c.replica_movement()
    after = {s: c.replica_hosts(s) for s in range(64)}
    # default identity domains: device plan == host lookup_k churn
    want = {s for s in range(64) if before[s] != after[s]}
    assert set(mv) == want
    for s in mv:
        assert mv[s]["old"] == before[s] and mv[s]["new"] == after[s]
