"""Engine specifics beyond the conformance grid (DESIGN.md §6).

The (algorithm × op-mode × plane) bit-identity matrix — plain lookup,
k-replica, fused bounded-replica, bounded assignment, epoch diff — lives
in ``tests/test_conformance.py`` now, derived from the registry.  This
module keeps what is NOT a per-algorithm conformance cell: the engine's
error surfaces, Memento's compact table mode, cross-algorithm diffs, and
the mesh-sharded serving plane on top of the engine.
"""
from __future__ import annotations

import numpy as np
import pytest

from conformance import ALGORITHMS, state
from repro.core import DeviceImageStore, make_hash
from repro.kernels import engine, ref

PLANES = ["jnp", "pallas"]

_load_len = engine.bounded_load_len  # the one sizing rule for load words


KEYS = np.random.default_rng(77).integers(0, 2**32, size=700, dtype=np.uint32)


# ---------------------------------------------------------------------------
# Error surfaces and engine-only modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plane", PLANES)
def test_bounded_replica_infeasible_cap_raises(plane):
    """Fewer than k DISTINCT below-cap buckets must raise, like the host
    oracle — never silently return duplicate or over-cap replica sets."""
    h = make_hash("memento", 2, variant="32")
    image = h.device_image()
    load = np.zeros(_load_len(image), np.int32)
    load[1] = 5  # bucket 1 full: only bucket 0 remains below cap
    with pytest.raises(RuntimeError, match="salt budget"):
        engine.engine_lookup(KEYS[:32], image, k=2, load=load, cap=5,
                             plane=plane)
    # the fully-saturated fleet (zero below-cap buckets) raises too
    full = np.full_like(load, 5)
    with pytest.raises(RuntimeError, match="salt budget"):
        engine.engine_lookup(KEYS[:16], image, k=2, load=full, cap=5,
                             plane=plane)


def test_memento_compact_all_modes():
    h = state("memento", 200, 130, seed=7)
    image = h.device_image()
    host = ref.lookup_host(KEYS, h)
    out = np.asarray(engine.engine_lookup(KEYS, image, plane="pallas",
                                          table="compact"))
    np.testing.assert_array_equal(out, host)


def test_engine_op_validation():
    with pytest.raises(ValueError):
        engine.EngineOp("cuckoo")
    with pytest.raises(ValueError):
        engine.EngineOp("memento", k=0)
    with pytest.raises(ValueError):
        engine.EngineOp("anchor", table="compact")
    with pytest.raises(ValueError):
        engine.EngineOp("memento", mode="walk", k=2)
    h = state("memento", 16, 0, seed=0)
    with pytest.raises(ValueError):
        engine.engine_lookup(KEYS[:4], h.device_image(), plane="cuda")
    with pytest.raises(ValueError):
        engine.engine_lookup(KEYS[:4], h.device_image(), load=np.zeros(16))


def test_shim_modules_are_gone():
    """The PR-4 re-export shims were retired after their one release: the
    engine is the only import surface for device lookups."""
    for mod in ("memento_lookup", "anchor_lookup", "dx_lookup",
                "jump_lookup", "replica_lookup", "migrate"):
        with pytest.raises(ImportError):
            __import__(f"repro.kernels.{mod}")


def test_cross_algo_diff_jnp():
    """Algorithm migrations diff across table layouts on the jnp plane."""
    hm = state("memento", 64, 10, seed=10)
    ha = state("anchor", 64, 10, seed=10)
    d = engine.engine_diff(KEYS[:128], hm.device_image(), ha.device_image(),
                           plane="jnp")
    np.testing.assert_array_equal(d.old, ref.lookup_host(KEYS[:128], hm))
    np.testing.assert_array_equal(d.new, ref.lookup_host(KEYS[:128], ha))
    with pytest.raises(ValueError):
        engine.engine_diff(KEYS[:8], hm.device_image(), ha.device_image(),
                           plane="pallas")


# ---------------------------------------------------------------------------
# Sharded serving plane (this process' devices; multi-device: property test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGORITHMS)
def test_sharded_plane_matches_single_device(algo):
    from repro.serve.plane import ShardedLookupPlane

    h = state(algo, 96, 30, seed=11)
    store = DeviceImageStore(h)
    plane = ShardedLookupPlane(store)
    keys = np.random.default_rng(12).integers(0, 2**32, size=4321,
                                              dtype=np.uint32)
    np.testing.assert_array_equal(plane.lookup(keys),
                                  store.lookup(keys, plane="jnp"))
    p2 = ShardedLookupPlane(store, k=2)
    np.testing.assert_array_equal(p2.lookup(keys[:512]),
                                  store.lookup(keys[:512], k=2, plane="jnp"))


def test_sharded_plane_stream_tracks_epochs():
    from repro.serve.plane import ShardedLookupPlane

    h = state("memento", 64, 10, seed=13)
    store = DeviceImageStore(h)
    plane = ShardedLookupPlane(store)
    keys = np.random.default_rng(14).integers(0, 2**32, size=1000,
                                              dtype=np.uint32)

    def batches():
        yield keys
        h.remove(sorted(h.working_set())[0])
        store.sync()  # flips between batches; plane must re-pin
        yield keys

    out0, out1 = list(plane.route_stream(batches()))
    np.testing.assert_array_equal(out1, ref.lookup_host(keys, h))
    assert (out0 != out1).any()


def test_router_route_stream_matches_route_batch():
    from repro.serve.router import SessionRouter

    r = SessionRouter(12)
    ids = [np.arange(i * 64, (i + 1) * 64, dtype=np.uint64) for i in range(3)]
    streamed = list(r.route_stream(iter(ids)))
    for batch, out in zip(ids, streamed):
        np.testing.assert_array_equal(out, r.route_batch(batch))


def test_router_route_stream_honours_mark_failed():
    """Streamed traffic must fail over around a health-marked replica with
    the same rule as route_batch — BEFORE the membership delta lands."""
    from repro.serve.router import SessionRouter

    r = SessionRouter(8, replicas_k=2)
    ids = np.arange(0, 256, dtype=np.uint64)
    primary = r.route_batch(ids)
    victim = int(np.bincount(primary).argmax())
    r.mark_failed(victim)
    want = r.route_batch(ids)
    assert victim not in set(want.tolist())
    (streamed,) = list(r.route_stream([ids]))
    np.testing.assert_array_equal(streamed, want)
    assert r.stats.failovers > 0
    r._failed.clear()
    (clean,) = list(r.route_stream([ids]))
    np.testing.assert_array_equal(clean, primary)


def test_router_route_stream_survives_fleet_collapse():
    """replicas_k > 1 with the fleet collapsed to one survivor: the
    k-clamped (1-D) replica sets must stream without error, matching
    route_batch."""
    from repro.serve.router import SessionRouter

    r = SessionRouter(3, replicas_k=2)
    ids = np.arange(0, 64, dtype=np.uint64)
    r.fail_replica(2)
    r.fail_replica(1)
    r.mark_failed(0)  # every candidate marked → keep the primary
    want = r.route_batch(ids)
    (streamed,) = list(r.route_stream([ids]))
    np.testing.assert_array_equal(streamed, want)


def test_elastic_replica_movement_plan():
    from repro.runtime.elastic import ElasticCluster

    c = ElasticCluster(16, num_shards=64, replica_k=2)
    before = {s: c.replica_hosts(s) for s in range(64)}
    c.fail(5)
    mv = c.replica_movement()
    after = {s: c.replica_hosts(s) for s in range(64)}
    # default identity domains: device plan == host lookup_k churn
    want = {s for s in range(64) if before[s] != after[s]}
    assert set(mv) == want
    for s in mv:
        assert mv[s]["old"] == before[s] and mv[s]["new"] == after[s]
