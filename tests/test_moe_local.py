"""Local (shard_map) MoE dispatch must match the global pjit dispatch."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import mlp
from repro.models.common import init_tree
from repro.sharding.rules import default_rules


def _mesh1():
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh((1, 1), ("data", "model"))


def test_local_dispatch_matches_global():
    cfg = dataclasses.replace(smoke_config("olmoe-1b-7b"), dtype="float32",
                              moe_capacity_factor=4.0)  # ample: no drops
    p = init_tree(mlp.moe_desc(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    rules = default_rules(_mesh1())

    y_g, aux_g = mlp.moe_apply(cfg, p, x, impl="global")
    y_l, aux_l = mlp.moe_apply(cfg, p, x, rules=rules, impl="local")
    np.testing.assert_allclose(np.asarray(y_l), np.asarray(y_g), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_l["load_balance"]),
                               float(aux_g["load_balance"]), rtol=1e-5)
    np.testing.assert_allclose(float(aux_l["router_z"]),
                               float(aux_g["router_z"]), rtol=1e-5)


def test_local_dispatch_grads_match():
    cfg = dataclasses.replace(smoke_config("phi3.5-moe-42b-a6.6b"),
                              dtype="float32", moe_capacity_factor=4.0)
    p = init_tree(mlp.moe_desc(cfg), jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8, cfg.d_model)),
                    jnp.float32)
    rules = default_rules(_mesh1())

    def loss(p, impl, r):
        y, aux = mlp.moe_apply(cfg, p, x, rules=r, impl=impl)
        return jnp.sum(y * y) + aux["load_balance"]

    g_g = jax.grad(loss)(p, "global", None)
    g_l = jax.grad(loss)(p, "local", rules)
    for a, b in zip(jax.tree.leaves(g_g), jax.tree.leaves(g_l)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4, atol=1e-5)
