"""Survey-extra algorithms + the bounded-load overlay (paper §X)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.bounded import BoundedLoadMemento
from repro.core.extras import MaglevHash, MultiProbeHash, RendezvousHash, RingHash

KEYS = [int(k) for k in np.random.default_rng(0).integers(0, 2**63, size=300)]
ALGOS = [
    lambda: RingHash(12, vnodes=64),
    lambda: RendezvousHash(12),
    lambda: MaglevHash(12, table_size=4099),
    lambda: MultiProbeHash(12, probes=21),
]


@pytest.mark.parametrize("mk", ALGOS)
def test_lands_on_working_and_minimal_disruption(mk):
    h = mk()
    before = {k: h.lookup(k) for k in KEYS}
    assert set(before.values()) <= h.working_set()
    victim = sorted(h.working_set())[3]
    h.remove(victim)
    after = {k: h.lookup(k) for k in KEYS}
    bad = sum(1 for k in KEYS if before[k] != victim and after[k] != before[k])
    if isinstance(h, MaglevHash):
        assert bad <= 0.05 * len(KEYS)  # Maglev: small (not zero) disruption
    else:
        assert bad == 0
    assert all(v != victim for v in after.values())


@pytest.mark.parametrize("mk", ALGOS)
def test_balance(mk):
    h = mk()
    keys = np.random.default_rng(1).integers(0, 2**63, size=20000)
    counts: dict[int, int] = {}
    for k in keys:
        b = h.lookup(int(k))
        counts[b] = counts.get(b, 0) + 1
    expected = len(keys) / h.working
    arr = np.asarray([counts.get(b, 0) for b in h.working_set()])
    # ring with few vnodes & multiprobe are coarser: generous bound
    assert arr.max() < 2.5 * expected, arr
    assert arr.min() > 0.2 * expected, arr


def test_bounded_load_overlay():
    bl = BoundedLoadMemento(10, c=1.25)
    keys = [int(k) for k in np.random.default_rng(2).integers(0, 2**63, size=2000)]
    for k in keys:
        bl.assign(k)
    assert bl.peak_to_mean() <= 1.3
    # removing a bucket moves only its keys (plus bounded-capacity spill)
    before = dict(bl.assignment)
    victim = sorted(bl.m.working_set())[0]
    victims = {k for k, b in before.items() if b == victim}
    moves = bl.remove(victim)
    assert set(moves) == victims
    assert bl.peak_to_mean() <= 1.35
    for k, b in bl.assignment.items():
        if k not in victims:
            assert b == before[k]
