"""Integration: the multi-pod dry-run lowers+compiles a real cell end-to-end.

Runs in a subprocess because dryrun.py must own XLA_FLAGS (512 placeholder
devices) before jax initializes — the test process keeps its single device.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def test_dryrun_cell_compiles(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "mamba2-780m", "--shape", "long_500k", "--mesh", "multi",
           "--variant", "citest", "--out-dir", str(tmp_path)]
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"}
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=str(REPO), timeout=540)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.loads((tmp_path / "mamba2-780m__long_500k__multi__citest.json").read_text())
    assert rec["chips"] == 512
    assert rec["memory_analysis"]["peak_memory_in_bytes"] < 16 * 2**30
    rl = rec["roofline"]
    assert rl["t_compute"] > 0 and rl["t_memory"] > 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")
