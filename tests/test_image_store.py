"""Epoch-versioned device control plane: deltas, double-buffered images,
migration diffs (DESIGN.md §3.5).

Deterministic tier-1 coverage; the heavier randomized sweeps (≥1000 events
per algorithm, hypothesis-driven) live in ``test_property_deltas.py``.
"""
from __future__ import annotations

import numpy as np
import pytest

from conformance import (ALGORITHM_REGISTRY, ALGORITHMS as ALGOS, lifo_only,
                         pick_victim)
from repro.core import DeviceImageStore, apply_delta, make_hash

KEYS = np.random.default_rng(3).integers(0, 2**32, size=400, dtype=np.uint32)


def _mk(algo, n0=64):
    return make_hash(algo, n0, capacity=4 * n0, variant="32")


def _churn_once(h, rng):
    """One random remove-or-add; returns the op performed."""
    if h.working > 1 and (rng.random() < 0.6
                          or (ALGORITHM_REGISTRY[h.name].fixed_capacity
                              and not h.R)):
        h.remove(pick_victim(h, rng))
        return "remove"
    try:
        h.add()
        return "add"
    except ValueError:  # fixed-capacity algo at full fleet
        h.remove(pick_victim(h, rng))
        return "remove"


def _assert_matches_fresh(store, h):
    """Store front image must be bit-identical to a fresh snapshot."""
    fresh = h.device_image()
    img = store.image()
    assert img.n == fresh.n
    assert img.epoch == fresh.epoch == h.epoch
    assert img.scalars == fresh.scalars
    for name, arr in fresh.arrays.items():
        got = np.asarray(img.arrays[name])
        np.testing.assert_array_equal(got[: arr.shape[0]], arr)


# ---------------------------------------------------------------------------
# delta emission (host side)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_deltas_are_o_changed_words(algo):
    """A single event's delta must scatter O(1) words, not O(n)."""
    h = _mk(algo, n0=96)
    e0 = h.epoch
    if lifo_only(algo):
        h.remove(h.size - 1)
    else:
        h.remove(sorted(h.working_set())[10])
    d = h.device_delta(e0)
    assert d is not None and d.events == 1
    assert d.num_words() <= 4  # ≤ 2 scatter pairs per event (Anchor's A+K)


@pytest.mark.parametrize("algo", ALGOS)
def test_host_apply_delta_equals_fresh_snapshot(algo):
    rng = np.random.default_rng(7)
    h = _mk(algo)
    img = h.device_image(capacity=4 * h.size)
    for i in range(150):
        _churn_once(h, rng)
        if i % 13 == 0:
            img = apply_delta(img, h.device_delta(img.epoch))
    img = apply_delta(img, h.device_delta(img.epoch))
    fresh = h.device_image()
    assert img.n == fresh.n and img.epoch == fresh.epoch
    assert img.scalars == fresh.scalars
    for name, arr in fresh.arrays.items():
        np.testing.assert_array_equal(np.asarray(img.arrays[name])[: arr.shape[0]], arr)


def test_delta_log_window_returns_none():
    h = _mk("memento")
    h._DELTA_LOG_CAP = 8
    h._delta_log = h._delta_log[:0]
    for _ in range(20):
        h.remove(sorted(h.working_set())[0])
        h.add()
    assert h.device_delta(0) is None  # fell out of the bounded log
    assert h.device_delta(h.epoch).events == 0  # up-to-date ⇒ empty delta
    with pytest.raises(ValueError):
        h.device_delta(h.epoch + 1)


# ---------------------------------------------------------------------------
# DeviceImageStore: sync modes, equivalence, epoch flip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plane", ["jnp", "pallas"])
@pytest.mark.parametrize("algo", ALGOS)
def test_store_delta_sync_matches_fresh_snapshot(algo, plane):
    rng = np.random.default_rng(11)
    h = _mk(algo)
    store = DeviceImageStore(h, plane=plane)
    events = 60 if plane == "pallas" else 150
    for i in range(events):
        _churn_once(h, rng)
        if i % 7 == 0:
            store.sync()
            _assert_matches_fresh(store, h)
    store.sync()
    _assert_matches_fresh(store, h)
    assert store.totals.delta_applies > 0
    # device lookups against the synced image equal the host plane
    host = np.asarray([h.lookup(int(k)) for k in KEYS[:120]], np.int32)
    np.testing.assert_array_equal(store.lookup(KEYS[:120]), host)


def test_store_transfers_o_changed_words_per_event():
    """The acceptance bar: after one remove(), the sync payload is a few
    words — not the O(n) image."""
    h = _mk("memento", n0=1024)
    store = DeviceImageStore(h)
    h.remove(sorted(h.working_set())[100])
    st = store.sync()
    assert st.mode == "delta"
    assert st.words <= 4
    image_words = sum(int(v.size) for v in store.image().arrays.values())
    assert image_words >= 1024  # what a snapshot would have re-sent


def test_epoch_flip_atomicity():
    """Lookups against the epoch-N image stay valid while N+1 is applied."""
    from repro.core.jax_lookup import lookup_image

    h = _mk("memento")
    store = DeviceImageStore(h)
    old_img = store.image()
    old_host = np.asarray([h.lookup(int(k)) for k in KEYS], np.int32)

    victim = sorted(h.working_set())[len(h.working_set()) // 2]
    h.remove(victim)
    # the store has NOT synced: the front image still serves epoch N
    assert store.image() is old_img
    np.testing.assert_array_equal(np.asarray(lookup_image(KEYS, old_img)),
                                  old_host)
    st = store.sync()
    assert st.mode == "delta" and store.epoch == h.epoch
    # the flip retained epoch N intact as the previous image...
    assert store.previous_image() is old_img
    np.testing.assert_array_equal(np.asarray(lookup_image(KEYS, old_img)),
                                  old_host)
    # ...while the new front serves epoch N+1
    new_host = np.asarray([h.lookup(int(k)) for k in KEYS], np.int32)
    np.testing.assert_array_equal(store.lookup(KEYS), new_host)
    assert (new_host != old_host).sum() == (old_host == victim).sum()


def test_store_growth_falls_back_to_snapshot():
    h = _mk("memento", n0=100)
    store = DeviceImageStore(h)
    cap0 = store.capacity["repl"]
    for _ in range(3 * cap0):
        h.add()
    st = store.sync()
    assert st.mode == "snapshot"
    assert store.capacity["repl"] >= 2 * h.size
    _assert_matches_fresh(store, h)


def test_store_log_overflow_falls_back_to_snapshot():
    h = _mk("anchor")
    store = DeviceImageStore(h)
    h._DELTA_LOG_CAP = 4
    for b in sorted(h.working_set())[:12]:
        h.remove(b)
    st = store.sync()
    assert st.mode == "snapshot"
    _assert_matches_fresh(store, h)
    assert store.sync().mode == "noop"


# ---------------------------------------------------------------------------
# async (deferred-flip) sync — DESIGN.md §9.1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_sync_async_defers_flip_until_commit(algo):
    h = _mk(algo)
    store = DeviceImageStore(h)
    e0 = store.epoch
    old_host = np.asarray([h.lookup(int(k)) for k in KEYS], np.int32)

    if lifo_only(algo):
        h.remove(h.size - 1)
    else:
        h.remove(sorted(h.working_set())[5])
    handle = store.sync_async()
    assert store.pending is handle and not handle.done
    # the dispatch changed NOTHING observable: old epoch keeps serving
    assert store.epoch == e0
    np.testing.assert_array_equal(store.lookup(KEYS), old_host)

    st = handle.commit()
    assert handle.done and store.pending is None
    assert st.mode == "delta" and store.epoch == h.epoch == st.epoch
    _assert_matches_fresh(store, h)
    assert handle.commit() is st  # idempotent after the flip


@pytest.mark.parametrize("algo", ALGOS)
def test_sync_async_poll_and_flush_paths(algo):
    rng = np.random.default_rng(17)
    h = _mk(algo)
    store = DeviceImageStore(h)
    for _ in range(10):
        _churn_once(h, rng)
        handle = store.sync_async()
        while not store.poll():  # non-blocking path eventually lands it
            pass
        assert handle.done
    _churn_once(h, rng)
    store.sync_async()
    st = store.flush()  # blocking path lands the pending handle
    assert st is not None and store.pending is None
    _assert_matches_fresh(store, h)
    # a new sync() linearizes after any pending async epoch
    _churn_once(h, rng)
    store.sync_async()
    _churn_once(h, rng)
    store.sync()
    assert store.pending is None and store.epoch == h.epoch
    _assert_matches_fresh(store, h)
    assert store.sync_async().done  # up-to-date → noop handle


@pytest.mark.parametrize("plane", ["jnp", "pallas"])
@pytest.mark.parametrize("algo", ALGOS)
def test_async_sync_concurrent_lookups_never_torn(algo, plane):
    """The §9.1 atomicity law under real threads: lookups racing an
    in-flight ``sync_async()`` observe a complete epoch — the full old
    vector or the full new one, never a mix of the two."""
    import threading

    rng = np.random.default_rng(23)
    h = _mk(algo)
    store = DeviceImageStore(h, plane=plane)
    keys = KEYS[:96] if plane == "pallas" else KEYS[:200]

    def oracle():
        return np.asarray([h.lookup(int(k)) for k in keys],
                          np.int32).tobytes()

    valid = {oracle()}
    stop = threading.Event()
    seen: list[bytes] = []
    errors: list[Exception] = []

    def hammer():
        try:
            while not stop.is_set():
                seen.append(np.asarray(store.lookup(keys)).tobytes())
        except Exception as e:  # surfaced in the main thread
            errors.append(e)

    t = threading.Thread(target=hammer)
    t.start()
    try:
        for _ in range(6 if plane == "pallas" else 12):
            _churn_once(h, rng)
            valid.add(oracle())
            handle = store.sync_async()
            while not handle.poll():
                pass
    finally:
        stop.set()
        t.join()
    assert not errors, errors
    assert seen  # the hammer thread actually raced the flips
    torn = [s for s in set(seen) if s not in valid]
    assert not torn, f"{len(torn)} torn lookup result(s)"
    store.flush()
    _assert_matches_fresh(store, h)


# ---------------------------------------------------------------------------
# migration diff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plane", ["jnp", "pallas"])
@pytest.mark.parametrize("algo", ALGOS)
def test_migration_diff_matches_host(algo, plane):
    from repro.kernels.engine import engine_diff

    h = _mk(algo)
    store = DeviceImageStore(h)
    before = np.asarray([h.lookup(int(k)) for k in KEYS], np.int32)
    victim = (h.size - 1 if lifo_only(algo)
              else sorted(h.working_set())[len(h.working_set()) // 3])
    h.remove(victim)
    store.sync()
    after = np.asarray([h.lookup(int(k)) for k in KEYS], np.int32)

    d = engine_diff(KEYS, store.previous_image(), store.image(), plane=plane)
    np.testing.assert_array_equal(d.old, before)
    np.testing.assert_array_equal(d.new, after)
    np.testing.assert_array_equal(d.moved, before != after)
    # device-side minimal disruption: only the victim's keys moved
    assert d.num_moved == int((before == victim).sum())
    assert not np.any(d.new[d.moved] == victim)


def test_migration_diff_cross_algorithm_jnp():
    """The jnp plane may diff two different algorithms (algo migration)."""
    from repro.kernels.engine import engine_diff

    a = _mk("memento")
    b = _mk("anchor")
    d = engine_diff(KEYS[:100], a.device_image(), b.device_image())
    host_a = np.asarray([a.lookup(int(k)) for k in KEYS[:100]])
    host_b = np.asarray([b.lookup(int(k)) for k in KEYS[:100]])
    np.testing.assert_array_equal(d.old, host_a)
    np.testing.assert_array_equal(d.new, host_b)
    np.testing.assert_array_equal(d.moved, host_a != host_b)


def test_migration_diff_pallas_rejects_cross_algorithm():
    from repro.kernels.engine import engine_diff

    a, b = _mk("memento"), _mk("anchor")
    with pytest.raises(ValueError):
        engine_diff(KEYS[:10], a.device_image(), b.device_image(),
                       plane="pallas")


# ---------------------------------------------------------------------------
# consumers
# ---------------------------------------------------------------------------

def test_router_pushes_deltas_instead_of_rebuilding():
    from repro.serve.router import SessionRouter

    r = SessionRouter(num_replicas=16)
    sessions = np.arange(9000, 9500, dtype=np.uint64)
    first = r.route_batch(sessions)
    store = r.image_store()
    assert store.totals.snapshot_rebuilds == 0

    victim = int(first[0])
    info = r.fail_replica(victim)
    assert info["control_plane"]["mode"] == "delta"
    assert info["control_plane"]["words"] <= 4
    after = r.route_batch(sessions)
    moved = after != first
    assert np.all(first[moved] == victim)  # minimal disruption on device
    r.restore_replica()
    np.testing.assert_array_equal(r.route_batch(sessions), first)
    assert store.totals.delta_applies >= 2
    assert store.totals.snapshot_rebuilds == 0


def test_router_session_lru_is_bounded():
    from repro.serve.router import SessionRouter

    r = SessionRouter(num_replicas=4, max_sessions=100)
    for s in range(1000):
        r.route(s)
    assert len(r._last) == 100
    assert 999 in r._last and 0 not in r._last  # newest kept, coldest evicted
    r.route(999)
    assert r.stats.affinity_hits >= 1


def test_shard_placement_plans_on_device_plane():
    from repro.data.pipeline import ShardPlacement

    p = ShardPlacement(num_shards=256, num_hosts=16)
    plan = p.fail_host(5)
    assert plan["minimal"]
    assert p.image_store().totals.delta_applies >= 1
    plan2 = p.add_host()
    assert plan2["monotone"] and plan2["host"] == 5
    assert set(plan2["moved"]) <= set(plan["moved"])


def test_elastic_cluster_honours_algo_for_ckpt_buckets():
    from repro.runtime.elastic import ElasticCluster

    for algo in (a for a in ALGOS if not lifo_only(a)):
        c = ElasticCluster(num_hosts=8, num_shards=64, algo=algo)
        assert c.ckpt_ch.name == algo
        st = c.state()
        assert st["algo"] == algo and st["ckpt"]["algo"] == algo
        assert st["working"] == 8
        c.fail(3)
        assert c.state()["working"] == 7
        c.join()
        assert c.state()["working"] == 8
    # Memento keeps exposing the paper's ⟨n, R, l⟩
    c = ElasticCluster(num_hosts=8, num_shards=64)
    assert {"n", "l", "R"} <= set(c.state())
