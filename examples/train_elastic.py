"""Elastic training driver: Memento-sharded data, checkpoint/restart, a host
failure mid-run, and straggler mitigation — the fault-tolerance story end to
end on a small LM.

    PYTHONPATH=src python examples/train_elastic.py [--steps 30]
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import AsyncCheckpointer, restore_checkpoint
from repro.configs import smoke_config
from repro.data import DataPipeline
from repro.models import LM
from repro.runtime import ElasticCluster, StragglerMonitor
from repro.train import TrainStepConfig, init_state, make_train_step


def host_batches(cluster, pipes, per_host_batch):
    """Assemble the global batch from every live host's pipeline."""
    parts = [pipes[h].next_batch() for h in sorted(cluster.hosts)]
    return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--fail-at", type=int, default=12)
    ap.add_argument("--restart-at", type=int, default=20)
    ap.add_argument("--ckpt-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = smoke_config("qwen2.5-14b")
    model = LM(cfg, attn_chunk=8)
    step_fn = jax.jit(make_train_step(model, TrainStepConfig(lr=3e-3, microbatches=1)))
    state = init_state(model, jax.random.PRNGKey(0))

    cluster = ElasticCluster(num_hosts=4, num_shards=64)
    per_host_batch, seq = 2, 32
    pipes = {h: DataPipeline(cluster.placement, h, batch=per_host_batch,
                             seq_len=seq, vocab_size=cfg.vocab_size)
             for h in cluster.hosts}
    straggler = StragglerMonitor(k_sigma=3.0)
    rng = np.random.default_rng(0)

    ckpt_dir = tempfile.mkdtemp(prefix="memento_ckpt_")
    ck = AsyncCheckpointer(ckpt_dir, num_buckets=4)
    print(f"checkpoints → {ckpt_dir}")

    losses = []
    step = 0
    while step < args.steps:
        if step == args.fail_at:
            plan = cluster.fail(2)
            pipes.pop(2)
            # surviving hosts pick up the dead host's shards automatically
            for h in pipes:
                pipes[h].placement = cluster.placement
            print(f"step {step}: HOST 2 FAILED — {len(plan['moved'])} shards "
                  f"re-placed (minimal: {plan['minimal']}); "
                  f"{len(cluster.hosts)} hosts continue")

        if step == args.restart_at:
            ck.wait()
            restored, manifest = restore_checkpoint(ckpt_dir)
            state = jax.tree.map(jnp.asarray, restored)
            step = int(manifest["step"]) + 1
            print(f"SIMULATED CRASH → restored checkpoint @step {manifest['step']}, "
                  f"resuming from step {step}")
            args.restart_at = -1
            continue

        # simulated per-host step latencies (host 1 occasionally straggles)
        lat = {h: 1.0 + 0.02 * rng.normal() + (8.0 if (h == 1 and step % 9 == 7) else 0)
               for h in cluster.hosts}
        verdict = straggler.filter_step(lat)
        if verdict["skipped"]:
            print(f"step {step}: straggler(s) {sorted(verdict['skipped'])} skipped, "
                  f"grad rescale ×{verdict['grad_scale']:.2f}")

        batch_np = host_batches(cluster, pipes, per_host_batch)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % 5 == 0:
            print(f"step {step}: loss {losses[-1]:.3f} "
                  f"(hosts={sorted(cluster.hosts)})")
        if step % args.ckpt_every == 0:
            ck.save(state, step)
        step += 1

    ck.wait()
    print(f"\nfinal loss {losses[-1]:.3f} (first {losses[0]:.3f}); "
          f"total resource movement across events: {cluster.movement_total()} shards")
    assert losses[-1] < losses[0], "training did not progress"
    return 0


if __name__ == "__main__":
    main()
