"""Quickstart: the MementoHash API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (AnchorHash, DxHash, JumpHash, MementoHash,
                        MementoTables, PowerHash)
from repro.kernels import ops


def main():
    # 1. a 10-node cluster — Memento starts empty (Θ(1) state, like Jump)
    m = MementoHash(10)
    keys = [f"user:{i}" for i in range(6)]
    from repro.core.hashing import key_to_u64
    print("initial placement:", {k: m.lookup(key_to_u64(k)) for k in keys})
    print(f"state: n={m.n} |R|={len(m.R)} memory={m.memory_bytes()}B")

    # 2. node 4 fails (random removal — the case JumpHash cannot handle)
    m.remove(4)
    print("\nafter node 4 fails:", {k: m.lookup(key_to_u64(k)) for k in keys})
    print(f"state: n={m.n} |R|={len(m.R)} l={m.l} R={m.R}")

    # 3. scale out: the failed node is restored first (reverse order)
    print("restored node:", m.add())
    print("new tail node:", m.add())
    print(f"state: n={m.n} |R|={len(m.R)}")

    # 4. the device data plane: bulk lookups via the Pallas kernel
    m.remove(7)
    m.remove(2)
    tabs = MementoTables(m)
    batch = np.random.default_rng(0).integers(0, 2**32, size=8, dtype=np.uint32)
    out = ops.memento_lookup(batch, tabs.repl, tabs.n)  # interpret on CPU
    print("\nbatched device-plane lookups:", np.asarray(out).tolist())

    # 5. baselines for comparison (fixed capacity a = 10·w)
    for h in (JumpHash(10), AnchorHash(100, 10), DxHash(100, 10),
              PowerHash(10)):
        print(f"{h.name:8s} lookup({keys[0]!r}) → {h.lookup(key_to_u64(keys[0]))}"
              f"   memory={h.memory_bytes()}B")

    # 6. every algorithm speaks the same protocol: one device plane for all
    from repro.core import ALGORITHM_REGISTRY, ALGORITHMS, make_hash
    print("\nprotocol device plane (host == device, variant='32'):")
    for algo in ALGORITHMS:
        h = make_hash(algo, 10, variant="32")
        if ALGORITHM_REGISTRY[algo].lifo_only:
            h.remove(h.size - 1)
        else:
            h.remove(3)
        out = ops.device_lookup(batch, h.device_image())  # Pallas (interpret on CPU)
        assert [h.lookup(int(k)) for k in batch] == np.asarray(out).tolist()
        print(f"  {algo:8s} → {np.asarray(out).tolist()}")


if __name__ == "__main__":
    main()
