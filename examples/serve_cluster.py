"""End-to-end serving driver: a small LM served by a replica fleet with
MementoHash session routing, batched decoding, and a mid-run replica failure.

This is the paper's intended deployment shape: sessions (KV caches) are
consistent-hashed onto replicas, so the failure moves ONLY the dead
replica's sessions (their caches re-prefill — a measured, minimal cache-miss
set) while every other session keeps decoding on its warm cache.

The churn script is a scenario-engine trace (DESIGN.md §7): the rounds and
the mid-run failure replay ``repro.sim.traces.serving_failure_trace``, with
the victim resolved by the simulator's own ``pick_victim`` rule — the demo
and ``benchmarks/bench_scenarios.py`` exercise ONE churn path.

    PYTHONPATH=src python examples/serve_cluster.py [--replicas 4] [--sessions 24]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import LM
from repro.serve.router import BatchScheduler, Request, SessionRouter
from repro.sim import make_trace, pick_victim


class Replica:
    """One model replica: holds per-session KV caches (warm state)."""

    def __init__(self, rid: int, model: LM, params, max_len: int):
        self.rid = rid
        self.model = model
        self.params = params
        self.max_len = max_len
        self.caches: dict[int, tuple] = {}   # session → (cache, pos, last_tok)
        self.prefills = 0
        self.decodes = 0
        self._decode = jax.jit(model.decode_step)

    def serve(self, session: int, prompt: np.ndarray) -> int:
        """Decode one token for the session (prefill on cache miss)."""
        if session not in self.caches:
            tokens = jnp.asarray(prompt[None, :], jnp.int32)
            cache, logits = self.model.prefill(self.params, tokens=tokens,
                                               max_len=self.max_len)
            self.prefills += 1
            pos = prompt.shape[0]
        else:
            cache, pos, last = self.caches[session]
            cache, logits = self._decode(self.params, cache,
                                         jnp.asarray([[last]], jnp.int32),
                                         jnp.int32(pos))
            self.decodes += 1
            pos += 1
        tok = int(jnp.argmax(logits[0, -1]))
        self.caches[session] = (cache, pos, tok)
        return tok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--sessions", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--fail-at", type=int, default=3)
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="seed of the serving_failure scenario trace")
    ap.add_argument("--cache-dtype", default="bfloat16",
                    choices=["bfloat16", "int8"])
    args = ap.parse_args(argv)

    cfg = smoke_config("gemma-2b")
    model = LM(cfg, attn_chunk=8, remat="none", cache_dtype=args.cache_dtype)
    params = model.init(jax.random.PRNGKey(0))
    max_len = 64

    router = SessionRouter(args.replicas)
    sched = BatchScheduler(router, max_batch=32)
    replicas = {r: Replica(r, model, params, max_len) for r in router.replicas}

    rng = np.random.default_rng(0)
    prompts = {s: rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for s in range(args.sessions)}
    outputs: dict[int, list[int]] = {s: [] for s in prompts}
    placement_before_failure = {}
    retired: list[Replica] = []

    # the churn script: rounds + ONE mid-run failure, as a replayable
    # scenario trace — the same path the scenario engine benchmarks drive
    trace = make_trace("serving_failure", seed=args.trace_seed,
                       replicas=args.replicas, rounds=args.rounds,
                       fail_at=args.fail_at)
    trace_rng = np.random.default_rng([trace.seed, 0])  # membership stream

    t0 = time.time()
    rnd = 0
    for ev in trace.events:
        if ev.op == "fail":
            victim = pick_victim(router.ch, ev.select, trace_rng, ev.bucket)
            placement_before_failure = {
                s: router.route(s) for s in prompts}
            info = router.fail_replica(victim)
            dead = replicas.pop(victim)
            retired.append(dead)
            print(f"\n!! replica {victim} FAILED "
                  f"(held {len(dead.caches)} warm sessions; "
                  f"router moved {info['sessions_moved']})")
            continue
        assert ev.op == "route"  # one decode round
        batches, overflow = sched.assign([Request(session_id=s) for s in prompts])
        if overflow:
            print(f"   (back-pressure: {len(overflow)} requests re-queued)")
        for rid, reqs in sorted(batches.items()):
            rep = replicas[rid]
            for req in reqs:
                tok = rep.serve(req.session_id, prompts[req.session_id])
                outputs[req.session_id].append(tok)
        done = sum(len(v) for v in outputs.values())
        print(f"round {rnd}: {done} tokens total, "
              f"replicas={{{', '.join(f'{r}:{len(rep.caches)}s' for r, rep in sorted(replicas.items()))}}}")
        rnd += 1

    # --- report ---------------------------------------------------------
    fleet = list(replicas.values()) + retired
    total_prefills = sum(r.prefills for r in fleet)
    total_decodes = sum(r.decodes for r in fleet)
    elapsed = time.time() - t0
    print(f"\nserved {total_prefills} prefills + {total_decodes} decodes "
          f"in {elapsed:.1f}s")

    # minimal disruption check: only the dead replica's sessions re-prefilled
    if placement_before_failure:
        victim_sessions = {s for s, r in placement_before_failure.items()
                           if r not in replicas}
        expected = args.sessions + len(victim_sessions)
        assert total_prefills == expected, (total_prefills, expected)
        print(f"minimal disruption VERIFIED: exactly the {len(victim_sessions)} "
              f"failed-replica sessions re-prefilled (cache misses); "
              f"{args.sessions - len(victim_sessions)} sessions kept warm caches")
    return 0


if __name__ == "__main__":
    main()
