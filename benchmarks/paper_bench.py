"""Paper-reproduction benchmarks: lookup time + memory for every
registered algorithm (Memento / Anchor / Dx / Jump / Power) across the
paper's scenarios (§VIII).

Scenarios (one function per paper figure group):

  * stable            — Figs. 17/18: no removals, sizes 10…10⁶
  * one-shot removals — Figs. 19-22: 90 % of nodes removed, LIFO (best) and
                        random (worst)
  * incremental       — Figs. 23-26: growing removal fraction
  * sensitivity       — Figs. 27-32: Anchor/Dx vs the a/w over-provisioning
                        ratio ∈ {5,10,20,50,100}
  * quality           — §II metrics: balance, minimal disruption, monotonicity

Anchor and Dx are initialized with a = 10·w (the paper's compromise).
Default sizes are CPU-budget scaled; ``--full`` switches to paper scale
(10⁶ nodes).  Timings are wall-clock over pre-generated uint64 keys.

``bench_device_scenarios`` additionally times the *device* data plane
(batched jnp + Pallas lookups over each algorithm's DeviceImage) across
the stable / one-shot / incremental scenarios — the comparison §VIII never
ran on hardware.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ALGORITHM_REGISTRY, ALGORITHMS, JumpHash,
                        MementoHash, PowerHash, make_hash)

A_OVER_W = 10

#: algorithms whose only legal removal is the highest bucket id
_LIFO = frozenset(n for n in ALGORITHMS if ALGORITHM_REGISTRY[n].lifo_only)


def _mk(algo: str, w: int, a_over_w: int = A_OVER_W, variant: str = "64"):
    """Every registered algorithm through the one ConsistentHash factory."""
    return make_hash(algo, w, capacity=a_over_w * w, variant=variant)


def _time_lookup(h, keys) -> float:
    """µs per lookup."""
    lookup = h.lookup
    t0 = time.perf_counter()
    for k in keys:
        lookup(k)
    return (time.perf_counter() - t0) / len(keys) * 1e6


def _keys(n, seed=0):
    return [int(k) for k in np.random.default_rng(seed).integers(0, 2**63, size=n)]


def _remove_random(h, count, seed=1):
    rng = np.random.default_rng(seed)
    ws = sorted(h.working_set())  # maintained incrementally: Θ(a) scan once
    for _ in range(count):
        i = int(rng.integers(len(ws)))
        h.remove(ws[i])
        ws.pop(i)


def _remove_lifo(h, count):
    for _ in range(count):
        if isinstance(h, (MementoHash, JumpHash, PowerHash)):
            h.remove(h.n - 1)
        else:
            h.remove(max(h.working_set()))


ALGOS = ALGORITHMS


def bench_stable(sizes, n_keys, emit):
    keys = _keys(n_keys)
    for w in sizes:
        for algo in ALGOS:
            h = _mk(algo, w)
            us = _time_lookup(h, keys)
            emit("stable_lookup", algo, w, "us_per_lookup", us)
            emit("stable_memory", algo, w, "bytes", h.memory_bytes())


def bench_oneshot(sizes, n_keys, emit, frac=0.9):
    keys = _keys(n_keys)
    for w in sizes:
        removals = int(frac * w)
        for case, remover in (("best", _remove_lifo), ("worst", _remove_random)):
            for algo in ALGOS:
                h = _mk(algo, w)
                if algo in _LIFO:
                    _remove_lifo(h, removals)  # Jump/Power support LIFO only
                else:
                    remover(h, removals)
                us = _time_lookup(h, keys)
                emit(f"oneshot_{case}_lookup", algo, w, "us_per_lookup", us)
                emit(f"oneshot_{case}_memory", algo, w, "bytes", h.memory_bytes())


def bench_incremental(w0, fractions, n_keys, emit):
    keys = _keys(n_keys)
    for case in ("best", "worst"):
        for algo in ALGOS:
            h = _mk(algo, w0)
            removed = 0
            for frac in fractions:
                target = int(frac * w0)
                step = target - removed
                if algo in _LIFO or case == "best":
                    _remove_lifo(h, step)
                else:
                    _remove_random(h, step, seed=int(frac * 100))
                removed = target
                us = _time_lookup(h, keys)
                emit(f"incremental_{case}_lookup", algo, frac, "us_per_lookup", us)
                emit(f"incremental_{case}_memory", algo, frac, "bytes", h.memory_bytes())


def bench_sensitivity(w, ratios, n_keys, emit):
    keys = _keys(n_keys)
    for scenario, frac in (("stable", 0.0), ("removed20", 0.2), ("removed65", 0.65)):
        # Memento baseline (no a/w dependence)
        m = MementoHash(w)
        if frac:
            _remove_random(m, int(frac * w))
        emit(f"sensitivity_{scenario}_lookup", "memento", 0, "us_per_lookup",
             _time_lookup(m, keys))
        emit(f"sensitivity_{scenario}_memory", "memento", 0, "bytes",
             m.memory_bytes())
        for ratio in ratios:
            for algo in ("anchor", "dx"):
                h = _mk(algo, w, a_over_w=ratio)
                if frac:
                    _remove_random(h, int(frac * w))
                emit(f"sensitivity_{scenario}_lookup", algo, ratio,
                     "us_per_lookup", _time_lookup(h, keys))
                emit(f"sensitivity_{scenario}_memory", algo, ratio, "bytes",
                     h.memory_bytes())


def bench_quality(w, n_keys, emit, removals_frac=0.3):
    """§II metrics: balance / minimal disruption / monotonicity, all algos."""
    keys = _keys(n_keys)
    for algo in ALGOS:
        h = _mk(algo, w)
        if algo not in _LIFO:
            _remove_random(h, int(removals_frac * w))
        else:
            _remove_lifo(h, int(removals_frac * w))
        live = len(h.working_set())
        counts: dict[int, int] = {}
        before = {}
        for k in keys:
            b = h.lookup(k)
            before[k] = b
            counts[b] = counts.get(b, 0) + 1
        arr = np.asarray(list(counts.values()) + [0] * (live - len(counts)))
        expected = len(keys) / live
        emit("quality_balance", algo, w, "peak_to_mean", float(arr.max() / expected))
        emit("quality_balance", algo, w, "cv", float(arr.std() / expected))
        # CV × √E ≈ 1 for an ideal uniform assignment (multinomial noise)
        emit("quality_balance", algo, w, "cv_normalized",
             float(arr.std() / expected * np.sqrt(expected)))

        # minimal disruption: remove one more bucket
        victim = sorted(h.working_set())[-1] if algo in _LIFO else sorted(h.working_set())[len(h.working_set()) // 2]
        h.remove(victim)
        moved_bad = sum(1 for k in keys
                        if before[k] != victim and h.lookup(k) != before[k])
        emit("quality_min_disruption", algo, w, "bad_moves", moved_bad)

        # monotonicity: add it back
        b = h.add()
        moved_bad = sum(1 for k in keys if h.lookup(k) not in (before[k], b))
        emit("quality_monotonicity", algo, w, "bad_moves", moved_bad)


def bench_resize(w, n_ops, emit):
    """Table I resize/init columns: add/remove cost."""
    for algo in ALGOS:
        h = _mk(algo, w)
        rng = np.random.default_rng(0)
        ws = sorted(h.working_set())
        victims = [ws[int(rng.integers(len(ws)))] for _ in range(n_ops)]
        t0 = time.perf_counter()
        for v in victims:
            if algo in _LIFO:
                h.remove(h.n - 1)
            else:
                h.remove(v)
            h.add()
        us = (time.perf_counter() - t0) / (2 * n_ops) * 1e6
        emit("resize", algo, w, "us_per_op", us)

        t0 = time.perf_counter()
        _mk(algo, w)
        emit("init", algo, w, "us", (time.perf_counter() - t0) * 1e6)


# ---------------------------------------------------------------------------
# Device plane: bulk-lookup timings for every registry algorithm (§VIII scenarios)
# ---------------------------------------------------------------------------

def bench_device_scenarios(emit, w=1024, a_over_w=4, n_keys=8192,
                           oneshot_frac=0.5, inc_fractions=(0.2, 0.5),
                           pallas_keys=2048):
    """Bulk device-plane lookups (jnp jit + Pallas) per algorithm × scenario.

    Scenarios mirror the paper's §VIII groups on `variant="32"` states whose
    host lookups are bit-identical to the device planes:

      * ``stable``       — no removals,
      * ``oneshot``      — `oneshot_frac` of nodes removed at random
                           (LIFO for Jump, which supports nothing else),
      * ``incremental``  — growing removal fraction, re-timed per step.

    On CPU the Pallas column runs in interpret mode (correctness path, NOT
    TPU performance) over a smaller key batch; the jnp column is the
    XLA-compiled number to watch off-TPU.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.jax_lookup import lookup_image
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 2**32, size=n_keys, dtype=np.uint32))
    pkeys = jnp.asarray(np.asarray(keys)[:pallas_keys])

    def _time_planes(h, scenario, x):
        image = h.device_image()
        jnp_lookup = jax.jit(lambda k: lookup_image(k, image))
        out = jnp_lookup(keys)
        out.block_until_ready()  # compile+warm
        t0 = time.perf_counter()
        for _ in range(5):
            jnp_lookup(keys).block_until_ready()
        emit(f"device_{scenario}_lookup", h.name, x, "jnp_us_per_key",
             (time.perf_counter() - t0) / (5 * n_keys) * 1e6)

        pout = ops.device_lookup(pkeys, image)  # interpret on CPU, Mosaic on TPU
        pout.block_until_ready()
        np.testing.assert_array_equal(np.asarray(out)[:pallas_keys], np.asarray(pout))
        t0 = time.perf_counter()
        ops.device_lookup(pkeys, image).block_until_ready()
        emit(f"device_{scenario}_lookup", h.name, x, "pallas_us_per_key",
             (time.perf_counter() - t0) / pallas_keys * 1e6)
        emit(f"device_{scenario}_memory", h.name, x, "bytes", h.memory_bytes())

    for algo in ALGOS:
        # stable
        h = _mk(algo, w, a_over_w=a_over_w, variant="32")
        _time_planes(h, "stable", w)

        # one-shot removals
        h = _mk(algo, w, a_over_w=a_over_w, variant="32")
        removals = int(oneshot_frac * w)
        if algo in _LIFO:
            _remove_lifo(h, removals)
        else:
            _remove_random(h, removals)
        _time_planes(h, "oneshot", w)

        # incremental removals
        h = _mk(algo, w, a_over_w=a_over_w, variant="32")
        removed = 0
        for frac in inc_fractions:
            step = int(frac * w) - removed
            if algo in _LIFO:
                _remove_lifo(h, step)
            else:
                _remove_random(h, step, seed=int(frac * 100))
            removed += step
            _time_planes(h, "incremental", frac)
