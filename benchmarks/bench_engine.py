"""Unified-engine benchmark: fused vs multi-launch ops + mesh scale-out.

Two stories (DESIGN.md §6), for the four algorithms across the paper's
§VIII scenario groups (stable / one-shot / incremental, ``variant="32"``):

* **fusion** — the engine's single-program ops against their multi-launch
  decompositions, bit-equality asserted alongside the timing:

    - epoch diff:        ``engine_diff`` (one program, both epoch tables)
      vs two independent lookups + host compare,
    - replica-set diff:  ``engine_diff(k=2)`` vs two k-replica lookups +
      host compare,
    - bounded k-replica: the fused ``engine_lookup(k, load=, cap=)``
      throughput relative to the plain k-replica lookup (the op had no
      single-launch form before the engine),

* **scale-out** — single-device engine throughput vs the mesh-sharded
  :class:`~repro.serve.plane.ShardedLookupPlane` for 10⁵–10⁷-key batches
  (``--full`` reaches 10⁷), with sharded == single-device equality
  asserted.  Run standalone (``python -m benchmarks.bench_engine``) the
  module forces ``--xla_force_host_platform_device_count=2`` BEFORE jax
  initializes, so even the CPU container exercises a real 2-device mesh;
  under ``benchmarks.run --engine`` it uses whatever devices exist.

Correctness gates are deterministic and CI-hard (``check_engine_claims``);
timings — including the ≥1.8× two-device target at 10⁶ keys — are
advisory on CPU (interpret-mode Pallas and simulated host devices are not
TPU performance).  ``--out BENCH_engine.json`` writes the artifact CI
uploads and ``benchmarks/report.py`` renders into RESULTS.md.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from pathlib import Path

import numpy as np

ALGOS = ("memento", "jump", "anchor", "dx")
SCENARIOS = ("stable", "oneshot", "incremental")


def _remove(h, count, rng):
    for _ in range(count):
        if h.name == "jump":
            h.remove(h.size - 1)
        else:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])


def _scenario_state(algo, scenario, w, a_over_w, frac, rng):
    from repro.core import make_hash

    h = make_hash(algo, w, capacity=a_over_w * w, variant="32")
    if scenario == "oneshot":
        _remove(h, int(frac * w), rng)
    elif scenario == "incremental":
        # ride out removals one by one (worst-case replacement chains)
        _remove(h, int(frac * w), rng)
        for _ in range(int(0.1 * w)):
            h.add()
            _remove(h, 1, rng)
    return h


def _time(fn, repeats=3):
    fn()  # warm/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def bench_engine(emit, w=1024, a_over_w=4, key_counts=(100_000, 1_000_000),
                 k_values=(1, 2, 3), algos=ALGOS, scenarios=SCENARIOS,
                 frac=0.5, seed=0):
    """Emit (table, algo, x, metric, value) rows; return the JSON summary."""
    import jax

    from repro.core import DeviceImageStore
    from repro.kernels.engine import engine_diff, engine_lookup
    from repro.serve.plane import ShardedLookupPlane

    rng = np.random.default_rng(seed)
    devices = len(jax.devices())
    summary: dict = {
        "bench": "engine", "w": w, "key_counts": list(key_counts),
        "k_values": list(k_values),
        "mesh": {"devices": devices, "axes": ["data"]},
        "results": {},
    }

    for algo in algos:
        for scenario in scenarios:
            h = _scenario_state(algo, scenario, w, a_over_w, frac, rng)
            store = DeviceImageStore(h)
            image = store.image()
            key = f"{algo}_{scenario}"
            entry = summary["results"].setdefault(key, {
                "algo": algo, "scenario": scenario, "working": h.working,
            })

            # -- single-device vs mesh throughput -------------------------
            plane = ShardedLookupPlane(store)
            for n_keys in key_counts:
                keys = rng.integers(0, 2**32, size=n_keys, dtype=np.uint32)
                single = np.asarray(engine_lookup(keys, image, plane="jnp"))
                t_single = _time(lambda: np.asarray(
                    engine_lookup(keys, image, plane="jnp")))
                sharded = plane.lookup(keys)
                t_mesh = _time(lambda: plane.lookup(keys))
                equal = bool(np.array_equal(sharded, single))
                tag = f"{n_keys}"
                emit("engine_throughput", algo, tag,
                     f"{scenario}_single_us_per_key", t_single / n_keys * 1e6)
                emit("engine_throughput", algo, tag,
                     f"{scenario}_mesh{devices}_us_per_key",
                     t_mesh / n_keys * 1e6)
                emit("engine_throughput", algo, tag,
                     f"{scenario}_mesh_speedup", t_single / t_mesh)
                entry[f"single_us_per_key_{n_keys}"] = t_single / n_keys * 1e6
                entry[f"mesh_us_per_key_{n_keys}"] = t_mesh / n_keys * 1e6
                entry[f"mesh_speedup_{n_keys}"] = t_single / t_mesh
                entry["sharded_equal"] = entry.get("sharded_equal", True) and equal

            # -- fused vs multi-launch ops (smallest key count) -----------
            keys = rng.integers(0, 2**32, size=min(key_counts),
                                dtype=np.uint32)
            nk = len(keys)
            _remove(h, max(1, w // 100), rng)
            store.sync()
            old, new = store.previous_image(), store.image()

            d = engine_diff(keys, old, new, plane="jnp")
            t_fused = _time(lambda: engine_diff(keys, old, new, plane="jnp"))

            def two_launch(k=1):
                o = np.asarray(engine_lookup(keys, old, k=k, plane="jnp"))
                n_ = np.asarray(engine_lookup(keys, new, k=k, plane="jnp"))
                return o, n_, (o != n_) if k == 1 else (o != n_).any(axis=1)

            o2, n2, m2 = two_launch()
            fused_equal = (np.array_equal(d.old, o2)
                           and np.array_equal(d.new, n2)
                           and np.array_equal(d.moved, m2))
            t_two = _time(lambda: two_launch())
            emit("engine_fusion", algo, scenario, "diff_fused_us_per_key",
                 t_fused / nk * 1e6)
            emit("engine_fusion", algo, scenario, "diff_two_launch_us_per_key",
                 t_two / nk * 1e6)
            entry["diff_fused_us_per_key"] = t_fused / nk * 1e6
            entry["diff_two_launch_us_per_key"] = t_two / nk * 1e6

            if max(k_values) > 1:
                kk = max(k for k in k_values if k > 1)
                dk = engine_diff(keys, old, new, k=kk, plane="jnp")
                t_kfused = _time(lambda: engine_diff(keys, old, new, k=kk,
                                                     plane="jnp"))
                ok2, nk2, mk2 = two_launch(kk)
                fused_equal = (fused_equal and np.array_equal(dk.old, ok2)
                               and np.array_equal(dk.new, nk2)
                               and np.array_equal(dk.moved, mk2))
                t_ktwo = _time(lambda: two_launch(kk))
                emit("engine_fusion", algo, scenario,
                     f"replica{kk}_diff_fused_us_per_key", t_kfused / nk * 1e6)
                emit("engine_fusion", algo, scenario,
                     f"replica{kk}_diff_two_launch_us_per_key",
                     t_ktwo / nk * 1e6)
                entry[f"replica{kk}_diff_fused_us_per_key"] = t_kfused / nk * 1e6
                entry[f"replica{kk}_diff_two_launch_us_per_key"] = t_ktwo / nk * 1e6

                # fused bounded k-replica: no pre-engine single-launch form
                from repro.kernels.engine import bounded_load_len
                cap = max(2, math.ceil(1.25 * nk / h.working))
                load = np.zeros(bounded_load_len(new), np.int32)
                full = sorted(h.working_set())[: max(1, h.working // 4)]
                load[full] = cap
                bounded = np.asarray(engine_lookup(
                    keys, new, k=kk, load=load, cap=cap, plane="jnp"))
                entry["bounded_under_cap"] = bool((load[bounded] < cap).all())
                t_bounded = _time(lambda: np.asarray(engine_lookup(
                    keys, new, k=kk, load=load, cap=cap, plane="jnp")))
                t_plain = _time(lambda: np.asarray(engine_lookup(
                    keys, new, k=kk, plane="jnp")))
                emit("engine_fusion", algo, scenario,
                     f"bounded_replica{kk}_us_per_key", t_bounded / nk * 1e6)
                emit("engine_fusion", algo, scenario,
                     f"plain_replica{kk}_us_per_key", t_plain / nk * 1e6)
                entry[f"bounded_replica{kk}_us_per_key"] = t_bounded / nk * 1e6
                entry[f"plain_replica{kk}_us_per_key"] = t_plain / nk * 1e6

            entry["fused_equal"] = fused_equal
    return summary


def check_engine_claims(summary: dict) -> bool:
    """Deterministic acceptance gates (timings stay advisory):

    * sharded lookups equal the single-device engine for every cell,
    * fused diffs (k=1 and k>1) are bit-identical to their two-launch
      decompositions,
    * every fused bounded-replica bucket is below the cap.
    """
    ok = True

    def claim(name, cond):
        nonlocal ok
        print(f"# claim: {name}: {'OK' if cond else 'FAIL'}")
        ok &= bool(cond)

    for key, e in summary["results"].items():
        claim(f"{key}: sharded == single-device", e.get("sharded_equal"))
        claim(f"{key}: fused diff == two-launch diff", e.get("fused_equal"))
        if "bounded_under_cap" in e:
            claim(f"{key}: bounded replicas below cap", e["bounded_under_cap"])
    devices = summary["mesh"]["devices"]
    for key, e in summary["results"].items():
        for n_keys in summary["key_counts"]:
            sp = e.get(f"mesh_speedup_{n_keys}")
            if sp is not None:
                print(f"# advisory: {key} mesh({devices}) speedup "
                      f"@{n_keys}: {sp:.2f}×")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true", help="10⁷-key batches")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    args = ap.parse_args(argv)

    if args.quick:
        kw = dict(w=256, key_counts=(100_000,), k_values=(1, 2),
                  scenarios=("stable", "oneshot"))
    elif args.full:
        kw = dict(w=10_000, key_counts=(100_000, 1_000_000, 10_000_000))
    else:
        kw = dict(w=1024, key_counts=(100_000, 1_000_000))

    rows = []

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}"
              if isinstance(value, float) else
              f"{table},{algo},{x},{metric},{value}", flush=True)

    print("table,algo,x,metric,value")
    t0 = time.time()
    summary = bench_engine(emit, **kw)
    ok = check_engine_claims(summary)
    summary["claims_pass"] = bool(ok)
    summary["elapsed_s"] = round(time.time() - t0, 2)
    if args.out:
        Path(args.out).write_text(json.dumps(summary, indent=2) + "\n")
        print(f"# wrote {args.out}")
    print(f"# total {summary['elapsed_s']}s — engine claims: "
          f"{'PASS' if ok else 'MISMATCH'}")
    return 0 if ok else 1


if __name__ == "__main__":
    # Force a 2-device host platform BEFORE jax initializes so the CPU
    # container exercises a real mesh (the dry-run launcher's trick).
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=2").strip()
    sys.exit(main())
