"""Unified-engine benchmark: fused vs multi-launch ops + mesh scale-out.

Two stories (DESIGN.md §6), for the four algorithms across the paper's
§VIII scenario groups (stable / one-shot / incremental, ``variant="32"``):

* **fusion** — the engine's single-program ops against their multi-launch
  decompositions, bit-equality asserted alongside the timing:

    - epoch diff:        ``engine_diff`` (one program, both epoch tables)
      vs two independent lookups + host compare,
    - replica-set diff:  ``engine_diff(k=2)`` vs two k-replica lookups +
      host compare,
    - bounded k-replica: the fused ``engine_lookup(k, load=, cap=)``
      throughput relative to the plain k-replica lookup (the op had no
      single-launch form before the engine),

* **scale-out** — single-device engine throughput vs the mesh-sharded
  :class:`~repro.serve.plane.ShardedLookupPlane` for 10⁵–10⁷-key batches
  (``--full`` reaches 10⁷), with sharded == single-device equality
  asserted.  Run standalone (``python -m benchmarks.bench_engine``) the
  module forces ``--xla_force_host_platform_device_count=2`` BEFORE jax
  initializes, so even the CPU container exercises a real 2-device mesh;
  under ``benchmarks.run --engine`` it uses whatever devices exist.

Correctness gates are deterministic and CI-hard (``check_engine_claims``);
timings — including the ≥1.8× two-device target at 10⁶ keys — are
advisory on CPU (interpret-mode Pallas and simulated host devices are not
TPU performance).  ``--out BENCH_engine.json`` writes the artifact CI
uploads and ``benchmarks/report.py`` renders into RESULTS.md.

Beyond timings the benchmark *accounts* (DESIGN.md §8):

* **bytes/key + roofline utilization per op** — the HLO cost model
  (``launch/hlo_analysis.analyze_jit``) over the engine's jnp program,
  divided against the detected backend's roofline
  (``launch/roofline.HARDWARE``; override with ``REPRO_ROOFLINE_HW``),
* **compact images** — the 10⁶-bucket packed-vs-dense table-byte claim
  (``pack_image``; gated ≥ 2× for Memento, with bit-identical lookups),
* **tuning** (``--tune``) — refreshes ``benchmarks/results/
  TUNE_engine.json``, the autotuner cache the engine consults at
  dispatch time.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import ALGORITHM_REGISTRY, ALGORITHMS as ALGOS

SCENARIOS = ("stable", "oneshot", "incremental")


def _remove(h, count, rng):
    for _ in range(count):
        if ALGORITHM_REGISTRY[h.name].lifo_only:
            h.remove(h.size - 1)
        else:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])


def _scenario_state(algo, scenario, w, a_over_w, frac, rng):
    from repro.core import make_hash

    h = make_hash(algo, w, capacity=a_over_w * w, variant="32")
    if scenario == "oneshot":
        _remove(h, int(frac * w), rng)
    elif scenario == "incremental":
        # ride out removals one by one (worst-case replacement chains)
        _remove(h, int(frac * w), rng)
        for _ in range(int(0.1 * w)):
            h.add()
            _remove(h, 1, rng)
    return h


from benchmarks.timing import time_fn as _time  # warm-up + block_until_ready


def _lookup_accounting(images, op, keys, n_keys, measured_s):
    """bytes/key + roofline terms for one engine op, from the HLO cost
    model of its jnp program (the canonical algorithmic traffic — the
    Pallas plane runs the same algorithm with hand-placed tiles)."""
    import jax.numpy as jnp

    from repro.kernels.engine import _engine_jnp, _jnp_operands
    from repro.launch.hlo_analysis import analyze_jit
    from repro.launch.roofline import lookup_roofline

    arrays, scalars = _jnp_operands(images)
    a = analyze_jit(_engine_jnp, (jnp.asarray(keys),), arrays, scalars,
                    None, None, static={"op": op})
    return lookup_roofline(a.traffic_bytes, a.flops, n_keys,
                           measured_s=measured_s)


def bench_engine(emit, w=1024, a_over_w=4, key_counts=(100_000, 1_000_000),
                 k_values=(1, 2, 3), algos=ALGOS, scenarios=SCENARIOS,
                 frac=0.5, seed=0):
    """Emit (table, algo, x, metric, value) rows; return the JSON summary."""
    import jax

    from repro.core import DeviceImageStore
    from repro.kernels.engine import engine_diff, engine_lookup
    from repro.serve.plane import ShardedLookupPlane

    from dataclasses import asdict

    from repro.launch.roofline import hardware_spec

    rng = np.random.default_rng(seed)
    devices = len(jax.devices())
    summary: dict = {
        "bench": "engine", "w": w, "key_counts": list(key_counts),
        "k_values": list(k_values),
        "mesh": {"devices": devices, "axes": ["data"]},
        "hardware": asdict(hardware_spec()),
        "results": {},
    }

    for algo in algos:
        for scenario in scenarios:
            h = _scenario_state(algo, scenario, w, a_over_w, frac, rng)
            store = DeviceImageStore(h)
            image = store.image()
            key = f"{algo}_{scenario}"
            entry = summary["results"].setdefault(key, {
                "algo": algo, "scenario": scenario, "working": h.working,
            })

            # -- single-device vs mesh throughput -------------------------
            plane = ShardedLookupPlane(store)
            for n_keys in key_counts:
                keys = rng.integers(0, 2**32, size=n_keys, dtype=np.uint32)
                single = np.asarray(engine_lookup(keys, image, plane="jnp"))
                t_single = _time(lambda: np.asarray(
                    engine_lookup(keys, image, plane="jnp")))
                sharded = plane.lookup(keys)
                t_mesh = _time(lambda: plane.lookup(keys))
                equal = bool(np.array_equal(sharded, single))
                tag = f"{n_keys}"
                emit("engine_throughput", algo, tag,
                     f"{scenario}_single_us_per_key", t_single / n_keys * 1e6)
                emit("engine_throughput", algo, tag,
                     f"{scenario}_mesh{devices}_us_per_key",
                     t_mesh / n_keys * 1e6)
                emit("engine_throughput", algo, tag,
                     f"{scenario}_mesh_speedup", t_single / t_mesh)
                entry[f"single_us_per_key_{n_keys}"] = t_single / n_keys * 1e6
                entry[f"mesh_us_per_key_{n_keys}"] = t_mesh / n_keys * 1e6
                entry[f"mesh_speedup_{n_keys}"] = t_single / t_mesh
                entry["sharded_equal"] = entry.get("sharded_equal", True) and equal

                if n_keys == min(key_counts):
                    from repro.kernels.engine import EngineOp
                    acct = _lookup_accounting(
                        [image], EngineOp(algo=algo), keys, n_keys, t_single)
                    entry["lookup_accounting"] = acct
                    emit("engine_accounting", algo, scenario,
                         "lookup_bytes_per_key", acct["bytes_per_key"])
                    emit("engine_accounting", algo, scenario,
                         "lookup_roofline_utilization",
                         acct["roofline_utilization"])

            # -- fused vs multi-launch ops (smallest key count) -----------
            keys = rng.integers(0, 2**32, size=min(key_counts),
                                dtype=np.uint32)
            nk = len(keys)
            _remove(h, max(1, w // 100), rng)
            store.sync()
            old, new = store.previous_image(), store.image()

            d = engine_diff(keys, old, new, plane="jnp")
            t_fused = _time(lambda: engine_diff(keys, old, new, plane="jnp"))

            def two_launch(k=1):
                o = np.asarray(engine_lookup(keys, old, k=k, plane="jnp"))
                n_ = np.asarray(engine_lookup(keys, new, k=k, plane="jnp"))
                return o, n_, (o != n_) if k == 1 else (o != n_).any(axis=1)

            o2, n2, m2 = two_launch()
            fused_equal = (np.array_equal(d.old, o2)
                           and np.array_equal(d.new, n2)
                           and np.array_equal(d.moved, m2))
            t_two = _time(lambda: two_launch())
            emit("engine_fusion", algo, scenario, "diff_fused_us_per_key",
                 t_fused / nk * 1e6)
            emit("engine_fusion", algo, scenario, "diff_two_launch_us_per_key",
                 t_two / nk * 1e6)
            entry["diff_fused_us_per_key"] = t_fused / nk * 1e6
            entry["diff_two_launch_us_per_key"] = t_two / nk * 1e6

            from repro.kernels.engine import EngineOp
            acct_d = _lookup_accounting(
                [old, new], EngineOp(algo=algo, diff=True), keys, nk, t_fused)
            entry["diff_accounting"] = acct_d
            emit("engine_accounting", algo, scenario, "diff_bytes_per_key",
                 acct_d["bytes_per_key"])
            emit("engine_accounting", algo, scenario,
                 "diff_roofline_utilization", acct_d["roofline_utilization"])

            if max(k_values) > 1:
                kk = max(k for k in k_values if k > 1)
                dk = engine_diff(keys, old, new, k=kk, plane="jnp")
                t_kfused = _time(lambda: engine_diff(keys, old, new, k=kk,
                                                     plane="jnp"))
                ok2, nk2, mk2 = two_launch(kk)
                fused_equal = (fused_equal and np.array_equal(dk.old, ok2)
                               and np.array_equal(dk.new, nk2)
                               and np.array_equal(dk.moved, mk2))
                t_ktwo = _time(lambda: two_launch(kk))
                emit("engine_fusion", algo, scenario,
                     f"replica{kk}_diff_fused_us_per_key", t_kfused / nk * 1e6)
                emit("engine_fusion", algo, scenario,
                     f"replica{kk}_diff_two_launch_us_per_key",
                     t_ktwo / nk * 1e6)
                entry[f"replica{kk}_diff_fused_us_per_key"] = t_kfused / nk * 1e6
                entry[f"replica{kk}_diff_two_launch_us_per_key"] = t_ktwo / nk * 1e6

                # fused bounded k-replica: no pre-engine single-launch form
                from repro.kernels.engine import bounded_load_len
                cap = max(2, math.ceil(1.25 * nk / h.working))
                load = np.zeros(bounded_load_len(new), np.int32)
                full = sorted(h.working_set())[: max(1, h.working // 4)]
                load[full] = cap
                bounded = np.asarray(engine_lookup(
                    keys, new, k=kk, load=load, cap=cap, plane="jnp"))
                entry["bounded_under_cap"] = bool((load[bounded] < cap).all())
                t_bounded = _time(lambda: np.asarray(engine_lookup(
                    keys, new, k=kk, load=load, cap=cap, plane="jnp")))
                t_plain = _time(lambda: np.asarray(engine_lookup(
                    keys, new, k=kk, plane="jnp")))
                emit("engine_fusion", algo, scenario,
                     f"bounded_replica{kk}_us_per_key", t_bounded / nk * 1e6)
                emit("engine_fusion", algo, scenario,
                     f"plain_replica{kk}_us_per_key", t_plain / nk * 1e6)
                entry[f"bounded_replica{kk}_us_per_key"] = t_bounded / nk * 1e6
                entry[f"plain_replica{kk}_us_per_key"] = t_plain / nk * 1e6

            entry["fused_equal"] = fused_equal
    return summary


def bench_compact(emit, n=1_000_000, removals=1024, n_keys=8192, seed=0):
    """The packed-image claim (DESIGN.md §8.2): at 10⁶ buckets, the packed
    Memento table is ≥ 2× smaller than the dense int32 image with
    bit-identical lookups on host, jnp, and Pallas.  Dx is reported against
    the 4·n int32 image it would need WITHOUT its bitmap encoding (its
    dense layout is already packed — the precedent the Memento packing
    follows)."""
    from repro.core import make_hash
    from repro.core.packing import image_table_bytes, pack_image
    from repro.kernels import ref
    from repro.kernels.engine import engine_lookup

    rng = np.random.default_rng(seed)
    out: dict = {}
    for algo in ("memento", "dx"):
        h = make_hash(algo, n, variant="32")
        # distinct random removals: each target is still working when its
        # turn comes, so no O(n·removals) working-set rescans
        for b in rng.choice(n, size=removals, replace=False):
            h.remove(int(b))
        dense = h.device_image()
        packed = pack_image(dense)
        keys = rng.integers(0, 2**32, size=n_keys, dtype=np.uint32)
        host = ref.lookup_host(keys, h)
        planes_equal = True
        for img in (dense, packed):
            for plane in ("jnp", "pallas"):
                got = np.asarray(engine_lookup(keys, img, plane=plane))
                planes_equal &= bool(np.array_equal(got, host))
        db, pb = image_table_bytes(dense), image_table_bytes(packed)
        int32_equiv = 4 * n  # one int32 word per bucket
        ratio = (db if algo == "memento" else int32_equiv) / max(pb, 1)
        t_dense = _time(lambda: np.asarray(
            engine_lookup(keys, dense, plane="jnp")))
        t_packed = _time(lambda: np.asarray(
            engine_lookup(keys, packed, plane="jnp")))
        out[algo] = {
            "n": n, "removals": removals,
            "dense_bytes": int(db), "packed_bytes": int(pb),
            "int32_equivalent_bytes": int(int32_equiv),
            "reduction_ratio": round(ratio, 2),
            "planes_equal": planes_equal,
            "dense_us_per_key": t_dense / n_keys * 1e6,
            "packed_us_per_key": t_packed / n_keys * 1e6,
        }
        emit("engine_compact", algo, f"{n}", "reduction_ratio", ratio)
        emit("engine_compact", algo, f"{n}", "packed_bytes", float(pb))
    return out


def tune_engine(w=1024, n_keys=16_384, seed=0, out_path=None):
    """Refresh the autotuner cache: one cell per (algo × layout) at the
    benchmark's serving shape, saved deterministically (sorted keys) so
    re-tuning on identical hardware is a no-op diff."""
    from repro.core import make_hash
    from repro.core.packing import pack_image
    from repro.kernels import autotune

    rng = np.random.default_rng(seed)
    cache = autotune.TuneCache.load(out_path or autotune.DEFAULT_CACHE_PATH)
    tuned = {}
    for algo in ALGOS:
        h = _scenario_state(algo, "oneshot", w, 4, 0.5, rng)
        images = [h.device_image()]
        images.append(pack_image(h.device_image()))
        for image in images:
            key, cfg = autotune.autotune_lookup(image, n_keys, seed=seed,
                                                cache=cache)
            tuned[key] = {"block_rows": cfg.block_rows, "plane": cfg.plane,
                          "us_per_key": cfg.us_per_key}
            print(f"# tuned {key}: block_rows={cfg.block_rows} "
                  f"plane={cfg.plane} ({cfg.us_per_key} us/key)", flush=True)
    path = cache.save(out_path)
    autotune.set_active_cache(cache)  # dispatch sees the fresh winners
    print(f"# wrote {path} ({len(cache)} entries)")
    return tuned


def check_engine_claims(summary: dict) -> bool:
    """Deterministic acceptance gates (timings stay advisory):

    * sharded lookups equal the single-device engine for every cell,
    * fused diffs (k=1 and k>1) are bit-identical to their two-launch
      decompositions,
    * every fused bounded-replica bucket is below the cap.
    """
    ok = True

    def claim(name, cond):
        nonlocal ok
        print(f"# claim: {name}: {'OK' if cond else 'FAIL'}")
        ok &= bool(cond)

    for key, e in summary["results"].items():
        claim(f"{key}: sharded == single-device", e.get("sharded_equal"))
        claim(f"{key}: fused diff == two-launch diff", e.get("fused_equal"))
        if "bounded_under_cap" in e:
            claim(f"{key}: bounded replicas below cap", e["bounded_under_cap"])
    for algo, c in summary.get("compact", {}).items():
        claim(f"compact[{algo}]: ≥2× table-byte reduction "
              f"({c['reduction_ratio']}×)", c["reduction_ratio"] >= 2)
        claim(f"compact[{algo}]: packed lookups bit-identical on all planes",
              c["planes_equal"])
    devices = summary["mesh"]["devices"]
    for key, e in summary["results"].items():
        for n_keys in summary["key_counts"]:
            sp = e.get(f"mesh_speedup_{n_keys}")
            if sp is not None:
                print(f"# advisory: {key} mesh({devices}) speedup "
                      f"@{n_keys}: {sp:.2f}×")
        acct = e.get("lookup_accounting")
        if acct:
            print(f"# advisory: {key} lookup {acct['bytes_per_key']:.0f} "
                  f"bytes/key, {acct['roofline_utilization']:.1%} of the "
                  f"{acct['hardware']} roofline")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true", help="10⁷-key batches")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    ap.add_argument("--tune", action="store_true",
                    help="refresh the autotuner cache (TUNE_engine.json)")
    ap.add_argument("--no-compact", action="store_true",
                    help="skip the 10⁶-bucket packed-image claim")
    args = ap.parse_args(argv)

    if args.quick:
        kw = dict(w=256, key_counts=(100_000,), k_values=(1, 2),
                  scenarios=("stable", "oneshot"))
    elif args.full:
        kw = dict(w=10_000, key_counts=(100_000, 1_000_000, 10_000_000))
    else:
        kw = dict(w=1024, key_counts=(100_000, 1_000_000))

    rows = []

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}"
              if isinstance(value, float) else
              f"{table},{algo},{x},{metric},{value}", flush=True)

    print("table,algo,x,metric,value")
    t0 = time.time()
    if args.tune:
        tune_engine()
    summary = bench_engine(emit, **kw)
    if not args.no_compact:
        summary["compact"] = bench_compact(emit)
    ok = check_engine_claims(summary)
    summary["claims_pass"] = bool(ok)
    summary["elapsed_s"] = round(time.time() - t0, 2)
    if args.out:
        Path(args.out).write_text(json.dumps(summary, indent=2) + "\n")
        print(f"# wrote {args.out}")
    print(f"# total {summary['elapsed_s']}s — engine claims: "
          f"{'PASS' if ok else 'MISMATCH'}")
    return 0 if ok else 1


if __name__ == "__main__":
    # Force a 2-device host platform BEFORE jax initializes so the CPU
    # container exercises a real mesh (the dry-run launcher's trick).
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=2").strip()
    sys.exit(main())
