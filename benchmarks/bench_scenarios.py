"""Scenario-engine benchmark: the paper's lifecycle scenarios (and the
beyond-paper ones) replayed through the whole device stack (DESIGN.md §7).

For every built-in trace in :data:`repro.sim.traces.SCENARIOS` (minus
the fleet-scale ``churn_storm_xl``, which is bench_async's cell) × every
registry algorithm this replays the script through the production path (host
algorithm → epoch deltas → :class:`~repro.core.DeviceImageStore` → unified
engine / :class:`~repro.serve.router.SessionRouter`) and records moved-key
counts, delta words transferred, epoch-flip latencies, and per-scenario
lookup throughput.  A larger incremental replay captures the
**degradation profile** (mean host lookup steps vs fraction removed) whose
knee reproduces the paper's ~70 % graceful-degradation story
(Figs. 23–26).

Deterministic claims gates (CI-hard):

* every guarantee checker — minimal disruption, balance, replica
  stability, bounded-load caps — stays silent on every scenario × algo,
* host / jnp / Pallas replays of the same trace agree **bit-for-bit**
  (fingerprint equality) on the cross-plane subset,
* Memento's degradation knee sits in the paper's ~70 % band, and its
  worst-case steps stay at-or-below Dx's up to the knee (Fig. 24).

Timings are advisory (CI runners are noisy).  ``python -m
benchmarks.bench_scenarios --out BENCH_scenarios.json`` writes the
artifact CI uploads and ``benchmarks/report.py`` renders into RESULTS.md.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.core import ALGORITHMS as ALGOS

#: scenarios replayed additionally on host + Pallas planes, gating
#: bit-for-bit replay equality across all three (the others run jnp-only
#: to keep the smoke cheap — tests/test_sim.py covers them all).
CROSS_PLANE = ("oneshot", "churn_storm")


def bench_scenarios(emit, *, w=64, n_keys=2048, probe_keys=1024,
                    deg_w=256, deg_keys=512, seed=0, replica_k=2,
                    scenarios=None, algos=ALGOS):
    """Emit (table, algo, x, metric, value) rows; return the JSON summary."""
    from repro.sim import SCENARIOS, degradation_knee, make_trace, replay

    results: dict[str, dict] = {}
    fingerprints_ok = True
    crossed: list[str] = []  # cross-plane cells that actually replayed

    # churn_storm_xl needs a 1e4+-node fleet (its constructor enforces
    # it) — that cell belongs to bench_async (DESIGN.md §9.4), not this
    # sweep's w≈64 grids.
    default = [s for s in SCENARIOS if s != "churn_storm_xl"]
    for name in (scenarios or default):
        for algo in algos:
            kw = {}
            if name == "session_affinity":
                kw = dict(replicas=w, sessions=n_keys)
            elif name == "serving_failure":
                kw = dict(replicas=max(4, w // 8))
            else:
                kw = dict(w=w, n_keys=n_keys)
            trace = make_trace(name, seed=seed, **kw)
            # the churn_storm protagonist cell replays with the telemetry
            # plane live, so its summary embeds the full serving-stack
            # registry snapshot into BENCH_scenarios.json (DESIGN.md §11)
            telem = name == "churn_storm" and algo == "memento"
            r = replay(trace, algo=algo, plane="jnp",
                       probe_keys=probe_keys, replica_k=replica_k,
                       telemetry=telem)
            s = r.summary()
            s["violation_details"] = [str(v) for v in r.violations]
            if name in CROSS_PLANE:
                if name not in crossed:
                    crossed.append(name)
                planes = {"jnp": r.fingerprint}
                for plane in ("host", "pallas"):
                    planes[plane] = replay(trace, algo=algo, plane=plane,
                                           probe_keys=probe_keys,
                                           replica_k=replica_k).fingerprint
                s["plane_fingerprints"] = planes
                s["planes_agree"] = len(set(planes.values())) == 1
                fingerprints_ok &= s["planes_agree"]
            results[f"{name}_{algo}"] = s
            for metric in ("moved_probe_total", "delta_words_total",
                           "snapshot_rebuilds", "epoch_flip_us_mean",
                           "violations"):
                emit("scenarios", algo, name, metric, s.get(metric, 0))
            for op_metric in ("lookup_us_per_key", "route_us_per_key",
                              "assign_us_per_key"):
                if op_metric in s:
                    emit("scenarios", algo, name, op_metric, s[op_metric])

    # -- degradation profile (paper Figs. 23–26) ----------------------------
    profiles: dict[str, list] = {}
    knees: dict[str, float | None] = {}
    for algo in algos:
        trace = make_trace("incremental", seed=seed, w=deg_w, n_keys=deg_keys)
        r = replay(trace, algo=algo, plane="jnp", probe_keys=probe_keys)
        prof = r.metrics.degradation
        profiles[algo] = [[f, s] for f, s in prof]
        knees[algo] = degradation_knee(prof)
        for f, steps in prof:
            emit("scenario_degradation", algo, round(f, 4), "lookup_steps",
                 steps)

    return {"results": results, "degradation_profile": profiles,
            "knee": knees, "fingerprints_ok": fingerprints_ok,
            "cross_plane_cells": crossed,
            "w": w, "n_keys": n_keys, "probe_keys": probe_keys,
            "deg_w": deg_w, "seed": seed, "replica_k": replica_k}


def check_scenario_claims(summary: dict) -> bool:
    """The deterministic guarantee gates (hard); timings stay advisory."""
    ok = True

    def claim(name, cond):
        nonlocal ok
        print(f"# claim: {name}: {'OK' if cond else 'FAIL'}")
        ok &= bool(cond)

    bad = {key: s["violation_details"] for key, s in summary["results"].items()
           if s["violations"]}
    claim("scenarios: every guarantee checker silent "
          f"({len(summary['results'])} scenario×algo cells)", not bad)
    for key, details in bad.items():
        print(f"#   {key}: {details[:3]}")

    crossed = summary["cross_plane_cells"]
    if crossed:  # claim only what actually replayed on all three planes
        claim("scenarios: host/jnp/Pallas replays bit-identical "
              f"(cross-plane cells: {', '.join(crossed)})",
              summary["fingerprints_ok"])
    else:
        print("# claim: scenarios: cross-plane equality NOT EXERCISED "
              "(no CROSS_PLANE scenario in this run)")

    profiles = summary["degradation_profile"]
    if "memento" in profiles:  # knee claims need the paper's protagonist
        knee = summary["knee"].get("memento")
        claim(f"degradation: Memento knee in the paper's ~70% band "
              f"(measured {knee})", knee is not None and 0.55 <= knee <= 0.85)
        if "dx" in profiles:
            # Fig. 24: Memento at-or-below Dx through the knee region
            prof_m = dict((round(f, 3), s) for f, s in profiles["memento"])
            prof_d = dict((round(f, 3), s) for f, s in profiles["dx"])
            shared = [f for f in prof_m if f in prof_d and f <= 0.7]
            claim("degradation: Memento ≤ Dx lookup steps up to the knee",
                  bool(shared) and all(prof_m[f] <= prof_d[f] for f in shared))
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true", help="bigger fleets")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    args = ap.parse_args(argv)

    if args.quick:
        sizes = dict(w=32, n_keys=512, probe_keys=512, deg_w=128,
                     deg_keys=256)
    elif args.full:
        sizes = dict(w=256, n_keys=8192, probe_keys=2048, deg_w=1024,
                     deg_keys=1024)
    else:
        sizes = dict(w=64, n_keys=2048, probe_keys=1024, deg_w=256,
                     deg_keys=512)

    rows = []

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}"
              if isinstance(value, float) else
              f"{table},{algo},{x},{metric},{value}", flush=True)

    print("table,algo,x,metric,value")
    t0 = time.time()
    summary = bench_scenarios(emit, **sizes)
    ok = check_scenario_claims(summary)
    payload = {
        "bench": "scenarios",
        **{k: summary[k] for k in ("w", "n_keys", "probe_keys", "deg_w",
                                   "seed", "replica_k")},
        "cross_plane": summary["cross_plane_cells"],
        "results": summary["results"],
        "degradation_profile": summary["degradation_profile"],
        "knee": summary["knee"],
        "claims_pass": bool(ok),
        "elapsed_s": round(time.time() - t0, 2),
    }
    if args.out:
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {args.out}")
    print(f"# total {payload['elapsed_s']}s — scenario claims: "
          f"{'PASS' if ok else 'MISMATCH'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
