"""Replica-aware serving benchmark: k-replication throughput + bounded-load
balance on the device data plane (DESIGN.md §4).

For every registry algorithm across the paper's §VIII scenario groups (stable /
one-shot / incremental removals, ``variant="32"`` states) this measures:

  * **k-replica lookup throughput** — µs/key to compute k ∈ {1,2,3}
    distinct replicas per key with the unified engine's ``k>1``
    configuration (one jitted jnp program; one Pallas launch — interpret
    mode on CPU, so the Pallas column is a correctness path off-TPU), and

  * **bounded-load balance** — peak-to-mean load after assigning the key
    batch with cap ``ceil(c·keys/working)`` for c ∈ {1.05, 1.25, ∞}
    (∞ = plain consistent hashing, the no-bound baseline) via the
    device-plane chain walk (:func:`~repro.kernels.engine.
    bounded_assign`).

The deterministic claims gate (``check_replica_claims``): replica sets are
pairwise distinct with column 0 equal to the plain lookup, and bounded
assignment never exceeds the cap.  Timings are advisory (CI runners are
noisy).  ``python -m benchmarks.bench_replicas --out BENCH_replicas.json``
writes the artifact CI uploads and ``benchmarks/report.py`` renders into
RESULTS.md.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.timing import time_fn

from repro.core import ALGORITHM_REGISTRY, ALGORITHMS as ALGOS

K_VALUES = (1, 2, 3)
C_VALUES = (1.05, 1.25, float("inf"))


def _remove(h, count, rng):
    for _ in range(count):
        if ALGORITHM_REGISTRY[h.name].lifo_only:
            h.remove(h.size - 1)
        else:
            ws = sorted(h.working_set())
            h.remove(ws[int(rng.integers(len(ws)))])


def _scenario_states(algo, w, a_over_w, oneshot_frac, inc_fractions, rng):
    """(scenario, x, state) tuples mirroring paper_bench's §VIII groups."""
    from repro.core import make_hash

    yield "stable", w, make_hash(algo, w, capacity=a_over_w * w, variant="32")

    h = make_hash(algo, w, capacity=a_over_w * w, variant="32")
    _remove(h, int(oneshot_frac * w), rng)
    yield "oneshot", w, h

    h = make_hash(algo, w, capacity=a_over_w * w, variant="32")
    removed = 0
    for frac in inc_fractions:
        step = int(frac * w) - removed
        _remove(h, step, rng)
        removed += step
        yield "incremental", frac, h


def bench_replicas(emit, w=1024, a_over_w=4, n_keys=8192, pallas_keys=2048,
                   oneshot_frac=0.5, inc_fractions=(0.2, 0.5), seed=0):
    """Emit (table, algo, x, metric, value) rows; return the JSON summary."""
    import jax.numpy as jnp
    from repro.core.protocol import replica_sets
    # both ops are single configurations of the unified engine (DESIGN.md §6)
    from repro.kernels.engine import (bounded_assign as bounded_assign_device,
                                      replica_lookup)

    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**32, size=n_keys, dtype=np.uint32)
    jkeys = jnp.asarray(keys)
    pkeys = jnp.asarray(keys[:pallas_keys])
    summary: dict[str, dict] = {}

    for algo in ALGOS:
        for scenario, x, h in _scenario_states(algo, w, a_over_w,
                                               oneshot_frac, inc_fractions,
                                               rng):
            image = h.device_image()
            working = h.working
            entry = summary.setdefault(f"{algo}_{scenario}_{x}", {
                "algo": algo, "scenario": scenario, "x": x,
                "working": working, "n_keys": n_keys,
            })

            # -- k-replica lookup throughput -----------------------------
            for k in K_VALUES:
                out = np.asarray(replica_lookup(jkeys, image, k, plane="jnp"))
                # deterministic correctness gates ride with the timing
                host = replica_sets(h, keys[:64], k)
                np.testing.assert_array_equal(out[:64], host)
                distinct = all(len(set(row)) == k for row in out.tolist())
                us = time_fn(lambda: replica_lookup(jkeys, image, k,
                                                    plane="jnp"),
                             repeats=5) / n_keys * 1e6
                emit(f"replicas_{scenario}_lookup", algo, x,
                     f"k{k}_jnp_us_per_key", us)
                entry[f"k{k}_jnp_us_per_key"] = us
                entry[f"k{k}_distinct"] = bool(distinct)

                pout = np.asarray(replica_lookup(pkeys, image, k,
                                                 plane="pallas"))
                np.testing.assert_array_equal(pout, out[:pallas_keys])
                pus = time_fn(lambda: replica_lookup(pkeys, image, k,
                                                     plane="pallas"),
                              repeats=1) / pallas_keys * 1e6
                emit(f"replicas_{scenario}_lookup", algo, x,
                     f"k{k}_pallas_us_per_key", pus)
                entry[f"k{k}_pallas_us_per_key"] = pus

            # -- bounded-load balance ------------------------------------
            from repro.kernels.engine import bounded_load_len
            load_len = bounded_load_len(image)
            mean = n_keys / working
            for c in C_VALUES:
                if math.isinf(c):
                    b = np.asarray(replica_lookup(jkeys, image, 1,
                                                  plane="jnp"))[:, 0]
                    peak = int(np.bincount(b).max())
                    cap = None
                    t_us = float("nan")
                else:
                    cap = max(1, math.ceil(c * n_keys / working))
                    assigned, load = bounded_assign_device(
                        keys, image, np.zeros(load_len, np.int32), cap,
                        plane="jnp")
                    t_us = time_fn(
                        lambda: bounded_assign_device(
                            keys, image, np.zeros(load_len, np.int32), cap,
                            plane="jnp"),
                        repeats=1, warmup=0) / n_keys * 1e6  # warmed above
                    peak = int(load.max())
                    assert peak <= cap, (algo, scenario, c, peak, cap)
                    assert (assigned >= 0).all()
                label = "inf" if math.isinf(c) else f"{c:g}"
                emit(f"replicas_{scenario}_balance", algo, x,
                     f"c{label}_peak_to_mean", peak / mean)
                entry[f"c{label}_peak_to_mean"] = peak / mean
                if cap is not None:
                    entry[f"c{label}_cap"] = cap
                    entry[f"c{label}_assign_us_per_key"] = t_us
                    emit(f"replicas_{scenario}_balance", algo, x,
                         f"c{label}_assign_us_per_key", t_us)
    return summary


def check_replica_claims(summary: dict) -> bool:
    """The deterministic acceptance gates (timing is advisory):

    * k-replica sets are pairwise distinct for every algorithm/scenario/k,
    * bounded-load peak never exceeds c · mean (cap enforcement) for
      finite c, and relaxing c (1.05 → ∞) never *improves* the peak.
    """
    ok = True

    def claim(name, cond):
        nonlocal ok
        print(f"# claim: {name}: {'OK' if cond else 'FAIL'}")
        ok &= bool(cond)

    for key, e in summary.items():
        claim(f"{key}: k-replica sets distinct (k=2,3)",
              e.get("k2_distinct") and e.get("k3_distinct"))
        eps = 1e-9
        claim(f"{key}: bounded peak/mean ≤ c (c=1.05, 1.25)",
              e["c1.05_peak_to_mean"] <= e["c1.05_cap"] /
              (e["n_keys"] / e["working"]) + eps
              and e["c1.25_peak_to_mean"] <= e["c1.25_cap"] /
              (e["n_keys"] / e["working"]) + eps)
        claim(f"{key}: bounding helps (peak c=1.05 ≤ peak unbounded)",
              e["c1.05_peak_to_mean"] <= e["cinf_peak_to_mean"] + eps)
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true", help="larger fleet")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    args = ap.parse_args(argv)

    if args.quick:
        kw = dict(w=256, n_keys=2048, pallas_keys=512, inc_fractions=(0.5,))
    elif args.full:
        kw = dict(w=10_000, n_keys=16384, pallas_keys=2048,
                  inc_fractions=(0.2, 0.5))
    else:
        kw = dict(w=1024, n_keys=8192, pallas_keys=2048,
                  inc_fractions=(0.2, 0.5))

    rows = []

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}"
              if isinstance(value, float) else
              f"{table},{algo},{x},{metric},{value}", flush=True)

    print("table,algo,x,metric,value")
    t0 = time.time()
    summary = bench_replicas(emit, **kw)
    ok = check_replica_claims(summary)
    payload = {
        "bench": "replicas",
        "w": kw["w"],
        "n_keys": kw["n_keys"],
        "k_values": list(K_VALUES),
        "c_values": [("inf" if math.isinf(c) else c) for c in C_VALUES],
        "results": summary,
        "claims_pass": bool(ok),
        "elapsed_s": round(time.time() - t0, 2),
    }
    if args.out:
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {args.out}")
    print(f"# total {payload['elapsed_s']}s — replica claims: "
          f"{'PASS' if ok else 'MISMATCH'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
