"""Shared benchmark timing helpers.

Every advisory timing in this repo follows the same discipline: an
explicit warm-up call first (so first-call jit compilation can never
pollute the measurement) and ``jax.block_until_ready`` on each result (so
async dispatch can't end the clock before the device finishes).  This
module is the ONE implementation — ``bench_engine``/``bench_churn``/
``bench_replicas``/``bench_async`` all import it instead of growing
per-module ``_time()`` clones.
"""
from __future__ import annotations

import time


def _settle(out):
    """Block until ``out`` (any pytree of jax arrays / numpy / scalars) is
    materialized on device."""
    import jax

    if out is not None:
        jax.block_until_ready(out)
    return out


def time_fn(fn, repeats: int = 3, *, warmup: int = 1) -> float:
    """Mean wall-clock seconds per call of ``fn()``.

    Runs ``warmup`` untimed calls (compile + caches), then ``repeats``
    timed ones; every call's result is blocked on before its clock stops.
    """
    for _ in range(max(warmup, 0)):
        _settle(fn())
    t0 = time.perf_counter()
    for _ in range(repeats):
        _settle(fn())
    return (time.perf_counter() - t0) / max(repeats, 1)


def block_image(image) -> None:
    """``block_until_ready`` every array of a DeviceImage (sync-latency
    clocks must include the device materialization, not just dispatch)."""
    for arr in image.arrays.values():
        if hasattr(arr, "block_until_ready"):
            arr.block_until_ready()
