"""Shared benchmark timing helpers.

Every advisory timing in this repo follows the same discipline: an
explicit warm-up call first (so first-call jit compilation can never
pollute the measurement) and ``jax.block_until_ready`` on each result (so
async dispatch can't end the clock before the device finishes).  This
module is the ONE implementation — ``bench_engine``/``bench_churn``/
``bench_replicas``/``bench_async`` all import it instead of growing
per-module ``_time()`` clones.

The accumulator is an obs :class:`~repro.obs.metrics.Histogram` — the
same log-bucketed primitive the runtime telemetry plane records into
(DESIGN.md §11) — so benchmark timings and live latency metrics share one
implementation.  Pass ``histogram=`` to land per-repeat samples on a
registry you are snapshotting; the returned mean is computed from the
histogram's exact sum/count deltas either way (bucketing never rounds
it).
"""
from __future__ import annotations

import time

from repro.obs.metrics import Histogram


def _settle(out):
    """Block until ``out`` (any pytree of jax arrays / numpy / scalars) is
    materialized on device."""
    import jax

    if out is not None:
        jax.block_until_ready(out)
    return out


def time_fn(fn, repeats: int = 3, *, warmup: int = 1,
            histogram: Histogram | None = None) -> float:
    """Mean wall-clock seconds per call of ``fn()``.

    Runs ``warmup`` untimed calls (compile + caches), then ``repeats``
    timed ones; every call's result is blocked on before its clock stops.
    Each timed call's latency is observed (in µs) into ``histogram`` — a
    fresh private one by default, or a shared registry histogram (e.g.
    ``reg.histogram("bench.lookup.us")``) whose quantiles a telemetry
    snapshot then exposes.  The mean comes from the histogram's sum/count
    *deltas*, so pre-existing samples on a shared histogram never skew it.
    """
    hist = histogram if histogram is not None else Histogram("bench.call.us")
    for _ in range(max(warmup, 0)):
        _settle(fn())
    c0, s0 = hist.count, hist.sum
    for _ in range(repeats):
        t0 = time.perf_counter()
        _settle(fn())
        hist.observe((time.perf_counter() - t0) * 1e6)
    n = hist.count - c0
    return (hist.sum - s0) / 1e6 / max(n, 1)


def block_image(image) -> None:
    """``block_until_ready`` every array of a DeviceImage (sync-latency
    clocks must include the device materialization, not just dispatch)."""
    for arr in image.arrays.values():
        if hasattr(arr, "block_until_ready"):
            arr.block_until_ready()
