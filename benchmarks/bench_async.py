"""Async-overlap benchmark: non-blocking epoch sync, lookup availability
during churn storms, and follower replication convergence (DESIGN.md §9).

For every cell (churn trace × algorithm) this replays the SAME seeded
storm twice through the real stack (host algorithm → epoch deltas →
:class:`~repro.core.DeviceImageStore` → unified engine):

  * ``sync_mode="block"``   — classic synchronous flip; its
    ``epoch_flip_us_mean`` is the full delta-apply + flip latency the hot
    path used to pay per membership event,
  * ``sync_mode="overlap"`` — :meth:`~repro.core.DeviceImageStore.
    sync_async` dispatch with the flip deferred behind lookup traffic;
    its ``sync_dispatch_us_mean`` is the only part the hot path still
    pays, and a :class:`~repro.launch.replicate.ReplicationGroup`
    follower consumes the leader's delta frames alongside.

The **overlap ratio** — the fraction of the blocking flip latency the
async pipeline hides, ``1 − dispatch/flip`` — is the headline number
(advisory off-TPU: CI runners are noisy).  The CI-HARD gates are the
deterministic ones:

* the block and overlap replays are **bit-identical** (replay
  fingerprint equality — deferring the flip may never change a lookup),
* every guarantee checker stays silent in both modes, including the
  eventual-epoch-convergence checker: the follower reaches the leader's
  epoch with a bit-identical image after every storm,
* every lookup event during the storms is answered (availability:
  the epoch-N front image serves while epoch N+1 is in flight).

``python -m benchmarks.bench_async --out BENCH_async.json`` writes the
artifact CI uploads and ``benchmarks/report.py`` renders into RESULTS.md;
``python -m benchmarks.run --async`` runs the same cells inside the main
driver grid.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import ALGORITHMS as ALGOS

#: (trace name, trace kwargs) cells; every run includes the 10⁴-node
#: churn_storm_xl grid the acceptance bar names — quick shrinks the
#: bursts, full adds the 10⁵-node fleet.
CELLS = {
    "quick": [
        ("churn_storm", dict(w=96, storms=2, burst=12, n_keys=512)),
        ("churn_storm_xl", dict(w=10_000, storms=2, burst=200,
                                n_keys=1024)),
    ],
    "default": [
        ("churn_storm", dict(w=256, storms=3, burst=32, n_keys=2048)),
        ("churn_storm_xl", dict(w=10_000, storms=3, burst=500,
                                n_keys=4096)),
    ],
    "full": [
        ("churn_storm", dict(w=256, storms=4, burst=32, n_keys=2048)),
        ("churn_storm_xl", dict(w=10_000, storms=3, burst=1_000,
                                n_keys=4096)),
        ("churn_storm_xl", dict(w=100_000, storms=3, burst=2_000,
                                n_keys=4096)),
    ],
}


#: wire-stream configs the storm replication sub-bench compares.  The
#: per-epoch dense stream (one DELTA frame per epoch, full-width layout)
#: is the pre-batching baseline; the batched+packed stream is the
#: headline: one DELTA_BATCH per storm burst over the §8.2 packed layout,
#: whose announce snapshot is Θ(n/8 + r) instead of the dense Θ(4n).
WIRE_CONFIGS = [
    ("per_epoch_dense", dict(batch_epochs=1)),
    ("batched_dense", dict(batch_epochs=0)),
    ("batched_packed", dict(batch_epochs=0, packed=True)),
    ("batched_packed_tree", dict(batch_epochs=0, packed=True,
                                 topology="tree", arity=2)),
]

#: (algo, churn_storm_xl kwargs) wire cells; the acceptance gate rides the
#: largest Memento cell — default and full include the 10⁶-node fleet.
WIRE_CELLS = {
    "quick": [
        ("memento", dict(w=10_000, storms=2, burst=200)),
    ],
    "default": [
        ("memento", dict(w=10_000, storms=3, burst=500)),
        ("anchor", dict(w=10_000, storms=3, burst=500)),
        ("memento", dict(w=1_000_000, storms=2, burst=500)),
    ],
    "full": [
        ("memento", dict(w=10_000, storms=3, burst=500)),
        ("anchor", dict(w=10_000, storms=3, burst=500)),
        ("memento", dict(w=1_000_000, storms=3, burst=2_000)),
    ],
}


def _drive_wire(trace, algo, group_kw, followers=3):
    """Replay a storm trace's MEMBERSHIP events straight through a host
    state + :class:`~repro.launch.replicate.ReplicationGroup` (no driver,
    no checkers, no lookup traffic) and return the wire accounting — the
    replication cost of one storm, isolated from everything else."""
    from repro.core import image_fingerprint, make_hash
    from repro.launch.replicate import ReplicationGroup
    from repro.sim.driver import resolve_victims

    h = make_hash(algo, trace.initial_nodes,
                  capacity=trace.capacity_factor * trace.initial_nodes,
                  variant="32")
    g = ReplicationGroup(h, followers, **group_kw)
    g.publish()
    announce_bytes = g.stats.total_bytes  # the initial snapshot fan-out
    rng = np.random.default_rng([trace.seed, 0])
    bursts = 0
    for ev in trace.events:
        if ev.op == "remove":
            for b in resolve_victims(h, ev, rng, trace.num_domains):
                h.remove(b)
        elif ev.op == "add":
            for _ in range(ev.count):
                try:
                    h.add()
                except ValueError:
                    break
        else:
            continue  # wire bytes only; lookups don't touch the stream
        g.publish()
        bursts += 1
    img = h.device_image()
    stream = g.stats.total_bytes - announce_bytes
    return {
        "bytes_total": g.stats.total_bytes,
        "announce_bytes": announce_bytes,
        "stream_bytes": stream,
        # the headline normalization: EVERYTHING the stream cost (announce
        # included — a joining follower pays it) per storm burst event
        "bytes_per_burst": g.stats.total_bytes / max(bursts, 1),
        "stream_bytes_per_burst": stream / max(bursts, 1),
        "frames": g.stats.frames,
        "leader_sends": g.stats.leader_sends,
        "leader_bytes": g.stats.leader_bytes,
        "catchup_frames": g.stats.catchup_frames,
        "snapshot_fallbacks": max(f.snapshots for f in g.followers) - 1,
        "epoch": int(h.epoch),
        "converged": bool(g.converged(img)),
        "leader_fingerprint": image_fingerprint(img),
        "follower_fingerprint": g.followers[0].fingerprint(),
    }


def bench_replication(emit, *, mode="default", followers=3, seed=0):
    """The storm-scale replication sub-bench: wire bytes per storm burst
    across stream configs, tree-vs-flat leader fan-out cost through the
    full driver (checkers on), and partitioned-follower targeted catch-up.
    Returns the ``"replication"`` section of BENCH_async.json."""
    from repro.sim import make_trace, replay

    out: dict[str, dict] = {"wire": {}, "topology": {}, "catchup": {}}

    # -- wire bytes per storm burst, per stream config ------------------------
    for algo, kw in WIRE_CELLS[mode]:
        trace = make_trace("churn_storm_xl", seed=seed, **kw)
        key = f"{algo}_w{kw['w']}"
        cell: dict[str, dict] = {}
        for cfg_name, cfg in WIRE_CONFIGS:
            r = _drive_wire(trace, algo, cfg, followers=followers)
            cell[cfg_name] = r
            for metric in ("bytes_per_burst", "stream_bytes_per_burst",
                           "announce_bytes", "frames", "leader_sends"):
                emit("wire", algo, f"w{kw['w']}_{cfg_name}", metric,
                     r[metric])
            emit("wire", algo, f"w{kw['w']}_{cfg_name}", "converged",
                 int(r["converged"]))
        base = cell["per_epoch_dense"]
        packed = cell["batched_packed"]
        fps = {c["leader_fingerprint"] for c in cell.values()}
        fps |= {c["follower_fingerprint"] for c in cell.values()}
        cell["_meta"] = {
            "algo": algo, "w": kw["w"], "storms": kw["storms"],
            "burst": kw["burst"], "followers": followers,
            # every config reached the same leader state and every
            # follower (dense, packed, flat, tree) fingerprints equal to
            # it — the bit-identical gate across layouts and topologies
            "fingerprints_equal": len(fps) == 1,
            "all_converged": all(c["converged"] for c in cell.values()
                                 if "converged" in c),
            "wire_ratio_vs_per_epoch":
                base["bytes_per_burst"] / packed["bytes_per_burst"],
        }
        emit("wire", algo, f"w{kw['w']}", "wire_ratio_vs_per_epoch",
             cell["_meta"]["wire_ratio_vs_per_epoch"])
        out["wire"][key] = cell

    # -- leader fan-out cost: flat vs tree through the full driver ------------
    topo_kw = (dict(w=96, storms=2, burst=8, n_keys=256) if mode == "quick"
               else dict(w=256, storms=2, burst=16, n_keys=512))
    trace = make_trace("churn_storm", seed=seed, **topo_kw)
    for name, cfg in [("flat", dict(topology="flat", batch_epochs=0)),
                      ("tree_a2", dict(topology="tree", arity=2,
                                       batch_epochs=0)),
                      ("tree_a4", dict(topology="tree", arity=4,
                                       batch_epochs=0))]:
        r = replay(trace, algo="memento", plane="jnp", sync_mode="overlap",
                   followers=7, repl_config=cfg)
        s = r.summary()
        out["topology"][name] = {
            "violations": s["violations"],
            "fingerprint": s["fingerprint"],
            "fanout_depth": s["fanout_depth"],
            "wire_frames_total": s["wire_frames_total"],
            "wire_bytes_total": s["wire_bytes_total"],
            "leader_sends_total": s["leader_sends_total"],
            "follower_lag_max": s["follower_lag_max"],
        }
        for metric in ("leader_sends_total", "wire_bytes_total",
                       "fanout_depth", "violations"):
            emit("topology", "memento", name, metric,
                 out["topology"][name][metric])

    # -- partitioned interior follower: targeted catch-up ---------------------
    rng = np.random.default_rng(seed)
    from repro.core import make_hash
    from repro.launch.replicate import ReplicationGroup

    h = make_hash("memento", 256, variant="32")
    g = ReplicationGroup(h, 3, topology="tree", arity=2, batch_epochs=0)
    g.publish()

    def churn(k):
        for _ in range(k):
            if rng.random() < 0.5 and h.working > 8:
                h.remove(sorted(h.working_set())[-1])
            else:
                h.add()

    churn(16)
    g.publish()
    g.set_online(0, False)  # interior node 1: its subtree starves with it
    churn(16)
    g.publish()
    g.set_online(0, True)
    churn(16)
    g.publish()  # gap detected → targeted pulls repair node 1 AND node 3
    out["catchup"] = {
        "catchup_frames": g.stats.catchup_frames,
        "catchup_bytes": g.stats.catchup_bytes,
        "epoch": int(h.epoch),
        "converged": bool(g.converged(h.device_image())),
    }
    for metric in ("catchup_frames", "catchup_bytes", "converged"):
        emit("catchup", "memento", "tree_a2", metric,
             int(out["catchup"][metric]))
    return out


def bench_async(emit, *, cells=None, followers=1, seed=0, algos=ALGOS):
    """Emit (table, algo, x, metric, value) rows; return the JSON summary."""
    from repro.sim import make_trace, replay

    cells = cells if cells is not None else CELLS["default"]
    results: dict[str, dict] = {}

    for name, kw in cells:
        trace = make_trace(name, seed=seed, **kw)
        for algo in algos:
            blk = replay(trace, algo=algo, plane="jnp",
                         sync_mode="block").summary()
            ovl_r = replay(trace, algo=algo, plane="jnp",
                           sync_mode="overlap", followers=followers)
            ovl = ovl_r.summary()

            flip = blk["epoch_flip_us_mean"]
            disp = ovl.get("sync_dispatch_us_mean", flip)
            hidden = 1.0 - disp / flip if flip > 0 else 0.0
            cell = {
                "trace": name, "w": kw["w"], "storms": kw["storms"],
                "burst": kw["burst"], "n_keys": kw["n_keys"],
                "flip_us_mean_block": flip,
                "dispatch_us_mean_overlap": disp,
                "overlap_hidden_frac": hidden,
                "lookup_us_per_key_block": blk.get("lookup_us_per_key", 0.0),
                "lookup_us_per_key_overlap": ovl.get("lookup_us_per_key",
                                                     0.0),
                "lookup_keys_total": ovl.get("lookup_keys_total", 0),
                "delta_words_total": ovl["delta_words_total"],
                "followers": ovl.get("followers", 0),
                "follower_lag_max": ovl.get("follower_lag_max", 0),
                "follower_lag_mean": ovl.get("follower_lag_mean", 0.0),
                "fingerprints_equal": blk["fingerprint"]
                == ovl["fingerprint"],
                "violations_block": blk["violations"],
                "violations_overlap": ovl["violations"],
                "violation_details": [str(v) for v in ovl_r.violations][:5],
            }
            results[f"{name}_{algo}_w{kw['w']}"] = cell
            for metric in ("flip_us_mean_block", "dispatch_us_mean_overlap",
                           "overlap_hidden_frac",
                           "lookup_us_per_key_overlap", "follower_lag_max",
                           "violations_overlap"):
                emit("async", algo, f"{name}_w{kw['w']}", metric,
                     cell[metric])
            emit("async", algo, f"{name}_w{kw['w']}", "fingerprints_equal",
                 int(cell["fingerprints_equal"]))
    return {"results": results, "followers": followers, "seed": seed,
            "cells": [[n, kw] for n, kw in cells]}


def check_async_claims(summary: dict, min_hidden: float = 0.5) -> bool:
    """CI-HARD: bit-identical replays, silent checkers (incl. follower
    convergence), every storm lookup answered.  The ≥``min_hidden``
    overlap ratio on the 10⁴-node grid is printed but ADVISORY off-TPU —
    wall-clock on shared runners inverts under noise."""
    ok = True

    def claim(name, cond):
        nonlocal ok
        print(f"# claim: {name}: {'OK' if cond else 'FAIL'}")
        ok &= bool(cond)

    for key, c in summary["results"].items():
        claim(f"{key}: overlap lookups bit-identical to blocking sync",
              c["fingerprints_equal"])
        claim(f"{key}: guarantee + convergence checkers silent",
              c["violations_block"] == 0 and c["violations_overlap"] == 0)
        for d in c["violation_details"]:
            print(f"#   {key}: {d}")
        claim(f"{key}: lookups answered during storms "
              f"({c['lookup_keys_total']} keys)",
              c["lookup_keys_total"] > 0)
        if c["followers"]:
            claim(f"{key}: follower converged (lag drains to 0 per storm)",
                  c["violations_overlap"] == 0)
        tag = ("advisory" if c["w"] >= 10_000 else "small cell, advisory")
        verdict = "OK" if c["overlap_hidden_frac"] >= min_hidden else "MISS"
        print(f"# claim: {key}: overlap hides ≥{min_hidden:.0%} of flip "
              f"latency (measured {c['overlap_hidden_frac']:.1%}, "
              f"dispatch {c['dispatch_us_mean_overlap']:.0f}µs vs flip "
              f"{c['flip_us_mean_block']:.0f}µs) [{tag}]: {verdict}")
    repl = summary.get("replication")
    if repl:
        ok &= check_replication_claims(repl, claim)
    return ok


def check_replication_claims(repl: dict, claim, min_ratio: float = 5.0) -> bool:
    """CI-HARD gates on the replication section: bit-identical follower
    fingerprints across flat/tree topologies and dense/packed layouts,
    zero convergence violations, tree leader fan-out strictly below flat,
    targeted catch-up repairing a partitioned subtree, and ≥``min_ratio``
    fewer wire bytes per storm burst for the batched packed Memento stream
    vs the per-epoch dense baseline (the anchor cells report the ratio but
    only gate convergence — their packed layout cannot dtype-narrow at
    fleet scale, so the win there is batching alone, advisory)."""
    ok = True

    def sub(name, cond):
        nonlocal ok
        claim(name, cond)

    for key, cell in repl["wire"].items():
        meta = cell["_meta"]
        sub(f"wire {key}: every stream config converged",
            meta["all_converged"])
        sub(f"wire {key}: follower fingerprints bit-identical across "
            f"configs (dense/packed × flat/tree)",
            meta["fingerprints_equal"])
        ratio = meta["wire_ratio_vs_per_epoch"]
        if meta["algo"] == "memento":
            sub(f"wire {key}: batched packed stream ≥{min_ratio:.0f}× "
                f"fewer bytes/burst than per-epoch dense "
                f"(measured {ratio:.1f}×)", ratio >= min_ratio)
        else:
            print(f"# claim: wire {key}: bytes/burst ratio {ratio:.1f}× "
                  f"[advisory — batching only, no packed narrowing]")
    topo = repl["topology"]
    for name, t in topo.items():
        sub(f"topology {name}: checkers silent (incl. follower "
            f"convergence)", t["violations"] == 0)
    sub("topology: flat and tree replays bit-identical",
        len({t["fingerprint"] for t in topo.values()}) == 1)
    sub("topology: tree leader pays fewer sends than flat",
        topo["tree_a2"]["leader_sends_total"]
        < topo["flat"]["leader_sends_total"])
    cu = repl["catchup"]
    sub("catchup: partitioned interior subtree repaired by targeted "
        f"pull ({cu['catchup_frames']} frames)",
        cu["catchup_frames"] > 0 and cu["converged"])
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true",
                    help="adds the 10⁵-node storm cell")
    ap.add_argument("--followers", type=int, default=1,
                    help="replication followers per overlap replay")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    args = ap.parse_args(argv)

    cells = CELLS["quick" if args.quick else
                  "full" if args.full else "default"]
    rows = []

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}"
              if isinstance(value, float) else
              f"{table},{algo},{x},{metric},{value}", flush=True)

    print("table,algo,x,metric,value")
    t0 = time.time()
    summary = bench_async(emit, cells=cells, followers=args.followers)
    mode = "quick" if args.quick else "full" if args.full else "default"
    summary["replication"] = bench_replication(emit, mode=mode)
    ok = check_async_claims(summary)
    payload = {
        "bench": "async",
        "followers": summary["followers"],
        "seed": summary["seed"],
        "cells": summary["cells"],
        "results": summary["results"],
        "replication": summary["replication"],
        "claims_pass": bool(ok),
        "elapsed_s": round(time.time() - t0, 2),
    }
    if args.out:
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {args.out}")
    print(f"# total {payload['elapsed_s']}s — async claims: "
          f"{'PASS' if ok else 'MISMATCH'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
