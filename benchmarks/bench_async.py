"""Async-overlap benchmark: non-blocking epoch sync, lookup availability
during churn storms, and follower replication convergence (DESIGN.md §9).

For every cell (churn trace × algorithm) this replays the SAME seeded
storm twice through the real stack (host algorithm → epoch deltas →
:class:`~repro.core.DeviceImageStore` → unified engine):

  * ``sync_mode="block"``   — classic synchronous flip; its
    ``epoch_flip_us_mean`` is the full delta-apply + flip latency the hot
    path used to pay per membership event,
  * ``sync_mode="overlap"`` — :meth:`~repro.core.DeviceImageStore.
    sync_async` dispatch with the flip deferred behind lookup traffic;
    its ``sync_dispatch_us_mean`` is the only part the hot path still
    pays, and a :class:`~repro.launch.replicate.ReplicationGroup`
    follower consumes the leader's delta frames alongside.

The **overlap ratio** — the fraction of the blocking flip latency the
async pipeline hides, ``1 − dispatch/flip`` — is the headline number
(advisory off-TPU: CI runners are noisy).  The CI-HARD gates are the
deterministic ones:

* the block and overlap replays are **bit-identical** (replay
  fingerprint equality — deferring the flip may never change a lookup),
* every guarantee checker stays silent in both modes, including the
  eventual-epoch-convergence checker: the follower reaches the leader's
  epoch with a bit-identical image after every storm,
* every lookup event during the storms is answered (availability:
  the epoch-N front image serves while epoch N+1 is in flight).

``python -m benchmarks.bench_async --out BENCH_async.json`` writes the
artifact CI uploads and ``benchmarks/report.py`` renders into RESULTS.md;
``python -m benchmarks.run --async`` runs the same cells inside the main
driver grid.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.core import ALGORITHMS as ALGOS

#: (trace name, trace kwargs) cells; every run includes the 10⁴-node
#: churn_storm_xl grid the acceptance bar names — quick shrinks the
#: bursts, full adds the 10⁵-node fleet.
CELLS = {
    "quick": [
        ("churn_storm", dict(w=96, storms=2, burst=12, n_keys=512)),
        ("churn_storm_xl", dict(w=10_000, storms=2, burst=200,
                                n_keys=1024)),
    ],
    "default": [
        ("churn_storm", dict(w=256, storms=3, burst=32, n_keys=2048)),
        ("churn_storm_xl", dict(w=10_000, storms=3, burst=500,
                                n_keys=4096)),
    ],
    "full": [
        ("churn_storm", dict(w=256, storms=4, burst=32, n_keys=2048)),
        ("churn_storm_xl", dict(w=10_000, storms=3, burst=1_000,
                                n_keys=4096)),
        ("churn_storm_xl", dict(w=100_000, storms=3, burst=2_000,
                                n_keys=4096)),
    ],
}


def bench_async(emit, *, cells=None, followers=1, seed=0, algos=ALGOS):
    """Emit (table, algo, x, metric, value) rows; return the JSON summary."""
    from repro.sim import make_trace, replay

    cells = cells if cells is not None else CELLS["default"]
    results: dict[str, dict] = {}

    for name, kw in cells:
        trace = make_trace(name, seed=seed, **kw)
        for algo in algos:
            blk = replay(trace, algo=algo, plane="jnp",
                         sync_mode="block").summary()
            ovl_r = replay(trace, algo=algo, plane="jnp",
                           sync_mode="overlap", followers=followers)
            ovl = ovl_r.summary()

            flip = blk["epoch_flip_us_mean"]
            disp = ovl.get("sync_dispatch_us_mean", flip)
            hidden = 1.0 - disp / flip if flip > 0 else 0.0
            cell = {
                "trace": name, "w": kw["w"], "storms": kw["storms"],
                "burst": kw["burst"], "n_keys": kw["n_keys"],
                "flip_us_mean_block": flip,
                "dispatch_us_mean_overlap": disp,
                "overlap_hidden_frac": hidden,
                "lookup_us_per_key_block": blk.get("lookup_us_per_key", 0.0),
                "lookup_us_per_key_overlap": ovl.get("lookup_us_per_key",
                                                     0.0),
                "lookup_keys_total": ovl.get("lookup_keys_total", 0),
                "delta_words_total": ovl["delta_words_total"],
                "followers": ovl.get("followers", 0),
                "follower_lag_max": ovl.get("follower_lag_max", 0),
                "follower_lag_mean": ovl.get("follower_lag_mean", 0.0),
                "fingerprints_equal": blk["fingerprint"]
                == ovl["fingerprint"],
                "violations_block": blk["violations"],
                "violations_overlap": ovl["violations"],
                "violation_details": [str(v) for v in ovl_r.violations][:5],
            }
            results[f"{name}_{algo}_w{kw['w']}"] = cell
            for metric in ("flip_us_mean_block", "dispatch_us_mean_overlap",
                           "overlap_hidden_frac",
                           "lookup_us_per_key_overlap", "follower_lag_max",
                           "violations_overlap"):
                emit("async", algo, f"{name}_w{kw['w']}", metric,
                     cell[metric])
            emit("async", algo, f"{name}_w{kw['w']}", "fingerprints_equal",
                 int(cell["fingerprints_equal"]))
    return {"results": results, "followers": followers, "seed": seed,
            "cells": [[n, kw] for n, kw in cells]}


def check_async_claims(summary: dict, min_hidden: float = 0.5) -> bool:
    """CI-HARD: bit-identical replays, silent checkers (incl. follower
    convergence), every storm lookup answered.  The ≥``min_hidden``
    overlap ratio on the 10⁴-node grid is printed but ADVISORY off-TPU —
    wall-clock on shared runners inverts under noise."""
    ok = True

    def claim(name, cond):
        nonlocal ok
        print(f"# claim: {name}: {'OK' if cond else 'FAIL'}")
        ok &= bool(cond)

    for key, c in summary["results"].items():
        claim(f"{key}: overlap lookups bit-identical to blocking sync",
              c["fingerprints_equal"])
        claim(f"{key}: guarantee + convergence checkers silent",
              c["violations_block"] == 0 and c["violations_overlap"] == 0)
        for d in c["violation_details"]:
            print(f"#   {key}: {d}")
        claim(f"{key}: lookups answered during storms "
              f"({c['lookup_keys_total']} keys)",
              c["lookup_keys_total"] > 0)
        if c["followers"]:
            claim(f"{key}: follower converged (lag drains to 0 per storm)",
                  c["violations_overlap"] == 0)
        tag = ("advisory" if c["w"] >= 10_000 else "small cell, advisory")
        verdict = "OK" if c["overlap_hidden_frac"] >= min_hidden else "MISS"
        print(f"# claim: {key}: overlap hides ≥{min_hidden:.0%} of flip "
              f"latency (measured {c['overlap_hidden_frac']:.1%}, "
              f"dispatch {c['dispatch_us_mean_overlap']:.0f}µs vs flip "
              f"{c['flip_us_mean_block']:.0f}µs) [{tag}]: {verdict}")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true",
                    help="adds the 10⁵-node storm cell")
    ap.add_argument("--followers", type=int, default=1,
                    help="replication followers per overlap replay")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    args = ap.parse_args(argv)

    cells = CELLS["quick" if args.quick else
                  "full" if args.full else "default"]
    rows = []

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}"
              if isinstance(value, float) else
              f"{table},{algo},{x},{metric},{value}", flush=True)

    print("table,algo,x,metric,value")
    t0 = time.time()
    summary = bench_async(emit, cells=cells, followers=args.followers)
    ok = check_async_claims(summary)
    payload = {
        "bench": "async",
        "followers": summary["followers"],
        "seed": summary["seed"],
        "cells": summary["cells"],
        "results": summary["results"],
        "claims_pass": bool(ok),
        "elapsed_s": round(time.time() - t0, 2),
    }
    if args.out:
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {args.out}")
    print(f"# total {payload['elapsed_s']}s — async claims: "
          f"{'PASS' if ok else 'MISMATCH'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
