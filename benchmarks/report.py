"""Render benchmark artifacts into markdown.

Two modes:

* default (``--results``, the checked-in story): render **RESULTS.md** at
  the repo root from the five benchmark artifacts —

      benchmarks/results/paper/bench.csv        (paper §VIII reproduction)
      benchmarks/results/BENCH_churn.json       (epoch-delta control plane)
      benchmarks/results/BENCH_replicas.json    (k-replication + bounded load)
      benchmarks/results/BENCH_engine.json      (unified engine + mesh plane)
      benchmarks/results/BENCH_scenarios.json   (scenario-engine lifecycles)
      benchmarks/results/BENCH_async.json       (overlapped epoch pipeline)
      benchmarks/results/BENCH_obs.json         (telemetry-plane gates)

  Tables are keyed to the paper's figure numbers.  Rendering is a pure
  function of the artifacts, so CI can regenerate RESULTS.md and fail on
  drift (``python -m benchmarks.report && git diff --exit-code RESULTS.md``).

* ``--dryrun``: the legacy EXPERIMENTS.md §Dry-run / §Roofline tables from
  ``benchmarks/results/dryrun/*.json`` (printed to stdout).
"""
from __future__ import annotations

import argparse
import csv
import json
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parent / "results"
DRYRUN = RESULTS_DIR / "dryrun"
REPO_ROOT = Path(__file__).resolve().parent.parent

# stdlib-only module (the docs CI job runs it with no numpy/jax installed),
# so the registry cannot be imported here; tests/test_conformance.py asserts
# this literal == repro.core.ALGORITHMS.
ALGOS = ("memento", "anchor", "dx", "jump", "power")  # registry-literal-ok


# ---------------------------------------------------------------------------
# RESULTS.md — paper tables + beyond-paper device-plane stories
# ---------------------------------------------------------------------------

def _load_csv(path: Path) -> list[tuple]:
    rows = []
    with open(path, newline="") as f:
        for r in csv.DictReader(f):
            rows.append((r["table"], r["algo"], r["x"], r["metric"],
                         float(r["value"])))
    return rows


def _pivot(rows, table, metric=None, fmt="{:.2f}"):
    """markdown table: one row per x, one column per algorithm."""
    sel = [r for r in rows if r[0] == table
           and (metric is None or r[3] == metric)]
    if not sel:
        return "_(no data in artifact)_"

    def _x_key(x):
        try:
            return (0, float(x))
        except ValueError:
            return (1, x)

    xs = sorted({r[2] for r in sel}, key=_x_key)
    algos = [a for a in ALGOS if any(r[1] == a for r in sel)]
    algos += sorted({r[1] for r in sel} - set(algos))
    out = ["| x | " + " | ".join(algos) + " |",
           "|---" * (len(algos) + 1) + "|"]
    for x in xs:
        cells = []
        for a in algos:
            v = [r[4] for r in sel if r[1] == a and r[2] == x]
            cells.append(fmt.format(v[0]) if v else "—")
        out.append(f"| {x} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _churn_table(churn: dict) -> str:
    out = ["| state | delta words/event | snapshot words/event | "
           "delta µs/event | snapshot µs/event | speedup | "
           "serve µs/key during churn |",
           "|---|---|---|---|---|---|---|"]
    for key, s in churn["results"].items():
        out.append(
            f"| {key} | {s['delta_words_per_event']:.0f} | "
            f"{s['snapshot_words_per_event']:.0f} | "
            f"{s['delta_us_per_event']:.0f} | "
            f"{s['snapshot_us_per_event']:.0f} | "
            f"{s['speedup']:.1f}× | "
            f"{s['serve_us_per_key_during_churn']:.2f} |")
    return "\n".join(out)


def _replica_lookup_table(rep: dict) -> str:
    out = ["| state | k=1 jnp | k=2 jnp | k=3 jnp | k=1 Pallas† | "
           "k=2 Pallas† | k=3 Pallas† |",
           "|---|---|---|---|---|---|---|"]
    for key, e in rep["results"].items():
        cells = [f"{e[f'k{k}_{p}_us_per_key']:.2f}"
                 for p in ("jnp", "pallas") for k in (1, 2, 3)]
        out.append(f"| {key} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _replica_balance_table(rep: dict) -> str:
    out = ["| state | peak/mean c=1.05 | c=1.25 | unbounded (c=∞) | "
           "cap c=1.05 | assign µs/key c=1.05 |",
           "|---|---|---|---|---|---|"]
    for key, e in rep["results"].items():
        out.append(
            f"| {key} | {e['c1.05_peak_to_mean']:.3f} | "
            f"{e['c1.25_peak_to_mean']:.3f} | "
            f"{e['cinf_peak_to_mean']:.3f} | "
            f"{e['c1.05_cap']} | {e['c1.05_assign_us_per_key']:.2f} |")
    return "\n".join(out)


def _engine_throughput_table(eng: dict) -> str:
    devices = eng["mesh"]["devices"]
    key_counts = eng["key_counts"]
    head = ["state"]
    for n in key_counts:
        head += [f"single µs/key @{n:,}", f"mesh({devices}) µs/key @{n:,}",
                 f"speedup @{n:,}"]
    out = ["| " + " | ".join(head) + " |", "|---" * len(head) + "|"]
    for key, e in eng["results"].items():
        cells = []
        for n in key_counts:
            if f"single_us_per_key_{n}" not in e:
                cells += ["—", "—", "—"]
                continue
            cells += [f"{e[f'single_us_per_key_{n}']:.3f}",
                      f"{e[f'mesh_us_per_key_{n}']:.3f}",
                      f"{e[f'mesh_speedup_{n}']:.2f}×"]
        out.append(f"| {key} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _engine_fusion_table(eng: dict) -> str:
    kk = max((k for k in eng.get("k_values", [1]) if k > 1), default=None)
    head = ["state", "diff fused", "diff 2-launch"]
    if kk:
        head += [f"replica{kk} diff fused", f"replica{kk} diff 2-launch",
                 f"bounded replica{kk}", f"plain replica{kk}"]
    out = ["All columns µs/key.\n",
           "| " + " | ".join(head) + " |",
           "|---" * len(head) + "|"]
    for key, e in eng["results"].items():
        cells = [f"{e['diff_fused_us_per_key']:.3f}",
                 f"{e['diff_two_launch_us_per_key']:.3f}"]
        if kk:
            cells += [
                f"{e.get(f'replica{kk}_diff_fused_us_per_key', float('nan')):.3f}",
                f"{e.get(f'replica{kk}_diff_two_launch_us_per_key', float('nan')):.3f}",
                f"{e.get(f'bounded_replica{kk}_us_per_key', float('nan')):.3f}",
                f"{e.get(f'plain_replica{kk}_us_per_key', float('nan')):.3f}"]
        out.append(f"| {key} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _engine_accounting_table(eng: dict) -> str:
    head = ["state", "lookup bytes/key", "lookup roofline util",
            "diff bytes/key", "diff roofline util"]
    out = ["| " + " | ".join(head) + " |", "|---" * len(head) + "|"]
    for key, e in eng["results"].items():
        la, da = e.get("lookup_accounting"), e.get("diff_accounting")
        if not (la or da):
            continue
        cells = []
        for a in (la, da):
            if a:
                cells += [f"{a['bytes_per_key']:.0f}",
                          f"{a['roofline_utilization']:.1%}"]
            else:
                cells += ["—", "—"]
        out.append(f"| {key} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _engine_compact_table(eng: dict) -> str:
    comp = eng.get("compact", {})
    head = ["algo", "n", "dense bytes", "packed bytes", "reduction",
            "planes equal", "dense µs/key", "packed µs/key"]
    out = ["| " + " | ".join(head) + " |", "|---" * len(head) + "|"]
    for algo, c in comp.items():
        dense = (c["dense_bytes"] if algo == "memento"
                 else c["int32_equivalent_bytes"])
        out.append(
            f"| {algo} | {c['n']:,} | {dense:,} | {c['packed_bytes']:,} | "
            f"{c['reduction_ratio']:.1f}× | "
            f"{'yes' if c['planes_equal'] else 'NO'} | "
            f"{c['dense_us_per_key']:.3f} | {c['packed_us_per_key']:.3f} |")
    return "\n".join(out)


def _scenario_table(scen: dict, key: str, fmt="{:.0f}") -> str:
    """rows = scenarios, columns = algorithms, cells = results[key]."""
    res = scen["results"]
    names = sorted({k.rsplit("_", 1)[0] for k in res},
                   key=lambda n: list(res).index(f"{n}_{ALGOS[0]}")
                   if f"{n}_{ALGOS[0]}" in res else 99)
    out = ["| scenario | " + " | ".join(ALGOS) + " |",
           "|---" * (len(ALGOS) + 1) + "|"]
    for name in names:
        cells = []
        for a in ALGOS:
            v = res.get(f"{name}_{a}", {}).get(key)
            cells.append(fmt.format(v) if v is not None else "—")
        out.append(f"| {name} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _degradation_table(scen: dict) -> str:
    prof = scen["degradation_profile"]
    fracs = [f for f, _ in prof[ALGOS[0]]]
    out = ["| fraction removed | " + " | ".join(ALGOS) + " |",
           "|---" * (len(ALGOS) + 1) + "|"]
    for i, f in enumerate(fracs):
        cells = [f"{prof[a][i][1]:.2f}" if i < len(prof[a]) else "—"
                 for a in ALGOS]
        out.append(f"| {f:.2f} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _async_table(asy: dict) -> str:
    head = ["cell", "flip µs (block)", "dispatch µs (overlap)", "hidden",
            "lookup µs/key (overlap)", "follower lag max", "bit-identical"]
    out = ["| " + " | ".join(head) + " |", "|---" * len(head) + "|"]
    for key, c in asy["results"].items():
        out.append(
            f"| {key} | {c['flip_us_mean_block']:.0f} | "
            f"{c['dispatch_us_mean_overlap']:.0f} | "
            f"{c['overlap_hidden_frac']:.1%} | "
            f"{c['lookup_us_per_key_overlap']:.2f} | "
            f"{c['follower_lag_max']} | "
            f"{'yes' if c['fingerprints_equal'] else 'NO'} |")
    return "\n".join(out)


def _wire_table(repl: dict) -> str:
    configs = ["per_epoch_dense", "batched_dense", "batched_packed",
               "batched_packed_tree"]
    head = ["cell"] + [c.replace("_", " ") for c in configs] + [
        "ratio", "bit-identical"]
    out = ["| " + " | ".join(head) + " |", "|---" * len(head) + "|"]
    for cell, res in repl["wire"].items():
        meta = res["_meta"]
        cells = [f"{res[c]['bytes_per_burst']:,.0f}" if c in res else "—"
                 for c in configs]
        out.append(
            f"| {cell} | " + " | ".join(cells)
            + f" | {meta['wire_ratio_vs_per_epoch']:.1f}× | "
            + f"{'yes' if meta['fingerprints_equal'] else 'NO'} |")
    return "\n".join(out)


def _topology_table(repl: dict) -> str:
    head = ["topology", "fan-out depth", "leader sends", "total wire bytes",
            "lag max", "violations"]
    out = ["| " + " | ".join(head) + " |", "|---" * len(head) + "|"]
    for name, res in repl["topology"].items():
        out.append(
            f"| {name} | {res['fanout_depth']} | "
            f"{res['leader_sends_total']} | {res['wire_bytes_total']:,} | "
            f"{res['follower_lag_max']} | {res['violations']} |")
    return "\n".join(out)


def _telemetry_counter_table(counters: dict) -> str:
    """Registry counters grouped by subsystem prefix, one table."""
    groups: dict[str, list[tuple[str, int]]] = {}
    for name, v in sorted(counters.items()):
        groups.setdefault(name.split(".", 1)[0], []).append((name, v))
    out = ["| subsystem | counter | value |", "|---|---|---|"]
    for prefix in ("engine", "store", "router", "plane", "repl", "sim"):
        for name, v in groups.get(prefix, []):
            out.append(f"| {prefix} | `{name}` | {v:,} |")
    return "\n".join(out)


def _telemetry_latency_table(hists: dict) -> str:
    """Populated latency histograms: count + log-bucket quantiles (µs)."""
    out = ["| histogram | count | p50 | p95 | p99 | max |",
           "|---|---|---|---|---|---|"]
    for name, h in sorted(hists.items()):
        if not h["count"] or ".us" not in name:
            continue
        out.append(f"| `{name}` | {h['count']} | {h['p50']:.1f} | "
                   f"{h['p95']:.1f} | {h['p99']:.1f} | {h['max']:.1f} |")
    return "\n".join(out)


def _obs_overhead_table(obs: dict) -> str:
    lk, ins = obs["lookup"], obs["primitives"]
    out = ["| measurement | value |", "|---|---|"]
    out.append(f"| engine lookup, telemetry off (µs/key) | "
               f"{lk['us_per_key_off']:.3f} |")
    out.append(f"| engine lookup, telemetry on (µs/key) | "
               f"{lk['us_per_key_on']:.3f} |")
    out.append(f"| overhead (advisory, budget < 5 %) | "
               f"{lk['overhead_pct']:+.1f} % |")
    out.append(f"| live `counter.inc` / `histogram.observe` (ns/op) | "
               f"{ins['counter_inc_ns_live']:.0f} / "
               f"{ins['hist_observe_ns_live']:.0f} |")
    out.append(f"| null `counter.inc` / `histogram.observe` (ns/op) | "
               f"{ins['counter_inc_ns_null']:.0f} / "
               f"{ins['hist_observe_ns_null']:.0f} |")
    return "\n".join(out)


def render_results() -> str:
    rows = _load_csv(RESULTS_DIR / "paper" / "bench.csv")
    churn = json.loads((RESULTS_DIR / "BENCH_churn.json").read_text())
    rep = json.loads((RESULTS_DIR / "BENCH_replicas.json").read_text())
    eng = json.loads((RESULTS_DIR / "BENCH_engine.json").read_text())
    scen = json.loads((RESULTS_DIR / "BENCH_scenarios.json").read_text())
    asy = json.loads((RESULTS_DIR / "BENCH_async.json").read_text())
    obs_path = RESULTS_DIR / "BENCH_obs.json"
    obs = json.loads(obs_path.read_text()) if obs_path.exists() else None

    s = []
    s.append("# RESULTS — measured reproduction tables\n")
    s.append(
        "**Generated file — do not edit.**  Regenerate with\n"
        "`PYTHONPATH=src python -m benchmarks.report` from the checked-in\n"
        "artifacts `benchmarks/results/paper/bench.csv`,\n"
        "`benchmarks/results/BENCH_churn.json`,\n"
        "`benchmarks/results/BENCH_replicas.json`,\n"
        "`benchmarks/results/BENCH_engine.json`, and\n"
        "`benchmarks/results/BENCH_scenarios.json` (CI fails on drift).\n"
        "Numbers are CPU-budget runs (small sizes, Pallas in interpret\n"
        "mode) — orderings and invariants are the signal, absolute\n"
        "timings are not TPU performance.  See [README.md](README.md) for\n"
        "the claims and [DESIGN.md](DESIGN.md) for the architecture.\n")

    s.append("## Paper §VIII scenarios (host plane, `variant=\"64\"`)\n")
    s.append("### Stable clusters — lookup µs/key (paper Figs. 17/18)\n")
    s.append(_pivot(rows, "stable_lookup", "us_per_lookup") + "\n")
    s.append("### Stable clusters — memory bytes (paper Figs. 17/18)\n")
    s.append(_pivot(rows, "stable_memory", "bytes", fmt="{:.0f}") + "\n")
    s.append("### One-shot removals, best case (LIFO) — memory bytes "
             "(paper Figs. 19/21)\n")
    s.append(_pivot(rows, "oneshot_best_memory", "bytes", fmt="{:.0f}") + "\n")
    s.append("### One-shot removals, worst case (random) — memory bytes "
             "(paper Figs. 20/22)\n")
    s.append(_pivot(rows, "oneshot_worst_memory", "bytes", fmt="{:.0f}") + "\n")
    s.append("### Incremental removals, worst case — lookup µs/key by "
             "removed fraction (paper Figs. 23–26)\n")
    s.append(_pivot(rows, "incremental_worst_lookup", "us_per_lookup") + "\n")
    s.append("### Sensitivity to a/w over-provisioning — stable lookup "
             "µs/key by ratio (paper Figs. 27–32)\n")
    s.append(_pivot(rows, "sensitivity_stable_lookup", "us_per_lookup") + "\n")
    s.append("### Placement quality (paper §II metrics)\n")
    s.append("Normalized coefficient of variation of bucket loads "
             "(≈ 1 is multinomial-noise-level balance):\n")
    s.append(_pivot(rows, "quality_balance", "cv_normalized") + "\n")
    s.append("Minimal-disruption / monotonicity violations (must be 0):\n")
    s.append(_pivot(rows, "quality_min_disruption", "bad_moves",
                    fmt="{:.0f}") + "\n")

    s.append("## Beyond paper: epoch-delta control plane "
             "(DESIGN.md §3.5, `BENCH_churn.json`)\n")
    s.append("Per membership event: O(changed-words) delta apply vs full "
             "snapshot rebuild, while bulk lookups keep serving the old "
             "epoch.\n")
    s.append(_churn_table(churn) + "\n")
    claims = "PASS" if churn.get("claims_pass") else "MISMATCH"
    s.append(f"Churn claims at capture time: **{claims}** "
             f"(plane={churn.get('plane')}, sizes={churn.get('sizes')}).\n")

    s.append("## Beyond paper: k-replication + bounded load "
             "(DESIGN.md §4, `BENCH_replicas.json`)\n")
    s.append("### k-replica lookup µs/key (salted `lookup_k`, device "
             "planes)\n")
    s.append("† Pallas columns run in interpret mode on CPU — a "
             "correctness path, not kernel performance.\n")
    s.append(_replica_lookup_table(rep) + "\n")
    s.append("### Bounded-load balance (cap = ⌈c·keys/working⌉)\n")
    s.append(_replica_balance_table(rep) + "\n")
    claims = "PASS" if rep.get("claims_pass") else "MISMATCH"
    s.append(f"Replica claims at capture time: **{claims}** "
             f"(w={rep.get('w')}, n_keys={rep.get('n_keys')}).\n")

    s.append("## Beyond paper: the unified engine + mesh-sharded plane "
             "(DESIGN.md §6, `BENCH_engine.json`)\n")
    s.append("### Single-device vs mesh throughput "
             "(`ShardedLookupPlane`, jnp plane)\n")
    s.append("Simulated host devices on CPU — speedups are advisory; the "
             "sharded == single-device equality gates are the hard part.\n")
    s.append(_engine_throughput_table(eng) + "\n")
    s.append("### Fused ops vs their multi-launch decompositions "
             "(bit-identical, one program each)\n")
    s.append(_engine_fusion_table(eng) + "\n")
    hw = eng.get("hardware", {})
    if any("lookup_accounting" in e for e in eng["results"].values()):
        s.append("### Bytes/key + roofline utilization per op "
                 "(DESIGN.md §8, HLO cost model)\n")
        s.append(f"Rooflines computed against the `{hw.get('name', '?')}` "
                 "hardware spec (`launch/roofline.HARDWARE`; utilization = "
                 "memory-bound floor time / measured time).\n")
        s.append(_engine_accounting_table(eng) + "\n")
    if eng.get("compact"):
        s.append("### Compact (packed) device images at 10⁶ buckets "
                 "(DESIGN.md §8.2)\n")
        s.append("Memento compares against its dense int32 image; Dx "
                 "against the int32-per-bucket image its bitmap already "
                 "avoids.  Lookups are bit-identical on host, jnp, and "
                 "Pallas planes (gated).\n")
        s.append(_engine_compact_table(eng) + "\n")
    claims = "PASS" if eng.get("claims_pass") else "MISMATCH"
    s.append(f"Engine claims at capture time: **{claims}** "
             f"(w={eng.get('w')}, devices={eng['mesh']['devices']}).\n")

    s.append("## Beyond paper: the scenario engine "
             "(DESIGN.md §7, `BENCH_scenarios.json`)\n")
    s.append("The paper's §VIII lifecycles (stable / one-shot 90 % / "
             "incremental) and six beyond-paper churn traces, replayed "
             "through the production stack (epoch deltas → image store → "
             "unified engine → router) with the guarantee checkers — "
             "minimal disruption, balance, replica stability, bounded "
             "caps — asserted per event.\n")
    s.append("### Probe keys moved per scenario "
             "(minimal movement, paper §II)\n")
    s.append(_scenario_table(scen, "moved_probe_total") + "\n")
    s.append("### Control-plane delta words per scenario "
             "(DESIGN.md §3.5)\n")
    s.append(_scenario_table(scen, "delta_words_total") + "\n")
    s.append("### Guarantee-checker violations (must be 0)\n")
    s.append(_scenario_table(scen, "violations") + "\n")
    s.append("### Degradation profile — mean host lookup steps by "
             "fraction removed (paper Figs. 23–26)\n")
    s.append(_degradation_table(scen) + "\n")
    knees = ", ".join(f"{a}={scen['knee'][a]:.2f}" if scen["knee"].get(a)
                      else f"{a}=—" for a in ALGOS)
    s.append(f"Degradation knees (fraction removed at the elbow): {knees} — "
             "Memento stays in the cheap half of its degradation until "
             "~70 % of the fleet is gone, the paper's graceful-degradation "
             "claim.\n")
    claims = "PASS" if scen.get("claims_pass") else "MISMATCH"
    s.append(f"Scenario claims at capture time: **{claims}** "
             f"(w={scen.get('w')}, probe={scen.get('probe_keys')}, "
             f"cross-plane cells: {', '.join(scen.get('cross_plane', []))}).\n")

    s.append("## Beyond paper: overlapped epoch pipeline "
             "(DESIGN.md §9, `BENCH_async.json`)\n")
    s.append("Each churn-storm cell replays twice — blocking sync vs "
             "`sync_async` with the flip deferred behind lookup traffic — "
             "with a replication follower consuming the leader's delta "
             "frames.  \"Hidden\" is the fraction of the blocking flip "
             "latency the hot path no longer pays (advisory on CPU); the "
             "hard gates are bit-identical replays, silent checkers, and "
             "follower epoch convergence per storm.\n")
    s.append(_async_table(asy) + "\n")
    repl = asy.get("replication")
    if repl:
        s.append("### Storm-scale replication — wire bytes per storm burst "
                 "(DESIGN.md §9.5–§9.7)\n")
        s.append("Each cell replays the same churn-storm stream through "
                 "four publisher configs: per-epoch dense frames (the "
                 "baseline), cross-epoch `DELTA_BATCH` composition, packed "
                 "`SNAPSHOT_PACKED` announce + packed deltas (§8.2 bitmap + "
                 "slot tables on the wire, Θ(n/8+r) vs Θ(4n)), and the same "
                 "packed stream over an arity-2 relay tree.  Bytes/burst "
                 "counts every link including the announce snapshot; the "
                 "ratio column (packed batched vs per-epoch dense) gates "
                 "hard at ≥5× for Memento cells.  Anchor cannot narrow its "
                 "fleet-scale dtypes, so its ratio is batching-only "
                 "(advisory).  All configs must converge to bit-identical "
                 "follower fingerprints.\n")
        s.append(_wire_table(repl) + "\n")
        s.append("### Tree fan-out vs flat broadcast (7 followers, same "
                 "storm)\n")
        s.append("Interior followers relay verbatim frames, so total wire "
                 "bytes match flat while the leader pays O(arity) sends "
                 "instead of O(F); flat and tree replays are bit-identical "
                 "(gated).\n")
        s.append(_topology_table(repl) + "\n")
        cu = repl["catchup"]
        s.append("Targeted catch-up: a partitioned interior subtree "
                 f"re-converged via {cu['catchup_frames']} pulled frame(s) "
                 f"({cu['catchup_bytes']:,} bytes) at the follower's own "
                 "base epoch — no full re-announce "
                 f"(converged={'yes' if cu['converged'] else 'NO'}).\n")
    claims = "PASS" if asy.get("claims_pass") else "MISMATCH"
    s.append(f"Async claims at capture time: **{claims}** "
             f"(followers={asy.get('followers')}, "
             f"cells={len(asy.get('results', {}))}).\n")

    telem = scen["results"].get("churn_storm_memento", {}).get("telemetry")
    if obs or telem:
        s.append("## Beyond paper: runtime telemetry plane "
                 "(DESIGN.md §11, `BENCH_obs.json`)\n")
    if obs:
        s.append("Cost of observing: the `repro.obs` registry instruments "
                 "every serving layer.  Hard gates (all must PASS): "
                 "telemetry never changes a lookup (bit-identical "
                 "off/on/off), replay counter snapshots are deterministic, "
                 "replay fingerprints match telemetry on vs off, and the "
                 "Prometheus/JSONL exports round-trip.  The overhead row "
                 "is advisory on shared runners.\n")
        s.append(_obs_overhead_table(obs) + "\n")
        claims = "PASS" if obs.get("claims_pass") else "MISMATCH"
        s.append(f"Telemetry claims at capture time: **{claims}** "
                 f"(lookup batch={obs['lookup']['n_keys']:,} keys, replay "
                 f"events={obs['replay']['events']}, "
                 f"sink events={obs['replay']['sink_events']}).\n")
    if telem:
        s.append("### Telemetry snapshot — `churn_storm` × memento, "
                 "captured live during the scenario replay\n")
        s.append("The registry snapshot `ScenarioDriver(telemetry=True)` "
                 "embedded into `BENCH_scenarios.json`: every subsystem the "
                 "storm touched, as the exposition endpoint would serve "
                 "it.  Counters are bit-deterministic across replays of "
                 "the resolved trace; histogram quantiles are log-bucketed "
                 "wall-clock (advisory).\n")
        s.append(_telemetry_counter_table(telem["counters"]) + "\n")
        s.append("Latency distributions (µs):\n")
        s.append(_telemetry_latency_table(telem["histograms"]) + "\n")
    return "\n".join(s)


# ---------------------------------------------------------------------------
# Legacy dry-run / roofline tables
# ---------------------------------------------------------------------------

def load(variant="base"):
    recs = []
    for p in sorted(DRYRUN.glob(f"*__{variant}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | peak GiB/dev | params+args GiB/dev | compile s | collectives (weighted ops) | dominant collective |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        colls = r["collectives"]
        if colls["by_kind"]:
            dom = max(colls["by_kind"].items(), key=lambda kv: kv[1]["ring_bytes"])
            dom_s = f"{dom[0]} ({dom[1]['ring_bytes']/2**30:.1f} GiB ring)"
        else:
            dom_s = "—"
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_bytes(r['memory_analysis'].get('peak_memory_in_bytes', 0))} | "
            f"{fmt_bytes(r['memory_analysis'].get('argument_size_in_bytes', 0))} | "
            f"{r['compile_seconds']:.0f} | {colls['count']:.0f} | {dom_s} |")
    return "\n".join(rows)


NOTES = {
    ("compute",): "raise arithmetic intensity (fuse attention, larger microbatch)",
    ("memory",): "cut activation traffic: fused/flash attention, bf16 score staging, fewer f32 intermediates",
    ("collective",): "re-shard to remove the dominant collective (EP local dispatch, reduce-scatter grads, overlap)",
}


def roofline_table(recs, mesh):
    rows = ["| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | useful/HLO flops | roofline frac | what would move the dominant term |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        note = NOTES[(rl["bottleneck"],)]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['t_compute']:.2e} | "
            f"{rl['t_memory']:.2e} | {rl['t_collective_ring']:.2e} | "
            f"**{rl['bottleneck']}** | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | {note} |")
    return "\n".join(rows)


def pick_hillclimb(recs):
    singles = [r for r in recs if r["mesh"] == "single"]
    worst = min(singles, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(singles, key=lambda r: r["roofline"]["t_collective_ring"])
    return worst, coll


def dryrun_main(variant):
    recs = load(variant)
    print(f"## Dry-run ({len(recs)} cells, variant={variant})\n")
    for mesh, title in (("single", "single-pod (16×16 = 256 chips)"),
                        ("multi", "multi-pod (2×16×16 = 512 chips)")):
        print(f"### {title}\n")
        print(dryrun_table(recs, mesh))
        print()
    print("## Roofline (single-pod)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(recs, "multi"))
    worst, coll = pick_hillclimb(recs)
    print(f"\nworst roofline fraction: {worst['arch']} × {worst['shape']} "
          f"({worst['roofline']['roofline_fraction']:.4f})")
    print(f"most collective-bound:   {coll['arch']} × {coll['shape']} "
          f"(t_coll {coll['roofline']['t_collective_ring']:.1f}s)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dryrun", action="store_true",
                    help="legacy dry-run/roofline tables (stdout)")
    ap.add_argument("--variant", default="base", help="dry-run variant")
    ap.add_argument("--out", default=str(REPO_ROOT / "RESULTS.md"),
                    help="RESULTS.md output path")
    args = ap.parse_args(argv)
    if args.dryrun:
        dryrun_main(args.variant)
        return
    text = render_results()
    Path(args.out).write_text(text)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
