"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.report [--variant base]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parent / "results" / "dryrun"


def load(variant="base"):
    recs = []
    for p in sorted(DRYRUN.glob(f"*__{variant}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | peak GiB/dev | params+args GiB/dev | compile s | collectives (weighted ops) | dominant collective |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        colls = r["collectives"]
        if colls["by_kind"]:
            dom = max(colls["by_kind"].items(), key=lambda kv: kv[1]["ring_bytes"])
            dom_s = f"{dom[0]} ({dom[1]['ring_bytes']/2**30:.1f} GiB ring)"
        else:
            dom_s = "—"
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_bytes(r['memory_analysis'].get('peak_memory_in_bytes', 0))} | "
            f"{fmt_bytes(r['memory_analysis'].get('argument_size_in_bytes', 0))} | "
            f"{r['compile_seconds']:.0f} | {colls['count']:.0f} | {dom_s} |")
    return "\n".join(rows)


NOTES = {
    ("compute",): "raise arithmetic intensity (fuse attention, larger microbatch)",
    ("memory",): "cut activation traffic: fused/flash attention, bf16 score staging, fewer f32 intermediates",
    ("collective",): "re-shard to remove the dominant collective (EP local dispatch, reduce-scatter grads, overlap)",
}


def roofline_table(recs, mesh):
    rows = ["| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | useful/HLO flops | roofline frac | what would move the dominant term |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        note = NOTES[(rl["bottleneck"],)]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['t_compute']:.2e} | "
            f"{rl['t_memory']:.2e} | {rl['t_collective_ring']:.2e} | "
            f"**{rl['bottleneck']}** | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | {note} |")
    return "\n".join(rows)


def pick_hillclimb(recs):
    singles = [r for r in recs if r["mesh"] == "single"]
    worst = min(singles, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(singles, key=lambda r: r["roofline"]["t_collective_ring"])
    return worst, coll


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="base")
    args = ap.parse_args(argv)
    recs = load(args.variant)
    print(f"## Dry-run ({len(recs)} cells, variant={args.variant})\n")
    for mesh, title in (("single", "single-pod (16×16 = 256 chips)"),
                        ("multi", "multi-pod (2×16×16 = 512 chips)")):
        print(f"### {title}\n")
        print(dryrun_table(recs, mesh))
        print()
    print("## Roofline (single-pod)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(recs, "multi"))
    worst, coll = pick_hillclimb(recs)
    print(f"\nworst roofline fraction: {worst['arch']} × {worst['shape']} "
          f"({worst['roofline']['roofline_fraction']:.4f})")
    print(f"most collective-bound:   {coll['arch']} × {coll['shape']} "
          f"(t_coll {coll['roofline']['t_collective_ring']:.1f}s)")


if __name__ == "__main__":
    main()
