"""Churn-latency benchmark: per-event control-plane cost under membership
churn, for every registry algorithm (DESIGN.md §3.5).

This is the scenario the paper's O(1) update story (Algs. 2/3) implies but
§VIII never times on hardware: a serving cluster rides out a stream of
remove/add events while the device data plane keeps answering bulk
lookups.  Per event we measure BOTH ways of mirroring the change to the
device:

  * ``snapshot`` — rebuild the full :class:`DeviceImage` on host and
    re-transfer it (the pre-epoch-store behaviour: O(n) per event),
  * ``delta``    — drain ``device_delta()`` and scatter O(changed-words)
    into the double-buffered :class:`DeviceImageStore` (epoch flip).

plus the data-plane side of availability: µs/key of bulk lookups served
from the epoch-N front image *between* the event and the sync (stale but
consistent serving — the old behaviour was a null image and a blocking
rebuild), and the fused epoch-diff cost (one launch of the unified
engine, DESIGN.md §6) that replaces per-key host loops in the movement
planners.  Both paths run through :class:`~repro.core.DeviceImageStore`,
whose ``lookup``/``migration_diff`` are engine configurations.

Emits the repo's usual ``(table, algo, x, metric, value)`` rows and
returns a JSON-able summary; ``python -m benchmarks.bench_churn --out
BENCH_churn.json`` writes the artifact CI uploads, so the perf trajectory
of the control plane is tracked per commit.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.timing import block_image as _block
from repro.core import ALGORITHM_REGISTRY, ALGORITHMS as ALGOS


def _churn_victim(h, rng):
    if ALGORITHM_REGISTRY[h.name].lifo_only:
        return h.size - 1
    ws = sorted(h.working_set())
    return ws[int(rng.integers(len(ws)))]


def bench_churn(emit, sizes=(1024, 10_000), events=200, n_keys=4096,
                a_over_w=4, plane="jnp", seed=0):
    """Per-event delta-vs-snapshot cost + lookup availability during churn."""
    import jax.numpy as jnp
    from repro.core import DeviceImageStore, make_hash

    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**32, size=n_keys, dtype=np.uint32)
    diff_keys = keys[: max(n_keys // 4, 512)]
    summary: dict[str, dict] = {}

    for w in sizes:
        for algo in ALGOS:
            h = make_hash(algo, w, capacity=a_over_w * w, variant="32")
            # measure in the paper's incremental-removal regime (§VIII):
            # a fleet that has already ridden out failures, not a pristine
            # one — this is where snapshot rebuilds pay Θ(state) per event.
            pre = int(0.3 * w)
            if ALGORITHM_REGISTRY[algo].lifo_only:
                for _ in range(pre):
                    h.remove(h.size - 1)
            else:
                ws = sorted(h.working_set())
                for i in rng.choice(len(ws), size=pre, replace=False):
                    h.remove(ws[int(i)])
            store = DeviceImageStore(h, plane=plane)
            # warm every jitted path (bulk lookup, delta scatter, fused
            # migration diff) outside the timed loop — shapes are stable
            # across events, so these compiles happen exactly once
            store.lookup(keys)
            h.remove(_churn_victim(h, rng))
            store.sync()
            store.lookup(diff_keys)
            store.migration_diff(diff_keys, plane=plane)
            h.add()
            store.sync()

            t_delta, t_snap, t_diff, t_serve = [], [], [], []
            words_delta, words_snap = [], []
            removed = 0
            for ev in range(events):
                # biased random walk: mostly removals, occasional restores
                if h.working > 1 and (rng.random() < 0.7 or removed == 0):
                    h.remove(_churn_victim(h, rng))
                    removed += 1
                else:
                    try:
                        h.add()
                        removed -= 1
                    except ValueError:
                        h.remove(_churn_victim(h, rng))
                        removed += 1

                # (a) availability: bulk lookup served from the epoch-N
                # front image BEFORE the device has seen the event.
                t0 = time.perf_counter()
                out = store.lookup(diff_keys)
                t_serve.append((time.perf_counter() - t0) / len(diff_keys) * 1e6)
                assert out.min() >= 0

                # (b) the old control plane: full snapshot rebuild+transfer.
                t0 = time.perf_counter()
                img = h.device_image()
                dev = {k: jnp.asarray(v) for k, v in img.arrays.items()}
                for arr in dev.values():
                    arr.block_until_ready()
                t_snap.append((time.perf_counter() - t0) * 1e6)
                words_snap.append(sum(int(v.size) for v in img.arrays.values()) + 1)

                # (c) the epoch store: O(changed-words) delta apply + flip.
                t0 = time.perf_counter()
                st = store.sync()
                _block(store.image())
                t_delta.append((time.perf_counter() - t0) * 1e6)
                words_delta.append(st.words)

                # (d) fused migration diff between the two buffered epochs.
                t0 = time.perf_counter()
                d = store.migration_diff(diff_keys, plane=plane)
                t_diff.append((time.perf_counter() - t0) * 1e6)
                assert d.num_moved <= len(diff_keys)

            stats = {
                "delta_us_per_event": float(np.mean(t_delta)),
                "snapshot_us_per_event": float(np.mean(t_snap)),
                "speedup": float(np.mean(t_snap) / np.mean(t_delta)),
                "delta_words_per_event": float(np.mean(words_delta)),
                "snapshot_words_per_event": float(np.mean(words_snap)),
                "serve_us_per_key_during_churn": float(np.mean(t_serve)),
                "migration_diff_us_per_event": float(np.mean(t_diff)),
                "snapshot_rebuilds": store.totals.snapshot_rebuilds,
                "delta_applies": store.totals.delta_applies,
                "events": events,
            }
            summary[f"{algo}_w{w}"] = stats
            for metric, value in stats.items():
                emit("churn", algo, w, metric, value)
    return summary


def check_churn_claims(summary: dict, min_nodes: int = 10_000) -> bool:
    """Delta apply must beat full-snapshot rebuild per event at ≥ min_nodes.

    The HARD gate is the deterministic one: the delta's host→device payload
    must be a vanishing fraction of the snapshot's (O(changed-words) vs
    O(n)).  The wall-clock speedup is printed and recorded but advisory
    only — mean timings on a shared CI runner invert under noise.  The
    stateless algorithms (Jump, Power) are exempt: their image IS a single
    scalar; there is nothing to beat.
    """
    ok = True
    for key, stats in summary.items():
        algo, w = key.rsplit("_w", 1)
        w = int(w)
        if w < min_nodes or not ALGORITHM_REGISTRY[algo].tables:
            continue
        good = (stats["delta_words_per_event"]
                < stats["snapshot_words_per_event"])
        timing = "delta faster" if stats["speedup"] > 1.0 else "delta SLOWER"
        print(f"# claim: churn @{key}: delta payload ≪ snapshot "
              f"({stats['delta_words_per_event']:.0f} vs "
              f"{stats['snapshot_words_per_event']:.0f} words): "
              f"{'OK' if good else 'FAIL'} "
              f"[timing advisory: {stats['speedup']:.1f}x, {timing}]")
        ok &= good
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale")
    ap.add_argument("--plane", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--out", default=None, help="write JSON summary here")
    args = ap.parse_args(argv)

    if args.quick:
        sizes, events, n_keys = (512, 10_000), 40, 1024
    elif args.full:
        sizes, events, n_keys = (1024, 10_000, 100_000), 300, 16384
    else:
        sizes, events, n_keys = (1024, 10_000), 150, 4096

    rows = []

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}" if isinstance(value, float)
              else f"{table},{algo},{x},{metric},{value}", flush=True)

    print("table,algo,x,metric,value")
    t0 = time.time()
    summary = bench_churn(emit, sizes=sizes, events=events, n_keys=n_keys,
                          plane=args.plane)
    ok = check_churn_claims(summary)
    payload = {
        "bench": "churn",
        "plane": args.plane,
        "sizes": list(sizes),
        "events_per_size": events,
        "results": summary,
        "claims_pass": bool(ok),
        "elapsed_s": round(time.time() - t0, 2),
    }
    if args.out:
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {args.out}")
    print(f"# total {payload['elapsed_s']}s — churn claims: "
          f"{'PASS' if ok else 'MISMATCH'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
